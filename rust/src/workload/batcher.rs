//! Dynamic request batcher.
//!
//! Serving frameworks (Triton, TF-Serving) coalesce individual requests
//! into batches before dispatching to the GPU. The paper's serving
//! experiments fix the batch size; this batcher is the realistic front-end
//! used by the `serve_mig` example and the batching ablation bench: close
//! a batch when it reaches `max_batch` or when the oldest request has
//! waited `max_delay_s`.

/// A single queued request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PendingRequest {
    /// Request id (monotonic).
    pub id: u64,
    /// Arrival timestamp, seconds.
    pub arrived_at: f64,
}

/// A closed batch ready for dispatch.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    /// Requests in the batch, arrival order.
    pub requests: Vec<PendingRequest>,
    /// Time the batch was closed.
    pub closed_at: f64,
}

impl Batch {
    /// Batch size.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True if the batch carries no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Mean queueing delay of the batch's requests at close time.
    pub fn mean_wait_s(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| self.closed_at - r.arrived_at).sum::<f64>()
            / self.requests.len() as f64
    }
}

/// Dynamic batcher with max-size and max-delay closing rules.
#[derive(Debug)]
pub struct DynamicBatcher {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before forced dispatch.
    pub max_delay_s: f64,
    queue: Vec<PendingRequest>,
    next_id: u64,
}

impl DynamicBatcher {
    /// Batcher with the given policy.
    pub fn new(max_batch: usize, max_delay_s: f64) -> Self {
        assert!(max_batch >= 1 && max_delay_s >= 0.0);
        DynamicBatcher { max_batch, max_delay_s, queue: Vec::new(), next_id: 0 }
    }

    /// Number of queued requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue a request at time `t`; returns a closed batch if the size
    /// rule fires.
    pub fn offer(&mut self, t: f64) -> Option<Batch> {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(PendingRequest { id, arrived_at: t });
        if self.queue.len() >= self.max_batch {
            return Some(self.close(t));
        }
        None
    }

    /// The deadline by which the current queue must be dispatched, if any.
    pub fn deadline(&self) -> Option<f64> {
        self.queue.first().map(|r| r.arrived_at + self.max_delay_s)
    }

    /// Check the delay rule at time `t`; returns a batch if the oldest
    /// request has waited out the delay.
    pub fn poll(&mut self, t: f64) -> Option<Batch> {
        match self.deadline() {
            Some(d) if t >= d && !self.queue.is_empty() => Some(self.close(t)),
            _ => None,
        }
    }

    /// Force-close whatever is queued.
    pub fn flush(&mut self, t: f64) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.close(t))
        }
    }

    fn close(&mut self, t: f64) -> Batch {
        Batch { requests: std::mem::take(&mut self.queue), closed_at: t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_rule_fires_at_max_batch() {
        let mut b = DynamicBatcher::new(4, 1.0);
        assert!(b.offer(0.0).is_none());
        assert!(b.offer(0.1).is_none());
        assert!(b.offer(0.2).is_none());
        let batch = b.offer(0.3).expect("4th request closes the batch");
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn delay_rule_fires_on_poll() {
        let mut b = DynamicBatcher::new(8, 0.5);
        b.offer(0.0);
        b.offer(0.1);
        assert!(b.poll(0.4).is_none(), "deadline not reached");
        let batch = b.poll(0.5).expect("deadline reached");
        assert_eq!(batch.len(), 2);
        assert!((batch.mean_wait_s() - 0.45).abs() < 1e-9);
    }

    #[test]
    fn deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(8, 1.0);
        assert_eq!(b.deadline(), None);
        b.offer(2.0);
        b.offer(3.0);
        assert_eq!(b.deadline(), Some(3.0));
    }

    #[test]
    fn flush_closes_partial() {
        let mut b = DynamicBatcher::new(8, 1.0);
        b.offer(0.0);
        let batch = b.flush(0.2).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.flush(0.3).is_none());
    }

    #[test]
    fn ids_are_monotonic_across_batches() {
        let mut b = DynamicBatcher::new(2, 1.0);
        b.offer(0.0);
        let first = b.offer(0.0).unwrap();
        b.offer(1.0);
        let second = b.offer(1.0).unwrap();
        assert_eq!(first.requests[1].id + 1, second.requests[0].id);
    }

    #[test]
    fn batch_of_one_when_max_batch_is_one() {
        let mut b = DynamicBatcher::new(1, 0.0);
        let batch = b.offer(5.0).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.mean_wait_s(), 0.0);
    }
}
