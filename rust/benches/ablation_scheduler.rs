//! Ablation: hybrid-workload partition optimizer vs static layouts.
//!
//! The paper's future-work scenario (§5): orchestrate training + two
//! inference services on one A100. This bench compares the exhaustive
//! optimizer's plan against the three obvious static strategies and
//! reports training goodput with all inference SLOs held constant —
//! quantifying what the "reconfigurable machine scheduling" step buys.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::mig::profile::lookup as gi_lookup;
use migperf::models::zoo;
use migperf::scheduler::{Objective, Scheduler, SloWorkload};
use migperf::simgpu::perfmodel::PerfModel;
use migperf::simgpu::resource::ExecResource;
use migperf::util::table::{fmt_num, Table};
use migperf::workload::spec::WorkloadSpec;

const SLO_MS: f64 = 15.0;

fn static_plan_train_tput(train_profile: &str, infer_profile: &str) -> Option<f64> {
    // Static strategy: fixed profiles; check SLOs manually.
    let pm = PerfModel::default();
    let gpu = GpuModel::A100_80GB;
    let infer = WorkloadSpec::inference(zoo::lookup("resnet50").unwrap(), 4, 224);
    let infer_res = ExecResource::from_gi(gpu, gi_lookup(gpu, infer_profile)?);
    let est = pm.step(&infer_res, &infer.step_cost()).ok()?;
    if est.seconds * 1e3 > SLO_MS {
        return None;
    }
    let train = WorkloadSpec::training(zoo::lookup("bert-base").unwrap(), 32, 128);
    let train_res = ExecResource::from_gi(gpu, gi_lookup(gpu, train_profile)?);
    let t = pm.step(&train_res, &train.step_cost()).ok()?;
    Some(32.0 / t.seconds)
}

fn main() {
    banner("Ablation", "partition optimizer vs static layouts (train + 2×serve on A100)");
    let sched = Scheduler::new(GpuModel::A100_80GB);
    let bert = zoo::lookup("bert-base").unwrap();
    let resnet = zoo::lookup("resnet50").unwrap();
    let workloads = [
        SloWorkload::best_effort(WorkloadSpec::training(bert, 32, 128)),
        SloWorkload::with_slo(WorkloadSpec::inference(resnet, 4, 224), SLO_MS),
        SloWorkload::with_slo(WorkloadSpec::inference(resnet, 4, 224), SLO_MS),
    ];
    let plan = sched.plan(&workloads, Objective::MaxThroughput).expect("feasible plan");
    let train_tput_opt =
        plan.assignments.iter().find(|a| a.workload == 0).map(|a| a.throughput).unwrap();

    let mut t = Table::new(&["strategy", "layout", "train seq/s", "SLOs met"]);
    t.row(&[
        "optimizer (exhaustive)".into(),
        format!("{:?}", plan.layout),
        fmt_num(train_tput_opt),
        "yes".into(),
    ]);
    let statics: &[(&str, &str, &str)] = &[
        ("equal thirds", "2g.20gb", "2g.20gb"),
        ("train-heavy 3g", "3g.40gb", "2g.20gb"),
        ("uniform sevenths", "1g.10gb", "1g.10gb"),
    ];
    let mut static_best: f64 = 0.0;
    for (name, tp, ip) in statics {
        match static_plan_train_tput(tp, ip) {
            Some(tput) => {
                static_best = static_best.max(tput);
                t.row(&[
                    name.to_string(),
                    format!("[{tp}, {ip}, {ip}]"),
                    fmt_num(tput),
                    "yes".into(),
                ]);
            }
            None => {
                t.row(&[name.to_string(), format!("[{tp}, {ip}, {ip}]"), "-".into(), "NO".into()]);
            }
        }
    }
    println!("\n{}", t.render());
    println!(
        "optimizer improves training goodput {:.2}× over the best evaluated static layout",
        train_tput_opt / static_best
    );
    shape_check(
        "optimizer ≥ best static layout",
        train_tput_opt >= static_best * 0.999,
    );
    shape_check(
        "optimizer assigns training the largest slice in its plan",
        {
            let train_profile =
                plan.assignments.iter().find(|a| a.workload == 0).unwrap().profile;
            let slices = |p: &str| p.split('g').next().unwrap().parse::<u32>().unwrap();
            plan.assignments.iter().all(|a| slices(train_profile) >= slices(a.profile))
        },
    );
}
