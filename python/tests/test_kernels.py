"""L1 correctness: Pallas kernels vs pure-jnp references.

The core build-time signal: every kernel must match its ``ref.py`` oracle
across shapes and dtypes (hypothesis sweeps), and its custom VJP must
produce the reference gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import fused_attention, mha
from compile.kernels.linear import fused_linear_gelu

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


class TestAttentionKernel:
    def test_matches_ref_basic(self):
        k = jax.random.PRNGKey(0)
        kq, kk, kv = jax.random.split(k, 3)
        q, kk_, v = _rand(kq, (4, 16, 8)), _rand(kk, (4, 16, 8)), _rand(kv, (4, 16, 8))
        np.testing.assert_allclose(
            fused_attention(q, kk_, v), ref.mha_ref(q, kk_, v), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=25, deadline=None)
    @given(
        bh=st.integers(1, 6),
        seq=st.integers(2, 24),
        hd=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, bh, seq, hd, seed):
        k = jax.random.PRNGKey(seed)
        kq, kk, kv = jax.random.split(k, 3)
        q, kk_, v = _rand(kq, (bh, seq, hd)), _rand(kk, (bh, seq, hd)), _rand(kv, (bh, seq, hd))
        np.testing.assert_allclose(
            fused_attention(q, kk_, v), ref.mha_ref(q, kk_, v), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        k = jax.random.PRNGKey(3)
        kq, kk, kv = jax.random.split(k, 3)
        q = _rand(kq, (2, 8, 8), dtype)
        kk_ = _rand(kk, (2, 8, 8), dtype)
        v = _rand(kv, (2, 8, 8), dtype)
        out = fused_attention(q, kk_, v)
        expect = ref.mha_ref(q, kk_, v)
        assert out.dtype == dtype
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            out.astype(jnp.float32), expect.astype(jnp.float32), rtol=tol, atol=tol
        )

    def test_softmax_rows_implicitly_normalized(self):
        # With v = identity-ish stacking, output rows are convex combos of v
        # rows: all outputs stay within [min(v), max(v)].
        k = jax.random.PRNGKey(5)
        kq, kk, kv = jax.random.split(k, 3)
        q, kk_, v = _rand(kq, (1, 8, 4)), _rand(kk, (1, 8, 4)), _rand(kv, (1, 8, 4))
        out = np.asarray(fused_attention(q, kk_, v))
        assert out.max() <= np.asarray(v).max() + 1e-5
        assert out.min() >= np.asarray(v).min() - 1e-5

    def test_numerical_stability_large_logits(self):
        # Large-magnitude q/k would overflow a naive softmax.
        k = jax.random.PRNGKey(7)
        kq, kk, kv = jax.random.split(k, 3)
        q = _rand(kq, (1, 8, 8), scale=60.0)
        kk_ = _rand(kk, (1, 8, 8), scale=60.0)
        v = _rand(kv, (1, 8, 8))
        out = np.asarray(fused_attention(q, kk_, v))
        assert np.isfinite(out).all()

    def test_gradients_match_reference(self):
        k = jax.random.PRNGKey(11)
        kq, kk, kv = jax.random.split(k, 3)
        q, kk_, v = _rand(kq, (2, 8, 4)), _rand(kk, (2, 8, 4)), _rand(kv, (2, 8, 4))
        g_kernel = jax.grad(lambda a, b, c: fused_attention(a, b, c).sum(), argnums=(0, 1, 2))(
            q, kk_, v
        )
        g_ref = jax.grad(lambda a, b, c: ref.mha_ref(a, b, c).sum(), argnums=(0, 1, 2))(q, kk_, v)
        for gk, gr in zip(g_kernel, g_ref):
            np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-5)

    def test_mha_wrapper_shapes(self):
        k = jax.random.PRNGKey(13)
        x = _rand(k, (2, 8, 16))
        out = mha(x, x, x, num_heads=4)
        assert out.shape == (2, 8, 16)

    def test_jit_compatible(self):
        k = jax.random.PRNGKey(17)
        kq, kk, kv = jax.random.split(k, 3)
        q, kk_, v = _rand(kq, (2, 4, 4)), _rand(kk, (2, 4, 4)), _rand(kv, (2, 4, 4))
        jitted = jax.jit(fused_attention)
        np.testing.assert_allclose(jitted(q, kk_, v), fused_attention(q, kk_, v), rtol=1e-6)


class TestLinearGeluKernel:
    def test_matches_ref_basic(self):
        k = jax.random.PRNGKey(0)
        kx, kw, kb = jax.random.split(k, 3)
        x, w, b = _rand(kx, (16, 32)), _rand(kw, (32, 64)), _rand(kb, (64,))
        np.testing.assert_allclose(
            fused_linear_gelu(x, w, b), ref.linear_gelu_ref(x, w, b), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(1, 40),
        in_dim=st.sampled_from([4, 16, 32]),
        out_dim=st.sampled_from([8, 24, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, rows, in_dim, out_dim, seed):
        # rows intentionally not a multiple of the 8-row block: exercises
        # the padding path.
        k = jax.random.PRNGKey(seed)
        kx, kw, kb = jax.random.split(k, 3)
        x, w, b = _rand(kx, (rows, in_dim)), _rand(kw, (in_dim, out_dim)), _rand(kb, (out_dim,))
        np.testing.assert_allclose(
            fused_linear_gelu(x, w, b), ref.linear_gelu_ref(x, w, b), rtol=1e-4, atol=1e-4
        )

    def test_gradients_match_reference(self):
        k = jax.random.PRNGKey(23)
        kx, kw, kb = jax.random.split(k, 3)
        x, w, b = _rand(kx, (5, 8)), _rand(kw, (8, 12)), _rand(kb, (12,))
        gk = jax.grad(lambda *a: fused_linear_gelu(*a).sum(), argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(lambda *a: ref.linear_gelu_ref(*a).sum(), argnums=(0, 1, 2))(x, w, b)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-5)

    def test_gelu_ref_known_values(self):
        # gelu(0) = 0; gelu is ~identity for large positive x, ~0 for large
        # negative x.
        x = jnp.array([-10.0, 0.0, 10.0])
        y = np.asarray(ref.gelu_ref(x))
        assert abs(y[1]) < 1e-7
        assert abs(y[2] - 10.0) < 1e-3
        assert abs(y[0]) < 1e-3

    def test_single_row(self):
        k = jax.random.PRNGKey(29)
        kx, kw, kb = jax.random.split(k, 3)
        x, w, b = _rand(kx, (1, 4)), _rand(kw, (4, 4)), _rand(kb, (4,))
        np.testing.assert_allclose(
            fused_linear_gelu(x, w, b), ref.linear_gelu_ref(x, w, b), rtol=1e-5, atol=1e-5
        )


class TestLayernormKernel:
    def test_matches_ref_basic(self):
        from compile.kernels.layernorm import fused_layernorm

        k = jax.random.PRNGKey(0)
        kx, kg, kb = jax.random.split(k, 3)
        x = _rand(kx, (16, 32), scale=3.0)
        g = 1.0 + _rand(kg, (32,), scale=0.1)
        b = _rand(kb, (32,), scale=0.1)
        np.testing.assert_allclose(
            fused_layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.integers(1, 40),
        dim=st.sampled_from([4, 16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, rows, dim, seed):
        from compile.kernels.layernorm import fused_layernorm

        k = jax.random.PRNGKey(seed)
        kx, kg, kb = jax.random.split(k, 3)
        x = _rand(kx, (rows, dim), scale=5.0)
        g = 1.0 + _rand(kg, (dim,), scale=0.2)
        b = _rand(kb, (dim,), scale=0.2)
        np.testing.assert_allclose(
            fused_layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-4, atol=1e-4
        )

    def test_gradients_match_reference(self):
        from compile.kernels.layernorm import fused_layernorm

        k = jax.random.PRNGKey(7)
        kx, kg, kb = jax.random.split(k, 3)
        x = _rand(kx, (5, 8))
        g = 1.0 + _rand(kg, (8,), scale=0.1)
        b = _rand(kb, (8,), scale=0.1)
        gk = jax.grad(lambda *a: fused_layernorm(*a).sum(), argnums=(0, 1, 2))(x, g, b)
        gr = jax.grad(lambda *a: ref.layernorm_ref(*a).sum(), argnums=(0, 1, 2))(x, g, b)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-5)


class TestLayernormRef:
    def test_normalizes(self):
        k = jax.random.PRNGKey(31)
        x = _rand(k, (4, 16), scale=5.0)
        y = np.asarray(ref.layernorm_ref(x, jnp.ones(16), jnp.zeros(16)))
        np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-2)

    def test_affine_params(self):
        x = jnp.ones((2, 4))  # constant rows → normalized to 0
        y = np.asarray(ref.layernorm_ref(x, jnp.full(4, 3.0), jnp.full(4, 7.0)))
        np.testing.assert_allclose(y, 7.0, atol=1e-2)
