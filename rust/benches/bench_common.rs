//! Shared helpers for the figure/table benches.
//!
//! Each bench binary (`cargo bench --bench figN_...`) regenerates one
//! table or figure from the paper's evaluation section: it runs the
//! corresponding experiment on the simulated substrate and prints the
//! same rows/series the paper reports, plus a `shape-check:` line
//! asserting the qualitative finding. Optionally writes CSV next to the
//! terminal output when `MIGPERF_BENCH_OUT` is set.

use migperf::profiler::report::BenchReport;
use migperf::util::table::{fmt_num, sparkline};

/// Print a figure banner.
#[allow(dead_code)]
pub fn banner(id: &str, caption: &str) {
    println!("==========================================================");
    println!("{id}: {caption}");
    println!("==========================================================");
}

/// Print per-instance series of one metric as aligned rows + sparkline.
#[allow(dead_code)]
pub fn print_series(
    report: &BenchReport,
    metric_name: &str,
    metric: impl Fn(&migperf::metrics::collector::RunSummary) -> f64,
    x_name: &str,
    x_is_seq: bool,
) {
    let series = report.series(&metric, x_is_seq);
    let xs: Vec<u32> = series
        .first()
        .map(|(_, pts)| pts.iter().map(|&(x, _)| x).collect())
        .unwrap_or_default();
    println!("\n{metric_name} vs {x_name}:");
    print!("{:>10} |", x_name);
    for x in &xs {
        print!("{x:>9}");
    }
    println!();
    for (inst, pts) in &series {
        print!("{inst:>10} |");
        for &(_, y) in pts {
            print!("{:>9}", fmt_num(y));
        }
        let ys: Vec<f64> = pts.iter().map(|&(_, y)| y).collect();
        println!("  {}", sparkline(&ys));
    }
}

/// Write a report's summaries as CSV if MIGPERF_BENCH_OUT is set.
#[allow(dead_code)]
pub fn maybe_write_csv(name: &str, report: &BenchReport) {
    if let Some(dir) = std::env::var_os("MIGPERF_BENCH_OUT") {
        let dir = std::path::PathBuf::from(dir);
        let _ = std::fs::create_dir_all(&dir);
        let rows: Vec<_> = report.rows().iter().map(|r| r.summary.clone()).collect();
        let csv = migperf::metrics::export::summaries_to_csv(&rows);
        let path = dir.join(format!("{name}.csv"));
        if std::fs::write(&path, csv).is_ok() {
            println!("(csv written to {})", path.display());
        }
    }
}

/// Assert + report a qualitative shape check.
#[allow(dead_code)]
pub fn shape_check(desc: &str, ok: bool) {
    println!("shape-check: {desc} ... {}", if ok { "OK" } else { "FAILED" });
    assert!(ok, "shape check failed: {desc}");
}
