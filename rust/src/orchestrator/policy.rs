//! Pluggable repartitioning policies.
//!
//! A [`Policy`] watches windowed metrics from the running workloads and
//! decides when (and to what) the GPU should be repartitioned. Three
//! reference policies ship behind the trait:
//!
//! * [`StaticOracle`] — the baseline: today's exhaustive optimizer
//!   applied once to whole-trace average rates, never touched again;
//! * [`Reactive`] — MISO-style hysteresis thresholds on observed SLO
//!   pressure and utilization, candidate layouts re-planned from
//!   [`crate::mig::enumerate::maximal_layouts`] and scored with the
//!   roofline model at the observed window rates;
//! * [`Predictive`] — the same machinery driven by a short-horizon
//!   arrival forecast ([`RateForecaster`]), so the resize happens
//!   *before* a diurnal ramp crests.

use crate::scheduler::{DemandWorkload, RatePlan, Scheduler};
use crate::workload::arrival::RateForecaster;

/// Windowed observation of one inference service.
#[derive(Debug, Clone)]
pub struct ServiceObs {
    /// Requests that arrived in the window.
    pub arrivals: u64,
    /// Arrival-rate estimate over the window, requests/s.
    pub rate_rps: f64,
    /// Requests completed in the window.
    pub completed: u64,
    /// Completions that exceeded the SLO in the window.
    pub violations: u64,
    /// p99 latency of the window's completions, ms (0 when none).
    pub p99_ms: f64,
    /// Fraction of the window the server was busy, in `[0, 1]`.
    pub busy_frac: f64,
    /// Requests still queued at the window boundary.
    pub queue_depth: usize,
}

/// One observation window over every workload.
#[derive(Debug, Clone)]
pub struct WindowObs {
    /// Window end time (simulated seconds).
    pub t: f64,
    /// Window length, seconds.
    pub window_s: f64,
    /// Per-service observations, in service order.
    pub services: Vec<ServiceObs>,
    /// Training steps completed in the window.
    pub train_steps: u64,
}

/// Read-only planning context handed to a policy at each window tick.
#[derive(Debug)]
pub struct PolicyCtx<'a> {
    /// Planner (layout enumeration + roofline scoring).
    pub scheduler: &'a Scheduler,
    /// Workload templates; service entries carry whole-trace mean rates
    /// as their demand (what the static baseline was sized for).
    pub workloads: &'a [DemandWorkload],
    /// Workload index of each service, in service order.
    pub service_workloads: &'a [usize],
    /// The plan currently in force.
    pub current: &'a RatePlan,
    /// Current time (window end), simulated seconds.
    pub now: f64,
    /// Time the layout last changed (0 if never).
    pub last_change_t: f64,
    /// Utilization bound used for sizing (ρ_max).
    pub rho_max: f64,
}

impl PolicyCtx<'_> {
    /// Clone the workload templates with per-service demand rates
    /// substituted in (rates in service order).
    pub fn workloads_at_rates(&self, rates: &[f64]) -> Vec<DemandWorkload> {
        let mut ws = self.workloads.to_vec();
        for (si, &wi) in self.service_workloads.iter().enumerate() {
            ws[wi].demand_rps = Some(rates.get(si).copied().unwrap_or(0.0).max(0.0));
        }
        ws
    }
}

/// A repartitioning policy.
pub trait Policy {
    /// Short name used in reports ("static", "reactive", ...).
    fn name(&self) -> &'static str;

    /// Called at the end of each observation window while the system is
    /// running normally. Return `Some(plan)` to repartition to `plan`
    /// (the engine ignores proposals whose layout equals the current
    /// one), or `None` to keep the current layout.
    fn decide(&mut self, obs: &WindowObs, ctx: &PolicyCtx) -> Option<RatePlan>;
}

/// Tunables shared by the reactive and predictive policies.
#[derive(Debug, Clone, PartialEq)]
pub struct ReactiveParams {
    /// Minimum seconds between reconfigurations.
    pub cooldown_s: f64,
    /// Minimum relative score gain for a *voluntary* move (no observed
    /// pressure); the hysteresis band that prevents flapping.
    pub hysteresis: f64,
    /// Busy fraction that flags a server as saturated.
    pub busy_trigger: f64,
}

impl Default for ReactiveParams {
    fn default() -> Self {
        ReactiveParams { cooldown_s: 40.0, hysteresis: 0.10, busy_trigger: 0.9 }
    }
}

/// Tunables of the predictive policy.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictiveParams {
    /// Threshold/hysteresis machinery shared with [`Reactive`].
    pub reactive: ReactiveParams,
    /// Forecaster level gain.
    pub alpha: f64,
    /// Forecaster trend gain.
    pub beta: f64,
    /// How many windows ahead to size for.
    pub horizon_windows: f64,
}

impl Default for PredictiveParams {
    fn default() -> Self {
        PredictiveParams {
            reactive: ReactiveParams::default(),
            alpha: 0.5,
            beta: 0.3,
            horizon_windows: 2.0,
        }
    }
}

/// Which policy to run — plain data, cloneable into sweep grids;
/// [`PolicyKind::build`] constructs the stateful policy.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    /// Fixed layout from whole-trace average rates (the baseline).
    Static,
    /// Hysteresis thresholds on observed window metrics.
    Reactive(ReactiveParams),
    /// Proactive resize from a short-horizon arrival forecast.
    Predictive(PredictiveParams),
}

impl PolicyKind {
    /// Report name of the policy.
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Static => "static",
            PolicyKind::Reactive(_) => "reactive",
            PolicyKind::Predictive(_) => "predictive",
        }
    }

    /// Parse a policy name (default parameters).
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "static" | "oracle" => Some(PolicyKind::Static),
            "reactive" => Some(PolicyKind::Reactive(ReactiveParams::default())),
            "predictive" => Some(PolicyKind::Predictive(PredictiveParams::default())),
            _ => None,
        }
    }

    /// Construct the stateful policy.
    pub fn build(&self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Static => Box::new(StaticOracle),
            PolicyKind::Reactive(p) => Box::new(Reactive { params: p.clone() }),
            PolicyKind::Predictive(p) => {
                Box::new(Predictive { params: p.clone(), forecasters: Vec::new() })
            }
        }
    }
}

/// The baseline: never repartitions. Its initial layout (computed by the
/// engine from whole-trace mean rates) is exactly what the offline
/// exhaustive optimizer would pick for the averaged workload.
#[derive(Debug)]
pub struct StaticOracle;

impl Policy for StaticOracle {
    fn name(&self) -> &'static str {
        "static"
    }
    fn decide(&mut self, _obs: &WindowObs, _ctx: &PolicyCtx) -> Option<RatePlan> {
        None
    }
}

/// Shared decision core: size for `rates`, repartition when the current
/// plan is predicted-infeasible at those rates, when observed pressure
/// (SLO p99 blown or a saturated server) demands it, or when the best
/// candidate clears the hysteresis band.
fn decide_for_rates(
    rates: &[f64],
    obs: &WindowObs,
    ctx: &PolicyCtx,
    params: &ReactiveParams,
) -> Option<RatePlan> {
    if ctx.now - ctx.last_change_t < params.cooldown_s {
        return None;
    }
    let ws = ctx.workloads_at_rates(rates);
    let candidate = ctx.scheduler.plan_for_demand(&ws, ctx.rho_max)?;
    if candidate.layout == ctx.current.layout {
        return None;
    }
    let (cur_score, cur_feasible) = ctx.scheduler.evaluate_plan(ctx.current, &ws, ctx.rho_max);
    let pressure = obs.services.iter().enumerate().any(|(si, s)| {
        let slo = ctx.service_workloads.get(si).and_then(|&wi| ctx.workloads[wi].slo_ms);
        let p99_blown = slo.map(|slo| s.completed > 0 && s.p99_ms > slo).unwrap_or(false);
        p99_blown || s.busy_frac >= params.busy_trigger
    });
    let improvement = candidate.score > cur_score * (1.0 + params.hysteresis);
    if !cur_feasible || pressure || improvement {
        Some(candidate)
    } else {
        None
    }
}

/// Reactive hysteresis policy: sizes for the rates observed in the last
/// window.
#[derive(Debug)]
pub struct Reactive {
    /// Thresholds and hysteresis band.
    pub params: ReactiveParams,
}

impl Policy for Reactive {
    fn name(&self) -> &'static str {
        "reactive"
    }
    fn decide(&mut self, obs: &WindowObs, ctx: &PolicyCtx) -> Option<RatePlan> {
        let rates: Vec<f64> = obs.services.iter().map(|s| s.rate_rps).collect();
        decide_for_rates(&rates, obs, ctx, &self.params)
    }
}

/// Predictive policy: sizes for a short-horizon forecast of each
/// service's arrival rate (never below the currently observed rate, so a
/// falling forecast cannot shrink a service that is still loaded).
#[derive(Debug)]
pub struct Predictive {
    /// Thresholds plus forecaster gains and horizon.
    pub params: PredictiveParams,
    forecasters: Vec<RateForecaster>,
}

impl Policy for Predictive {
    fn name(&self) -> &'static str {
        "predictive"
    }
    fn decide(&mut self, obs: &WindowObs, ctx: &PolicyCtx) -> Option<RatePlan> {
        if self.forecasters.len() != obs.services.len() {
            self.forecasters = vec![
                RateForecaster::new(self.params.alpha, self.params.beta);
                obs.services.len()
            ];
        }
        for (f, s) in self.forecasters.iter_mut().zip(&obs.services) {
            f.observe(s.rate_rps);
        }
        let rates: Vec<f64> = self
            .forecasters
            .iter()
            .zip(&obs.services)
            .map(|(f, s)| f.forecast(self.params.horizon_windows).max(s.rate_rps))
            .collect();
        decide_for_rates(&rates, obs, ctx, &self.params.reactive)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::GpuModel;
    use crate::models::zoo::lookup;
    use crate::workload::spec::WorkloadSpec;

    fn workloads(mean_rate: f64) -> Vec<DemandWorkload> {
        let bert = lookup("bert-base").unwrap();
        vec![
            DemandWorkload::training(WorkloadSpec::training(bert, 32, 128)),
            DemandWorkload::service(WorkloadSpec::inference(bert, 8, 128), 40.0, mean_rate),
            DemandWorkload::service(WorkloadSpec::inference(bert, 8, 128), 40.0, mean_rate),
        ]
    }

    fn obs(rates: [f64; 2], p99_ms: f64, busy: f64) -> WindowObs {
        WindowObs {
            t: 100.0,
            window_s: 20.0,
            services: rates
                .iter()
                .map(|&r| ServiceObs {
                    arrivals: (r * 20.0) as u64,
                    rate_rps: r,
                    completed: (r * 20.0) as u64,
                    violations: 0,
                    p99_ms,
                    busy_frac: busy,
                    queue_depth: 0,
                })
                .collect(),
            train_steps: 100,
        }
    }

    fn ctx_fixture<'a>(
        sched: &'a Scheduler,
        ws: &'a [DemandWorkload],
        current: &'a RatePlan,
        last_change_t: f64,
    ) -> PolicyCtx<'a> {
        PolicyCtx {
            scheduler: sched,
            workloads: ws,
            service_workloads: &[1, 2],
            current,
            now: 100.0,
            last_change_t,
            rho_max: 0.75,
        }
    }

    #[test]
    fn static_policy_never_moves() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let ws = workloads(33.0);
        let plan = sched.plan_for_demand(&ws, 0.75).unwrap();
        let ctx = ctx_fixture(&sched, &ws, &plan, 0.0);
        assert!(StaticOracle.decide(&obs([60.0, 60.0], 500.0, 1.0), &ctx).is_none());
    }

    #[test]
    fn reactive_keeps_layout_at_mean_load() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let ws = workloads(33.0);
        let plan = sched.plan_for_demand(&ws, 0.75).unwrap();
        let ctx = ctx_fixture(&sched, &ws, &plan, 0.0);
        let mut r = Reactive { params: ReactiveParams::default() };
        assert!(r.decide(&obs([33.0, 33.0], 25.0, 0.5), &ctx).is_none());
    }

    #[test]
    fn reactive_repartitions_under_overload() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let ws = workloads(33.0);
        let plan = sched.plan_for_demand(&ws, 0.75).unwrap();
        let ctx = ctx_fixture(&sched, &ws, &plan, 0.0);
        let mut r = Reactive { params: ReactiveParams::default() };
        let target = r.decide(&obs([60.0, 60.0], 120.0, 1.0), &ctx).expect("must repartition");
        assert!(target.layout != plan.layout);
        // Every service lands on an instance that sustains the peak rate.
        for a in target.assignments.iter().filter(|a| a.workload > 0) {
            assert!(a.utilization <= 0.75, "{a:?}");
            assert!(a.latency_ms <= 40.0, "{a:?}");
        }
    }

    #[test]
    fn cooldown_blocks_back_to_back_moves() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let ws = workloads(33.0);
        let plan = sched.plan_for_demand(&ws, 0.75).unwrap();
        // Layout changed 10 s ago; cooldown is 40 s.
        let ctx = ctx_fixture(&sched, &ws, &plan, 95.0);
        let mut r = Reactive { params: ReactiveParams::default() };
        assert!(r.decide(&obs([60.0, 60.0], 120.0, 1.0), &ctx).is_none());
    }

    #[test]
    fn predictive_moves_on_forecast_before_overload_arrives() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let ws = workloads(33.0);
        let plan = sched.plan_for_demand(&ws, 0.75).unwrap();
        let mut p = Predictive {
            params: PredictiveParams::default(),
            forecasters: Vec::new(),
        };
        // Steep observed ramp, but the *current* rate (45) is still one
        // the static layout can serve: only the forecast crosses the
        // capacity bound, so a move now is proactive.
        let mut moved = None;
        for (i, r) in [15.0, 25.0, 35.0, 45.0].iter().enumerate() {
            let mut o = obs([*r, *r], 20.0, 0.6);
            o.t = 100.0 + i as f64 * 20.0;
            let ctx = PolicyCtx { now: o.t, ..ctx_fixture(&sched, &ws, &plan, 0.0) };
            if let Some(t) = p.decide(&o, &ctx) {
                moved = Some((i, t));
                break;
            }
        }
        let (_, target) = moved.expect("predictive must act on the forecast");
        assert!(target.layout != plan.layout);
    }
}
