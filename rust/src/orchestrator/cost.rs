//! Reconfiguration cost model.
//!
//! Repartitioning a MIG GPU is not free: in-flight requests must drain,
//! GPU instances are destroyed and recreated (driver churn plus serving
//! process restart), and the training job checkpoints before the switch
//! and restores after it. The orchestrator pays these costs explicitly in
//! simulated time, so a policy that flaps loses goodput to its own
//! downtime — the central tension the MISO / reconfigurable-scheduling
//! literature studies.

use crate::mig::enumerate::Layout;

/// Tunable reconfiguration costs (seconds of simulated time).
#[derive(Debug, Clone, PartialEq)]
pub struct ReconfigCost {
    /// Seconds per GPU instance destroyed or created (nvml GI/CI churn
    /// plus the amortized serving-process restart).
    pub instance_churn_s: f64,
    /// Extra seconds before the training job resumes after a repartition
    /// (checkpoint restore).
    pub train_restore_s: f64,
}

impl Default for ReconfigCost {
    fn default() -> Self {
        ReconfigCost { instance_churn_s: 0.5, train_restore_s: 5.0 }
    }
}

impl ReconfigCost {
    /// Reject negative or non-finite cost parameters.
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("instance_churn_s", self.instance_churn_s),
            ("train_restore_s", self.train_restore_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("reconfig cost {name} = {v} must be non-negative and finite"));
            }
        }
        Ok(())
    }

    /// Post-drain reconfiguration latency for switching `from` → `to`.
    pub fn latency_s(&self, from: &Layout, to: &Layout) -> f64 {
        self.instance_churn_s * churn(from, to) as f64
    }
}

/// Number of instances destroyed plus created when switching `from` →
/// `to`. Instances present in both layouts at the same (profile, offset)
/// survive the switch untouched.
pub fn churn(from: &Layout, to: &Layout) -> u32 {
    let destroyed = from.placements.iter().filter(|p| !to.placements.contains(p)).count();
    let created = to.placements.iter().filter(|p| !from.placements.contains(p)).count();
    (destroyed + created) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::enumerate::maximal_layouts;
    use crate::mig::gpu::GpuModel;

    fn layouts() -> Vec<Layout> {
        maximal_layouts(GpuModel::A30_24GB)
    }

    #[test]
    fn identical_layouts_have_zero_churn() {
        for l in layouts() {
            assert_eq!(churn(&l, &l), 0);
            assert_eq!(ReconfigCost::default().latency_s(&l, &l), 0.0);
        }
    }

    #[test]
    fn disjoint_layouts_churn_everything() {
        let ls = layouts();
        let whole = ls.iter().find(|l| l.profile_names() == vec!["4g.24gb"]).unwrap();
        let quads = ls.iter().find(|l| l.profile_names() == vec!["1g.6gb"; 4]).unwrap();
        assert_eq!(churn(whole, quads), 5, "1 destroyed + 4 created");
        assert_eq!(churn(quads, whole), 5, "symmetric");
        let cost = ReconfigCost { instance_churn_s: 2.0, train_restore_s: 0.0 };
        assert_eq!(cost.latency_s(whole, quads), 10.0);
    }

    #[test]
    fn shared_instances_survive() {
        let ls = layouts();
        // 2g@0 + 2g@2  →  2g@0 + 1g@2 + 1g@3: the 2g@0 instance is kept.
        let two_two = ls.iter().find(|l| l.profile_names() == vec!["2g.12gb", "2g.12gb"]).unwrap();
        let two_one_one = ls
            .iter()
            .find(|l| l.profile_names() == vec!["2g.12gb", "1g.6gb", "1g.6gb"])
            .unwrap();
        assert_eq!(churn(two_two, two_one_one), 3, "destroy 2g@2, create 1g@2 + 1g@3");
    }

    #[test]
    fn validate_rejects_bad_costs() {
        assert!(ReconfigCost::default().validate().is_ok());
        let bad = ReconfigCost { instance_churn_s: -1.0, train_restore_s: 0.0 };
        assert!(bad.validate().is_err());
        let nan = ReconfigCost { instance_churn_s: 0.5, train_restore_s: f64::NAN };
        assert!(nan.validate().is_err());
    }
}
