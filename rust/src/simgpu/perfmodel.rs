//! Roofline performance model: price a step on an execution resource.
//!
//! The model is a three-term roofline with an SM-saturation efficiency
//! curve (DESIGN.md §3.4):
//!
//! ```text
//! t_step = t_launch + max(flops / (peak·f_c·eff), hbm_bytes / (bw·f_b))
//! eff(batch, slices) = batch / (batch + k·slices)
//! ```
//!
//! `eff` captures the paper's central utilization observation: a small GI
//! (few SMs) saturates at small batch — throughput flattens and GRACT
//! stays high (Fig 2a/2b) — while a large GI needs much more parallel work
//! to fill, so its utilization is lower and latency is nearly
//! batch-insensitive (Fig 3a/3b).

use crate::models::cost::{Precision, StepCost};

use super::resource::ExecResource;

/// Result of pricing one step on a resource.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepEstimate {
    /// Wall time for the step, seconds (simulated GPU time).
    pub seconds: f64,
    /// Achieved compute utilization (GRACT analogue), in `[0, 1]`.
    pub gract: f64,
    /// True if the step was compute-bound (vs memory-bound).
    pub compute_bound: bool,
    /// Frame-buffer residency of the workload, bytes.
    pub fb_bytes: f64,
}

/// Why a step could not run.
#[derive(Debug, Clone, PartialEq)]
pub enum PerfError {
    /// Workload does not fit in the resource's frame buffer.
    OutOfMemory {
        /// Required GiB.
        need_gib: f64,
        /// Available GiB.
        have_gib: f64,
    },
}

impl std::fmt::Display for PerfError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PerfError::OutOfMemory { need_gib, have_gib } => write!(
                f,
                "out of memory: workload needs {need_gib:.2} GiB, instance has {have_gib:.2} GiB"
            ),
        }
    }
}

impl std::error::Error for PerfError {}

/// Tunable constants of the model. Defaults are calibrated so whole-GPU
/// numbers land in the envelope of published A100 benchmarks; `runtime`
/// re-calibrates `flop_efficiency` against real HLO execution.
#[derive(Debug, Clone)]
pub struct PerfModel {
    /// Kernel-launch plus framework overhead per step, seconds.
    pub launch_overhead_s: f64,
    /// Saturation constant `k`: batch needed per compute slice to reach
    /// 50% of peak.
    pub saturation_k: f64,
    /// Fraction of datasheet peak reachable by real kernels (fusion,
    /// tensor-core residency). ~0.45 matches measured BERT/ResNet numbers.
    pub flop_efficiency: f64,
    /// Fraction of datasheet bandwidth reachable (~0.8 typical).
    pub bw_efficiency: f64,
}

impl Default for PerfModel {
    fn default() -> Self {
        PerfModel {
            launch_overhead_s: 0.45e-3,
            saturation_k: 3.0,
            flop_efficiency: 0.45,
            bw_efficiency: 0.80,
        }
    }
}

impl PerfModel {
    /// SM-saturation efficiency for a batch on a resource.
    ///
    /// `slices` is the compute-slice count (SMs / SMs-per-slice); MPS
    /// resources have full SM reach, so they saturate like the whole GPU.
    pub fn efficiency(&self, batch: u32, res: &ExecResource) -> f64 {
        let slices = res.sm_count as f64 / res.spec().sms_per_slice() as f64;
        let b = batch as f64;
        b / (b + self.saturation_k * slices)
    }

    /// Price one step of `cost` on `res`. Fails if it does not fit in FB.
    pub fn step(&self, res: &ExecResource, cost: &StepCost) -> Result<StepEstimate, PerfError> {
        if cost.fb_bytes > res.fb_capacity_bytes {
            return Err(PerfError::OutOfMemory {
                need_gib: cost.fb_bytes / super::resource::GIB,
                have_gib: res.fb_capacity_bytes / super::resource::GIB,
            });
        }
        let half = cost.precision == Precision::Half;
        let eff = self.efficiency(cost.batch, res);
        let peak = res.peak_flops(half) * self.flop_efficiency;
        let bw = res.bandwidth() * self.bw_efficiency;
        let t_compute = cost.flops / (peak * eff);
        let t_memory = cost.hbm_bytes / bw;
        let t_body = t_compute.max(t_memory);
        let seconds = self.launch_overhead_s + t_body;
        // GRACT: fraction of the step the compute engines were active.
        // Compute-bound steps hold the SMs for the whole body at `eff`;
        // memory-bound steps keep them active only during the compute
        // portion.
        let gract = (t_compute / t_body) * eff * (t_body / seconds);
        Ok(StepEstimate {
            seconds,
            gract: gract.clamp(0.0, 1.0),
            compute_bound: t_compute >= t_memory,
            fb_bytes: cost.fb_bytes,
        })
    }

    /// Throughput (samples/s) for a step estimate.
    pub fn throughput(&self, est: &StepEstimate, batch: u32) -> f64 {
        batch as f64 / est.seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::GpuModel;
    use crate::mig::profile::lookup;
    use crate::models::cost::{infer_cost, train_cost};
    use crate::models::zoo;

    fn gi(name: &str) -> ExecResource {
        ExecResource::from_gi(GpuModel::A100_80GB, lookup(GpuModel::A100_80GB, name).unwrap())
    }

    #[test]
    fn small_gi_saturates_early() {
        let pm = PerfModel::default();
        let small = gi("1g.10gb");
        let large = gi("7g.80gb");
        assert!(pm.efficiency(32, &small) > 0.9, "1g at batch 32 should be saturated");
        assert!(pm.efficiency(32, &large) < 0.75, "7g at batch 32 should be unsaturated");
    }

    #[test]
    fn fig2a_small_gi_throughput_flattens() {
        // Paper Fig 2a: on 1g.10gb, throughput stops growing past batch 32.
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-base").unwrap();
        let small = gi("1g.10gb");
        let tp = |b: u32| {
            let est = pm.step(&small, &train_cost(m, b, 128, Precision::Half)).unwrap();
            pm.throughput(&est, b)
        };
        let gain_32_128 = tp(128) / tp(32);
        assert!(
            gain_32_128 < 1.15,
            "1g throughput gain 32→128 = {gain_32_128}, expected ≈flat"
        );
        let gain_8_32 = tp(32) / tp(8);
        assert!(gain_8_32 > 1.15, "1g should still gain from 8→32, got {gain_8_32}");
    }

    #[test]
    fn fig2a_large_gi_keeps_scaling() {
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-base").unwrap();
        let large = gi("7g.80gb");
        let tp = |b: u32| {
            let est = pm.step(&large, &train_cost(m, b, 128, Precision::Half)).unwrap();
            pm.throughput(&est, b)
        };
        let gain = tp(128) / tp(32);
        assert!(gain > 1.3, "7g throughput must keep growing with batch, got {gain}");
    }

    #[test]
    fn fig2b_gract_high_on_small_low_on_large() {
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-base").unwrap();
        let cost = train_cost(m, 32, 128, Precision::Half);
        let g_small = pm.step(&gi("1g.10gb"), &cost).unwrap().gract;
        let g_large = pm.step(&gi("7g.80gb"), &cost).unwrap().gract;
        assert!(g_small > g_large, "small {g_small} vs large {g_large}");
        assert!(g_small > 0.8);
    }

    #[test]
    fn fig3a_latency_batch_sensitive_only_on_small_gi() {
        // Paper Fig 3a: latency grows with batch on small GIs; marginal on
        // large GIs.
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-base").unwrap();
        let lat = |r: &ExecResource, b: u32| {
            pm.step(r, &infer_cost(m, b, 128, Precision::Half)).unwrap().seconds
        };
        let small = gi("1g.10gb");
        let large = gi("7g.80gb");
        let small_ratio = lat(&small, 32) / lat(&small, 1);
        let large_ratio = lat(&large, 32) / lat(&large, 1);
        assert!(small_ratio > 4.0, "small GI ratio {small_ratio}");
        assert!(large_ratio < small_ratio / 2.0, "large GI ratio {large_ratio}");
    }

    #[test]
    fn bigger_gi_is_never_slower() {
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-base").unwrap();
        let cost = infer_cost(m, 16, 128, Precision::Half);
        let names = ["1g.10gb", "2g.20gb", "3g.40gb", "4g.40gb", "7g.80gb"];
        let times: Vec<f64> =
            names.iter().map(|n| pm.step(&gi(n), &cost).unwrap().seconds).collect();
        for w in times.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "latency must be non-increasing in GI size: {times:?}");
        }
    }

    #[test]
    fn oom_on_small_instance() {
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-large").unwrap();
        let cost = train_cost(m, 128, 128, Precision::Half);
        let err = pm.step(&gi("1g.10gb"), &cost);
        assert!(matches!(err, Err(PerfError::OutOfMemory { .. })));
        // Same workload fits the whole GPU.
        assert!(pm.step(&gi("7g.80gb"), &cost).is_ok());
    }

    #[test]
    fn whole_a100_bert_throughput_in_published_envelope() {
        // Sanity: BERT-base seq128 fp16 training on a full A100 is
        // published around 300–800 sequences/s depending on stack.
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-base").unwrap();
        let r = ExecResource::whole_gpu(GpuModel::A100_80GB);
        let est = pm.step(&r, &train_cost(m, 64, 128, Precision::Half)).unwrap();
        let tput = pm.throughput(&est, 64);
        assert!((150.0..2000.0).contains(&tput), "throughput {tput} seq/s out of envelope");
    }

    #[test]
    fn batch1_on_large_gi_underutilized() {
        // Paper Fig 3b: large GIs cannot be filled by small requests — the
        // model reflects that as low achieved utilization at batch 1.
        let pm = PerfModel::default();
        let m = zoo::lookup("bert-base").unwrap();
        let est = pm.step(&gi("7g.80gb"), &infer_cost(m, 1, 128, Precision::Half)).unwrap();
        assert!(
            est.gract < 0.3,
            "batch-1 on 7g should be badly underutilized, gract={}",
            est.gract
        );
        let est1g = pm.step(&gi("1g.10gb"), &infer_cost(m, 1, 128, Precision::Half)).unwrap();
        assert!(est1g.gract > est.gract, "1g must be better utilized than 7g at batch 1");
    }

    #[test]
    fn launch_overhead_floors_latency() {
        let pm = PerfModel::default();
        let m = zoo::lookup("resnet18").unwrap();
        let est = pm.step(&gi("7g.80gb"), &infer_cost(m, 1, 224, Precision::Half)).unwrap();
        assert!(est.seconds >= pm.launch_overhead_s);
    }
}
