//! Framework-compatibility rig (paper §4.6, Tables 1–2).
//!
//! Reproduces the paper's finding that, on a 2-GI A30, every tested
//! training and serving framework can only use the *first* MIG instance:
//! the CUDA runtime exposes at most one MIG compute instance per process,
//! so frameworks enumerate 0 or 1 devices and "MIG 1" is never reachable
//! without container binding.
//!
//! [`cuda`] models the CUDA-runtime enumeration semantics; [`compat`]
//! registers the paper's seven frameworks and runs the compatibility
//! matrix; [`docker`] models the container-binding workaround (and its
//! reconfiguration friction) the paper describes.

pub mod compat;
pub mod cuda;
pub mod docker;

pub use compat::{run_serving_matrix, run_training_matrix, CompatResult, Framework};
