"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. ``python/tests`` asserts
``assert_allclose(kernel(x), ref(x))`` across shapes and dtypes (including
hypothesis sweeps) — this is the core L1 correctness signal. The custom
VJPs of the kernels also differentiate *through these references*, so
training gradients are exactly the reference gradients.
"""

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Scaled dot-product attention for one head.

    Args:
      q, k, v: ``[seq, head_dim]`` arrays.

    Returns:
      ``[seq, head_dim]`` attention output.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = (q @ k.T) * scale
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return weights @ v


def mha_ref(q, k, v):
    """Batched multi-head attention: ``[batch*heads, seq, head_dim]``."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("bsd,btd->bst", q, k) * scale
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return jnp.einsum("bst,btd->bsd", weights, v)


def gelu_ref(x):
    """tanh-approximated GELU (the BERT variant)."""
    c = jnp.asarray(0.7978845608028654, dtype=x.dtype)  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def linear_gelu_ref(x, w, b):
    """Fused ``gelu(x @ w + b)``.

    Args:
      x: ``[rows, in_dim]``.
      w: ``[in_dim, out_dim]``.
      b: ``[out_dim]``.
    """
    return gelu_ref(x @ w + b)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Layer normalization over the last axis."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
