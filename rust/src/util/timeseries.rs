//! Time-series storage for sampled metrics.
//!
//! The paper's performance aggregator "saves results into a local file in a
//! time series manner" (§3.1). This module is the in-memory half of that:
//! a tagged series of (timestamp, value) points with windowed reduction,
//! consumed by the exporters in `metrics::export`.

use std::collections::BTreeMap;

/// One sampled point on the simulation (or wall) clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// Seconds since the start of the run (virtual clock for simulated
    /// workloads, wall clock for real-execution runs).
    pub t: f64,
    pub value: f64,
}

/// Why a sample was rejected by [`Series::try_push`]: its timestamp
/// precedes the last recorded point (or is NaN).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutOfOrder {
    /// Timestamp of the last recorded point.
    pub last_t: f64,
    /// Offending timestamp.
    pub t: f64,
}

impl std::fmt::Display for OutOfOrder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "out-of-order sample: t = {} precedes last timestamp {}", self.t, self.last_t)
    }
}

impl std::error::Error for OutOfOrder {}

/// A named, tag-annotated series of points, kept in insertion order.
///
/// Timestamps must be non-decreasing (the DES clock only moves forward):
/// [`Series::push`] saturates out-of-order timestamps to the last point's
/// and [`Series::try_push`] rejects them.
#[derive(Debug, Clone, Default)]
pub struct Series {
    /// Metric name, e.g. `gract`, `fb_used_mib`, `power_w`.
    pub name: String,
    /// Free-form tags, e.g. `{"gi": "1g.10gb", "model": "bert-base"}`.
    pub tags: BTreeMap<String, String>,
    points: Vec<Point>,
}

impl Series {
    /// New empty series with a metric name.
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), tags: BTreeMap::new(), points: Vec::new() }
    }

    /// Builder-style tag attachment.
    pub fn with_tag(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        self.tags.insert(k.into(), v.into());
        self
    }

    /// Append a sample. Timestamps must be non-decreasing; an
    /// out-of-order (or NaN) `t` is *saturated* to the last point's
    /// timestamp instead of being stored as-is.
    ///
    /// This used to be a `debug_assert!` only, so release builds silently
    /// accepted out-of-order points and `time_weighted_mean` / `integral`
    /// accumulated negative areas. Saturation keeps those reductions
    /// correct in every build; use [`Series::try_push`] to surface the
    /// violation as an error instead.
    pub fn push(&mut self, t: f64, value: f64) {
        let t = match self.points.last() {
            Some(p) if t < p.t || t.is_nan() => p.t,
            // A NaN *first* sample would poison every later comparison
            // (nothing is < NaN), so it saturates to the clock origin.
            None if t.is_nan() => 0.0,
            _ => t,
        };
        self.points.push(Point { t, value });
    }

    /// Append a sample, rejecting out-of-order (or NaN) timestamps
    /// instead of saturating them. A NaN on an empty series reports the
    /// clock origin (0) as `last_t`.
    pub fn try_push(&mut self, t: f64, value: f64) -> Result<(), OutOfOrder> {
        let last_t = self.points.last().map_or(0.0, |p| p.t);
        if t < last_t || t.is_nan() {
            return Err(OutOfOrder { last_t, t });
        }
        self.points.push(Point { t, value });
        Ok(())
    }

    /// All points, in time order.
    pub fn points(&self) -> &[Point] {
        &self.points
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True if no samples recorded.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Mean of values over the whole series (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|p| p.value).sum::<f64>() / self.points.len() as f64
    }

    /// Time-weighted average: each sample holds until the next sample's
    /// timestamp. More faithful than `mean` for utilization counters whose
    /// sampling interval varies. Returns plain mean when < 2 points.
    pub fn time_weighted_mean(&self) -> f64 {
        if self.points.len() < 2 {
            return self.mean();
        }
        let mut area = 0.0;
        for w in self.points.windows(2) {
            area += w[0].value * (w[1].t - w[0].t);
        }
        let span = self.points.last().unwrap().t - self.points[0].t;
        if span <= 0.0 {
            self.mean()
        } else {
            area / span
        }
    }

    /// Trapezoidal integral of the series over time (e.g. power → energy).
    pub fn integral(&self) -> f64 {
        let mut area = 0.0;
        for w in self.points.windows(2) {
            area += 0.5 * (w[0].value + w[1].value) * (w[1].t - w[0].t);
        }
        area
    }

    /// Largest value (0 if empty).
    pub fn max(&self) -> f64 {
        self.points.iter().map(|p| p.value).fold(0.0, f64::max)
    }

    /// Downsample into fixed windows of `dt` seconds, averaging within each
    /// window. Used by the visualizer/exporter to bound output size.
    pub fn downsample(&self, dt: f64) -> Series {
        assert!(dt > 0.0);
        let mut out =
            Series { name: self.name.clone(), tags: self.tags.clone(), points: Vec::new() };
        if self.points.is_empty() {
            return out;
        }
        let t0 = self.points[0].t;
        let mut window = 0usize;
        let mut acc = 0.0;
        let mut n = 0u32;
        for p in &self.points {
            let w = ((p.t - t0) / dt) as usize;
            if w != window && n > 0 {
                out.push(t0 + (window as f64 + 0.5) * dt, acc / n as f64);
                acc = 0.0;
                n = 0;
                window = w;
            }
            acc += p.value;
            n += 1;
        }
        if n > 0 {
            out.push(t0 + (window as f64 + 0.5) * dt, acc / n as f64);
        }
        out
    }
}

/// A bundle of series produced by one profiling run.
#[derive(Debug, Clone, Default)]
pub struct SeriesSet {
    series: Vec<Series>,
}

impl SeriesSet {
    /// Empty set.
    pub fn new() -> Self {
        SeriesSet { series: Vec::new() }
    }

    /// Add a complete series.
    pub fn add(&mut self, s: Series) {
        self.series.push(s);
    }

    /// All series.
    pub fn all(&self) -> &[Series] {
        &self.series
    }

    /// Find the first series with the given metric name.
    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Find a series by name and a required tag key/value.
    pub fn get_tagged(&self, name: &str, key: &str, value: &str) -> Option<&Series> {
        self.series
            .iter()
            .find(|s| s.name == name && s.tags.get(key).map(String::as_str) == Some(value))
    }

    /// Merge another set into this one.
    pub fn extend(&mut self, other: SeriesSet) {
        self.series.extend(other.series);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp() -> Series {
        let mut s = Series::new("ramp");
        for i in 0..=10 {
            s.push(i as f64, i as f64);
        }
        s
    }

    #[test]
    fn push_and_len() {
        let s = ramp();
        assert_eq!(s.len(), 11);
        assert!(!s.is_empty());
        assert_eq!(s.points()[3], Point { t: 3.0, value: 3.0 });
    }

    #[test]
    fn mean_and_max() {
        let s = ramp();
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn time_weighted_mean_of_step_function() {
        let mut s = Series::new("step");
        // 0 for 9 seconds, then 10 at the last instant: plain mean = 5,
        // time-weighted ≈ 0 (the 10 holds for zero duration).
        s.push(0.0, 0.0);
        s.push(9.0, 0.0);
        s.push(9.0, 10.0);
        assert!(s.time_weighted_mean() < 0.01);
    }

    #[test]
    fn integral_of_constant_power() {
        let mut s = Series::new("power_w");
        s.push(0.0, 100.0);
        s.push(60.0, 100.0);
        // 100 W for 60 s = 6000 J
        assert!((s.integral() - 6000.0).abs() < 1e-9);
    }

    #[test]
    fn integral_trapezoid() {
        let mut s = Series::new("p");
        s.push(0.0, 0.0);
        s.push(2.0, 2.0);
        assert!((s.integral() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn downsample_halves_points() {
        let s = ramp();
        let d = s.downsample(2.0);
        assert!(d.len() <= 6);
        assert!((d.mean() - 5.0).abs() < 1.0);
    }

    #[test]
    fn seriesset_lookup() {
        let mut set = SeriesSet::new();
        set.add(Series::new("gract").with_tag("gi", "1g.10gb"));
        set.add(Series::new("gract").with_tag("gi", "7g.80gb"));
        assert!(set.get("gract").is_some());
        assert!(set.get_tagged("gract", "gi", "7g.80gb").is_some());
        assert!(set.get_tagged("gract", "gi", "3g.40gb").is_none());
        assert!(set.get("nope").is_none());
    }

    #[test]
    fn out_of_order_push_saturates_instead_of_corrupting() {
        // Release builds used to store the out-of-order point as-is,
        // silently producing negative areas in the reductions.
        let mut s = Series::new("oops");
        s.push(0.0, 1.0);
        s.push(10.0, 2.0);
        s.push(5.0, 3.0); // out of order: saturated to t = 10
        assert_eq!(s.points()[2].t, 10.0);
        assert!(s.points().windows(2).all(|w| w[1].t >= w[0].t));
        assert!(s.time_weighted_mean() >= 0.0);
        assert!(s.integral() >= 0.0, "no negative areas after saturation");
        s.push(f64::NAN, 4.0); // NaN timestamps saturate too
        assert_eq!(s.points()[3].t, 10.0);
        // A NaN *first* sample saturates to the clock origin instead of
        // poisoning every later comparison (nothing is < NaN).
        let mut s = Series::new("nan-first");
        s.push(f64::NAN, 1.0);
        assert_eq!(s.points()[0].t, 0.0);
        s.push(2.0, 3.0);
        assert!(s.points().windows(2).all(|w| w[1].t >= w[0].t));
        assert!(s.integral().is_finite());
    }

    #[test]
    fn try_push_rejects_out_of_order_timestamps() {
        let mut s = Series::new("strict");
        assert!(s.try_push(1.0, 10.0).is_ok());
        assert!(s.try_push(1.0, 11.0).is_ok(), "equal timestamps are fine");
        let err = s.try_push(0.5, 12.0).unwrap_err();
        assert_eq!(err, OutOfOrder { last_t: 1.0, t: 0.5 });
        assert!(err.to_string().contains("out-of-order"), "{err}");
        assert!(s.try_push(f64::NAN, 13.0).is_err());
        assert_eq!(s.len(), 2, "rejected samples are not stored");
        assert!(s.try_push(2.0, 14.0).is_ok());
        // NaN is rejected even as the first sample.
        let mut empty = Series::new("e");
        let err = empty.try_push(f64::NAN, 1.0).unwrap_err();
        assert_eq!(err.last_t, 0.0, "empty series reports the clock origin");
        assert!(empty.is_empty());
    }

    #[test]
    fn empty_series_edge_cases() {
        let s = Series::new("e");
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.time_weighted_mean(), 0.0);
        assert_eq!(s.integral(), 0.0);
        assert_eq!(s.downsample(1.0).len(), 0);
    }
}
