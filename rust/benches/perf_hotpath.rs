//! L3 hot-path microbenchmarks (no criterion offline — first-party timing
//! harness with warmup, repetitions and ns/op reporting).
//!
//! Covers the paths the profiler and serving simulator hammer: roofline
//! pricing, DES event processing, latency-histogram recording, MPS
//! request pricing, serving simulation end-to-end, the parallel sweep
//! engine (serial vs multi-worker wall clock on the fig5/fig11-shaped
//! grids), and (when artifacts exist) real PJRT execution of the tiny
//! models. Used by the §Perf pass in EXPERIMENTS.md.
//!
//! Machine-readable output: writes `BENCH_serving.json` (into
//! `MIGPERF_BENCH_OUT` when set, else the working directory) so CI can
//! track the perf trajectory. Set `MIGPERF_PERF_SMOKE=1` to shrink
//! iteration counts for a quick CI smoke run.

// Benches are sanctioned wall-clock sites (clippy.toml disallows
// Instant::now elsewhere).
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use migperf::metrics::collector::MetricsCollector;
use migperf::mig::gpu::GpuModel;
use migperf::mig::profile::lookup as gi_lookup;
use migperf::models::cost::{infer_cost, Precision};
use migperf::models::zoo;
use migperf::sharing::mps::MpsModel;
use migperf::simgpu::desim::Des;
use migperf::simgpu::perfmodel::PerfModel;
use migperf::simgpu::resource::ExecResource;
use migperf::sweep::{self, SweepEngine};
use migperf::util::json::Json;
use migperf::util::prng::Prng;
use migperf::util::stats::LatencyHistogram;
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;

/// Collected results, flushed to BENCH_serving.json at the end.
struct Recorder {
    rows: Vec<(String, f64)>,
}

impl Recorder {
    fn push(&mut self, name: &str, ns_op: f64) {
        self.rows.push((name.to_string(), ns_op));
    }
}

/// Time `f` over `iters` iterations after `warmup` iterations; returns
/// ns/op. A black-box consume of the result prevents dead-code deletion.
fn bench<T>(
    rec: &mut Recorder,
    name: &str,
    warmup: u64,
    iters: u64,
    mut f: impl FnMut(u64) -> T,
) -> f64 {
    let mut sink = 0u64;
    for i in 0..warmup {
        sink = sink.wrapping_add(consume(&f(i)));
    }
    let start = Instant::now();
    for i in 0..iters {
        sink = sink.wrapping_add(consume(&f(i)));
    }
    let elapsed = start.elapsed().as_nanos() as f64;
    let ns_op = elapsed / iters as f64;
    println!("{name:<44} {:>12.1} ns/op   ({iters} iters, sink {sink:x})", ns_op);
    rec.push(name, ns_op);
    ns_op
}

fn consume<T>(t: &T) -> u64 {
    // Read one byte of the value so the optimizer must materialize it.
    let p = t as *const T as *const u8;
    if std::mem::size_of::<T>() == 0 {
        0
    } else {
        unsafe { std::ptr::read_volatile(p) as u64 }
    }
}

/// fig11-shaped serving grid: 4×1g.6gb MIG ResNet-50 servers over the
/// open-loop rate axis.
fn fig11_grid(requests: u64) -> Vec<ServingSim> {
    let p = gi_lookup(GpuModel::A30_24GB, "1g.6gb").unwrap();
    let resources = vec![ExecResource::from_gi(GpuModel::A30_24GB, p); 4];
    let spec = WorkloadSpec::inference(zoo::lookup("resnet50").unwrap(), 1, 224);
    [10.0, 20.0, 40.0, 80.0, 200.0, 480.0]
        .iter()
        .map(|&rate| ServingSim {
            mode: SharingMode::Mig(resources.clone()),
            load: LoadMode::OpenPoisson { rate, requests_per_server: requests },
            spec: spec.clone(),
            seed: 88,
        })
        .collect()
}

/// fig5-shaped serving grid: closed-loop MIG + MPS pairs over two models.
fn fig5_grid(requests: u64) -> Vec<ServingSim> {
    let gpu = GpuModel::A30_24GB;
    let p = gi_lookup(gpu, "2g.12gb").unwrap();
    let mut sims = Vec::new();
    for model in ["resnet18", "resnet50"] {
        let spec = WorkloadSpec::inference(zoo::lookup(model).unwrap(), 8, 224);
        sims.push(ServingSim {
            mode: SharingMode::Mig(vec![ExecResource::from_gi(gpu, p); 2]),
            load: LoadMode::Closed { requests_per_server: requests },
            spec: spec.clone(),
            seed: 55,
        });
        sims.push(ServingSim {
            mode: SharingMode::Mps {
                gpu: ExecResource::whole_gpu(gpu),
                n_clients: 2,
                model: MpsModel::default(),
            },
            load: LoadMode::Closed { requests_per_server: requests },
            spec,
            seed: 55,
        });
    }
    sims
}

/// Wall-clock seconds to run `sims` on `engine`, with a consistency probe.
fn sweep_wall(engine: &SweepEngine, sims: &[ServingSim]) -> (f64, f64) {
    let start = Instant::now();
    let outs = sweep::run_serving(engine, sims).expect("sweep grid");
    let wall = start.elapsed().as_secs_f64();
    // Checksum over pooled p99s: any nondeterminism across engines shows
    // up as a checksum mismatch in the emitted JSON.
    let checksum: f64 = outs.iter().map(|o| o.pooled.p99_latency_ms).sum();
    (wall, checksum)
}

fn main() {
    let smoke = std::env::var_os("MIGPERF_PERF_SMOKE").is_some();
    let scale = |n: u64| if smoke { (n / 50).max(1) } else { n };
    println!(
        "== perf_hotpath: L3 microbenchmarks{} ==\n",
        if smoke { " (smoke mode)" } else { "" }
    );
    let mut rec = Recorder { rows: Vec::new() };
    let pm = PerfModel::default();
    let m = zoo::lookup("bert-base").unwrap();
    let res = ExecResource::from_gi(
        GpuModel::A100_80GB,
        gi_lookup(GpuModel::A100_80GB, "2g.20gb").unwrap(),
    );
    let cost = infer_cost(m, 8, 128, Precision::Half);

    bench(&mut rec, "roofline step pricing", 1_000, scale(1_000_000), |_| {
        pm.step(&res, &cost).unwrap()
    });

    bench(&mut rec, "analytic cost construction", 1_000, scale(1_000_000), |i| {
        infer_cost(m, 1 + (i % 64) as u32, 128, Precision::Half)
    });

    let mut hist = LatencyHistogram::for_latency_ms();
    let mut rng = Prng::new(1);
    // Pre-generate samples so the PRNG's transcendental calls don't mask
    // the histogram cost being measured.
    let samples: Vec<f64> = (0..65536).map(|_| rng.lognormal(1.0, 0.5)).collect();
    bench(&mut rec, "latency histogram record", 10_000, scale(5_000_000), |i| {
        hist.record(samples[(i & 0xffff) as usize]);
    });
    bench(&mut rec, "latency histogram p99", 100, scale(200_000), |_| hist.percentile(99.0));

    let mps = MpsModel::default();
    let whole = ExecResource::whole_gpu(GpuModel::A30_24GB);
    let isolated = pm.step(&whole, &cost).unwrap();
    let mut rng2 = Prng::new(2);
    bench(&mut rec, "MPS request pricing (stochastic)", 10_000, scale(2_000_000), |_| {
        mps.request_time(&isolated, &cost, &whole, 3, &mut rng2)
    });

    bench(&mut rec, "DES schedule+pop", 1_000, scale(200_000), |i| {
        let mut des: Des<u32> = Des::new();
        for k in 0..16u32 {
            des.schedule_at((i % 97) as f64 + k as f64, k);
        }
        let mut last = 0;
        while let Some((_, e)) = des.next() {
            last = e;
        }
        last
    });

    bench(&mut rec, "metrics collector record+summarize/1k", 10, scale(2_000), |i| {
        let mut c = MetricsCollector::new("bench");
        for k in 0..1000u64 {
            c.record_completion((i + k) as f64 * 1e-3, 5.0, 1);
        }
        c.summarize().completed
    });

    // End-to-end serving sims (the figure benches' inner loop).
    let spec = WorkloadSpec::inference(zoo::lookup("resnet50").unwrap(), 8, 224);
    let p = gi_lookup(GpuModel::A30_24GB, "1g.6gb").unwrap();
    bench(&mut rec, "serving sim MIG 4×500 reqs", 2, scale(50), |i| {
        ServingSim {
            mode: SharingMode::Mig(vec![
                ExecResource::from_gi(GpuModel::A30_24GB, p);
                4
            ]),
            load: LoadMode::Closed { requests_per_server: 500 },
            spec: spec.clone(),
            seed: i,
        }
        .run()
        .unwrap()
        .pooled
        .completed
    });
    bench(&mut rec, "serving sim MPS 4×500 reqs", 2, scale(50), |i| {
        ServingSim {
            mode: SharingMode::Mps {
                gpu: ExecResource::whole_gpu(GpuModel::A30_24GB),
                n_clients: 4,
                model: MpsModel::default(),
            },
            load: LoadMode::Closed { requests_per_server: 500 },
            spec: spec.clone(),
            seed: i,
        }
        .run()
        .unwrap()
        .pooled
        .completed
    });

    // Replay-mode heap pressure: one long trace streamed lazily per
    // server (the event heap stays O(servers), not O(total requests)).
    {
        use migperf::workload::arrival::PoissonArrival;
        use migperf::workload::trace::Trace;
        let reqs = if smoke { 2_000 } else { 50_000 };
        let trace = Trace::capture(&mut PoissonArrival::new(200.0, 7), reqs);
        let p_small = gi_lookup(GpuModel::A30_24GB, "1g.6gb").unwrap();
        let spec1 = WorkloadSpec::inference(zoo::lookup("resnet50").unwrap(), 1, 224);
        bench(&mut rec, &format!("serving sim replay 4×{reqs} reqs"), 1, scale(10).min(5), |_| {
            ServingSim {
                mode: SharingMode::Mig(vec![
                    ExecResource::from_gi(GpuModel::A30_24GB, p_small);
                    4
                ]),
                load: LoadMode::Replay { traces: vec![trace.clone()] },
                spec: spec1.clone(),
                seed: 3,
            }
            .run()
            .unwrap()
            .pooled
            .completed
        });
    }

    // Sweep-engine throughput: the figure-bench grids, serial vs parallel.
    let requests = if smoke { 200 } else { 1_500 };
    let fig11 = fig11_grid(requests);
    let fig5 = fig5_grid(if smoke { 400 } else { 4_000 });
    let serial = SweepEngine::serial();
    let parallel = SweepEngine::from_env();
    println!();
    let (fig11_serial_s, ck_a) = sweep_wall(&serial, &fig11);
    let (fig11_parallel_s, ck_b) = sweep_wall(&parallel, &fig11);
    assert_eq!(ck_a, ck_b, "sweep results must be identical at any worker count");
    let (fig5_serial_s, ck_c) = sweep_wall(&serial, &fig5);
    let (fig5_parallel_s, ck_d) = sweep_wall(&parallel, &fig5);
    assert_eq!(ck_c, ck_d, "sweep results must be identical at any worker count");
    let fig11_speedup = fig11_serial_s / fig11_parallel_s.max(1e-12);
    let fig5_speedup = fig5_serial_s / fig5_parallel_s.max(1e-12);
    println!(
        "sweep fig11 grid ({} pts): serial {:.3}s, {} workers {:.3}s ({:.2}× speedup)",
        fig11.len(),
        fig11_serial_s,
        parallel.workers(),
        fig11_parallel_s,
        fig11_speedup
    );
    println!(
        "sweep fig5 grid ({} pts): serial {:.3}s, {} workers {:.3}s ({:.2}× speedup)",
        fig5.len(),
        fig5_serial_s,
        parallel.workers(),
        fig5_parallel_s,
        fig5_speedup
    );

    // Real PJRT execution, if artifacts are built.
    if migperf::runtime::artifacts_available() {
        use migperf::runtime::executor::{Engine, HostTensor};
        use migperf::runtime::Manifest;
        let manifest = Manifest::load(migperf::runtime::artifacts_dir()).unwrap();
        let e = manifest.entry("bert_tiny_infer_b4").unwrap();
        match Engine::cpu() {
            Ok(mut engine) => {
                engine.load_hlo_text(&e.name, &manifest.hlo_path(e)).unwrap();
                let seq = e.inputs[0].shape[1];
                let mut rng3 = Prng::new(3);
                let tokens: Vec<i32> = (0..4 * seq).map(|_| rng3.below(512) as i32).collect();
                let input = HostTensor::I32(tokens, vec![4, seq]);
                bench(&mut rec, "PJRT real exec bert_tiny_infer_b4", 3, scale(100), |_| {
                    engine.execute(&e.name, std::slice::from_ref(&input)).unwrap().outputs.len()
                });
            }
            Err(e) => println!("(PJRT bench skipped: {e})"),
        }
    } else {
        println!("(PJRT bench skipped: run `make artifacts` first)");
    }

    // Machine-readable perf record.
    let doc = Json::obj(vec![
        ("schema", Json::Str("migperf-bench-serving/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("workers", Json::Num(parallel.workers() as f64)),
        (
            "benches",
            Json::Arr(
                rec.rows
                    .iter()
                    .map(|(name, ns)| {
                        Json::obj(vec![
                            ("name", Json::Str(name.clone())),
                            ("ns_per_op", Json::Num(*ns)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "sweep",
            Json::obj(vec![
                ("fig11_grid_points", Json::Num(fig11.len() as f64)),
                ("fig11_serial_s", Json::Num(fig11_serial_s)),
                ("fig11_parallel_s", Json::Num(fig11_parallel_s)),
                ("fig11_speedup", Json::Num(fig11_speedup)),
                ("fig5_grid_points", Json::Num(fig5.len() as f64)),
                ("fig5_serial_s", Json::Num(fig5_serial_s)),
                ("fig5_parallel_s", Json::Num(fig5_parallel_s)),
                ("fig5_speedup", Json::Num(fig5_speedup)),
            ]),
        ),
    ]);
    let out_dir = std::env::var_os("MIGPERF_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let _ = std::fs::create_dir_all(&out_dir);
    let out_path = out_dir.join("BENCH_serving.json");
    match std::fs::write(&out_path, doc.to_pretty()) {
        Ok(()) => println!("\nperf record written to {}", out_path.display()),
        Err(e) => println!("\n(could not write {}: {e})", out_path.display()),
    }
    println!("done.");
}
