//! Multi-tenant grouping of fleet request classes.
//!
//! MISO (Li et al., 2022) observes that multi-tenant MIG systems need
//! explicit per-tenant resource weighting, and Tan et al. (2021) frame
//! MIG serving as reconfigurable machine scheduling where the *router*
//! is the fairness lever. A [`Tenant`] groups one or more fleet request
//! classes under a name and an SLO weight. The weight drives three
//! things:
//!
//! * the [`WeightedFair`](super::router::WeightedFair) router's
//!   deficit-round-robin ingress credit, so tenant throughput shares
//!   track weights;
//! * the tenant-weighted fleet demand split
//!   ([`crate::scheduler::tenant_scaled_demand`]): capacity is
//!   provisioned per tenant weight, not per offered load;
//! * per-tenant accounting in
//!   [`FleetOutcome`](super::engine::FleetOutcome), summarized by Jain's
//!   fairness index over weight-normalized goodput ([`jain_index`]).
//!
//! Tenancy is plain config data (clone freely into sweep grids) and
//! strictly additive: a config that declares no tenants behaves exactly
//! as before — the engine synthesizes one tenant per class
//! ([`Tenant::per_class`]) for accounting only, and both the demand
//! split and the reactive policy's replanning stay capacity-based.

/// One tenant: a named group of request classes with an SLO weight.
#[derive(Debug, Clone, PartialEq)]
pub struct Tenant {
    /// Report name ("gold", "bronze", ...).
    pub name: String,
    /// SLO weight: the tenant's relative claim on fleet capacity.
    /// Must be positive and finite.
    pub weight: f64,
    /// Indices of the request classes this tenant owns. Every class of
    /// the fleet must belong to exactly one tenant.
    pub classes: Vec<usize>,
}

impl Tenant {
    /// Construct a tenant.
    pub fn new(name: impl Into<String>, weight: f64, classes: Vec<usize>) -> Tenant {
        Tenant { name: name.into(), weight, classes }
    }

    /// The implicit default tenancy: one tenant per class (`t0`, `t1`,
    /// ...), each with weight 1. This is what the engine synthesizes for
    /// accounting when the config declares no tenants.
    pub fn per_class(n_classes: usize) -> Vec<Tenant> {
        (0..n_classes).map(|c| Tenant::new(format!("t{c}"), 1.0, vec![c])).collect()
    }
}

/// Reject tenant sets the engine cannot account for: empty sets, empty
/// or duplicate names, non-positive/non-finite weights, tenants with no
/// classes, out-of-range classes, and classes owned by zero or more
/// than one tenant (the partition must be exact for per-tenant
/// conservation to mean anything).
pub fn validate_tenants(tenants: &[Tenant], n_classes: usize) -> Result<(), String> {
    if tenants.is_empty() {
        return Err("at least one tenant is required".into());
    }
    let mut owner: Vec<Option<usize>> = vec![None; n_classes];
    for (ti, t) in tenants.iter().enumerate() {
        if t.name.is_empty() {
            return Err(format!("tenant {ti}: name must be non-empty"));
        }
        if tenants[..ti].iter().any(|o| o.name == t.name) {
            return Err(format!("tenant name '{}' appears twice", t.name));
        }
        if !(t.weight.is_finite() && t.weight > 0.0) {
            return Err(format!(
                "tenant '{}': weight {} must be positive and finite",
                t.name, t.weight
            ));
        }
        if t.classes.is_empty() {
            return Err(format!("tenant '{}': must own at least one class", t.name));
        }
        for &c in &t.classes {
            if c >= n_classes {
                return Err(format!(
                    "tenant '{}': class {c} out of range ({n_classes} classes)",
                    t.name
                ));
            }
            if let Some(prev) = owner[c] {
                return Err(format!(
                    "class {c} assigned to both '{}' and '{}'",
                    tenants[prev].name, t.name
                ));
            }
            owner[c] = Some(ti);
        }
    }
    for (c, o) in owner.iter().enumerate() {
        if o.is_none() {
            return Err(format!("class {c} belongs to no tenant (every class must be assigned)"));
        }
    }
    Ok(())
}

/// Class → tenant index map (length `n_classes`; unmapped classes, which
/// a validated set cannot produce, are `usize::MAX`).
pub fn tenant_of_classes(tenants: &[Tenant], n_classes: usize) -> Vec<usize> {
    let mut map = vec![usize::MAX; n_classes];
    for (ti, t) in tenants.iter().enumerate() {
        for &c in &t.classes {
            if c < n_classes {
                map[c] = ti;
            }
        }
    }
    map
}

/// Parse a `--tenants` spec: `NAME:WEIGHT:CLASS[,CLASS...]` entries
/// joined by `;` (quote the whole value in a shell), e.g.
/// `gold:3:0;bronze:1:1` or `batch:1:2,3`.
pub fn parse_tenants(spec: &str) -> Result<Vec<Tenant>, String> {
    let mut out = Vec::new();
    for raw in spec.split(';').filter(|s| !s.trim().is_empty()) {
        let item = raw.trim();
        let err = || format!("tenant '{item}': expected NAME:WEIGHT:CLASS[,CLASS...]");
        let mut parts = item.splitn(3, ':');
        let name = parts.next().filter(|s| !s.is_empty()).ok_or_else(err)?;
        let weight: f64 = parts.next().ok_or_else(err)?.parse().map_err(|_| err())?;
        let classes_s = parts.next().ok_or_else(err)?;
        let mut classes = Vec::new();
        for c in classes_s.split(',').filter(|s| !s.is_empty()) {
            classes.push(c.trim().parse::<usize>().map_err(|_| err())?);
        }
        if classes.is_empty() {
            return Err(err());
        }
        out.push(Tenant::new(name, weight, classes));
    }
    if out.is_empty() {
        return Err("--tenants needs at least one NAME:WEIGHT:CLASS entry".into());
    }
    Ok(out)
}

/// Jain's fairness index over an allocation vector:
/// `(Σx)² / (n · Σx²)`, in `[1/n, 1]`; 1 means perfectly fair. Empty or
/// all-zero allocations are vacuously fair (1.0). Fed with
/// weight-normalized tenant goodputs (`goodput_t / weight_t`) it
/// measures how well throughput shares track SLO weights.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    (s * s) / (xs.len() as f64 * s2)
}

/// Per-tenant slice of a fleet run's accounting, reported in
/// [`FleetOutcome`](super::engine::FleetOutcome) (tenant order).
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    /// Tenant name.
    pub name: String,
    /// SLO weight the run used.
    pub weight: f64,
    /// Classes the tenant owned, in class order.
    pub classes: Vec<usize>,
    /// Requests of this tenant's classes that arrived within the horizon.
    pub arrived: u64,
    /// Requests completed (including backlog served after the horizon).
    pub completed: u64,
    /// Completions that blew their SLO.
    pub slo_violations: u64,
    /// Requests that terminally failed (storm shed or stranded at end).
    pub failed: u64,
    /// Requests dumped by a crash with their retry budget exhausted.
    pub lost_in_crash: u64,
    /// Crash-dumped requests re-admitted at the ingress.
    pub retried: u64,
    /// Requests shed at dispatch because their deadline had expired.
    pub shed_deadline: u64,
    /// Requests shed by the bounded-queue discipline.
    pub shed_capacity: u64,
    /// Requests shed at the ingress while this tenant was browned out
    /// (lowest-weight tenants shed first under fleet-wide pressure).
    pub shed_brownout: u64,
    /// SLO-respecting completions per second over the run.
    pub goodput_rps: f64,
    /// Fraction of completions that blew their SLO.
    pub slo_violation_frac: f64,
    /// Weight-normalized goodput (`goodput_rps / weight`): the quantity
    /// Jain's index is computed over.
    pub norm_goodput_rps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gold_bronze() -> Vec<Tenant> {
        vec![Tenant::new("gold", 3.0, vec![0]), Tenant::new("bronze", 1.0, vec![1])]
    }

    #[test]
    fn per_class_default_covers_every_class_with_weight_one() {
        let ts = Tenant::per_class(3);
        assert_eq!(ts.len(), 3);
        validate_tenants(&ts, 3).unwrap();
        for (c, t) in ts.iter().enumerate() {
            assert_eq!(t.classes, vec![c]);
            assert_eq!(t.weight, 1.0);
            assert_eq!(t.name, format!("t{c}"));
        }
        assert_eq!(tenant_of_classes(&ts, 3), vec![0, 1, 2]);
    }

    #[test]
    fn validate_accepts_an_exact_partition() {
        validate_tenants(&gold_bronze(), 2).unwrap();
        let multi = vec![
            Tenant::new("gold", 2.5, vec![0, 2]),
            Tenant::new("bronze", 0.5, vec![1]),
        ];
        validate_tenants(&multi, 3).unwrap();
        assert_eq!(tenant_of_classes(&multi, 3), vec![0, 1, 0]);
    }

    #[test]
    fn validate_rejects_degenerate_sets() {
        assert!(validate_tenants(&[], 2).is_err(), "empty set");
        let t = |w: f64, cs: Vec<usize>| vec![Tenant::new("a", w, cs)];
        assert!(validate_tenants(&t(0.0, vec![0]), 1).is_err(), "zero weight");
        assert!(validate_tenants(&t(-1.0, vec![0]), 1).is_err(), "negative weight");
        assert!(validate_tenants(&t(f64::NAN, vec![0]), 1).is_err(), "NaN weight");
        assert!(validate_tenants(&t(f64::INFINITY, vec![0]), 1).is_err(), "inf weight");
        assert!(validate_tenants(&t(1.0, vec![]), 1).is_err(), "no classes");
        assert!(validate_tenants(&t(1.0, vec![1]), 1).is_err(), "class out of range");
        assert!(
            validate_tenants(&[Tenant::new("", 1.0, vec![0])], 1).is_err(),
            "empty name"
        );
        let dup_name = vec![Tenant::new("a", 1.0, vec![0]), Tenant::new("a", 1.0, vec![1])];
        assert!(validate_tenants(&dup_name, 2).is_err(), "duplicate name");
        let dup_class = vec![Tenant::new("a", 1.0, vec![0]), Tenant::new("b", 1.0, vec![0])];
        assert!(validate_tenants(&dup_class, 2).is_err(), "class owned twice");
        let uncovered = vec![Tenant::new("a", 1.0, vec![0])];
        assert!(validate_tenants(&uncovered, 2).is_err(), "class 1 unowned");
    }

    #[test]
    fn parse_round_trips_the_cli_format() {
        let ts = parse_tenants("gold:3:0;bronze:1:1").unwrap();
        assert_eq!(ts, gold_bronze());
        let ts = parse_tenants("a:2.5:0,2; b:0.5:1").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].classes, vec![0, 2]);
        assert_eq!(ts[0].weight, 2.5);
        assert_eq!(ts[1].name, "b");
        validate_tenants(&ts, 3).unwrap();
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(parse_tenants("").is_err());
        assert!(parse_tenants(";;").is_err());
        assert!(parse_tenants("gold").is_err(), "missing weight and classes");
        assert!(parse_tenants("gold:3").is_err(), "missing classes");
        assert!(parse_tenants("gold:3:").is_err(), "empty class list");
        assert!(parse_tenants(":3:0").is_err(), "empty name");
        assert!(parse_tenants("gold:x:0").is_err(), "bad weight");
        assert!(parse_tenants("gold:3:x").is_err(), "bad class");
    }

    #[test]
    fn jain_index_behaves() {
        assert_eq!(jain_index(&[]), 1.0, "empty allocation is vacuously fair");
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0, "all-zero allocation is vacuously fair");
        assert_eq!(jain_index(&[5.0, 5.0, 5.0]), 1.0, "equal shares are perfectly fair");
        let one_hot = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((one_hot - 0.25).abs() < 1e-12, "one-hot over n is 1/n, got {one_hot}");
        let skewed = jain_index(&[3.0, 1.0]);
        assert!((skewed - 0.8).abs() < 1e-12, "3:1 over two is 0.8, got {skewed}");
        // Scale invariance.
        assert_eq!(
            jain_index(&[3.0, 1.0]).to_bits(),
            jain_index(&[30.0, 10.0]).to_bits()
        );
    }
}
