//! End-to-end training driver: the real three-layer stack on a real
//! workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_training -- --steps 300
//! ```
//!
//! Loads the AOT-lowered tiny-BERT *training step* (fwd + bwd + SGD, with
//! the Pallas attention/linear kernels on the forward path), and drives a
//! few hundred optimizer steps from rust over a synthetic copy-task
//! corpus. Logs the loss curve, proving L1→L2→L3 compose; then calibrates
//! the simulator from the measured step time and reports what the same
//! step would cost on each A100 GPU instance. Results are recorded in
//! EXPERIMENTS.md §E2E.

use migperf::mig::gpu::GpuModel;
use migperf::mig::profile::profiles_for;
use migperf::models::cost::{train_cost, Precision};
use migperf::models::zoo;
use migperf::runtime::executor::{load_params, Engine, HostTensor};
use migperf::runtime::manifest::Manifest;
use migperf::runtime::{artifacts_available, artifacts_dir};
use migperf::simgpu::calibrate::Calibration;
use migperf::simgpu::perfmodel::PerfModel;
use migperf::simgpu::resource::ExecResource;
use migperf::util::argparse::Args;
use migperf::util::prng::Prng;
use migperf::util::table::{fmt_num, sparkline, Table};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let steps: u64 = args.parse_or("steps", 300u64)?;
    let log_every: u64 = args.parse_or("log-every", 20u64)?;

    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let manifest = Manifest::load(artifacts_dir())?;
    let entry = manifest.entry("bert_tiny_train_b8").expect("train entry in manifest");

    let mut engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    engine.load_hlo_text(&entry.name, &manifest.hlo_path(entry))?;
    let mut params = load_params(&manifest, entry)?;
    println!(
        "loaded {} parameter tensors ({} floats) + compiled {}",
        params.len(),
        params.iter().map(HostTensor::elements).sum::<usize>(),
        entry.hlo_file,
    );

    let batch = entry.inputs[entry.num_param_inputs].shape[0];
    let seq = entry.inputs[entry.num_param_inputs].shape[1];
    let vocab = 512u64;
    let mut rng = Prng::new(0x5eed);

    // Training loop: fresh synthetic batch each step (copy task: target =
    // tokens shifted right by one, matching model.synthetic_batch).
    let mut losses: Vec<f32> = Vec::new();
    let mut total_exec_s = 0.0;
    for step in 0..steps {
        let tokens: Vec<i32> =
            (0..batch * seq).map(|_| rng.below(vocab) as i32).collect();
        let mut targets = Vec::with_capacity(tokens.len());
        for row in tokens.chunks(seq as usize) {
            targets.push(row[seq as usize - 1]);
            targets.extend_from_slice(&row[..seq as usize - 1]);
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::I32(tokens, vec![batch, seq]));
        inputs.push(HostTensor::I32(targets, vec![batch, seq]));
        let out = engine.execute(&entry.name, &inputs)?;
        total_exec_s += out.wall_s;
        let loss = out.outputs[0].as_f32().expect("scalar loss")[0];
        losses.push(loss);
        params = out.outputs[1..].to_vec();
        if step % log_every == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }

    let first = losses[0];
    let last = *losses.last().unwrap();
    println!("\nloss curve: {}", sparkline(&losses.iter().map(|&x| x as f64).collect::<Vec<_>>()));
    println!("loss {first:.3} → {last:.3} over {steps} steps ({} samples)", steps * batch as u64);
    assert!(last < first, "training must reduce loss");

    // Calibration: anchor the simulator on the measured per-step cost.
    let per_step_s = total_exec_s / steps as f64;
    let cal = Calibration::from_measurement(&entry.name, entry.flops, per_step_s);
    println!(
        "\nmeasured {:.2} ms/step on PJRT-CPU → {:.2} GFLOP/s effective",
        per_step_s * 1e3,
        cal.cpu_eff_flops / 1e9
    );

    // What would the paper-scale BERT-base training step cost per GI?
    let pm = PerfModel::default();
    let m = zoo::lookup("bert-base").unwrap();
    let cost = train_cost(m, 32, 128, Precision::Half);
    let mut t = Table::new(&["A100 GI", "step_ms", "throughput seq/s", "gract"]);
    for p in profiles_for(GpuModel::A100_80GB) {
        let res = ExecResource::from_gi(GpuModel::A100_80GB, p);
        if let Some(est) = cal.predict_on(&pm, &res, &cost) {
            t.row(&[
                p.name.to_string(),
                fmt_num(est.seconds * 1e3),
                fmt_num(32.0 / est.seconds),
                fmt_num(est.gract),
            ]);
        }
    }
    println!("\nsimulated BERT-base (batch 32, seq 128) training step per A100 GI:\n{}", t.render());
    Ok(())
}
