//! ASCII line charts for the terminal visualizer.
//!
//! The paper's visualizer component renders benchmark series for quick
//! analysis (§3.2). Sparklines (`util::table::sparkline`) cover inline
//! use; this module draws full charts with axes and multiple labelled
//! series so `cargo bench` output approximates the paper's figures
//! without plotting tools.

use std::fmt::Write as _;

/// One labelled series of (x, y) points.
#[derive(Debug, Clone)]
pub struct PlotSeries {
    /// Legend label.
    pub label: String,
    /// Data points (x need not be uniform).
    pub points: Vec<(f64, f64)>,
}

/// Glyphs assigned to series, in order.
const GLYPHS: [char; 8] = ['●', '▲', '■', '◆', '○', '△', '□', '◇'];

/// Render a chart of the given pixel-grid size (columns × rows of text).
///
/// Y is linearly scaled between the data extremes; X likewise. Axis
/// labels show the extremes. Overlapping series draw in order, later
/// series on top.
pub fn render(series: &[PlotSeries], width: usize, height: usize) -> String {
    assert!(width >= 16 && height >= 4, "chart too small");
    let pts: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if pts.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in &s.points {
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = glyph;
        }
    }
    let mut out = String::new();
    let ylab = |v: f64| format!("{v:>9.3}");
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            ylab(y1)
        } else if r == height - 1 {
            ylab(y0)
        } else {
            " ".repeat(9)
        };
        let line: String = row.iter().collect();
        let _ = writeln!(out, "{label} │{line}");
    }
    let _ = writeln!(out, "{} └{}", " ".repeat(9), "─".repeat(width));
    let xlab_l = format!("{x0:.2}");
    let xlab_r = format!("{x1:.2}");
    let pad = width.saturating_sub(xlab_l.len() + xlab_r.len());
    let _ = writeln!(out, "{}  {}{}{}", " ".repeat(9), xlab_l, " ".repeat(pad), xlab_r);
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "{}  {} {}", " ".repeat(9), GLYPHS[si % GLYPHS.len()], s.label);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(label: &str, f: impl Fn(f64) -> f64) -> PlotSeries {
        PlotSeries {
            label: label.into(),
            points: (0..10).map(|i| (i as f64, f(i as f64))).collect(),
        }
    }

    #[test]
    fn renders_axes_and_legend() {
        let out = render(&[line("up", |x| x), line("down", |x| 9.0 - x)], 40, 10);
        assert!(out.contains('│'));
        assert!(out.contains('└'));
        assert!(out.contains("● up"));
        assert!(out.contains("▲ down"));
        // Extremes labelled.
        assert!(out.contains("9.000"));
        assert!(out.contains("0.000"));
    }

    #[test]
    fn monotone_series_hits_corners() {
        let out = render(&[line("up", |x| x)], 40, 8);
        let rows: Vec<&str> = out.lines().collect();
        // Top row holds the max point (right side), bottom data row the min.
        assert!(rows[0].trim_end().ends_with('●'));
        assert!(rows[7].contains('●'));
    }

    #[test]
    fn constant_series_does_not_panic() {
        let s = PlotSeries { label: "flat".into(), points: vec![(0.0, 5.0), (1.0, 5.0)] };
        let out = render(&[s], 20, 5);
        assert!(out.contains('●'));
    }

    #[test]
    fn empty_series_safe() {
        assert_eq!(render(&[], 20, 5), "(no data)\n");
    }

    #[test]
    #[should_panic(expected = "chart too small")]
    fn rejects_tiny_canvas() {
        let _ = render(&[], 4, 2);
    }
}
