//! MIG placement rule engine.
//!
//! Validates whether a set of GPU instances can coexist on one physical
//! GPU under NVIDIA's hard-coded rules:
//!
//! 1. each GI sits at one of its profile's published placement offsets;
//! 2. memory-slice intervals of live GIs are pairwise disjoint;
//! 3. total compute slices never exceed the device's compute slices;
//! 4. profile-pair exclusions hold (e.g. A100 forbids 4g.40gb + 3g.40gb).
//!
//! The engine answers both "is this layout valid" and "where can profile X
//! still go", which is what the controller uses for auto-placement.

use super::gpu::GpuModel;
use super::profile::{exclusions_for, GiProfile};

/// A placed GPU instance: a profile at a concrete memory-slice offset.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    /// Profile being placed.
    pub profile: &'static GiProfile,
    /// Start offset in memory slices.
    pub start: u32,
}

impl Placement {
    /// Memory-slice interval `[start, end)` occupied.
    pub fn interval(&self) -> (u32, u32) {
        (self.start, self.start + self.profile.memory_slices)
    }

    /// True if two placements overlap in the memory-slice map.
    pub fn overlaps(&self, other: &Placement) -> bool {
        let (a0, a1) = self.interval();
        let (b0, b1) = other.interval();
        a0 < b1 && b0 < a1
    }
}

/// Why a placement or layout was rejected.
#[derive(Debug, Clone, PartialEq)]
pub enum PlacementError {
    /// Offset not in the profile's published placement list.
    InvalidOffset {
        /// Profile name.
        profile: String,
        /// Requested offset.
        start: u32,
    },
    /// Memory-slice interval collides with an existing GI.
    MemoryOverlap {
        /// Requested interval start.
        start: u32,
        /// Requested interval end (exclusive).
        end: u32,
    },
    /// Device compute-slice budget exhausted.
    ComputeExhausted {
        /// Slices required by the new GI.
        need: u32,
        /// Slices remaining.
        avail: u32,
    },
    /// NVIDIA forbids this profile combination outright.
    ExcludedCombination {
        /// First profile.
        a: String,
        /// Second profile.
        b: String,
    },
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementError::InvalidOffset { profile, start } => {
                write!(f, "profile {profile} cannot be placed at memory-slice {start}")
            }
            PlacementError::MemoryOverlap { start, end } => {
                write!(f, "memory slices [{start}, {end}) already occupied")
            }
            PlacementError::ComputeExhausted { need, avail } => {
                write!(f, "compute slices exhausted: need {need}, only {avail} free")
            }
            PlacementError::ExcludedCombination { a, b } => {
                write!(f, "profiles {a} and {b} cannot coexist (NVIDIA hard-coded rule)")
            }
        }
    }
}

impl std::error::Error for PlacementError {}

/// Placement validator bound to one GPU model.
#[derive(Debug, Clone)]
pub struct PlacementEngine {
    model: GpuModel,
}

impl PlacementEngine {
    /// Engine for a GPU model.
    pub fn new(model: GpuModel) -> Self {
        PlacementEngine { model }
    }

    /// The GPU model this engine validates against.
    pub fn model(&self) -> GpuModel {
        self.model
    }

    /// Check whether `candidate` can join `existing` on this GPU.
    pub fn check(
        &self,
        existing: &[Placement],
        candidate: &Placement,
    ) -> Result<(), PlacementError> {
        let p = candidate.profile;
        if !p.placements.contains(&candidate.start) {
            return Err(PlacementError::InvalidOffset {
                profile: p.name.to_string(),
                start: candidate.start,
            });
        }
        for e in existing {
            if e.overlaps(candidate) {
                let (s, t) = candidate.interval();
                return Err(PlacementError::MemoryOverlap { start: s, end: t });
            }
        }
        let used: u32 = existing.iter().map(|e| e.profile.compute_slices).sum();
        let avail = self.model.spec().compute_slices.saturating_sub(used);
        if p.compute_slices > avail {
            return Err(PlacementError::ComputeExhausted { need: p.compute_slices, avail });
        }
        for (a, b) in exclusions_for(self.model) {
            let names: Vec<&str> = existing.iter().map(|e| e.profile.name).collect();
            if (p.name == *a && names.contains(b)) || (p.name == *b && names.contains(a)) {
                return Err(PlacementError::ExcludedCombination {
                    a: a.to_string(),
                    b: b.to_string(),
                });
            }
        }
        Ok(())
    }

    /// Validate an entire layout from scratch (order-independent).
    pub fn check_layout(&self, layout: &[Placement]) -> Result<(), PlacementError> {
        let mut placed: Vec<Placement> = Vec::new();
        for c in layout {
            self.check(&placed, c)?;
            placed.push(c.clone());
        }
        Ok(())
    }

    /// First valid offset where `profile` fits alongside `existing`, if any.
    pub fn find_slot(
        &self,
        existing: &[Placement],
        profile: &'static GiProfile,
    ) -> Option<u32> {
        profile
            .placements
            .iter()
            .copied()
            .find(|&start| self.check(existing, &Placement { profile, start }).is_ok())
    }

    /// All profiles (by reference) that can still be placed given `existing`.
    pub fn available_profiles(&self, existing: &[Placement]) -> Vec<&'static GiProfile> {
        super::profile::profiles_for(self.model)
            .iter()
            .filter(|p| self.find_slot(existing, p).is_some())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::lookup;

    fn prof(name: &str) -> &'static GiProfile {
        lookup(GpuModel::A100_80GB, name).unwrap()
    }
    fn prof30(name: &str) -> &'static GiProfile {
        lookup(GpuModel::A30_24GB, name).unwrap()
    }

    #[test]
    fn seven_small_instances_fit_a100() {
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let mut layout = Vec::new();
        for start in 0..7 {
            let c = Placement { profile: prof("1g.10gb"), start };
            eng.check(&layout, &c).unwrap();
            layout.push(c);
        }
        // Slot 7 exists in memory but 1g.10gb only publishes placements 0–6.
        assert!(eng.find_slot(&layout, prof("1g.10gb")).is_none());
    }

    #[test]
    fn paper_rule_no_4g_plus_3g() {
        // Paper §1: "users can not have both 4/7 and 3/7 GIs simultaneously".
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let four = Placement { profile: prof("4g.40gb"), start: 0 };
        eng.check(&[], &four).unwrap();
        let three = Placement { profile: prof("3g.40gb"), start: 4 };
        let err = eng.check(std::slice::from_ref(&four), &three);
        assert!(
            matches!(err, Err(PlacementError::ExcludedCombination { .. })),
            "expected exclusion, got {err:?}"
        );
    }

    #[test]
    fn paper_mixed_layout_4_2_1() {
        // Paper §1: "users are able to set up three 4/7, 2/7, and 1/7 GIs".
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let layout = vec![
            Placement { profile: prof("4g.40gb"), start: 0 },
            Placement { profile: prof("2g.20gb"), start: 4 },
            Placement { profile: prof("1g.10gb"), start: 6 },
        ];
        eng.check_layout(&layout).unwrap();
    }

    #[test]
    fn memory_overlap_rejected() {
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let a = Placement { profile: prof("2g.20gb"), start: 0 };
        let b = Placement { profile: prof("1g.10gb"), start: 1 };
        assert!(matches!(
            eng.check(&[a], &b),
            Err(PlacementError::MemoryOverlap { start: 1, end: 2 })
        ));
    }

    #[test]
    fn invalid_offset_rejected() {
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let c = Placement { profile: prof("3g.40gb"), start: 2 };
        assert!(matches!(eng.check(&[], &c), Err(PlacementError::InvalidOffset { .. })));
    }

    #[test]
    fn compute_exhaustion() {
        // 7g owns all compute; nothing else fits even though the memory map
        // check happens first for overlapping offsets.
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let seven = Placement { profile: prof("7g.80gb"), start: 0 };
        assert!(eng.available_profiles(&[seven]).is_empty());
    }

    #[test]
    fn two_3g_instances_allowed() {
        // 3g+3g is a supported combination (placements 0 and 4).
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let layout = vec![
            Placement { profile: prof("3g.40gb"), start: 0 },
            Placement { profile: prof("3g.40gb"), start: 4 },
        ];
        eng.check_layout(&layout).unwrap();
    }

    #[test]
    fn a30_four_small() {
        let eng = PlacementEngine::new(GpuModel::A30_24GB);
        let mut layout = Vec::new();
        for start in 0..4 {
            let c = Placement { profile: prof30("1g.6gb"), start };
            eng.check(&layout, &c).unwrap();
            layout.push(c);
        }
        assert!(eng.available_profiles(&layout).is_empty());
    }

    #[test]
    fn find_slot_skips_occupied() {
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        let existing = vec![Placement { profile: prof("2g.20gb"), start: 0 }];
        assert_eq!(eng.find_slot(&existing, prof("2g.20gb")), Some(2));
    }

    #[test]
    fn available_profiles_on_empty_gpu_is_full_table() {
        let eng = PlacementEngine::new(GpuModel::A100_80GB);
        assert_eq!(eng.available_profiles(&[]).len(), 6);
    }
}
