//! Discrete-event simulator core.
//!
//! A classic event-calendar simulator: a virtual clock plus a min-heap of
//! timestamped events. The serving experiments (paper Figs 4–7, 10–11)
//! run open-loop request streams against multiple simulated GPU instances
//! or MPS clients; the DES makes an hour of simulated traffic cost
//! milliseconds of wall time and keeps every run deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled on the virtual clock, carrying a user payload.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: f64,
    seq: u64, // tie-break: FIFO among equal timestamps
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Discrete-event simulation driver.
#[derive(Debug)]
pub struct Des<E> {
    now: f64,
    seq: u64,
    queue: BinaryHeap<Scheduled<E>>,
    processed: u64,
}

impl<E> Des<E> {
    /// Fresh simulator with the clock at zero.
    pub fn new() -> Self {
        Des { now: 0.0, seq: 0, queue: BinaryHeap::new(), processed: 0 }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `payload` at absolute virtual time `at` (must not be in
    /// the past).
    pub fn schedule_at(&mut self, at: f64, payload: E) {
        assert!(at >= self.now, "cannot schedule in the past: {at} < {}", self.now);
        self.queue.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay: f64, payload: E) {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next(&mut self) -> Option<(f64, E)> {
        self.queue.pop().map(|s| {
            self.now = s.at;
            self.processed += 1;
            (s.at, s.payload)
        })
    }

    /// Run until the queue is empty or `horizon` (virtual seconds) is
    /// passed. The handler may schedule further events through the `&mut
    /// Des` it receives.
    pub fn run_until(&mut self, horizon: f64, mut handler: impl FnMut(&mut Des<E>, f64, E)) {
        while let Some(s) = self.queue.peek() {
            if s.at > horizon {
                break;
            }
            let (at, payload) = self.next().unwrap();
            handler(self, at, payload);
        }
        // Advance the clock to the horizon only when it is finite. With
        // `horizon = f64::INFINITY` the old expression set `now` to
        // infinity, which poisoned every later `schedule_in` (now + delay
        // = inf); an exhausted-queue run leaves the clock at the last
        // processed event instead.
        if horizon.is_finite() {
            self.now = self.now.max(horizon);
        }
    }
}

impl<E> Default for Des<E> {
    fn default() -> Self {
        Des::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut des: Des<&str> = Des::new();
        des.schedule_at(3.0, "c");
        des.schedule_at(1.0, "a");
        des.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(des.now(), 3.0);
    }

    #[test]
    fn fifo_among_equal_timestamps() {
        let mut des: Des<u32> = Des::new();
        for i in 0..10 {
            des.schedule_at(5.0, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handler_can_reschedule() {
        // A self-perpetuating tick: event at t schedules another at t+1.
        let mut des: Des<()> = Des::new();
        des.schedule_at(0.0, ());
        let mut ticks = 0;
        des.run_until(5.5, |des, _t, ()| {
            ticks += 1;
            des.schedule_in(1.0, ());
        });
        assert_eq!(ticks, 6); // t = 0,1,2,3,4,5
        assert!(des.pending() == 1); // the t=6 tick remains
    }

    #[test]
    fn run_until_respects_horizon() {
        let mut des: Des<u32> = Des::new();
        des.schedule_at(1.0, 1);
        des.schedule_at(100.0, 2);
        let mut seen = Vec::new();
        des.run_until(10.0, |_, _, e| seen.push(e));
        assert_eq!(seen, vec![1]);
        assert_eq!(des.pending(), 1);
    }

    #[test]
    #[should_panic(expected = "cannot schedule in the past")]
    fn scheduling_in_past_panics() {
        let mut des: Des<()> = Des::new();
        des.schedule_at(5.0, ());
        des.next();
        des.schedule_at(1.0, ());
    }

    #[test]
    fn infinite_horizon_leaves_clock_usable() {
        let mut des: Des<u8> = Des::new();
        des.schedule_at(2.0, 1);
        des.run_until(f64::INFINITY, |_, _, _| {});
        assert_eq!(des.now(), 2.0, "clock stays at the last processed event");
        // Regression: this used to panic-or-poison because `now` was +inf.
        des.schedule_in(1.0, 2);
        assert_eq!(des.next(), Some((3.0, 2)));
    }

    #[test]
    fn finite_horizon_still_advances_clock() {
        let mut des: Des<u8> = Des::new();
        des.schedule_at(1.0, 1);
        des.run_until(10.0, |_, _, _| {});
        assert_eq!(des.now(), 10.0);
    }

    #[test]
    fn processed_counter() {
        let mut des: Des<u8> = Des::new();
        des.schedule_in(0.0, 0);
        des.schedule_in(1.0, 1);
        des.run_until(f64::INFINITY, |_, _, _| {});
        assert_eq!(des.processed(), 2);
        assert_eq!(des.pending(), 0);
    }
}
