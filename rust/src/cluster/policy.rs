//! Fleet-level repartitioning policies.
//!
//! The single-GPU orchestrator's [`Policy`](crate::orchestrator::Policy)
//! answers *when and to what* one GPU should be repartitioned. At fleet
//! scale the decision gains a dimension: *which GPU* — MISO-style layout
//! search (Li et al., 2022) lifted from one device to many. A
//! [`FleetPolicy`] watches windowed per-GPU metrics and proposes at most
//! one repartition per observation window, so reconfigurations roll
//! through the fleet one GPU at a time and the router can migrate that
//! GPU's traffic to its siblings while it churns.

use crate::orchestrator::{ReactiveParams, ServiceObs};
use crate::scheduler::{tenant_scaled_demand, DemandWorkload, RatePlan, Scheduler};

use super::tenancy::Tenant;

/// Windowed observation of one fleet GPU.
#[derive(Debug, Clone)]
pub struct GpuObs {
    /// Per-class replica observations, in class order.
    pub services: Vec<ServiceObs>,
    /// Training steps this GPU completed in the window.
    pub train_steps: u64,
    /// True while the GPU serves traffic (not draining, reconfiguring,
    /// or crashed by an injected fault).
    pub running: bool,
}

/// One observation window over the whole fleet.
#[derive(Debug, Clone)]
pub struct FleetObs {
    /// Window end time (simulated seconds).
    pub t: f64,
    /// Window length, seconds.
    pub window_s: f64,
    /// Per-GPU observations, in fleet order.
    pub gpus: Vec<GpuObs>,
}

/// Read-only planning context handed to a fleet policy at each window
/// tick.
#[derive(Debug)]
pub struct FleetCtx<'a> {
    /// One planner per fleet GPU, in fleet order.
    pub schedulers: &'a [Scheduler],
    /// Workload templates (training first if present, then classes);
    /// class entries carry fleet-wide mean rates as their demand.
    pub workloads: &'a [DemandWorkload],
    /// Workload index of each request class, in class order.
    pub class_workloads: &'a [usize],
    /// Tenants in force (the engine synthesizes one tenant per class
    /// when the config declares none), in tenant order.
    pub tenants: &'a [Tenant],
    /// Tenant index of each request class, in class order.
    pub tenant_of: &'a [usize],
    /// True when the config declared explicit tenants: per-GPU
    /// replanning then applies the tenant-weighted demand split
    /// ([`tenant_scaled_demand`]) on top of observed rates.
    pub weighted_planning: bool,
    /// The per-GPU plans currently in force, in fleet order.
    pub current: &'a [RatePlan],
    /// Capacity weight of each GPU (sums to 1).
    pub weights: &'a [f64],
    /// Current time (window end), simulated seconds.
    pub now: f64,
    /// Per-GPU time of the last layout change (0 if never).
    pub last_change_t: &'a [f64],
    /// Utilization bound used for sizing (ρ_max).
    pub rho_max: f64,
}

impl FleetCtx<'_> {
    /// Clone the workload templates with one GPU's observed per-class
    /// rates substituted in (rates in class order).
    pub fn workloads_at_rates(&self, rates: &[f64]) -> Vec<DemandWorkload> {
        let mut ws = self.workloads.to_vec();
        for (ci, &wi) in self.class_workloads.iter().enumerate() {
            ws[wi].demand_rps = Some(rates.get(ci).copied().unwrap_or(0.0).max(0.0));
        }
        ws
    }

    /// [`Self::workloads_at_rates`] as the planners should see it: under
    /// explicit tenancy the observed rates are re-split by tenant weight
    /// before sizing, so repartitions provision weighted shares.
    pub fn planning_workloads(&self, rates: &[f64]) -> Vec<DemandWorkload> {
        let ws = self.workloads_at_rates(rates);
        if self.weighted_planning {
            tenant_scaled_demand(&ws, self.class_workloads, self.tenants)
        } else {
            ws
        }
    }
}

/// A proposed repartition: which GPU, to what plan, and why.
#[derive(Debug, Clone)]
pub struct FleetAction {
    /// Fleet index of the GPU to repartition.
    pub gpu: usize,
    /// The plan the GPU should adopt.
    pub plan: RatePlan,
    /// Window observation that motivated the move.
    pub reason: String,
}

/// A fleet repartitioning policy.
pub trait FleetPolicy {
    /// Short name used in reports ("static", "reactive").
    fn name(&self) -> &'static str;

    /// Called at the end of each observation window while every GPU is
    /// running. Return `Some(action)` to repartition one GPU (the engine
    /// ignores proposals whose layout equals that GPU's current one), or
    /// `None` to keep every layout.
    fn decide(&mut self, obs: &FleetObs, ctx: &FleetCtx) -> Option<FleetAction>;
}

/// Which fleet policy to run — plain data, cloneable into sweep grids;
/// [`FleetPolicyKind::build`] constructs the stateful policy.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetPolicyKind {
    /// Fixed per-GPU layouts from whole-trace mean rates (the baseline).
    Static,
    /// Per-GPU hysteresis on observed pressure, one GPU per window.
    Reactive(ReactiveParams),
    /// Pre-scripted repartitions at fixed times (testing harness: makes
    /// *when* and *which GPU* exactly reproducible, unlike the
    /// observation-driven policies).
    Scripted(Vec<ScriptedRepartition>),
}

impl FleetPolicyKind {
    /// Report name of the policy.
    pub fn name(&self) -> &'static str {
        match self {
            FleetPolicyKind::Static => "static",
            FleetPolicyKind::Reactive(_) => "reactive",
            FleetPolicyKind::Scripted(_) => "scripted",
        }
    }

    /// Parse a policy name (default parameters).
    pub fn parse(s: &str) -> Option<FleetPolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "static" | "oracle" => Some(FleetPolicyKind::Static),
            "reactive" => Some(FleetPolicyKind::Reactive(ReactiveParams::default())),
            _ => None,
        }
    }

    /// Construct the stateful policy as an enum-dispatched
    /// [`FleetPolicyImpl`] (no heap allocation, no vtable on the window
    /// tick path).
    pub fn build(&self) -> FleetPolicyImpl {
        match self {
            FleetPolicyKind::Static => FleetPolicyImpl::Static(FleetStatic),
            FleetPolicyKind::Reactive(p) => {
                FleetPolicyImpl::Reactive(FleetReactive { params: p.clone() })
            }
            FleetPolicyKind::Scripted(s) => {
                FleetPolicyImpl::Scripted(FleetScripted { script: s.clone(), next: 0 })
            }
        }
    }
}

/// A built, stateful fleet policy with enum dispatch — the devirtualized
/// counterpart of `Box<dyn FleetPolicy>`, kept inline in the engine.
/// [`FleetPolicy`] stays implemented for generic consumers and tests.
#[derive(Debug)]
pub enum FleetPolicyImpl {
    /// Fixed layouts.
    Static(FleetStatic),
    /// Pressure-driven hysteresis.
    Reactive(FleetReactive),
    /// Pre-scripted repartitions.
    Scripted(FleetScripted),
}

impl FleetPolicyImpl {
    /// Short name used in reports ("static", "reactive", "scripted").
    pub fn name(&self) -> &'static str {
        match self {
            FleetPolicyImpl::Static(p) => FleetPolicy::name(p),
            FleetPolicyImpl::Reactive(p) => FleetPolicy::name(p),
            FleetPolicyImpl::Scripted(p) => FleetPolicy::name(p),
        }
    }

    /// Propose at most one repartition for this observation window.
    pub fn decide(&mut self, obs: &FleetObs, ctx: &FleetCtx) -> Option<FleetAction> {
        match self {
            FleetPolicyImpl::Static(p) => p.decide(obs, ctx),
            FleetPolicyImpl::Reactive(p) => p.decide(obs, ctx),
            FleetPolicyImpl::Scripted(p) => p.decide(obs, ctx),
        }
    }
}

impl FleetPolicy for FleetPolicyImpl {
    fn name(&self) -> &'static str {
        FleetPolicyImpl::name(self)
    }
    fn decide(&mut self, obs: &FleetObs, ctx: &FleetCtx) -> Option<FleetAction> {
        FleetPolicyImpl::decide(self, obs, ctx)
    }
}

/// One entry of a [`FleetPolicyKind::Scripted`] schedule: at the first
/// window tick at or after `at_t`, repartition `gpu` to whatever the
/// exhaustive planner picks for the template demand scaled by
/// `rate_scale`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScriptedRepartition {
    /// Earliest window-tick time the entry fires at, simulated seconds.
    pub at_t: f64,
    /// Fleet index of the GPU to repartition (taken modulo fleet size).
    pub gpu: usize,
    /// Multiplier on the template per-class demand the new plan is sized
    /// for; varying it is what forces a genuinely different layout.
    pub rate_scale: f64,
}

/// Deterministic script player: consumes due entries in order, at most
/// one per window tick (matching the engine's one-repartition-per-window
/// contract). Entries whose GPU is not running at their tick are retried
/// at the next tick rather than dropped — the engine only calls
/// [`FleetPolicy::decide`] while every GPU is running, so in practice a
/// due entry fires at the first all-running tick after `at_t`.
#[derive(Debug)]
pub struct FleetScripted {
    /// The schedule, in firing order.
    pub script: Vec<ScriptedRepartition>,
    /// Index of the next unconsumed entry.
    pub next: usize,
}

impl FleetPolicy for FleetScripted {
    fn name(&self) -> &'static str {
        "scripted"
    }
    fn decide(&mut self, obs: &FleetObs, ctx: &FleetCtx) -> Option<FleetAction> {
        while self.next < self.script.len() {
            let entry = &self.script[self.next];
            if entry.at_t > obs.t {
                return None; // not due yet; later entries fire even later
            }
            self.next += 1;
            let n = obs.gpus.len();
            if n == 0 {
                continue;
            }
            let g = entry.gpu % n;
            // Size for the template (whole-trace mean) demand scaled by
            // the entry's factor: deterministic, independent of window
            // observations.
            let scale = if entry.rate_scale.is_finite() && entry.rate_scale >= 0.0 {
                entry.rate_scale
            } else {
                1.0
            };
            let rates: Vec<f64> = ctx
                .class_workloads
                .iter()
                .map(|&wi| {
                    ctx.workloads[wi].demand_rps.unwrap_or(0.0).max(0.0) * scale
                        * ctx.weights.get(g).copied().unwrap_or(0.0)
                })
                .collect();
            let ws = ctx.planning_workloads(&rates);
            let Some(plan) = ctx.schedulers[g].plan_for_demand(&ws, ctx.rho_max) else {
                continue; // infeasible scale: skip the entry
            };
            let reason = format!(
                "scripted: gpu {g} at t={:.1} (rate_scale {:.2})",
                entry.at_t, entry.rate_scale
            );
            return Some(FleetAction { gpu: g, plan, reason });
        }
        None
    }
}

/// The baseline: every GPU keeps the layout the fleet demand packer
/// picked for whole-trace mean rates.
#[derive(Debug)]
pub struct FleetStatic;

impl FleetPolicy for FleetStatic {
    fn name(&self) -> &'static str {
        "static"
    }
    fn decide(&mut self, _obs: &FleetObs, _ctx: &FleetCtx) -> Option<FleetAction> {
        None
    }
}

/// Reactive fleet policy: repartition the GPU whose cooldown has expired
/// and whose window shows pressure — a blown p99, a saturated replica,
/// or a current plan that is no longer feasible at the rates the router
/// actually sent it. Under explicit tenancy, when several GPUs qualify
/// the policy sides with the *most-starved tenant* — the one with the
/// lowest weight-normalized window goodput — and repartitions the GPU
/// carrying the largest share of that tenant's window traffic (ties to
/// the lowest fleet index); without configured tenants the legacy
/// fleet-order scan is preserved exactly. The target plan comes from
/// the per-GPU exhaustive planner sized for the observed per-GPU rates,
/// tenant-weight-split under explicit tenancy.
#[derive(Debug)]
pub struct FleetReactive {
    /// Thresholds shared with the single-GPU reactive policy.
    pub params: ReactiveParams,
}

impl FleetPolicy for FleetReactive {
    fn name(&self) -> &'static str {
        "reactive"
    }
    fn decide(&mut self, obs: &FleetObs, ctx: &FleetCtx) -> Option<FleetAction> {
        // Most-starved tenant (lowest weight-normalized window goodput).
        // Only computed under explicit tenancy: with the synthesized
        // per-class default the legacy fleet-order scan must stay
        // byte-for-byte identical.
        let n_tenants = ctx.tenants.len();
        let starved: Option<usize> = if ctx.weighted_planning {
            let mut tenant_good = vec![0.0f64; n_tenants];
            let mut tenant_arrived = vec![0u64; n_tenants];
            for go in &obs.gpus {
                for (ci, s) in go.services.iter().enumerate() {
                    let Some(&t) = ctx.tenant_of.get(ci) else { continue };
                    if t < n_tenants {
                        tenant_good[t] += (s.completed - s.violations) as f64;
                        tenant_arrived[t] += s.arrivals;
                    }
                }
            }
            // Only tenants that actually offered traffic this window can
            // be starved: an idle tenant has zero goodput by choice, and
            // letting it win the argmin would both disable the steering
            // (its per-GPU share is zero everywhere) and mislabel every
            // repartition reason with a tenant that played no role.
            let mut best: Option<(usize, f64)> = None;
            for (t, tn) in ctx.tenants.iter().enumerate() {
                if tenant_arrived[t] == 0 || !(tn.weight.is_finite() && tn.weight > 0.0) {
                    continue;
                }
                let x = tenant_good[t] / tn.weight;
                match best {
                    Some((_, bx)) if bx <= x => {}
                    _ => best = Some((t, x)),
                }
            }
            best.map(|(t, _)| t)
        } else {
            None
        };

        // Candidate GPUs: running, out of cooldown, and showing pressure
        // or an infeasible current plan at the observed rates. Each
        // candidate caches its planning workload vector (for the planner
        // pass below) and its share of the starved tenant's window
        // arrivals (the sort key — 0 for everyone without explicit
        // tenancy, so the sort below preserves the legacy fleet-order
        // scan exactly).
        let mut candidates: Vec<(u64, usize, Vec<DemandWorkload>)> = Vec::new();
        for (g, go) in obs.gpus.iter().enumerate() {
            if !go.running {
                continue;
            }
            if ctx.now - ctx.last_change_t.get(g).copied().unwrap_or(0.0) < self.params.cooldown_s
            {
                continue;
            }
            let rates: Vec<f64> = go.services.iter().map(|s| s.rate_rps).collect();
            let ws = ctx.planning_workloads(&rates);
            let sched = &ctx.schedulers[g];
            let (_score, feasible) = sched.evaluate_plan(&ctx.current[g], &ws, ctx.rho_max);
            let pressure = go.services.iter().enumerate().any(|(ci, s)| {
                let slo = ctx.class_workloads.get(ci).and_then(|&wi| ctx.workloads[wi].slo_ms);
                let p99_blown = slo.map(|slo| s.completed > 0 && s.p99_ms > slo).unwrap_or(false);
                p99_blown || s.busy_frac >= self.params.busy_trigger
            });
            if feasible && !pressure {
                continue;
            }
            let starved_share: u64 = starved.map_or(0, |st| {
                go.services
                    .iter()
                    .enumerate()
                    .filter(|(ci, _)| ctx.tenant_of.get(*ci) == Some(&st))
                    .map(|(_, s)| s.arrivals)
                    .sum()
            });
            candidates.push((starved_share, g, ws));
        }
        // Repartition the GPU carrying the most of the starved tenant's
        // window traffic; ties (and the no-tenant case, where every key
        // is 0) fall back to the lowest fleet index.
        candidates.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for (_, g, ws) in candidates {
            let go = &obs.gpus[g];
            let sched = &ctx.schedulers[g];
            let Some(candidate) = sched.plan_for_demand(&ws, ctx.rho_max) else {
                continue; // even the best layout cannot host these rates
            };
            if candidate.layout == ctx.current[g].layout {
                continue;
            }
            let fmt = |f: &dyn Fn(&ServiceObs) -> f64| -> String {
                go.services.iter().map(|s| format!("{:.1}", f(s))).collect::<Vec<_>>().join(", ")
            };
            let starved_note = match starved {
                Some(st) if n_tenants > 1 => {
                    format!(", starved tenant {}", ctx.tenants[st].name)
                }
                _ => String::new(),
            };
            let reason = format!(
                "gpu {g}: window rates [{}] req/s, p99 [{}] ms{starved_note}",
                fmt(&|s| s.rate_rps),
                fmt(&|s| s.p99_ms)
            );
            return Some(FleetAction { gpu: g, plan: candidate, reason });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::GpuModel;
    use crate::models::zoo::lookup;
    use crate::scheduler::plan_fleet_for_demand;
    use crate::workload::spec::WorkloadSpec;

    fn workloads(mean_rate: f64) -> Vec<DemandWorkload> {
        let bert = lookup("bert-base").unwrap();
        vec![
            DemandWorkload::training(WorkloadSpec::training(bert, 32, 128)),
            DemandWorkload::service(WorkloadSpec::inference(bert, 8, 128), 40.0, mean_rate),
            DemandWorkload::service(WorkloadSpec::inference(bert, 8, 128), 40.0, mean_rate),
        ]
    }

    fn obs_gpu(rates: [f64; 2], p99_ms: f64, busy: f64) -> GpuObs {
        GpuObs {
            services: rates
                .iter()
                .map(|&r| ServiceObs {
                    arrivals: (r * 20.0) as u64,
                    rate_rps: r,
                    completed: (r * 20.0) as u64,
                    violations: 0,
                    p99_ms,
                    busy_frac: busy,
                    queue_depth: 0,
                })
                .collect(),
            train_steps: 100,
            running: true,
        }
    }

    struct Fixture {
        schedulers: Vec<Scheduler>,
        workloads: Vec<DemandWorkload>,
        tenants: Vec<Tenant>,
        tenant_of: Vec<usize>,
        plans: Vec<RatePlan>,
        weights: Vec<f64>,
        last_change: Vec<f64>,
    }

    fn fixture(n: usize, fleet_rate: f64) -> Fixture {
        let schedulers: Vec<Scheduler> =
            (0..n).map(|_| Scheduler::new(GpuModel::A100_80GB)).collect();
        let workloads = workloads(fleet_rate);
        let fp = plan_fleet_for_demand(&schedulers, &workloads, 0.75).expect("feasible fixture");
        Fixture {
            schedulers,
            workloads,
            tenants: Tenant::per_class(2),
            tenant_of: vec![0, 1],
            plans: fp.plans,
            weights: fp.weights,
            last_change: vec![0.0; n],
        }
    }

    fn ctx<'a>(f: &'a Fixture, now: f64) -> FleetCtx<'a> {
        FleetCtx {
            schedulers: &f.schedulers,
            workloads: &f.workloads,
            class_workloads: &[1, 2],
            tenants: &f.tenants,
            tenant_of: &f.tenant_of,
            weighted_planning: false,
            current: &f.plans,
            weights: &f.weights,
            now,
            last_change_t: &f.last_change,
            rho_max: 0.75,
        }
    }

    #[test]
    fn static_policy_never_moves() {
        let f = fixture(2, 66.0);
        let obs = FleetObs {
            t: 100.0,
            window_s: 20.0,
            gpus: vec![obs_gpu([60.0, 60.0], 500.0, 1.0), obs_gpu([60.0, 60.0], 500.0, 1.0)],
        };
        assert!(FleetStatic.decide(&obs, &ctx(&f, 100.0)).is_none());
    }

    #[test]
    fn reactive_keeps_layouts_at_mean_load() {
        let f = fixture(2, 66.0); // 33 req/s per GPU per class
        let obs = FleetObs {
            t: 100.0,
            window_s: 20.0,
            gpus: vec![obs_gpu([33.0, 33.0], 25.0, 0.5), obs_gpu([33.0, 33.0], 25.0, 0.5)],
        };
        let mut p = FleetReactive { params: ReactiveParams::default() };
        assert!(p.decide(&obs, &ctx(&f, 100.0)).is_none());
    }

    #[test]
    fn reactive_targets_the_pressured_gpu() {
        let f = fixture(2, 66.0);
        // GPU 0 calm, GPU 1 overloaded: the proposal must name GPU 1 and
        // its plan must serve the peak within SLO and utilization bounds.
        let obs = FleetObs {
            t: 100.0,
            window_s: 20.0,
            gpus: vec![obs_gpu([33.0, 33.0], 25.0, 0.5), obs_gpu([60.0, 60.0], 120.0, 1.0)],
        };
        let mut p = FleetReactive { params: ReactiveParams::default() };
        let action = p.decide(&obs, &ctx(&f, 100.0)).expect("must repartition");
        assert_eq!(action.gpu, 1);
        assert!(action.plan.layout != f.plans[1].layout);
        assert!(action.reason.contains("gpu 1"), "{}", action.reason);
        for a in action.plan.assignments.iter().filter(|a| a.workload > 0) {
            assert!(a.utilization <= 0.75, "{a:?}");
            assert!(a.latency_ms <= 40.0, "{a:?}");
        }
    }

    #[test]
    fn cooldown_and_non_running_gpus_are_skipped() {
        let mut f = fixture(2, 66.0);
        f.last_change = vec![95.0, 95.0]; // changed 5 s ago, cooldown 40 s
        let hot = FleetObs {
            t: 100.0,
            window_s: 20.0,
            gpus: vec![obs_gpu([60.0, 60.0], 120.0, 1.0), obs_gpu([60.0, 60.0], 120.0, 1.0)],
        };
        let mut p = FleetReactive { params: ReactiveParams::default() };
        assert!(p.decide(&hot, &ctx(&f, 100.0)).is_none(), "cooldown blocks both GPUs");

        f.last_change = vec![0.0, 0.0];
        let mut draining = hot.clone();
        draining.gpus[0].running = false;
        let action = p.decide(&draining, &ctx(&f, 100.0)).expect("gpu 1 still movable");
        assert_eq!(action.gpu, 1, "non-running gpu 0 must be skipped");
    }

    /// Per-class asymmetric observation: `(rate, completed)` per class.
    fn obs_asym(per_class: [(f64, u64); 2], p99_ms: f64, busy: f64) -> GpuObs {
        GpuObs {
            services: per_class
                .iter()
                .map(|&(r, completed)| ServiceObs {
                    arrivals: (r * 20.0) as u64,
                    rate_rps: r,
                    completed,
                    violations: 0,
                    p99_ms,
                    busy_frac: busy,
                    queue_depth: 0,
                })
                .collect(),
            train_steps: 100,
            running: true,
        }
    }

    #[test]
    fn scripted_policy_fires_in_order_and_at_most_once_per_tick() {
        let f = fixture(2, 66.0);
        let kind = FleetPolicyKind::Scripted(vec![
            ScriptedRepartition { at_t: 30.0, gpu: 0, rate_scale: 0.1 },
            ScriptedRepartition { at_t: 30.0, gpu: 5, rate_scale: 2.0 }, // gpu 5 % 2 = 1
            ScriptedRepartition { at_t: 90.0, gpu: 1, rate_scale: 1.0 },
        ]);
        assert_eq!(kind.name(), "scripted");
        let mut p = kind.build();
        let calm = |t: f64| FleetObs {
            t,
            window_s: 10.0,
            gpus: vec![obs_gpu([33.0, 33.0], 25.0, 0.5), obs_gpu([33.0, 33.0], 25.0, 0.5)],
        };
        // Before the first due time: nothing fires.
        assert!(p.decide(&calm(10.0), &ctx(&f, 10.0)).is_none());
        // Two entries due at t=30: exactly one fires per tick, in order.
        let a = p.decide(&calm(30.0), &ctx(&f, 30.0)).expect("first entry due");
        assert_eq!(a.gpu, 0);
        assert!(a.reason.contains("scripted"), "{}", a.reason);
        let b = p.decide(&calm(40.0), &ctx(&f, 40.0)).expect("second entry still queued");
        assert_eq!(b.gpu, 1, "gpu index taken modulo fleet size");
        assert!(p.decide(&calm(50.0), &ctx(&f, 50.0)).is_none(), "third not due until 90");
        assert!(p.decide(&calm(90.0), &ctx(&f, 90.0)).is_some());
        assert!(p.decide(&calm(500.0), &ctx(&f, 500.0)).is_none(), "script exhausted");
    }

    #[test]
    fn starved_tenant_steers_the_gpu_choice() {
        let mut f = fixture(2, 66.0);
        f.tenants = vec![
            Tenant::new("gold", 3.0, vec![0]),
            Tenant::new("bronze", 1.0, vec![1]),
        ];
        f.tenant_of = vec![0, 1];
        // Both GPUs are pressured. Gold's normalized window goodput is
        // (1200 + 200) / 3 ≈ 467; bronze's is (100 + 300) / 1 = 400 —
        // bronze is the most-starved tenant, and its window traffic
        // concentrates on GPU 1 (60 req/s vs 10 on GPU 0). The old
        // fleet-order scan would have repartitioned GPU 0.
        let obs = FleetObs {
            t: 100.0,
            window_s: 20.0,
            gpus: vec![
                obs_asym([(60.0, 1200), (10.0, 100)], 120.0, 1.0),
                obs_asym([(10.0, 200), (60.0, 300)], 120.0, 1.0),
            ],
        };
        let mut c = ctx(&f, 100.0);
        c.weighted_planning = true;
        let mut p = FleetReactive { params: ReactiveParams::default() };
        let action = p.decide(&obs, &c).expect("pressure must force a repartition");
        assert_eq!(action.gpu, 1, "must target the GPU carrying the starved tenant's traffic");
        assert!(
            action.reason.contains("starved tenant bronze"),
            "reason must name the starved tenant: {}",
            action.reason
        );
    }

    #[test]
    fn idle_tenants_are_never_the_starved_tenant() {
        let mut f = fixture(2, 66.0);
        f.tenants = vec![
            Tenant::new("gold", 3.0, vec![0]),
            Tenant::new("idle", 1.0, vec![1]),
        ];
        f.tenant_of = vec![0, 1];
        // Tenant "idle" offers no traffic this window: its zero goodput
        // is by choice, so starvation steering must follow gold — the
        // only tenant with arrivals — whose traffic concentrates on
        // GPU 1.
        let obs = FleetObs {
            t: 100.0,
            window_s: 20.0,
            gpus: vec![
                obs_asym([(20.0, 400), (0.0, 0)], 120.0, 1.0),
                obs_asym([(60.0, 600), (0.0, 0)], 120.0, 1.0),
            ],
        };
        let mut c = ctx(&f, 100.0);
        c.weighted_planning = true;
        let mut p = FleetReactive { params: ReactiveParams::default() };
        let action = p.decide(&obs, &c).expect("pressure must force a repartition");
        assert_eq!(action.gpu, 1, "steering follows the traffic-bearing tenant");
        assert!(
            action.reason.contains("starved tenant gold"),
            "an idle tenant must never be labeled starved: {}",
            action.reason
        );
    }
}
