//! Time-slicing baseline (default CUDA multi-process behaviour).
//!
//! Without MPS or MIG, concurrent processes on one GPU are time-sliced by
//! the driver with full context switches between them. The paper cites
//! this as the failure mode MPS was designed to avoid ("costly context
//! switches caused by multiple workloads in the same GPU", §2.2). The
//! model is included as an ablation baseline for the sharing benches:
//! requests serialize, and each switch between distinct processes pays a
//! fixed context-switch penalty.

use crate::simgpu::perfmodel::StepEstimate;

/// Time-slicing cost model.
#[derive(Debug, Clone)]
pub struct TimeSliceModel {
    /// Context-switch latency between processes, seconds. The driver swaps
    /// the full GPU context (~100 µs – 1 ms depending on residency).
    pub context_switch_s: f64,
    /// Scheduler quantum, seconds: how long one process runs before the
    /// driver considers switching.
    pub quantum_s: f64,
}

impl Default for TimeSliceModel {
    fn default() -> Self {
        TimeSliceModel { context_switch_s: 0.5e-3, quantum_s: 2e-3 }
    }
}

impl TimeSliceModel {
    /// Expected completion time for a request whose isolated estimate is
    /// `isolated`, with `busy` other processes round-robin sharing the
    /// GPU.
    ///
    /// With `n = busy + 1` runnable processes, a request that needs `w`
    /// seconds of GPU time waits `busy` quanta (plus switches) for every
    /// quantum it runs, so the turnaround is `w·n` plus switch overhead
    /// for every quantum boundary crossed.
    pub fn request_time(&self, isolated: &StepEstimate, busy: u32) -> f64 {
        let n = (busy + 1) as f64;
        let w = isolated.seconds;
        let quanta = (w / self.quantum_s).ceil().max(1.0);
        let switch_overhead = quanta * n * self.context_switch_s;
        w * n + switch_overhead
    }

    /// Effective throughput degradation factor vs exclusive access.
    pub fn slowdown(&self, isolated: &StepEstimate, busy: u32) -> f64 {
        self.request_time(isolated, busy) / isolated.seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(seconds: f64) -> StepEstimate {
        StepEstimate { seconds, gract: 0.8, compute_bound: true, fb_bytes: 0.0 }
    }

    #[test]
    fn solo_still_pays_switch_overhead_only_minimally() {
        let ts = TimeSliceModel::default();
        let e = est(0.010);
        let t = ts.request_time(&e, 0);
        assert!(t >= 0.010);
        assert!(t < 0.014, "solo overhead too large: {t}");
    }

    #[test]
    fn slowdown_exceeds_fair_share() {
        // Unlike MPS, time-slicing pays context switches on top of the
        // n-way share, so slowdown > n.
        let ts = TimeSliceModel::default();
        let e = est(0.010);
        for busy in [1u32, 3, 7] {
            let s = ts.slowdown(&e, busy);
            assert!(s > (busy + 1) as f64, "busy={busy}: slowdown {s} <= fair share");
        }
    }

    #[test]
    fn worse_than_mps_fair_share() {
        use crate::sharing::mps::MpsModel;
        let ts = TimeSliceModel::default();
        let mps = MpsModel::default();
        let e = est(0.010);
        // MPS deterministic part for 3 busy co-runners vs time-slicing.
        let t_mps = e.seconds * mps.fair_share_slowdown(3);
        let t_slice = ts.request_time(&e, 3);
        assert!(t_slice > t_mps, "time-slicing {t_slice} must exceed MPS {t_mps}");
    }

    #[test]
    fn monotone_in_busy() {
        let ts = TimeSliceModel::default();
        let e = est(0.005);
        let times: Vec<f64> = (0..5).map(|b| ts.request_time(&e, b)).collect();
        assert!(times.windows(2).all(|w| w[1] > w[0]));
    }
}
