//! Benchmark coordinator (paper Fig 1).
//!
//! "The system first accepts users' benchmarking tasks. Then it
//! distributes the tasks to dedicated servers to complete them
//! automatically. Finally, it will send a detailed report and guidelines
//! back to users."
//!
//! The coordinator owns a pool of worker threads, one per benchmark
//! server (the paper's A100 and A30 machines). Tasks are routed to the
//! worker whose server has the matching GPU model; each worker runs a
//! [`ProfileSession`] and sends the report back over a channel. The
//! client half ([`client`]) is the user-facing handle that submits tasks
//! and collects reports, mirroring the paper's remote-control client.

pub mod client;
pub mod leader;

pub use client::Client;
pub use leader::{Coordinator, TaskHandle, TaskStatus};
