//! MPS (Multi-Process Service) sharing model.
//!
//! MPS lets multiple processes share a GPU without context switches, but —
//! unlike MIG — provides **no physical isolation**: clients contend for
//! SMs, L2 and HBM bandwidth. The paper's GPU-sharing characterization
//! (§4.5, Figs 4–7, 10–11) turns on exactly this difference:
//!
//! * small requests: MPS ≈ MIG on average latency (contention is rare);
//! * large batches / large models: MPS tail latency blows up and becomes
//!   unstable, while MIG stays flat (physical isolation).
//!
//! The model prices a request in two parts: a fair-share slowdown that
//! grows smoothly with how much of the machine the co-runners demand, and
//! stochastic contention spikes (log-normal inflation) whose probability
//! scales with the request's own memory traffic relative to L2 capacity —
//! heavy traffic both suffers and causes interference.

use crate::models::cost::StepCost;
use crate::simgpu::perfmodel::{PerfError, PerfModel, StepEstimate};
use crate::simgpu::resource::ExecResource;
use crate::util::prng::Prng;

/// Tunables of the MPS interference model.
#[derive(Debug, Clone)]
pub struct MpsModel {
    /// Fair-share slowdown coefficient per busy co-runner.
    pub contention_alpha: f64,
    /// Base probability of a contention spike per request at reference
    /// traffic (one full L2's worth of data).
    pub spike_prob_at_ref: f64,
    /// Log-normal σ of spike inflation (μ is derived from severity).
    pub spike_sigma: f64,
    /// Mean multiplicative inflation when a spike hits.
    pub spike_mean_inflation: f64,
}

impl Default for MpsModel {
    fn default() -> Self {
        MpsModel {
            contention_alpha: 0.18,
            spike_prob_at_ref: 0.35,
            spike_sigma: 0.55,
            spike_mean_inflation: 2.6,
        }
    }
}

impl MpsModel {
    /// Deterministic fair-share slowdown multiplier with `busy` active
    /// co-runners (not counting the request's own process).
    pub fn fair_share_slowdown(&self, busy: u32) -> f64 {
        1.0 + self.contention_alpha * busy as f64
    }

    /// Probability that this request triggers/suffers a contention spike,
    /// given its HBM traffic and the GPU's L2 size. More co-runners and
    /// more traffic → more collisions.
    pub fn spike_probability(&self, cost: &StepCost, res: &ExecResource, busy: u32) -> f64 {
        if busy == 0 {
            return 0.0;
        }
        let l2_bytes = res.spec().l2_mib * (1u64 << 20) as f64;
        let traffic_ratio = (cost.hbm_bytes / (l2_bytes * 32.0)).min(4.0);
        let co = (busy as f64 / 3.0).min(1.5);
        (self.spike_prob_at_ref * traffic_ratio * co).min(0.95)
    }

    /// Price one request on an MPS client.
    ///
    /// `isolated` must be the estimate for this cost on a *whole-GPU*
    /// resource (MPS clients launch on the full SM array); `busy` is the
    /// number of other clients with work in flight; `rng` drives the
    /// stochastic spike draw.
    pub fn request_time(
        &self,
        isolated: &StepEstimate,
        cost: &StepCost,
        res: &ExecResource,
        busy: u32,
        rng: &mut Prng,
    ) -> f64 {
        let mut t = isolated.seconds * self.fair_share_slowdown(busy);
        let p = self.spike_probability(cost, res, busy);
        if rng.chance(p) {
            // Log-normal with mean `spike_mean_inflation`:
            // mean = exp(mu + sigma^2/2)  =>  mu = ln(mean) - sigma^2/2.
            let mu = self.spike_mean_inflation.ln() - self.spike_sigma * self.spike_sigma / 2.0;
            let inflation = rng.lognormal(mu, self.spike_sigma).max(1.0);
            t *= inflation;
        }
        t
    }

    /// Convenience: price a request end-to-end from a cost, running the
    /// roofline for the isolated time internally.
    pub fn step(
        &self,
        pm: &PerfModel,
        gpu: &ExecResource,
        cost: &StepCost,
        busy: u32,
        rng: &mut Prng,
    ) -> Result<f64, PerfError> {
        debug_assert!(
            gpu.compute_fraction == 1.0,
            "MPS isolated estimate must be priced on the whole GPU"
        );
        let isolated = pm.step(gpu, cost)?;
        Ok(self.request_time(&isolated, cost, gpu, busy, rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::GpuModel;
    use crate::models::cost::{infer_cost, Precision};
    use crate::models::zoo;
    use crate::util::stats::percentile;

    fn whole() -> ExecResource {
        ExecResource::whole_gpu(GpuModel::A30_24GB)
    }

    fn sample_latencies(batch: u32, busy: u32, n: usize, model: &str) -> Vec<f64> {
        let mps = MpsModel::default();
        let pm = PerfModel::default();
        let gpu = whole();
        let m = zoo::lookup(model).unwrap();
        let cost = infer_cost(m, batch, 128, Precision::Half);
        let mut rng = Prng::new(1234);
        (0..n).map(|_| mps.step(&pm, &gpu, &cost, busy, &mut rng).unwrap() * 1e3).collect()
    }

    #[test]
    fn no_corunners_no_interference() {
        let lat = sample_latencies(8, 0, 500, "resnet50");
        let spread = percentile(&lat, 99.0) / percentile(&lat, 50.0);
        assert!((spread - 1.0).abs() < 1e-9, "solo MPS must be deterministic, spread={spread}");
    }

    #[test]
    fn fig4_small_batch_mps_close_to_isolated() {
        // Paper Fig 4: at small batch, MPS average ≈ MIG average.
        let mps = MpsModel::default();
        let pm = PerfModel::default();
        let gpu = whole();
        let m = zoo::lookup("resnet18").unwrap();
        let cost = infer_cost(m, 1, 128, Precision::Half);
        let isolated = pm.step(&gpu, &cost).unwrap().seconds;
        let mut rng = Prng::new(7);
        let n = 2000;
        let mean: f64 = (0..n)
            .map(|_| mps.step(&pm, &gpu, &cost, 1, &mut rng).unwrap())
            .sum::<f64>()
            / n as f64;
        assert!(mean / isolated < 1.45, "small-batch MPS mean inflation {}", mean / isolated);
    }

    #[test]
    fn fig6_tail_gap_grows_with_batch() {
        // Paper Fig 6: p99 gap vs batch size grows.
        let tail_ratio = |batch: u32| {
            let lat = sample_latencies(batch, 1, 4000, "resnet50");
            percentile(&lat, 99.0) / percentile(&lat, 50.0)
        };
        let small = tail_ratio(1);
        let large = tail_ratio(32);
        assert!(large > small * 1.15, "tail blow-up must grow with batch: {small} → {large}");
    }

    #[test]
    fn fig7_larger_models_suffer_more() {
        // Paper Fig 7: MIG beats MPS more for larger models at batch 8.
        let spread = |model: &str| {
            let lat = sample_latencies(8, 1, 4000, model);
            percentile(&lat, 99.0) / percentile(&lat, 50.0)
        };
        assert!(
            spread("resnet101") > spread("resnet18"),
            "resnet101 spread {} vs resnet18 {}",
            spread("resnet101"),
            spread("resnet18")
        );
    }

    #[test]
    fn fair_share_monotone_in_busy() {
        let mps = MpsModel::default();
        assert_eq!(mps.fair_share_slowdown(0), 1.0);
        assert!(mps.fair_share_slowdown(3) > mps.fair_share_slowdown(1));
    }

    #[test]
    fn spike_probability_bounded() {
        let mps = MpsModel::default();
        let gpu = whole();
        let m = zoo::lookup("bert-large").unwrap();
        let cost = infer_cost(m, 64, 512, Precision::Half);
        let p = mps.spike_probability(&cost, &gpu, 10);
        assert!((0.0..=0.95).contains(&p));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sample_latencies(8, 2, 100, "resnet50");
        let b = sample_latencies(8, 2, 100, "resnet50");
        assert_eq!(a, b);
    }
}
