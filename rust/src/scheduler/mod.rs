//! Hybrid workload scheduler / partition optimizer.
//!
//! The paper's headline future-work item (§5): "hybrid scheduling for
//! training and inference on MIG and MIG/MPS orchestration", in the
//! spirit of the reconfigurable-machine-scheduling problem of Tan et al.
//! (2021) that the paper benchmarks against.
//!
//! Given a set of workloads — each a model + batch + kind, inference ones
//! carrying a latency SLO — the optimizer searches the *complete*
//! enumerated space of valid MIG layouts ([`mig::enumerate`]) and every
//! assignment of workloads to instances, scoring each plan by aggregate
//! goodput, and returns the best plan that satisfies all SLOs. On A100/
//! A30 the layout space is small enough that exhaustive search is exact
//! (and fast); the same interface would admit a heuristic for bigger
//! spaces.

pub mod fleet;
pub mod optimizer;

pub use fleet::{
    capacity_weights, plan_fleet_for_demand, plan_fleet_for_demand_weighted, scale_demand,
    tenant_scaled_demand, weights_from_slices, FleetPlan,
};
pub use optimizer::{
    Assignment, DemandWorkload, Objective, Plan, RateAssignment, RatePlan, Scheduler, SloWorkload,
};
