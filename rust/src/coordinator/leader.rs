//! Coordinator leader: task queue, routing and worker pool.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::mig::gpu::GpuModel;
use crate::mig::topology::ServerSpec;
use crate::profiler::report::BenchReport;
use crate::profiler::session::ProfileSession;
use crate::profiler::task::BenchTask;
use crate::sweep::SweepEngine;

/// Task identifier assigned at submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskHandle(pub u64);

/// Lifecycle of a submitted task.
#[derive(Debug, Clone)]
pub enum TaskStatus {
    /// Queued or running on a worker.
    Pending,
    /// Finished with a report.
    Done(std::sync::Arc<BenchReport>),
    /// Failed with an error message.
    Failed(String),
}

enum WorkerMsg {
    Run(TaskHandle, BenchTask),
    Shutdown,
}

struct Worker {
    gpu: GpuModel,
    tx: Sender<WorkerMsg>,
    handle: Option<JoinHandle<()>>,
}

/// The coordinator leader.
pub struct Coordinator {
    workers: Vec<Worker>,
    results_rx: Receiver<(TaskHandle, Result<BenchReport, String>)>,
    results_tx: Sender<(TaskHandle, Result<BenchReport, String>)>,
    statuses: BTreeMap<TaskHandle, TaskStatus>,
    next_id: u64,
    round_robin: usize,
}

impl Coordinator {
    /// Coordinator over the given benchmark servers (one worker thread
    /// per server). The machine's sweep-engine parallelism (see
    /// [`SweepEngine::from_env`]) is divided evenly among the workers, so
    /// each worker's `ProfileSession` fans its task's sweep grid across
    /// its share of cores while tasks themselves run concurrently.
    pub fn new(servers: &[&'static ServerSpec]) -> Self {
        let total = SweepEngine::from_env().workers();
        let per_worker = (total / servers.len().max(1)).max(1);
        Self::with_engine(servers, SweepEngine::new(per_worker))
    }

    /// Coordinator whose workers all use the given sweep engine for their
    /// in-task grids (explicit control for tests and benchmarks).
    pub fn with_engine(servers: &[&'static ServerSpec], engine: SweepEngine) -> Self {
        let (results_tx, results_rx) = channel();
        let workers = servers
            .iter()
            .map(|spec| {
                let (tx, rx) = channel::<WorkerMsg>();
                let results = results_tx.clone();
                let name = spec.name;
                let engine = engine.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("migperf-worker-{name}"))
                    .spawn(move || worker_loop(rx, results, engine))
                    .expect("spawn worker");
                Worker { gpu: spec.gpu_model, tx, handle: Some(handle) }
            })
            .collect();
        Coordinator {
            workers,
            results_rx,
            results_tx,
            statuses: BTreeMap::new(),
            next_id: 0,
            round_robin: 0,
        }
    }

    /// Coordinator over the paper's testbed (A100 + A30 servers).
    pub fn paper_testbed() -> Self {
        Coordinator::new(&[&crate::mig::topology::A100_SERVER, &crate::mig::topology::A30_SERVER])
    }

    /// Submit a task; it is routed to a worker whose server has the
    /// matching GPU model (round-robin among matches). Errors immediately
    /// if no server has that GPU.
    pub fn submit(&mut self, task: BenchTask) -> Result<TaskHandle, String> {
        let matches: Vec<usize> = self
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.gpu == task.gpu)
            .map(|(i, _)| i)
            .collect();
        if matches.is_empty() {
            return Err(format!("no benchmark server with GPU {:?}", task.gpu));
        }
        let target = matches[self.round_robin % matches.len()];
        self.round_robin += 1;
        let id = TaskHandle(self.next_id);
        self.next_id += 1;
        self.statuses.insert(id, TaskStatus::Pending);
        self.workers[target]
            .tx
            .send(WorkerMsg::Run(id, task))
            .map_err(|_| "worker thread died".to_string())?;
        Ok(id)
    }

    fn drain_results(&mut self, block_for: Option<TaskHandle>) {
        loop {
            let pending_target = block_for
                .map(|h| matches!(self.statuses.get(&h), Some(TaskStatus::Pending)))
                .unwrap_or(false);
            let msg = if pending_target {
                match self.results_rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => None,
                }
            } else {
                self.results_rx.try_recv().ok()
            };
            match msg {
                Some((id, Ok(report))) => {
                    self.statuses.insert(id, TaskStatus::Done(std::sync::Arc::new(report)));
                }
                Some((id, Err(e))) => {
                    self.statuses.insert(id, TaskStatus::Failed(e));
                }
                None => break,
            }
        }
    }

    /// Non-blocking status query.
    pub fn status(&mut self, id: TaskHandle) -> TaskStatus {
        self.drain_results(None);
        self.statuses.get(&id).cloned().unwrap_or(TaskStatus::Failed("unknown task".into()))
    }

    /// Block until a task finishes and return its report (or error).
    pub fn wait(&mut self, id: TaskHandle) -> Result<std::sync::Arc<BenchReport>, String> {
        self.drain_results(Some(id));
        match self.statuses.get(&id) {
            Some(TaskStatus::Done(r)) => Ok(r.clone()),
            Some(TaskStatus::Failed(e)) => Err(e.clone()),
            _ => Err("task did not complete".into()),
        }
    }

    /// Wait for a batch of tasks, preserving order.
    pub fn wait_all(
        &mut self,
        ids: &[TaskHandle],
    ) -> Vec<Result<std::sync::Arc<BenchReport>, String>> {
        ids.iter().map(|&id| self.wait(id)).collect()
    }

    /// Clone of the internal results sender (lets tests inject results).
    #[doc(hidden)]
    pub fn results_sender(&self) -> Sender<(TaskHandle, Result<BenchReport, String>)> {
        self.results_tx.clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(
    rx: Receiver<WorkerMsg>,
    results: Sender<(TaskHandle, Result<BenchReport, String>)>,
    engine: SweepEngine,
) {
    let session = ProfileSession::default().with_engine(engine);
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Run(id, task) => {
                let outcome = session.run(&task).map_err(|e| e.to_string());
                if results.send((id, outcome)).is_err() {
                    break; // coordinator gone
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::task::SweepAxis;
    use crate::workload::spec::WorkloadKind;

    fn task(gpu: GpuModel, name: &str) -> BenchTask {
        BenchTask {
            name: name.into(),
            gpu,
            gi_profiles: vec![if gpu == GpuModel::A100_80GB {
                "1g.10gb"
            } else {
                "1g.6gb"
            }
            .into()],
            model: "resnet18".into(),
            kind: WorkloadKind::Inference,
            batch: 4,
            seq: 224,
            sweep: SweepAxis::None,
            iterations: 10,
            layout: Default::default(),
        }
    }

    #[test]
    fn submits_and_completes() {
        let mut c = Coordinator::paper_testbed();
        let id = c.submit(task(GpuModel::A30_24GB, "t1")).unwrap();
        let report = c.wait(id).unwrap();
        assert_eq!(report.name, "t1");
        assert_eq!(report.rows().len(), 1);
    }

    #[test]
    fn routes_by_gpu_model() {
        let mut c = Coordinator::paper_testbed();
        let a = c.submit(task(GpuModel::A100_80GB, "a100")).unwrap();
        let b = c.submit(task(GpuModel::A30_24GB, "a30")).unwrap();
        let reports = c.wait_all(&[a, b]);
        assert!(reports.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn unroutable_gpu_rejected() {
        let mut c = Coordinator::new(&[&crate::mig::topology::A30_SERVER]);
        assert!(c.submit(task(GpuModel::A100_80GB, "x")).is_err());
    }

    #[test]
    fn failed_task_reports_error() {
        let mut c = Coordinator::paper_testbed();
        let mut t = task(GpuModel::A100_80GB, "bad");
        t.gi_profiles = vec!["4g.40gb".into(), "3g.40gb".into()]; // excluded combo
        t.layout = crate::profiler::task::LayoutMode::Concurrent;
        let id = c.submit(t).unwrap();
        let res = c.wait(id);
        assert!(res.is_err());
        // The controller's auto-placement finds no slot for 3g.40gb next
        // to 4g.40gb (NVIDIA exclusion rule).
        assert!(res.unwrap_err().contains("no valid placement"));
    }

    #[test]
    fn status_transitions() {
        let mut c = Coordinator::paper_testbed();
        let id = c.submit(task(GpuModel::A30_24GB, "s")).unwrap();
        let _ = c.wait(id);
        assert!(matches!(c.status(id), TaskStatus::Done(_)));
        assert!(matches!(c.status(TaskHandle(999)), TaskStatus::Failed(_)));
    }

    #[test]
    fn worker_engine_size_does_not_change_reports() {
        let mut t = task(GpuModel::A30_24GB, "det");
        t.sweep = SweepAxis::Batch(vec![1, 4, 8]);
        let mut serial = Coordinator::with_engine(
            &[&crate::mig::topology::A30_SERVER],
            SweepEngine::serial(),
        );
        let mut wide = Coordinator::with_engine(
            &[&crate::mig::topology::A30_SERVER],
            SweepEngine::new(4),
        );
        let ia = serial.submit(t.clone()).unwrap();
        let ra = serial.wait(ia).unwrap();
        let ib = wide.submit(t).unwrap();
        let rb = wide.wait(ib).unwrap();
        assert_eq!(ra.rows().len(), rb.rows().len());
        for (x, y) in ra.rows().iter().zip(rb.rows()) {
            assert_eq!(x.summary.throughput, y.summary.throughput);
            assert_eq!(x.summary.p99_latency_ms, y.summary.p99_latency_ms);
        }
    }

    #[test]
    fn many_tasks_in_parallel() {
        let mut c = Coordinator::paper_testbed();
        let ids: Vec<_> = (0..8)
            .map(|i| c.submit(task(GpuModel::A30_24GB, &format!("t{i}"))).unwrap())
            .collect();
        let reports = c.wait_all(&ids);
        assert_eq!(reports.len(), 8);
        assert!(reports.iter().all(|r| r.is_ok()));
    }
}
