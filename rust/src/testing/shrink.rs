//! Deterministic failing-sequence minimization and repro rendering.
//!
//! [`shrink`] is a ddmin-style reducer specialized to command
//! sequences: delete-chunk passes (chunk size n/2, halving down to 1)
//! remove whole command runs, then a halve-parameters pass shrinks the
//! numbers inside the survivors (burst sizes, time advances, spans)
//! toward small round values. Both passes are pure functions of the
//! input sequence — no randomness — so the same failure always minimizes
//! to the same repro, and the compiler's totality guarantee
//! ([`CommandSeq::compile`] accepts *every* sequence) means no candidate
//! ever has to be rejected as invalid.
//!
//! [`repro_string`] renders the result as pasteable Rust: the `Command`
//! grammar's `Debug` output is valid constructor syntax (and the
//! generator only emits dyadic parameters, so the decimals round-trip
//! exactly). Drop the snippet into `rust/tests/model_regressions.rs` to
//! pin the bug.

use crate::testing::command::{Command, CommandSeq};

/// Halve a command's magnitude parameters, preserving validity (the
/// compiler clamps anyway; halving just drives toward the floor). Time
/// *placement* parameters are left alone — deleting the preceding
/// `AdvanceTime` moves events, halving both would thrash.
fn halved(cmd: &Command) -> Command {
    match *cmd {
        Command::ArriveBurst { class, n, over_s } => {
            Command::ArriveBurst { class, n: (n / 2).max(1), over_s }
        }
        Command::AdvanceTime { dt_s } => Command::AdvanceTime { dt_s: (dt_s / 2.0).max(0.5) },
        ref c => c.clone(),
    }
}

/// Minimize a failing sequence. `fails` must return `true` for the input
/// (if it does not, the input is returned unchanged). The result still
/// fails and is 1-minimal under chunk deletion: removing any single
/// remaining command makes the failure disappear.
pub fn shrink(seq: &CommandSeq, fails: impl Fn(&CommandSeq) -> bool) -> CommandSeq {
    if !fails(seq) {
        return seq.clone();
    }
    let mut best = seq.clone();

    // Pass 1 — delete-chunk to a fixpoint: try removing spans of
    // halving sizes; restart at the large size after any success so
    // late deletions re-enable earlier ones.
    let mut progress = true;
    while progress {
        progress = false;
        let mut chunk = (best.commands.len() / 2).max(1);
        loop {
            let mut start = 0;
            while start < best.commands.len() {
                let end = (start + chunk).min(best.commands.len());
                let mut candidate = best.clone();
                candidate.commands.drain(start..end);
                if !candidate.commands.is_empty() && fails(&candidate) {
                    best = candidate;
                    progress = true;
                    // Do not advance: the next chunk now sits at `start`.
                } else {
                    start += 1;
                }
            }
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
    }

    // Pass 2 — halve parameters to a fixpoint: repeatedly halve each
    // command's magnitudes while the failure survives.
    let mut progress = true;
    while progress {
        progress = false;
        for i in 0..best.commands.len() {
            let h = halved(&best.commands[i]);
            if h == best.commands[i] {
                continue;
            }
            let mut candidate = best.clone();
            candidate.commands[i] = h;
            if fails(&candidate) {
                best = candidate;
                progress = true;
            }
        }
    }
    best
}

/// Render a sequence as a self-contained, pasteable repro block.
pub fn repro_string(seq: &CommandSeq) -> String {
    let mut s = String::new();
    s.push_str(&format!("let seq = CommandSeq {{\n    seed: {},\n    commands: vec![\n", seq.seed));
    for c in &seq.commands {
        s.push_str(&format!("        Command::{c:?},\n"));
    }
    s.push_str("    ],\n};\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(commands: Vec<Command>) -> CommandSeq {
        CommandSeq { seed: 7, commands }
    }

    #[test]
    fn shrinks_to_the_minimal_failing_core() {
        // Synthetic oracle: "fails" iff the sequence contains a CrashGpu
        // AND total burst volume ≥ 40. Everything else is noise the
        // shrinker must strip.
        let fails = |s: &CommandSeq| {
            let crash = s.commands.iter().any(|c| matches!(c, Command::CrashGpu { .. }));
            let volume: u64 = s
                .commands
                .iter()
                .map(|c| match c {
                    Command::ArriveBurst { n, .. } => *n,
                    _ => 0,
                })
                .sum();
            crash && volume >= 40
        };
        let noisy = seq(vec![
            Command::SetRolling { rolling: false },
            Command::ArriveBurst { class: 0, n: 100, over_s: 5.0 },
            Command::AdvanceTime { dt_s: 8.0 },
            Command::Repartition { gpu: 1, rate_scale: 1.5 },
            Command::CrashGpu { gpu: 0 },
            Command::ArriveBurst { class: 1, n: 100, over_s: 5.0 },
            Command::Recover { gpu: 0 },
            Command::SetRouter { router: 3 },
        ]);
        assert!(fails(&noisy));
        let min = shrink(&noisy, fails);
        assert!(fails(&min), "the minimized sequence must still fail");
        // Minimal core: one burst (halved down to the 40 threshold's
        // neighborhood) and one crash.
        assert_eq!(min.commands.len(), 2, "got: {}", repro_string(&min));
        assert!(min.commands.iter().any(|c| matches!(c, Command::CrashGpu { .. })));
        let volume: u64 = min
            .commands
            .iter()
            .map(|c| match c {
                Command::ArriveBurst { n, .. } => *n,
                _ => 0,
            })
            .sum();
        assert!(
            (40..80).contains(&volume),
            "halving must drive the burst toward the threshold, got {volume}"
        );
    }

    #[test]
    fn shrinker_is_deterministic_for_a_fixed_input() {
        let fails = |s: &CommandSeq| {
            s.commands.iter().filter(|c| matches!(c, Command::AdvanceTime { .. })).count() >= 2
        };
        let input = seq(vec![
            Command::AdvanceTime { dt_s: 16.0 },
            Command::ArriveBurst { class: 0, n: 10, over_s: 1.0 },
            Command::AdvanceTime { dt_s: 16.0 },
            Command::AdvanceTime { dt_s: 16.0 },
            Command::CrashGpu { gpu: 0 },
        ]);
        let a = shrink(&input, fails);
        let b = shrink(&input, fails);
        assert_eq!(a, b, "same input must minimize identically");
        assert_eq!(a.commands.len(), 2);
        assert!(a.commands.iter().all(|c| matches!(
            c,
            Command::AdvanceTime { dt_s } if *dt_s == 0.5
        )));
    }

    #[test]
    fn non_failing_input_is_returned_unchanged() {
        let input = seq(vec![Command::CrashGpu { gpu: 0 }]);
        let out = shrink(&input, |_| false);
        assert_eq!(out, input);
    }

    #[test]
    fn repro_round_trips_through_debug_syntax() {
        let input = seq(vec![
            Command::ArriveBurst { class: 0, n: 37, over_s: 2.5 },
            Command::CrashInstance { gpu: 1, class: 1 },
            Command::SetBrownout { threshold: 0.125 },
        ]);
        let r = repro_string(&input);
        assert!(r.contains("seed: 7"));
        assert!(r.contains("Command::ArriveBurst { class: 0, n: 37, over_s: 2.5 },"));
        assert!(r.contains("Command::CrashInstance { gpu: 1, class: 1 },"));
        assert!(r.contains("Command::SetBrownout { threshold: 0.125 },"));
    }
}
