//! Replays command sequences against the real engine and checks them.
//!
//! [`run_case`] compiles one [`CommandSeq`], runs it under an
//! [`InvariantInspector`] (live step-by-step checks through the engine's
//! [`EngineInspector`] hooks) and then applies the reference model's
//! closed-form checks ([`check_outcome`]) to the outcome. [`run_fuzz`]
//! fans many generated cases out through the [`SweepEngine`]; results
//! come back in input order, so the report digest is bitwise-identical
//! at any worker count, and every failing case is minimized by the
//! deterministic [`shrink`](crate::testing::shrink::shrink)er into a
//! pasteable repro.

use crate::cluster::engine::{EngineInspector, EngineProbe, FleetOutcome};
use crate::cluster::router::GpuHealth;
use crate::sweep::SweepEngine;
use crate::testing::command::CommandSeq;
use crate::testing::generate::generate;
use crate::testing::model::check_outcome;
use crate::testing::shrink::{repro_string, shrink};
use crate::util::prng::Prng;

/// Live invariant checker wired into the engine through the
/// [`EngineInspector`] hooks. It keeps its own crash ledger from the
/// `on_crash`/`on_recover` notifications and asserts, at every routing
/// decision, that the destination was eligible (health-gated, breaker
/// admitted) *at the moment of the decision* — catching
/// route-to-crashed/route-to-draining/route-past-open-breaker bugs the
/// end-of-run totals could mask.
#[derive(Debug)]
pub struct InvariantInspector {
    n_classes: usize,
    n_tenants: usize,
    gpu_down: Vec<bool>,
    replica_down: Vec<Vec<bool>>,
    prev_brownout: Option<usize>,
    routes_seen: u64,
    /// Violations observed live, in event order.
    pub violations: Vec<String>,
}

impl InvariantInspector {
    /// Inspector for a fleet of `n_gpus` GPUs serving `n_classes`
    /// classes across `n_tenants` tenants.
    pub fn new(n_gpus: usize, n_classes: usize, n_tenants: usize) -> Self {
        InvariantInspector {
            n_classes,
            n_tenants,
            gpu_down: vec![false; n_gpus],
            replica_down: vec![vec![false; n_classes]; n_gpus],
            prev_brownout: None,
            routes_seen: 0,
            violations: Vec::new(),
        }
    }

    /// Routing decisions observed (all dispatch paths).
    pub fn routes_seen(&self) -> u64 {
        self.routes_seen
    }
}

impl EngineInspector for InvariantInspector {
    fn on_route(&mut self, t: f64, gpu: usize, class: usize, probe: &EngineProbe) {
        self.routes_seen += 1;
        // The live eligibility predicate, probed at the exact moment the
        // router committed (before breaker bookkeeping consumes a
        // half-open probe).
        if !probe.may_route(gpu, class) {
            self.violations.push(format!(
                "t={t:.3}: routed class {class} to ineligible gpu {gpu} \
                 (health {:?}, replica_down {}, admits {})",
                probe.gpu_health(gpu),
                probe.replica_down(gpu, class),
                probe.gpu_admits(gpu)
            ));
        }
    }

    fn on_tick(&mut self, t: f64, probe: &EngineProbe) {
        let level = probe.brownout_level();
        let max_level = self.n_tenants.saturating_sub(1);
        if level > max_level {
            self.violations.push(format!(
                "t={t:.3}: brownout level {level} exceeds max {max_level}"
            ));
        }
        if let Some(prev) = self.prev_brownout {
            let step = level.abs_diff(prev);
            if step > 1 {
                self.violations.push(format!(
                    "t={t:.3}: brownout level jumped {prev} -> {level} in one tick"
                ));
            }
        }
        self.prev_brownout = Some(level);
    }

    fn on_crash(&mut self, t: f64, gpu: usize, class: Option<usize>, probe: &EngineProbe) {
        match class {
            None => {
                if probe.gpu_health(gpu) != GpuHealth::Down {
                    self.violations.push(format!(
                        "t={t:.3}: gpu {gpu} crashed but health is {:?}",
                        probe.gpu_health(gpu)
                    ));
                }
                // The crash dumps every queue on the GPU; anything left
                // would be silently lost without a ledger entry.
                for c in 0..self.n_classes {
                    if probe.queue_depth(gpu, c) != 0 || probe.replica_busy(gpu, c) {
                        self.violations.push(format!(
                            "t={t:.3}: gpu {gpu} class {c} kept work across a GPU crash"
                        ));
                    }
                }
                self.gpu_down[gpu] = true;
            }
            Some(c) => {
                if !probe.replica_down(gpu, c) {
                    self.violations.push(format!(
                        "t={t:.3}: replica ({gpu}, {c}) crashed but is not marked down"
                    ));
                }
                if probe.queue_depth(gpu, c) != 0 || probe.replica_busy(gpu, c) {
                    self.violations.push(format!(
                        "t={t:.3}: replica ({gpu}, {c}) kept work across an instance crash"
                    ));
                }
                self.replica_down[gpu][c] = true;
            }
        }
    }

    fn on_recover(&mut self, t: f64, gpu: usize, class: Option<usize>, probe: &EngineProbe) {
        match class {
            None => {
                if probe.gpu_health(gpu) == GpuHealth::Down {
                    self.violations.push(format!(
                        "t={t:.3}: gpu {gpu} recovered but health is still Down"
                    ));
                }
                if !self.gpu_down[gpu] {
                    self.violations.push(format!(
                        "t={t:.3}: gpu {gpu} recovered without a preceding crash"
                    ));
                }
                self.gpu_down[gpu] = false;
            }
            Some(c) => {
                if probe.replica_down(gpu, c) {
                    self.violations.push(format!(
                        "t={t:.3}: replica ({gpu}, {c}) recovered but is still down"
                    ));
                }
                if !self.replica_down[gpu][c] {
                    self.violations.push(format!(
                        "t={t:.3}: replica ({gpu}, {c}) recovered without a preceding crash"
                    ));
                }
                self.replica_down[gpu][c] = false;
            }
        }
    }
}

/// Why one case failed: the violations, with the sequence that produced
/// them.
#[derive(Debug, Clone)]
pub struct CaseFailure {
    /// The (unshrunk) failing sequence.
    pub seq: CommandSeq,
    /// Every violation: live inspector findings, model findings, or an
    /// engine error.
    pub violations: Vec<String>,
}

/// Compile and run one sequence against the real engine and the model.
/// `Ok` carries the outcome (regression tests assert extra facts on it);
/// `Err` carries every violation found.
pub fn run_case(seq: &CommandSeq) -> Result<FleetOutcome, CaseFailure> {
    let compiled = seq.compile();
    let cfg = compiled.config;
    let mut insp =
        InvariantInspector::new(cfg.gpus.len(), cfg.classes.len(), cfg.tenants.len().max(1));
    let out = match cfg.run_with_inspector(&mut insp) {
        Ok(out) => out,
        Err(e) => {
            return Err(CaseFailure {
                seq: seq.clone(),
                violations: vec![format!("engine error: {e}")],
            });
        }
    };
    let mut violations = insp.violations;
    violations.extend(check_outcome(&cfg, &out));
    if violations.is_empty() {
        Ok(out)
    } else {
        Err(CaseFailure { seq: seq.clone(), violations })
    }
}

/// One failing fuzz case, minimized.
#[derive(Debug, Clone)]
pub struct FailedCase {
    /// Case index within the run.
    pub index: usize,
    /// The derived per-case seed ([`generate`] with this seed and the
    /// run's `max_cmds` reproduces the unshrunk sequence).
    pub case_seed: u64,
    /// Violations from the original (unshrunk) sequence.
    pub violations: Vec<String>,
    /// The minimized sequence.
    pub minimized: CommandSeq,
    /// Self-contained pasteable repro of the minimized sequence.
    pub repro: String,
}

/// Result of a fuzz run.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    /// Cases executed.
    pub cases: usize,
    /// Master seed.
    pub seed: u64,
    /// Command-count cap per case.
    pub max_cmds: usize,
    /// FNV-1a digest over every case's outcome fingerprint, in case
    /// order — bitwise-identical at any worker count.
    pub digest: u64,
    /// The failing cases, minimized, in case order.
    pub failures: Vec<FailedCase>,
}

impl FuzzReport {
    /// True when every case passed.
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Per-case seed: a pure function of (master seed, index), so any worker
/// may compute it and a failing case replays standalone.
pub fn case_seed(seed: u64, index: u64) -> u64 {
    Prng::new(seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(index.wrapping_add(1))).next_u64()
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// The numbers a case contributes to the report digest: the whole
/// conservation ledger plus the bit patterns of the derived metrics.
fn fingerprint(out: &FleetOutcome) -> Vec<u64> {
    vec![
        out.arrived,
        out.routed,
        out.completed,
        out.slo_violations,
        out.failed_requests,
        out.lost_in_crash,
        out.shed_deadline,
        out.shed_capacity,
        out.shed_brownout,
        out.breaker_trips,
        out.reconfigurations,
        out.gpu_crashes,
        out.instance_crashes,
        out.goodput_rps.to_bits(),
        out.fairness_jain.to_bits(),
        out.availability.to_bits(),
    ]
}

/// Run `cases` generated cases on the worker pool. Failing cases are
/// shrunk serially afterwards (shrinking replays sequences, so keeping
/// it off the pool keeps the report digest independent of scheduling).
pub fn run_fuzz(cases: usize, seed: u64, max_cmds: usize, engine: &SweepEngine) -> FuzzReport {
    let idxs: Vec<u64> = (0..cases as u64).collect();
    let results: Vec<(Vec<u64>, Option<CaseFailure>)> = engine.run(&idxs, |&i| {
        let cs = case_seed(seed, i);
        let seq = generate(cs, max_cmds);
        match run_case(&seq) {
            Ok(out) => (fingerprint(&out), None),
            Err(f) => {
                // A failure's digest contribution is its violation text,
                // which is deterministic per case.
                let mut h = FNV_OFFSET;
                for v in &f.violations {
                    h = fnv1a(h, v.as_bytes());
                }
                (vec![u64::MAX, h], Some(f))
            }
        }
    });

    let mut digest = FNV_OFFSET;
    let mut failures = Vec::new();
    for (i, (fp, fail)) in results.into_iter().enumerate() {
        for w in &fp {
            digest = fnv1a(digest, &w.to_le_bytes());
        }
        if let Some(f) = fail {
            let minimized = shrink(&f.seq, |s| run_case(s).is_err());
            let repro = repro_string(&minimized);
            failures.push(FailedCase {
                index: i,
                case_seed: case_seed(seed, i as u64),
                violations: f.violations,
                minimized,
                repro,
            });
        }
    }
    FuzzReport { cases, seed, max_cmds, digest, failures }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_cases_run_clean() {
        // A pocket-sized version of the CI smoke: every generated case
        // must satisfy the live invariants and the reference model.
        for seed in 0..12u64 {
            let seq = generate(case_seed(7, seed), 16);
            if let Err(f) = run_case(&seq) {
                panic!(
                    "case seed {seed} violated the model:\n{}\nrepro:\n{}",
                    f.violations.join("\n"),
                    repro_string(&f.seq)
                );
            }
        }
    }

    #[test]
    fn fuzz_digest_is_worker_count_independent() {
        let serial = run_fuzz(8, 7, 12, &SweepEngine::serial());
        for workers in [2usize, 4, 16] {
            let par = run_fuzz(8, 7, 12, &SweepEngine::new(workers));
            assert_eq!(
                par.digest, serial.digest,
                "digest must be bitwise-identical at {workers} workers"
            );
            assert_eq!(par.failures.len(), serial.failures.len());
        }
    }

    #[test]
    fn case_seeds_are_distinct_and_stable() {
        let a: Vec<u64> = (0..64).map(|i| case_seed(42, i)).collect();
        let b: Vec<u64> = (0..64).map(|i| case_seed(42, i)).collect();
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), a.len(), "per-case seeds must not collide");
    }
}
