//! Deterministic pseudo-random number generation and distributions.
//!
//! The benchmark environment provides no external `rand` crate, so MIGPerf
//! carries its own small, fully deterministic PRNG. Every stochastic
//! component in the simulator (arrival processes, MPS interference spikes,
//! synthetic data) derives its randomness from an explicitly seeded
//! [`Prng`], which makes every figure bench reproducible bit-for-bit.
//!
//! The generator is SplitMix64 (Steele, Lea & Flood, OOPSLA'14): a tiny,
//! statistically solid 64-bit generator that is trivially splittable —
//! ideal for handing independent streams to concurrently simulated GPU
//! instances without cross-talk.

/// SplitMix64 pseudo-random number generator.
///
/// Passes BigCrush when used as a 64-bit generator; period 2^64. Not
/// cryptographic — strictly for simulation.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Create a generator from an explicit seed.
    pub fn new(seed: u64) -> Self {
        Prng { state: seed }
    }

    /// Derive an independent child stream. The child's seed is the parent's
    /// next output mixed with a distinct constant, so parent and child
    /// sequences do not overlap in practice.
    pub fn split(&mut self) -> Prng {
        let s = self.next_u64() ^ 0x9e37_79b9_7f4a_7c15;
        Prng::new(s.wrapping_mul(0xbf58_476d_1ce4_e5b9) | 1)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                m = (self.next_u64() as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial with success probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed sample with the given rate (λ).
    ///
    /// Used for Poisson arrival inter-arrival gaps (paper Figs 10–11).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // Inverse CDF; (1 - u) avoids ln(0).
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Standard normal sample via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64(); // (0, 1]
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal sample with mean `mu` and standard deviation `sigma`.
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal sample: `exp(N(mu, sigma))`.
    ///
    /// The MPS interference model uses log-normal inflation to produce the
    /// heavy latency tails the paper observes at large batch sizes.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Prng::new(7);
        let mut child = parent.split();
        let p: Vec<u64> = (0..32).map(|_| parent.next_u64()).collect();
        let c: Vec<u64> = (0..32).map(|_| child.next_u64()).collect();
        assert_ne!(p, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Prng::new(9);
        for n in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Prng::new(11);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean_close_to_inverse_rate() {
        let mut r = Prng::new(5);
        let rate = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_is_positive() {
        let mut r = Prng::new(17);
        for _ in 0..10_000 {
            assert!(r.lognormal(0.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(21);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Prng::new(23);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            match r.range_inclusive(-3, 3) {
                -3 => lo_seen = true,
                3 => hi_seen = true,
                x => assert!((-3..=3).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }
}
