"""L2 correctness: model shapes, loss behaviour and training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


class TestBert:
    def setup_method(self):
        self.cfg = model.TINY_BERT
        self.params = model.bert_init(self.cfg, seed=0)

    def test_param_specs_match_init(self):
        specs = model.bert_param_specs(self.cfg)
        assert len(specs) == len(self.params)
        for (name, shape), p in zip(specs, self.params):
            assert tuple(shape) == p.shape, name

    def test_forward_shape(self):
        tokens = jnp.zeros((2, self.cfg.max_seq), jnp.int32)
        logits = model.bert_forward(self.params, tokens, self.cfg)
        assert logits.shape == (2, self.cfg.max_seq, self.cfg.vocab)

    def test_pooled_shape(self):
        tokens = jnp.zeros((4, self.cfg.max_seq), jnp.int32)
        out = model.bert_infer_pooled(self.params, tokens, self.cfg)
        assert out.shape == (4, self.cfg.vocab)

    def test_forward_is_deterministic(self):
        key = jax.random.PRNGKey(0)
        tokens, _ = model.synthetic_batch(key, 2, self.cfg)
        a = model.bert_forward(self.params, tokens, self.cfg)
        b = model.bert_forward(self.params, tokens, self.cfg)
        np.testing.assert_array_equal(a, b)

    def test_initial_loss_near_uniform(self):
        # Untrained model ≈ uniform over vocab → loss ≈ ln(vocab).
        key = jax.random.PRNGKey(1)
        tokens, targets = model.synthetic_batch(key, 4, self.cfg)
        loss = float(model.bert_loss(self.params, tokens, targets, self.cfg))
        assert abs(loss - np.log(self.cfg.vocab)) < 1.0, loss

    def test_train_step_reduces_loss(self):
        key = jax.random.PRNGKey(2)
        tokens, targets = model.synthetic_batch(key, 8, self.cfg)
        params = self.params
        loss0, params = model.bert_train_step(params, tokens, targets, self.cfg)
        # Same batch repeatedly: loss must drop.
        for _ in range(10):
            loss, params = model.bert_train_step(params, tokens, targets, self.cfg)
        assert float(loss) < float(loss0), (float(loss0), float(loss))

    def test_train_step_preserves_shapes(self):
        key = jax.random.PRNGKey(3)
        tokens, targets = model.synthetic_batch(key, 8, self.cfg)
        _, new_params = model.bert_train_step(self.params, tokens, targets, self.cfg)
        assert len(new_params) == len(self.params)
        for a, b in zip(self.params, new_params):
            assert a.shape == b.shape

    def test_gradients_flow_to_all_params(self):
        key = jax.random.PRNGKey(4)
        tokens, targets = model.synthetic_batch(key, 2, self.cfg)
        grads = jax.grad(lambda p: model.bert_loss(p, tokens, targets, self.cfg))(
            list(self.params)
        )
        specs = model.bert_param_specs(self.cfg)
        for (name, _), g in zip(specs, grads):
            norm = float(jnp.abs(g).sum())
            # pos_emb rows beyond seq are unused but seq == max_seq here.
            assert norm > 0.0, f"no gradient for {name}"

    def test_synthetic_batch_is_shifted_copy(self):
        key = jax.random.PRNGKey(5)
        tokens, targets = model.synthetic_batch(key, 2, self.cfg)
        np.testing.assert_array_equal(np.roll(np.asarray(tokens), 1, axis=1), targets)
        assert tokens.dtype == jnp.int32
        assert int(tokens.max()) < self.cfg.vocab


class TestResNet:
    def setup_method(self):
        self.cfg = model.TINY_RESNET
        self.params = model.resnet_init(self.cfg, seed=1)

    def test_param_specs_match_init(self):
        specs = model.resnet_param_specs(self.cfg)
        assert len(specs) == len(self.params)
        for (name, shape), p in zip(specs, self.params):
            assert tuple(shape) == p.shape, name

    def test_forward_shape(self):
        images = jnp.zeros((3, 3, self.cfg.in_size, self.cfg.in_size), jnp.float32)
        logits = model.resnet_forward(self.params, images, self.cfg)
        assert logits.shape == (3, self.cfg.classes)

    def test_forward_finite(self):
        key = jax.random.PRNGKey(6)
        images = jax.random.normal(key, (2, 3, self.cfg.in_size, self.cfg.in_size))
        logits = np.asarray(model.resnet_forward(self.params, images, self.cfg))
        assert np.isfinite(logits).all()

    def test_batch_independence(self):
        # Per-sample outputs must not depend on other batch members.
        key = jax.random.PRNGKey(7)
        images = jax.random.normal(key, (4, 3, self.cfg.in_size, self.cfg.in_size))
        full = model.resnet_forward(self.params, images, self.cfg)
        solo = model.resnet_forward(self.params, images[:1], self.cfg)
        np.testing.assert_allclose(full[:1], solo, rtol=1e-5, atol=1e-5)


class TestBertBatchIndependence:
    def test_batch_independence(self):
        cfg = model.TINY_BERT
        params = model.bert_init(cfg, seed=0)
        key = jax.random.PRNGKey(8)
        tokens, _ = model.synthetic_batch(key, 4, cfg)
        full = model.bert_forward(params, tokens, cfg)
        solo = model.bert_forward(params, tokens[:1], cfg)
        np.testing.assert_allclose(full[:1], solo, rtol=1e-4, atol=1e-4)
