//! Minimal Rust source tokenizer for the determinism auditor.
//!
//! Produces a flat token stream (identifiers, literals, punctuation) plus
//! the `//` line comments, with string, char, raw-string and comment
//! interiors fully opaque — so no rule can ever fire on text that merely
//! *looks* like code inside a literal or a comment. This is deliberately
//! not a full Rust lexer: it covers exactly the subset the `lint` rules
//! need, and every rule shares it so they all agree on what is code.
//!
//! Handled: line and (nested) block comments, plain strings with escapes,
//! raw strings `r"…"`/`r#"…"#` with any hash count, byte strings and byte
//! chars (`b"…"`, `br#"…"#`, `b'x'`), char literals vs lifetimes
//! (`'a'` vs `'a`), raw identifiers (`r#type`), numeric literals with
//! separators/suffixes/exponents, and multi-char operators joined into
//! single tokens (so `>=` is never mistaken for an assignment).

/// Kind of one lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`foo`, `for`; `r#type` lexes as `type`).
    Ident,
    /// Numeric literal, including suffixes (`1_000u32`, `0xff`, `1.5e-3`).
    Num,
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`, `'_`).
    Lifetime,
    /// Punctuation; multi-char operators are joined (`::`, `>=`, `+=`).
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    /// What kind of token this is.
    pub kind: TokKind,
    /// Identifier name or operator text; empty for literals.
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

impl Tok {
    /// True if this token is the identifier `name`.
    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == TokKind::Ident && self.text == name
    }

    /// True if this token is the punctuation `op`.
    pub fn is_punct(&self, op: &str) -> bool {
        self.kind == TokKind::Punct && self.text == op
    }
}

/// One `//` line comment. Block comments are skipped entirely: the
/// suppression syntax is line-comment-only by design, so a stale
/// suppression can never hide inside a folded block comment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Comment {
    /// Text after the `//`, untrimmed.
    pub text: String,
    /// 1-based line of the comment.
    pub line: u32,
    /// True when only whitespace precedes the `//` on its line: the
    /// comment stands alone and annotates the next code line.
    pub leading: bool,
}

/// Lexer output: the token stream and the line comments.
#[derive(Debug, Clone, Default)]
pub struct Lexed {
    /// All non-comment tokens in source order.
    pub toks: Vec<Tok>,
    /// All `//` comments in source order.
    pub comments: Vec<Comment>,
}

/// Three-char operators, matched before two- and one-char ones.
const OPS3: &[&str] = &["<<=", ">>=", "..=", "..."];
/// Two-char operators.
const OPS2: &[&str] = &[
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "<<", ">>", "+=", "-=", "*=", "/=",
    "%=", "^=", "&=", "|=", "..",
];

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: unrecognized bytes become one-char
/// `Punct` tokens, so hostile input degrades to noise rather than a
/// missed or phantom rule match.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    // True until the first token on the current line; a `//` seen while
    // this holds is a leading (annotation-style) comment.
    let mut leading = true;

    let at = |i: usize| if i < n { b[i] } else { '\0' };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            leading = true;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && at(i + 1) == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            out.comments.push(Comment {
                text: b[start..j].iter().collect(),
                line,
                leading,
            });
            i = j;
            continue;
        }
        // Block comment (nested).
        if c == '/' && at(i + 1) == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && at(j + 1) == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && at(j + 1) == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw strings, raw identifiers, byte strings, byte chars.
        if c == 'r' || c == 'b' {
            // br"…" / br#"…"# (byte raw string).
            if c == 'b' && at(i + 1) == 'r' && (at(i + 2) == '"' || at(i + 2) == '#') {
                let start_line = line;
                if let Some(j) = scan_raw_string(&b, i + 2, &mut line) {
                    out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
                    leading = false;
                    i = j;
                    continue;
                }
            }
            // r"…" / r#"…"# (raw string) or r#ident (raw identifier).
            if c == 'r' && (at(i + 1) == '"' || at(i + 1) == '#') {
                let start_line = line;
                if let Some(j) = scan_raw_string(&b, i + 1, &mut line) {
                    out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
                    leading = false;
                    i = j;
                    continue;
                }
                if at(i + 1) == '#' && is_ident_start(at(i + 2)) {
                    let mut j = i + 2;
                    while j < n && is_ident_char(b[j]) {
                        j += 1;
                    }
                    let text: String = b[i + 2..j].iter().collect();
                    out.toks.push(Tok { kind: TokKind::Ident, text, line });
                    leading = false;
                    i = j;
                    continue;
                }
            }
            // b"…" (byte string with escapes).
            if c == 'b' && at(i + 1) == '"' {
                let start_line = line;
                i = scan_string(&b, i + 1, &mut line);
                out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
                leading = false;
                continue;
            }
            // b'…' (byte char).
            if c == 'b' && at(i + 1) == '\'' {
                if let Some(j) = scan_char(&b, i + 1) {
                    out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                    leading = false;
                    i = j;
                    continue;
                }
            }
            // Fall through: plain identifier starting with r/b.
        }
        // Plain string.
        if c == '"' {
            let start_line = line;
            i = scan_string(&b, i, &mut line);
            out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line: start_line });
            leading = false;
            continue;
        }
        // Char literal or lifetime.
        if c == '\'' {
            if let Some(j) = scan_char(&b, i) {
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                leading = false;
                i = j;
                continue;
            }
            // Lifetime: consume ident chars after the quote.
            let mut j = i + 1;
            while j < n && is_ident_char(b[j]) {
                j += 1;
            }
            let text: String = b[i..j].iter().collect();
            out.toks.push(Tok { kind: TokKind::Lifetime, text, line });
            leading = false;
            i = j.max(i + 1);
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_char(b[j]) {
                j += 1;
            }
            let text: String = b[i..j].iter().collect();
            out.toks.push(Tok { kind: TokKind::Ident, text, line });
            leading = false;
            i = j;
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            i = scan_number(&b, i);
            out.toks.push(Tok { kind: TokKind::Num, text: String::new(), line });
            leading = false;
            continue;
        }
        // Punctuation, longest operators first.
        let rest3: String = b[i..n.min(i + 3)].iter().collect();
        let rest2: String = b[i..n.min(i + 2)].iter().collect();
        let (text, len) = if OPS3.contains(&rest3.as_str()) {
            (rest3, 3)
        } else if OPS2.contains(&rest2.as_str()) {
            (rest2, 2)
        } else {
            (c.to_string(), 1)
        };
        out.toks.push(Tok { kind: TokKind::Punct, text, line });
        leading = false;
        i += len;
    }
    out
}

/// Scan a `"…"` string with `\`-escapes; `start` is the opening quote.
/// Returns the index one past the closing quote (or end of input).
fn scan_string(b: &[char], start: usize, line: &mut u32) -> usize {
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        match b[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            '\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    n
}

/// Scan a raw string whose hash-run (possibly empty) begins at `start`
/// (`start` points at the first `#` or the opening `"`). Returns the
/// index one past the closing delimiter, or `None` if this is not a raw
/// string opener (e.g. `r#ident`).
fn scan_raw_string(b: &[char], start: usize, line: &mut u32) -> Option<usize> {
    let n = b.len();
    let mut hashes = 0usize;
    let mut j = start;
    while j < n && b[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || b[j] != '"' {
        return None;
    }
    j += 1;
    while j < n {
        if b[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if b[j] == '"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(n)
}

/// Scan a char literal whose opening quote is at `start`. Returns the
/// index one past the closing quote, or `None` if this is a lifetime.
fn scan_char(b: &[char], start: usize) -> Option<usize> {
    let n = b.len();
    let next = if start + 1 < n { b[start + 1] } else { '\0' };
    if next == '\\' {
        // Escaped char: `'\n'`, `'\''`, `'\u{1F600}'`.
        let mut j = start + 2;
        if j < n && b[j] == 'u' && j + 1 < n && b[j + 1] == '{' {
            j += 2;
            while j < n && b[j] != '}' {
                j += 1;
            }
            j += 1;
        } else {
            j += 1;
        }
        if j < n && b[j] == '\'' {
            return Some(j + 1);
        }
        return None;
    }
    // Unescaped char: exactly one char then a closing quote.
    if next != '\0' && next != '\'' && start + 2 < n && b[start + 2] == '\'' {
        return Some(start + 3);
    }
    None
}

/// Scan a numeric literal starting at `start` (an ASCII digit). Returns
/// the index one past the literal. Tuple indices stay separate: `a.0.fmt`
/// lexes as `a` `.` `0` `.` `fmt` because the fractional dot is only
/// consumed when a digit follows it.
fn scan_number(b: &[char], start: usize) -> usize {
    let n = b.len();
    let mut j = start;
    // Radix prefixes consume alphanumerics wholesale (0xff_u8, 0b1010).
    if b[j] == '0' && j + 1 < n && matches!(b[j + 1], 'x' | 'o' | 'b') {
        j += 2;
        while j < n && is_ident_char(b[j]) {
            j += 1;
        }
        return j;
    }
    while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
        j += 1;
    }
    // Fractional part only if a digit follows the dot (not `0..10`).
    if j < n && b[j] == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
        j += 1;
        while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
            j += 1;
        }
    }
    // Exponent with optional sign.
    if j < n && (b[j] == 'e' || b[j] == 'E') {
        let sign = j + 1 < n && (b[j + 1] == '+' || b[j + 1] == '-');
        let digit_at = j + if sign { 2 } else { 1 };
        if digit_at < n && b[digit_at].is_ascii_digit() {
            j = digit_at;
            while j < n && (b[j].is_ascii_digit() || b[j] == '_') {
                j += 1;
            }
        }
    }
    // Type suffix (u32, f64, usize).
    while j < n && is_ident_char(b[j]) {
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn idents_and_puncts() {
        let l = lex("let x = a.partial_cmp(&b);");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["let", "x", "=", "a", ".", "partial_cmp", "(", "&", "b", ")", ";"]);
    }

    #[test]
    fn multi_char_ops_are_joined() {
        let l = lex("a >= b; c += 1; d == e; f => g; h..=i; j <<= 2;");
        let ops: Vec<String> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Punct)
            .map(|t| t.text.clone())
            .collect();
        assert!(ops.contains(&">=".to_string()));
        assert!(ops.contains(&"+=".to_string()));
        assert!(ops.contains(&"==".to_string()));
        assert!(ops.contains(&"=>".to_string()));
        assert!(ops.contains(&"..=".to_string()));
        assert!(ops.contains(&"<<=".to_string()));
        // `>=` must never decompose into a bare `=`.
        assert!(!ops.contains(&"=".to_string()));
    }

    #[test]
    fn strings_are_opaque() {
        let src = r#"let s = "Instant::now() HashMap.iter() // not a comment";"#;
        assert_eq!(idents(src), vec!["let", "s"]);
    }

    #[test]
    fn raw_strings_are_opaque() {
        let src = "let s = r#\"thread_rng() \"quoted\" SystemTime\"#; after();";
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "s", "after"]);
    }

    #[test]
    fn byte_and_raw_byte_strings_are_opaque() {
        let ids = idents("let a = b\"Instant\"; let c = br#\"HashMap\"#; tail();");
        assert_eq!(ids, vec!["let", "a", "let", "c", "tail"]);
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let src = "x(); // trailing Instant::now()\n  // lint:allow(wall-clock, reason=\"x\")\ny();";
        let l = lex(src);
        assert_eq!(l.comments.len(), 2);
        assert!(!l.comments[0].leading, "trailing comment after code");
        assert_eq!(l.comments[0].line, 1);
        assert!(l.comments[1].leading, "comment alone on its line");
        assert_eq!(l.comments[1].line, 2);
        // The comment text never reaches the token stream.
        assert_eq!(idents(src), vec!["x", "y"]);
    }

    #[test]
    fn block_comments_skipped_with_nesting_and_lines() {
        let src = "a();\n/* outer /* nested */ still comment\nInstant::now() */\nb();";
        let l = lex(src);
        assert_eq!(idents(src), vec!["a", "b"]);
        // Line counting survives the block comment.
        assert_eq!(l.toks.last().unwrap().line, 4);
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let l = lex("let c = 'x'; fn f<'a>(v: &'a str) { let q = '\\n'; }");
        let chars = l.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        let lifetimes = l.toks.iter().filter(|t| t.kind == TokKind::Lifetime).count();
        assert_eq!(chars, 2, "'x' and '\\n'");
        assert_eq!(lifetimes, 2, "<'a> and &'a");
    }

    #[test]
    fn unicode_escape_char_literal() {
        let l = lex("let c = '\\u{1F600}'; done();");
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
        assert!(l.toks.iter().any(|t| t.is_ident("done")));
    }

    #[test]
    fn raw_identifier() {
        let ids = idents("let r#type = 1; use r#fn;");
        assert_eq!(ids, vec!["let", "type", "use", "fn"]);
    }

    #[test]
    fn tuple_index_does_not_eat_method_call() {
        let l = lex("a.0.cmp(&b.0)");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        // `0` lexes as a number, `.cmp` stays a separate method call.
        assert!(texts.contains(&"cmp"));
    }

    #[test]
    fn range_is_not_a_float() {
        let l = lex("for i in 0..10 {}");
        assert!(l.toks.iter().any(|t| t.is_punct("..")));
    }

    #[test]
    fn numbers_with_suffixes_and_exponents() {
        let l = lex("let a = 1_000u32 + 0xff_u8 + 1.5e-3 + 2.0f64;");
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Num).count(), 4);
    }

    #[test]
    fn lines_are_tracked_through_strings() {
        let src = "let a = \"line\nbreak\";\nlet b = 1;";
        let l = lex(src);
        let b_tok = l.toks.iter().find(|t| t.is_ident("b")).unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn hostile_mix_never_leaks_literal_interiors() {
        // Every hazard the rules look for, hidden inside literals and
        // comments; the token stream must contain none of them.
        let src = concat!(
            "// Instant::now() in a comment\n",
            "/* HashMap::new().iter() */\n",
            "let a = \"thread_rng()\";\n",
            "let b = r\"SystemTime::now()\";\n",
            "let c = 'I';\n",
            "let d = \"sort_unstable_by\";\n",
        );
        let ids = idents(src);
        for hazard in ["Instant", "HashMap", "thread_rng", "SystemTime", "sort_unstable_by"] {
            assert!(!ids.iter().any(|i| i == hazard), "{hazard} leaked out of a literal");
        }
    }
}
