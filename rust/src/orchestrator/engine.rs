//! The online orchestration engine.
//!
//! Runs a hybrid training + inference workload mix inside the
//! discrete-event simulator under time-varying load, consulting a
//! [`Policy`](super::policy::Policy) at fixed observation windows and
//! paying the explicit [`ReconfigCost`] whenever the policy repartitions
//! the GPU:
//!
//! 1. **decide** — at a window tick the policy proposes a new
//!    [`RatePlan`]; the engine validates its layout against the MIG
//!    placement rules;
//! 2. **drain** — no new requests or training steps start; in-flight work
//!    completes under the old layout;
//! 3. **churn** — destroyed + created instances each cost
//!    `instance_churn_s` of downtime; queued arrivals keep accumulating;
//! 4. **resume** — services restart on their new instances, training
//!    resumes after an extra `train_restore_s` checkpoint-restore penalty.
//!
//! Everything is seeded and iteration-order deterministic, so orchestrator
//! runs are bit-identical at any sweep worker count.

use std::collections::VecDeque;

use crate::metrics::collector::{MetricsCollector, RunSummary};
use crate::mig::enumerate::Layout;
use crate::mig::gpu::GpuModel;
use crate::mig::placement::PlacementEngine;
use crate::scheduler::{DemandWorkload, RatePlan, Scheduler};
use crate::simgpu::desim::Des;
use crate::simgpu::perfmodel::{PerfError, StepEstimate};
use crate::simgpu::resource::ExecResource;
use crate::util::prng::Prng;
use crate::util::stats::percentile_sorted;
use crate::workload::arrival::{ArrivalError, ArrivalProcess, ArrivalSpec};
use crate::workload::serving::pool_collectors;
use crate::workload::spec::WorkloadSpec;

use super::cost::{churn, ReconfigCost};
use super::policy::{Policy, PolicyCtx, PolicyKind, ServiceObs, WindowObs};

/// One latency-bound inference service under orchestration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// The per-request workload.
    pub spec: WorkloadSpec,
    /// Latency SLO, milliseconds.
    pub slo_ms: f64,
    /// Arrival process driving the service.
    pub arrival: ArrivalSpec,
}

/// A complete orchestrator simulation (plain data: clone freely into
/// sweep grids).
#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    /// GPU being orchestrated.
    pub gpu: GpuModel,
    /// Best-effort training job co-located with the services, if any.
    pub train: Option<WorkloadSpec>,
    /// The inference services.
    pub services: Vec<ServiceConfig>,
    /// Repartitioning policy.
    pub policy: PolicyKind,
    /// Reconfiguration cost model.
    pub cost: ReconfigCost,
    /// Simulated run length, seconds.
    pub duration_s: f64,
    /// Observation-window length (policy tick period), seconds.
    pub window_s: f64,
    /// Utilization bound the planner sizes services for (ρ_max).
    pub rho_max: f64,
    /// PRNG seed (arrival streams derive per-service seeds from it).
    pub seed: u64,
}

/// Why an orchestrator run failed.
#[derive(Debug)]
pub enum OrchError {
    /// Configuration rejected before the simulation started.
    Invalid(String),
    /// No valid layout can host the workloads.
    Infeasible(String),
    /// An arrival process could not be constructed.
    Arrival(ArrivalError),
    /// A workload failed to fit its assigned instance.
    Perf(PerfError),
}

impl std::fmt::Display for OrchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OrchError::Invalid(m) => write!(f, "invalid orchestrator config: {m}"),
            OrchError::Infeasible(m) => write!(f, "infeasible: {m}"),
            OrchError::Arrival(e) => write!(f, "arrival process: {e}"),
            OrchError::Perf(e) => write!(f, "performance model: {e}"),
        }
    }
}

impl std::error::Error for OrchError {}

impl From<ArrivalError> for OrchError {
    fn from(e: ArrivalError) -> Self {
        OrchError::Arrival(e)
    }
}

impl From<PerfError> for OrchError {
    fn from(e: PerfError) -> Self {
        OrchError::Perf(e)
    }
}

/// One repartitioning event in the decision log.
#[derive(Debug, Clone)]
pub struct Decision {
    /// Time the policy decided to repartition (simulated seconds).
    pub t: f64,
    /// Layout before the switch (`+`-joined profile names).
    pub from: String,
    /// Layout after the switch.
    pub to: String,
    /// Window observation that motivated the move.
    pub reason: String,
    /// Instances destroyed plus created by the switch.
    pub churn: u32,
    /// Seconds from decision to resume (drain + instance churn).
    pub downtime_s: f64,
}

/// Aggregate result of one orchestrator run.
#[derive(Debug, Clone)]
pub struct OrchestratorOutcome {
    /// Policy that produced the run.
    pub policy: &'static str,
    /// Simulated run length, seconds.
    pub duration_s: f64,
    /// Pooled serving summary (exact pooled percentiles).
    pub pooled: RunSummary,
    /// Per-service serving summaries.
    pub per_service: Vec<RunSummary>,
    /// Requests that arrived within the horizon.
    pub arrived: u64,
    /// Requests completed (including backlog served after the horizon).
    pub completed: u64,
    /// Completions that blew their SLO.
    pub slo_violations: u64,
    /// SLO-respecting completions per second over the run (requests/s).
    pub goodput_rps: f64,
    /// Fraction of completions that blew their SLO.
    pub slo_violation_frac: f64,
    /// Training steps completed.
    pub train_steps: u64,
    /// Training throughput over the run, samples/s.
    pub train_samples_per_s: f64,
    /// Number of repartitions executed.
    pub reconfigurations: u64,
    /// Total downtime paid to repartitions, seconds.
    pub reconfig_downtime_s: f64,
    /// Every layout adopted, in order (initial layout first).
    pub layouts: Vec<Layout>,
    /// Per-repartition decision log.
    pub decisions: Vec<Decision>,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive { svc: usize },
    ServeDone { svc: usize },
    TrainDone,
    Tick,
    ReconfigDone,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    Running,
    Draining,
    Reconfiguring,
}

struct SvcState {
    queue: VecDeque<f64>, // arrival timestamps
    busy: bool,
    busy_since: f64,
    arrived: u64,
    slo_met: u64,
    violations: u64,
    window_arrivals: u64,
    window_completed: u64,
    window_violations: u64,
    window_busy_s: f64,
    window_lat: Vec<f64>,
}

fn start_service(des: &mut Des<Ev>, st: &mut SvcState, svc: usize, now: f64, service_s: f64) {
    debug_assert!(!st.busy, "server {svc} already busy");
    st.busy = true;
    st.busy_since = now;
    des.schedule_in(service_s, Ev::ServeDone { svc });
}

/// Drain barrier: once every server and the training job are idle (and a
/// repartition is pending), the instance churn begins and `ReconfigDone`
/// is scheduled.
fn maybe_begin_reconfig(
    des: &mut Des<Ev>,
    phase: &mut Phase,
    svcs: &[SvcState],
    train_busy: bool,
    current: &Layout,
    pending: &Option<(RatePlan, f64, String)>,
    cost: &ReconfigCost,
) {
    let Some((target, _, _)) = pending else { return };
    if *phase == Phase::Draining && !train_busy && svcs.iter().all(|s| !s.busy) {
        *phase = Phase::Reconfiguring;
        des.schedule_in(cost.latency_s(current, &target.layout), Ev::ReconfigDone);
    }
}

impl OrchestratorConfig {
    /// Reject configurations that would produce NaN clocks or degenerate
    /// simulations.
    pub fn validate(&self) -> Result<(), OrchError> {
        if self.services.is_empty() {
            return Err(OrchError::Invalid("at least one service is required".into()));
        }
        if !(self.duration_s.is_finite() && self.duration_s > 0.0) {
            return Err(OrchError::Invalid(format!(
                "duration_s = {} must be positive and finite",
                self.duration_s
            )));
        }
        if !(self.window_s.is_finite() && self.window_s > 0.0) {
            return Err(OrchError::Invalid(format!(
                "window_s = {} must be positive and finite",
                self.window_s
            )));
        }
        if self.window_s >= self.duration_s {
            return Err(OrchError::Invalid(format!(
                "window_s = {} must be smaller than duration_s = {}: no policy tick \
                 would ever fire, so every policy would silently behave as static",
                self.window_s, self.duration_s
            )));
        }
        if !(self.rho_max.is_finite() && self.rho_max > 0.0 && self.rho_max < 1.0) {
            return Err(OrchError::Invalid(format!(
                "rho_max = {} must be in (0, 1)",
                self.rho_max
            )));
        }
        for (i, s) in self.services.iter().enumerate() {
            if !(s.slo_ms.is_finite() && s.slo_ms > 0.0) {
                return Err(OrchError::Invalid(format!(
                    "service {i}: slo_ms = {} must be positive and finite",
                    s.slo_ms
                )));
            }
            s.arrival.validate()?;
        }
        self.cost.validate().map_err(OrchError::Invalid)
    }

    /// The demand-workload vector handed to the planner: training (if
    /// any) first, then services with their whole-trace mean rates.
    fn demand_workloads(&self) -> (Vec<DemandWorkload>, Vec<usize>) {
        let mut ws = Vec::with_capacity(self.services.len() + 1);
        if let Some(t) = &self.train {
            ws.push(DemandWorkload::training(t.clone()));
        }
        let base = ws.len();
        let service_workloads: Vec<usize> =
            (0..self.services.len()).map(|i| base + i).collect();
        for s in &self.services {
            ws.push(DemandWorkload::service(s.spec.clone(), s.slo_ms, s.arrival.mean_rate()));
        }
        (ws, service_workloads)
    }

    /// Resolve a plan into per-service step estimates + power draws and
    /// the training estimate.
    fn materialize(
        &self,
        scheduler: &Scheduler,
        plan: &RatePlan,
        svc_base: usize,
    ) -> Result<(Vec<StepEstimate>, Vec<f64>, Option<StepEstimate>), OrchError> {
        let mut svc_est = Vec::with_capacity(self.services.len());
        let mut svc_power = Vec::with_capacity(self.services.len());
        for (i, s) in self.services.iter().enumerate() {
            let inst = plan.instance_of(svc_base + i).ok_or_else(|| {
                OrchError::Infeasible(format!("service {i} missing from the plan"))
            })?;
            let res = ExecResource::from_gi(self.gpu, plan.layout.placements[inst].profile);
            let est = scheduler.perf.step(&res, &s.spec.step_cost())?;
            svc_power.push(scheduler.energy.power_w(&res, est.gract));
            svc_est.push(est);
        }
        let train_est = match &self.train {
            Some(spec) => {
                let inst = plan
                    .instance_of(0)
                    .ok_or_else(|| OrchError::Infeasible("training missing from the plan".into()))?;
                let res = ExecResource::from_gi(self.gpu, plan.layout.placements[inst].profile);
                Some(scheduler.perf.step(&res, &spec.step_cost())?)
            }
            None => None,
        };
        Ok((svc_est, svc_power, train_est))
    }

    /// Run the orchestrated simulation to completion.
    pub fn run(&self) -> Result<OrchestratorOutcome, OrchError> {
        self.validate()?;
        let scheduler = Scheduler::new(self.gpu);
        let placement = PlacementEngine::new(self.gpu);
        let (workloads, service_workloads) = self.demand_workloads();
        let svc_base = workloads.len() - self.services.len();

        // Initial layout: what the offline optimizer picks for the
        // whole-trace average rates — every policy starts from the same
        // baseline plan.
        let mut plan = scheduler.plan_for_demand(&workloads, self.rho_max).ok_or_else(|| {
            OrchError::Infeasible(
                "no maximal layout hosts every workload at whole-trace mean rates".into(),
            )
        })?;
        placement
            .check_layout(&plan.layout.placements)
            .map_err(|e| OrchError::Infeasible(e.to_string()))?;
        let (mut svc_est, mut svc_power, mut train_est) =
            self.materialize(&scheduler, &plan, svc_base)?;

        let n = self.services.len();
        let mut seeder = Prng::new(self.seed);
        let mut arrivals: Vec<ArrivalProcess> = Vec::with_capacity(n);
        for s in &self.services {
            arrivals.push(s.arrival.build(seeder.next_u64())?);
        }

        let mut des: Des<Ev> = Des::new();
        let mut svcs: Vec<SvcState> = (0..n)
            .map(|_| SvcState {
                queue: VecDeque::new(),
                busy: false,
                busy_since: 0.0,
                arrived: 0,
                slo_met: 0,
                violations: 0,
                window_arrivals: 0,
                window_completed: 0,
                window_violations: 0,
                window_busy_s: 0.0,
                window_lat: Vec::new(),
            })
            .collect();
        let mut collectors: Vec<MetricsCollector> = self
            .services
            .iter()
            .enumerate()
            .map(|(i, s)| MetricsCollector::new(format!("{}#{}", s.spec.label(), i)))
            .collect();

        let mut policy = self.policy.build();
        let mut phase = Phase::Running;
        // (target plan, decision time, reason) while draining/churning.
        let mut pending: Option<(RatePlan, f64, String)> = None;
        let mut train_busy = false;
        let mut train_steps: u64 = 0;
        let mut window_train_steps: u64 = 0;
        let mut last_change_t = 0.0;
        let mut reconfig_downtime = 0.0;
        let mut decisions: Vec<Decision> = Vec::new();
        let mut layouts: Vec<Layout> = vec![plan.layout.clone()];

        // Seed the calendar.
        for (i, a) in arrivals.iter_mut().enumerate() {
            let t0 = a.next_gap();
            if t0.is_finite() && t0 <= self.duration_s {
                des.schedule_at(t0, Ev::Arrive { svc: i });
            }
        }
        if let Some(est) = &train_est {
            train_busy = true;
            des.schedule_at(est.seconds, Ev::TrainDone);
        }
        if self.window_s < self.duration_s {
            des.schedule_at(self.window_s, Ev::Tick);
        }

        while let Some((t, ev)) = des.next() {
            match ev {
                Ev::Arrive { svc } => {
                    svcs[svc].arrived += 1;
                    svcs[svc].window_arrivals += 1;
                    svcs[svc].queue.push_back(t);
                    let gap = arrivals[svc].next_gap();
                    if gap.is_finite() && t + gap <= self.duration_s {
                        des.schedule_at(t + gap, Ev::Arrive { svc });
                    }
                    if phase == Phase::Running && !svcs[svc].busy {
                        start_service(&mut des, &mut svcs[svc], svc, t, svc_est[svc].seconds);
                    }
                }
                Ev::ServeDone { svc } => {
                    {
                        let st = &mut svcs[svc];
                        let arrived_at = st.queue.pop_front().expect("completion without request");
                        st.busy = false;
                        let busy_s = t - st.busy_since;
                        st.window_busy_s += busy_s;
                        let latency_ms = (t - arrived_at) * 1e3;
                        collectors[svc].record_completion(
                            t,
                            latency_ms,
                            self.services[svc].spec.batch as u64,
                        );
                        collectors[svc].record_energy(svc_power[svc] * busy_s);
                        collectors[svc].record_gract(svc_est[svc].gract);
                        collectors[svc].record_fb(svc_est[svc].fb_bytes);
                        st.window_completed += 1;
                        st.window_lat.push(latency_ms);
                        if latency_ms > self.services[svc].slo_ms {
                            st.violations += 1;
                            st.window_violations += 1;
                        } else {
                            st.slo_met += 1;
                        }
                    }
                    match phase {
                        Phase::Running => {
                            if !svcs[svc].queue.is_empty() {
                                start_service(
                                    &mut des,
                                    &mut svcs[svc],
                                    svc,
                                    t,
                                    svc_est[svc].seconds,
                                );
                            }
                        }
                        Phase::Draining => {
                            maybe_begin_reconfig(
                                &mut des,
                                &mut phase,
                                &svcs,
                                train_busy,
                                &plan.layout,
                                &pending,
                                &self.cost,
                            );
                        }
                        Phase::Reconfiguring => {}
                    }
                }
                Ev::TrainDone => {
                    train_busy = false;
                    train_steps += 1;
                    window_train_steps += 1;
                    match phase {
                        Phase::Running => {
                            if t < self.duration_s {
                                if let Some(est) = &train_est {
                                    train_busy = true;
                                    des.schedule_in(est.seconds, Ev::TrainDone);
                                }
                            }
                        }
                        Phase::Draining => {
                            maybe_begin_reconfig(
                                &mut des,
                                &mut phase,
                                &svcs,
                                train_busy,
                                &plan.layout,
                                &pending,
                                &self.cost,
                            );
                        }
                        Phase::Reconfiguring => {}
                    }
                }
                Ev::Tick => {
                    let mut services_obs = Vec::with_capacity(n);
                    for st in svcs.iter_mut() {
                        st.window_lat.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
                        services_obs.push(ServiceObs {
                            arrivals: st.window_arrivals,
                            rate_rps: st.window_arrivals as f64 / self.window_s,
                            completed: st.window_completed,
                            violations: st.window_violations,
                            p99_ms: percentile_sorted(&st.window_lat, 99.0),
                            busy_frac: (st.window_busy_s / self.window_s).min(1.0),
                            queue_depth: st.queue.len(),
                        });
                    }
                    let obs = WindowObs {
                        t,
                        window_s: self.window_s,
                        services: services_obs,
                        train_steps: window_train_steps,
                    };
                    if phase == Phase::Running {
                        let proposal = {
                            let ctx = PolicyCtx {
                                scheduler: &scheduler,
                                workloads: &workloads,
                                service_workloads: &service_workloads,
                                current: &plan,
                                now: t,
                                last_change_t,
                                rho_max: self.rho_max,
                            };
                            policy.decide(&obs, &ctx)
                        };
                        if let Some(target) = proposal {
                            if target.layout != plan.layout {
                                placement
                                    .check_layout(&target.layout.placements)
                                    .map_err(|e| OrchError::Infeasible(e.to_string()))?;
                                let rates: Vec<String> = obs
                                    .services
                                    .iter()
                                    .map(|s| format!("{:.1}", s.rate_rps))
                                    .collect();
                                let p99s: Vec<String> = obs
                                    .services
                                    .iter()
                                    .map(|s| format!("{:.1}", s.p99_ms))
                                    .collect();
                                let reason = format!(
                                    "window rates [{}] req/s, p99 [{}] ms",
                                    rates.join(", "),
                                    p99s.join(", ")
                                );
                                pending = Some((target, t, reason));
                                phase = Phase::Draining;
                                maybe_begin_reconfig(
                                    &mut des,
                                    &mut phase,
                                    &svcs,
                                    train_busy,
                                    &plan.layout,
                                    &pending,
                                    &self.cost,
                                );
                            }
                        }
                    }
                    for st in svcs.iter_mut() {
                        st.window_arrivals = 0;
                        st.window_completed = 0;
                        st.window_violations = 0;
                        st.window_busy_s = 0.0;
                        st.window_lat.clear();
                    }
                    window_train_steps = 0;
                    if t + self.window_s < self.duration_s {
                        des.schedule_at(t + self.window_s, Ev::Tick);
                    }
                }
                Ev::ReconfigDone => {
                    let (target, decided_t, reason) =
                        pending.take().expect("reconfiguration without a pending target");
                    let from = plan.profile_names().join("+");
                    let to = target.profile_names().join("+");
                    let churn_n = churn(&plan.layout, &target.layout);
                    plan = target;
                    let bound = self.materialize(&scheduler, &plan, svc_base)?;
                    svc_est = bound.0;
                    svc_power = bound.1;
                    train_est = bound.2;
                    let downtime = t - decided_t;
                    reconfig_downtime += downtime;
                    decisions.push(Decision {
                        t: decided_t,
                        from,
                        to,
                        reason,
                        churn: churn_n,
                        downtime_s: downtime,
                    });
                    layouts.push(plan.layout.clone());
                    last_change_t = t;
                    phase = Phase::Running;
                    for svc in 0..n {
                        if !svcs[svc].queue.is_empty() && !svcs[svc].busy {
                            start_service(&mut des, &mut svcs[svc], svc, t, svc_est[svc].seconds);
                        }
                    }
                    if t < self.duration_s {
                        if let Some(est) = &train_est {
                            train_busy = true;
                            des.schedule_in(self.cost.train_restore_s + est.seconds, Ev::TrainDone);
                        }
                    }
                }
            }
        }

        let per_service: Vec<RunSummary> = collectors.iter().map(|c| c.summarize()).collect();
        let pooled = pool_collectors("orchestrated", &collectors, &per_service);
        let arrived: u64 = svcs.iter().map(|s| s.arrived).sum();
        let slo_met: u64 = svcs.iter().map(|s| s.slo_met).sum();
        let violations: u64 = svcs.iter().map(|s| s.violations).sum();
        let completed = slo_met + violations;
        let train_batch = self.train.as_ref().map(|t| t.batch as f64).unwrap_or(0.0);
        Ok(OrchestratorOutcome {
            policy: self.policy.name(),
            duration_s: self.duration_s,
            pooled,
            per_service,
            arrived,
            completed,
            slo_violations: violations,
            goodput_rps: slo_met as f64 / self.duration_s,
            slo_violation_frac: if completed > 0 {
                violations as f64 / completed as f64
            } else {
                0.0
            },
            train_steps,
            train_samples_per_s: train_steps as f64 * train_batch / self.duration_s,
            reconfigurations: decisions.len() as u64,
            reconfig_downtime_s: reconfig_downtime,
            layouts,
            decisions,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::lookup;
    use crate::orchestrator::policy::{PredictiveParams, ReactiveParams};

    /// The §Orchestrator demo scenario, compressed for tests: BERT-base
    /// training + two BERT-base inference services under diurnal load
    /// whose peak overloads the statically sized layout.
    fn demo(policy: PolicyKind, duration_s: f64, period_s: f64) -> OrchestratorConfig {
        let bert = lookup("bert-base").unwrap();
        let service = ServiceConfig {
            spec: WorkloadSpec::inference(bert, 8, 128),
            slo_ms: 40.0,
            arrival: ArrivalSpec::Diurnal { base_rate: 6.0, peak_rate: 60.0, period_s },
        };
        OrchestratorConfig {
            gpu: GpuModel::A100_80GB,
            train: Some(WorkloadSpec::training(bert, 32, 128)),
            services: vec![service.clone(), service],
            policy,
            cost: ReconfigCost::default(),
            duration_s,
            window_s: 10.0,
            rho_max: 0.75,
            seed: 2024,
        }
    }

    #[test]
    fn static_run_completes_and_never_repartitions() {
        let out = demo(PolicyKind::Static, 240.0, 120.0).run().unwrap();
        assert!(out.arrived > 1000, "arrived {}", out.arrived);
        assert!(out.completed > 0 && out.completed <= out.arrived + 2);
        assert_eq!(out.reconfigurations, 0);
        assert!(out.decisions.is_empty());
        assert_eq!(out.layouts.len(), 1);
        assert_eq!(out.reconfig_downtime_s, 0.0);
        assert!(out.train_steps > 0);
        assert!(out.goodput_rps > 0.0);
    }

    #[test]
    fn reactive_under_flat_load_matches_static() {
        // Stable Poisson load at the mean: the hysteresis policy must not
        // move, and the run must be indistinguishable from the baseline.
        let flat = |policy: PolicyKind| {
            let mut cfg = demo(policy, 240.0, 120.0);
            for s in &mut cfg.services {
                s.arrival = ArrivalSpec::Poisson { rate: 33.0 };
            }
            cfg.run().unwrap()
        };
        let st = flat(PolicyKind::Static);
        let re = flat(PolicyKind::Reactive(ReactiveParams::default()));
        assert_eq!(re.reconfigurations, 0, "no reason to move under flat feasible load");
        assert_eq!(re.goodput_rps.to_bits(), st.goodput_rps.to_bits());
        assert_eq!(re.pooled.p99_latency_ms.to_bits(), st.pooled.p99_latency_ms.to_bits());
    }

    #[test]
    fn reactive_repartitions_under_diurnal_load() {
        let out = demo(PolicyKind::Reactive(ReactiveParams::default()), 240.0, 120.0)
            .run()
            .unwrap();
        assert!(out.reconfigurations >= 1, "diurnal peak must force a repartition");
        assert_eq!(out.decisions.len() as u64, out.reconfigurations);
        assert_eq!(out.layouts.len(), out.decisions.len() + 1);
        let downtime: f64 = out.decisions.iter().map(|d| d.downtime_s).sum();
        assert!((downtime - out.reconfig_downtime_s).abs() < 1e-9);
        for d in &out.decisions {
            assert!(d.churn > 0, "a layout switch must churn instances: {d:?}");
            assert!(d.downtime_s > 0.0);
            assert!(d.from != d.to, "{d:?}");
        }
    }

    #[test]
    fn predictive_repartitions_under_diurnal_load() {
        let out = demo(PolicyKind::Predictive(PredictiveParams::default()), 240.0, 120.0)
            .run()
            .unwrap();
        assert!(out.reconfigurations >= 1);
        assert!(out.train_steps > 0, "training must keep running across repartitions");
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let a = demo(PolicyKind::Reactive(ReactiveParams::default()), 240.0, 120.0)
            .run()
            .unwrap();
        let b = demo(PolicyKind::Reactive(ReactiveParams::default()), 240.0, 120.0)
            .run()
            .unwrap();
        assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits());
        assert_eq!(a.pooled.p99_latency_ms.to_bits(), b.pooled.p99_latency_ms.to_bits());
        assert_eq!(a.reconfigurations, b.reconfigurations);
        assert_eq!(a.reconfig_downtime_s.to_bits(), b.reconfig_downtime_s.to_bits());
        assert_eq!(a.train_steps, b.train_steps);
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.services.clear();
        assert!(matches!(cfg.run(), Err(OrchError::Invalid(_))));

        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.duration_s = f64::NAN;
        assert!(matches!(cfg.run(), Err(OrchError::Invalid(_))));

        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.rho_max = 1.5;
        assert!(matches!(cfg.run(), Err(OrchError::Invalid(_))));

        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.window_s = 240.0; // >= duration: no policy tick would ever fire
        assert!(matches!(cfg.run(), Err(OrchError::Invalid(_))));

        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.services[0].slo_ms = -1.0;
        assert!(matches!(cfg.run(), Err(OrchError::Invalid(_))));

        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.services[0].arrival = ArrivalSpec::Poisson { rate: f64::NAN };
        assert!(matches!(cfg.run(), Err(OrchError::Arrival(_))));

        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.cost.instance_churn_s = f64::INFINITY;
        assert!(matches!(cfg.run(), Err(OrchError::Invalid(_))));
    }

    #[test]
    fn impossible_slo_is_infeasible() {
        let mut cfg = demo(PolicyKind::Static, 240.0, 120.0);
        cfg.services[0].slo_ms = 0.01; // below launch overhead
        assert!(matches!(cfg.run(), Err(OrchError::Infeasible(_))));
    }

    #[test]
    fn orchestration_without_training_job() {
        let mut cfg = demo(PolicyKind::Reactive(ReactiveParams::default()), 240.0, 120.0);
        cfg.train = None;
        let out = cfg.run().unwrap();
        assert_eq!(out.train_steps, 0);
        assert_eq!(out.train_samples_per_s, 0.0);
        assert!(out.completed > 0);
    }
}
