//! Integration: AOT artifacts → PJRT CPU execution from rust.
//!
//! These tests prove the three-layer stack composes: the Pallas kernels
//! (L1) lowered inside the JAX models (L2) execute from the rust runtime
//! (L3) with correct numerics. They require `make artifacts` to have run;
//! if the artifacts directory is absent they are skipped with a note.

use migperf::runtime::executor::{load_params, Engine, HostTensor};
use migperf::runtime::manifest::Manifest;
use migperf::runtime::{artifacts_available, artifacts_dir};
use migperf::util::prng::Prng;

fn require_artifacts() -> Option<Manifest> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(artifacts_dir()).expect("manifest parses"))
}

fn random_tokens(rng: &mut Prng, batch: i64, seq: i64, vocab: u64) -> HostTensor {
    let data: Vec<i32> = (0..batch * seq).map(|_| rng.below(vocab) as i32).collect();
    HostTensor::I32(data, vec![batch, seq])
}

#[test]
fn manifest_lists_expected_entries() {
    let Some(m) = require_artifacts() else { return };
    for name in [
        "bert_tiny_infer_b1",
        "bert_tiny_infer_b4",
        "bert_tiny_infer_b8",
        "bert_tiny_train_b8",
        "resnet_tiny_infer_b1",
        "resnet_tiny_infer_b8",
    ] {
        assert!(m.entry(name).is_some(), "missing entry {name}");
    }
}

#[test]
fn bert_inference_executes_and_is_finite() {
    let Some(m) = require_artifacts() else { return };
    let mut engine = Engine::cpu().expect("PJRT CPU client");
    let e = m.entry("bert_tiny_infer_b4").unwrap();
    engine.load_hlo_text(&e.name, &m.hlo_path(e)).expect("compile");
    let mut rng = Prng::new(42);
    let tokens = random_tokens(&mut rng, 4, e.inputs[0].shape[1], 512);
    let out = engine.execute(&e.name, &[tokens]).expect("execute");
    assert_eq!(out.outputs.len(), 1);
    let logits = out.outputs[0].as_f32().expect("f32 logits");
    assert_eq!(out.outputs[0].shape(), &[4, 512]);
    assert!(logits.iter().all(|x| x.is_finite()), "non-finite logits");
    assert!(out.wall_s > 0.0);
}

#[test]
fn bert_inference_batch_consistency() {
    // The same token row must produce the same pooled logits whether it
    // runs at batch 1 or inside a batch of 4 (the models are batch-
    // independent; this catches artifact/shape mixups).
    let Some(m) = require_artifacts() else { return };
    let mut engine = Engine::cpu().unwrap();
    let e1 = m.entry("bert_tiny_infer_b1").unwrap();
    let e4 = m.entry("bert_tiny_infer_b4").unwrap();
    engine.load_hlo_text(&e1.name, &m.hlo_path(e1)).unwrap();
    engine.load_hlo_text(&e4.name, &m.hlo_path(e4)).unwrap();
    let seq = e1.inputs[0].shape[1];
    let mut rng = Prng::new(7);
    let row: Vec<i32> = (0..seq).map(|_| rng.below(512) as i32).collect();
    let mut four = row.clone();
    for _ in 0..3 {
        four.extend_from_slice(&row);
    }
    let out1 = engine
        .execute(&e1.name, &[HostTensor::I32(row, vec![1, seq])])
        .unwrap();
    let out4 = engine
        .execute(&e4.name, &[HostTensor::I32(four, vec![4, seq])])
        .unwrap();
    let a = out1.outputs[0].as_f32().unwrap();
    let b = &out4.outputs[0].as_f32().unwrap()[..512];
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-4, "batch inconsistency: {x} vs {y}");
    }
}

#[test]
fn resnet_inference_executes() {
    let Some(m) = require_artifacts() else { return };
    let mut engine = Engine::cpu().unwrap();
    let e = m.entry("resnet_tiny_infer_b8").unwrap();
    engine.load_hlo_text(&e.name, &m.hlo_path(e)).unwrap();
    let n: usize = e.inputs[0].elements();
    let mut rng = Prng::new(3);
    let images: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let out = engine
        .execute(&e.name, &[HostTensor::F32(images, e.inputs[0].shape.clone())])
        .unwrap();
    assert_eq!(out.outputs[0].shape(), &[8, 10]);
    assert!(out.outputs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn training_step_runs_and_loss_decreases() {
    // The headline integration: rust drives the full fwd+bwd+SGD HLO for
    // several steps on a fixed synthetic batch and the loss goes down.
    let Some(m) = require_artifacts() else { return };
    let mut engine = Engine::cpu().unwrap();
    let e = m.entry("bert_tiny_train_b8").unwrap();
    engine.load_hlo_text(&e.name, &m.hlo_path(e)).unwrap();
    let mut params = load_params(&m, e).expect("initial params");
    assert_eq!(params.len(), e.num_param_inputs);

    let batch = e.inputs[e.num_param_inputs].shape[0];
    let seq = e.inputs[e.num_param_inputs].shape[1];
    let mut rng = Prng::new(2024);
    let tokens = random_tokens(&mut rng, batch, seq, 512);
    // Copy-task targets: tokens shifted by one (see model.synthetic_batch).
    let targets = match &tokens {
        HostTensor::I32(v, shape) => {
            let s = seq as usize;
            let mut t = Vec::with_capacity(v.len());
            for row in v.chunks(s) {
                t.push(row[s - 1]);
                t.extend_from_slice(&row[..s - 1]);
            }
            HostTensor::I32(t, shape.clone())
        }
        _ => unreachable!(),
    };

    let mut losses = Vec::new();
    for _ in 0..5 {
        let mut inputs = params.clone();
        inputs.push(tokens.clone());
        inputs.push(targets.clone());
        let out = engine.execute(&e.name, &inputs).expect("train step");
        assert_eq!(out.outputs.len(), e.num_outputs);
        let loss = out.outputs[0].as_f32().unwrap()[0];
        assert!(loss.is_finite());
        losses.push(loss);
        params = out.outputs[1..].to_vec();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss must decrease: {losses:?}"
    );
}

#[test]
fn engine_caches_executables() {
    let Some(m) = require_artifacts() else { return };
    let mut engine = Engine::cpu().unwrap();
    let e = m.entry("bert_tiny_infer_b1").unwrap();
    engine.load_hlo_text(&e.name, &m.hlo_path(e)).unwrap();
    engine.load_hlo_text(&e.name, &m.hlo_path(e)).unwrap(); // idempotent
    assert_eq!(engine.cached(), 1);
    assert_eq!(engine.platform().to_lowercase(), "cpu");
}

#[test]
fn unknown_executable_is_an_error() {
    let Some(_m) = require_artifacts() else { return };
    let engine = Engine::cpu().unwrap();
    assert!(engine.execute("nope", &[]).is_err());
}
