//! Metrics pipeline: DCGM-style counters, collection, and export.
//!
//! Mirrors the paper's performance aggregator (§3.2): it "monitors the
//! workload performance and system resource usage and saves them in the
//! database … developed based on tools like DCGM". [`dcgm`] emulates the
//! counter sampling, [`collector`] aggregates a profiling run into the
//! report the paper's figures are drawn from, and [`export`] writes the
//! formats third-party tools consume (CSV, JSONL, Prometheus exposition).

pub mod collector;
pub mod dcgm;
pub mod export;
pub mod regression;

pub use collector::{MetricsCollector, RunSummary};
pub use dcgm::{DcgmCounter, DcgmSampler};
pub use regression::{compare, Comparison, Tolerance};
