//! Fig 10 (appendix C): tail latency of 4 MPS ResNet-50 inference
//! processes on A30 under different request arrival rates.
//!
//! "We run 4 simple PyTorch inference servers, and send asynchronous
//! requests to each server simultaneously … We set the batch size = 1."

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::models::zoo;
use migperf::sharing::mps::MpsModel;
use migperf::simgpu::resource::ExecResource;
use migperf::sweep::{self, SweepEngine};
use migperf::util::table::{fmt_num, sparkline, Table};
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;

const RATES: &[f64] = &[10.0, 20.0, 40.0, 80.0, 200.0, 480.0];
const REQUESTS: u64 = 1500;

fn main() {
    banner("Figure 10", "4 MPS ResNet-50 servers on A30: p99 vs arrival rate");
    let spec = WorkloadSpec::inference(zoo::lookup("resnet50").unwrap(), 1, 224);
    // Rate axis fanned across the sweep engine.
    let sims: Vec<ServingSim> = RATES
        .iter()
        .map(|&rate| ServingSim {
            mode: SharingMode::Mps {
                gpu: ExecResource::whole_gpu(GpuModel::A30_24GB),
                n_clients: 4,
                model: MpsModel::default(),
            },
            load: LoadMode::OpenPoisson { rate, requests_per_server: REQUESTS },
            spec: spec.clone(),
            seed: 88,
        })
        .collect();
    let outs = sweep::run_serving(&SweepEngine::from_env(), &sims).expect("fig10 sims");
    let mut t = Table::new(&["rate/server req/s", "avg_ms", "p99_ms", "max_ms"]);
    let mut p99s = Vec::new();
    for (&rate, out) in RATES.iter().zip(&outs) {
        let out = &out.pooled;
        p99s.push(out.p99_latency_ms);
        t.row(&[
            fmt_num(rate),
            fmt_num(out.avg_latency_ms),
            fmt_num(out.p99_latency_ms),
            fmt_num(out.max_latency_ms),
        ]);
    }
    println!("\n{}p99 trend: {}", t.render(), sparkline(&p99s));
    let chart = migperf::util::plot::render(
        &[migperf::util::plot::PlotSeries {
            label: "MPS p99 ms vs rate/server".into(),
            points: RATES.iter().zip(&p99s).map(|(&r, &p)| (r, p)).collect(),
        }],
        56,
        10,
    );
    println!("\n{chart}");
    shape_check(
        "p99 grows with arrival rate and explodes near saturation (Fig 10)",
        p99s.windows(2).all(|w| w[1] >= w[0] * 0.95) && p99s.last().unwrap() > &(p99s[0] * 5.0),
    );
    shape_check("MPS jitter visible even at low rate (Fig 10)", {
        // At the lowest rate, p99 already exceeds p50 service time due to
        // interference spikes.
        p99s[0] > 0.0
    });
}
