//! Fig 6: tail latency vs batch size, MIG vs MPS.
//!
//! Paper §4.5: "the gap of tail latency is very marginal when the batch
//! size is small and becomes larger as the batch size increases."

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::mig::profile::lookup as gi_lookup;
use migperf::models::zoo;
use migperf::sharing::mps::MpsModel;
use migperf::simgpu::resource::ExecResource;
use migperf::sweep::{self, SweepEngine};
use migperf::util::table::{fmt_num, sparkline, Table};
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;

const BATCHES: &[u32] = &[1, 2, 4, 8, 16, 32];
const TENANTS: u32 = 2;
const REQUESTS: u64 = 3000;
const MODELS: &[&str] = &["resnet18", "resnet50"];

fn sim(model: &str, batch: u32, mig: bool) -> ServingSim {
    let gpu = GpuModel::A30_24GB;
    let spec = WorkloadSpec::inference(zoo::lookup(model).unwrap(), batch, 224);
    let mode = if mig {
        let p = gi_lookup(gpu, "2g.12gb").unwrap();
        SharingMode::Mig(vec![ExecResource::from_gi(gpu, p); TENANTS as usize])
    } else {
        SharingMode::Mps {
            gpu: ExecResource::whole_gpu(gpu),
            n_clients: TENANTS,
            model: MpsModel::default(),
        }
    };
    ServingSim { mode, load: LoadMode::Closed { requests_per_server: REQUESTS }, spec, seed: 66 }
}

fn main() {
    banner("Figure 6", "p99 latency vs batch size, MIG vs MPS (A30)");
    // One parallel sweep over the full (model × batch × mode) grid.
    let mut sims = Vec::new();
    for model in MODELS {
        for &b in BATCHES {
            sims.push(sim(model, b, true));
            sims.push(sim(model, b, false));
        }
    }
    let outs = sweep::run_serving(&SweepEngine::from_env(), &sims).expect("fig6 sims");
    for (mi, model) in MODELS.iter().enumerate() {
        let mut t = Table::new(&["batch", "MIG p99_ms", "MPS p99_ms", "gap (MPS−MIG)"]);
        let mut gaps = Vec::new();
        for (bi, &b) in BATCHES.iter().enumerate() {
            let base = (mi * BATCHES.len() + bi) * 2;
            let m = outs[base].pooled.p99_latency_ms;
            let s = outs[base + 1].pooled.p99_latency_ms;
            gaps.push(s - m);
            t.row(&[b.to_string(), fmt_num(m), fmt_num(s), fmt_num(s - m)]);
        }
        println!("\n{model}:\n{}gap trend: {}", t.render(), sparkline(&gaps));
        shape_check(
            &format!("{model}: p99 gap grows with batch size (Fig 6)"),
            gaps.last().unwrap() > &(gaps[0] * 2.0).max(gaps[0] + 1.0),
        );
        shape_check(
            &format!("{model}: gap marginal at batch 1 relative to batch 32"),
            gaps[0] < gaps.last().unwrap() / 3.0,
        );
    }
}
