//! Exhaustive MIG partition optimizer.

use crate::mig::enumerate::{maximal_layouts, Layout};
use crate::mig::gpu::GpuModel;
use crate::simgpu::energy::EnergyModel;
use crate::simgpu::perfmodel::PerfModel;
use crate::simgpu::resource::ExecResource;
use crate::workload::spec::WorkloadSpec;

/// A workload to place, with an optional latency SLO (inference).
#[derive(Debug, Clone)]
pub struct SloWorkload {
    /// The workload.
    pub spec: WorkloadSpec,
    /// Per-step latency budget in milliseconds (None for training /
    /// best-effort jobs).
    pub slo_ms: Option<f64>,
}

impl SloWorkload {
    /// Best-effort workload (no SLO).
    pub fn best_effort(spec: WorkloadSpec) -> Self {
        SloWorkload { spec, slo_ms: None }
    }

    /// Latency-bound workload.
    pub fn with_slo(spec: WorkloadSpec, slo_ms: f64) -> Self {
        SloWorkload { spec, slo_ms: Some(slo_ms) }
    }
}

/// Optimization objective.
///
/// Under [`Objective::MaxThroughput`], SLO-bound workloads contribute
/// *goodput*: their throughput counts only up to the rate their SLO
/// demands (`batch / slo`), because serving a request faster than its
/// deadline adds no value. Best-effort workloads (training) contribute
/// raw throughput. This is what makes the optimizer hand the big slice
/// to training in the paper's hybrid scenario instead of gold-plating an
/// inference service that was already meeting its SLO.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Maximize summed goodput (samples/s, SLO-capped) across workloads.
    MaxThroughput,
    /// Minimize summed power draw while meeting SLOs.
    MinEnergy,
}

/// One placement decision in a plan.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// Index into the submitted workload list.
    pub workload: usize,
    /// GI profile name the workload got.
    pub profile: &'static str,
    /// Predicted per-step latency, ms.
    pub latency_ms: f64,
    /// Predicted throughput, samples/s.
    pub throughput: f64,
    /// SLO-capped goodput, samples/s (== throughput for best-effort).
    pub goodput: f64,
    /// Predicted power draw, W.
    pub power_w: f64,
}

/// A complete scheduling plan.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Chosen layout (profile names in offset order).
    pub layout: Vec<&'static str>,
    /// Workload → instance assignments.
    pub assignments: Vec<Assignment>,
    /// Objective score (higher is better; energy objective is negated).
    pub score: f64,
}

/// The optimizer.
#[derive(Debug)]
pub struct Scheduler {
    /// GPU being partitioned.
    pub gpu: GpuModel,
    /// Performance model used for predictions.
    pub perf: PerfModel,
    /// Energy model used for power predictions.
    pub energy: EnergyModel,
}

impl Scheduler {
    /// Scheduler with default models.
    pub fn new(gpu: GpuModel) -> Self {
        Scheduler { gpu, perf: PerfModel::default(), energy: EnergyModel::default() }
    }

    /// Find the best plan for `workloads` under `objective`.
    ///
    /// Returns `None` when no layout can host every workload within its
    /// SLO (and memory). Exhaustive over layouts × assignments; workload
    /// counts in the paper's scenarios are ≤ 7, so the assignment search
    /// (distinct instances, best-profile-first) stays tiny.
    pub fn plan(&self, workloads: &[SloWorkload], objective: Objective) -> Option<Plan> {
        if workloads.is_empty() {
            return None;
        }
        let mut best: Option<Plan> = None;
        for layout in maximal_layouts(self.gpu) {
            if layout.len() < workloads.len() {
                continue; // not enough instances
            }
            if let Some(plan) = self.best_assignment(&layout, workloads, objective) {
                let better = match &best {
                    None => true,
                    Some(b) => plan.score > b.score,
                };
                if better {
                    best = Some(plan);
                }
            }
        }
        best
    }

    /// Best assignment of workloads onto a specific layout, or None if
    /// some workload cannot meet its SLO anywhere.
    fn best_assignment(
        &self,
        layout: &Layout,
        workloads: &[SloWorkload],
        objective: Objective,
    ) -> Option<Plan> {
        // Predict each workload on each distinct instance of the layout.
        let resources: Vec<ExecResource> = layout
            .placements
            .iter()
            .map(|p| ExecResource::from_gi(self.gpu, p.profile))
            .collect();
        // candidates[w][i] = Some(assignment) if workload w fits instance i.
        let candidates: Vec<Vec<Option<Assignment>>> = workloads
            .iter()
            .enumerate()
            .map(|(wi, w)| {
                resources
                    .iter()
                    .enumerate()
                    .map(|(ri, res)| {
                        let est = self.perf.step(res, &w.spec.step_cost()).ok()?;
                        let latency_ms = est.seconds * 1e3;
                        let throughput = w.spec.batch as f64 / est.seconds;
                        let goodput = match w.slo_ms {
                            Some(slo) => {
                                if latency_ms > slo {
                                    return None;
                                }
                                // Value saturates at the SLO-demanded rate.
                                throughput.min(w.spec.batch as f64 * 1e3 / slo)
                            }
                            None => throughput,
                        };
                        Some(Assignment {
                            workload: wi,
                            profile: layout.placements[ri].profile.name,
                            latency_ms,
                            throughput,
                            goodput,
                            power_w: self.energy.marginal_power_w(res, est.gract),
                        })
                    })
                    .collect()
            })
            .collect();

        // Branch-and-bound over injective assignments (≤7! worst case,
        // but layouts have ≤7 instances and pruning cuts hard).
        let mut used = vec![false; resources.len()];
        let mut chosen: Vec<Assignment> = Vec::new();
        let mut best: Option<(f64, Vec<Assignment>)> = None;
        Self::search(&candidates, objective, 0, &mut used, &mut chosen, &mut best);
        let (score, assignments) = best?;
        Some(Plan { layout: layout.profile_names(), assignments, score })
    }

    fn score_of(a: &Assignment, objective: Objective) -> f64 {
        match objective {
            Objective::MaxThroughput => a.goodput,
            Objective::MinEnergy => -a.power_w,
        }
    }

    fn search(
        candidates: &[Vec<Option<Assignment>>],
        objective: Objective,
        w: usize,
        used: &mut [bool],
        chosen: &mut Vec<Assignment>,
        best: &mut Option<(f64, Vec<Assignment>)>,
    ) {
        if w == candidates.len() {
            let score: f64 = chosen.iter().map(|a| Self::score_of(a, objective)).sum();
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                *best = Some((score, chosen.clone()));
            }
            return;
        }
        for (ri, cand) in candidates[w].iter().enumerate() {
            if used[ri] {
                continue;
            }
            if let Some(a) = cand {
                used[ri] = true;
                chosen.push(a.clone());
                Self::search(candidates, objective, w + 1, used, chosen, best);
                chosen.pop();
                used[ri] = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::lookup;
    use crate::workload::spec::WorkloadSpec;

    fn bert_train() -> SloWorkload {
        SloWorkload::best_effort(WorkloadSpec::training(lookup("bert-base").unwrap(), 32, 128))
    }

    fn resnet_serve(slo_ms: f64) -> SloWorkload {
        SloWorkload::with_slo(WorkloadSpec::inference(lookup("resnet50").unwrap(), 4, 224), slo_ms)
    }

    #[test]
    fn paper_hybrid_scenario_produces_mixed_layout() {
        // §1's motivating setup: train + two inference services on one
        // A100. The optimizer should give training the big slice.
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let workloads = [bert_train(), resnet_serve(20.0), resnet_serve(20.0)];
        let plan = sched.plan(&workloads, Objective::MaxThroughput).expect("feasible");
        assert_eq!(plan.assignments.len(), 3);
        // Training gets the largest instance in the plan.
        let train_profile = plan.assignments.iter().find(|a| a.workload == 0).unwrap().profile;
        for a in &plan.assignments {
            let train_slices: u32 = train_profile.split('g').next().unwrap().parse().unwrap();
            let this: u32 = a.profile.split('g').next().unwrap().parse().unwrap();
            assert!(train_slices >= this, "training must own the biggest slice: {plan:?}");
        }
        // All SLOs met by construction.
        for a in plan.assignments.iter().filter(|a| a.workload > 0) {
            assert!(a.latency_ms <= 20.0);
        }
    }

    #[test]
    fn single_training_job_gets_whole_gpu() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let plan = sched.plan(&[bert_train()], Objective::MaxThroughput).unwrap();
        assert_eq!(plan.assignments[0].profile, "7g.80gb");
        assert_eq!(plan.layout, vec!["7g.80gb"]);
    }

    #[test]
    fn infeasible_slo_returns_none() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        // 0.01 ms SLO is physically impossible (launch overhead alone is
        // 0.45 ms).
        assert!(sched.plan(&[resnet_serve(0.01)], Objective::MaxThroughput).is_none());
    }

    #[test]
    fn too_many_workloads_for_device() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        let ws: Vec<_> = (0..5).map(|_| resnet_serve(1000.0)).collect();
        assert!(sched.plan(&ws, Objective::MaxThroughput).is_none(), "A30 has at most 4 GIs");
    }

    #[test]
    fn four_services_land_on_four_slices() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        let ws: Vec<_> = (0..4).map(|_| resnet_serve(1000.0)).collect();
        let plan = sched.plan(&ws, Objective::MaxThroughput).unwrap();
        assert_eq!(plan.layout, vec!["1g.6gb"; 4]);
    }

    #[test]
    fn energy_objective_prefers_smaller_slices() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let w = [resnet_serve(1000.0)];
        let tput_plan = sched.plan(&w, Objective::MaxThroughput).unwrap();
        let energy_plan = sched.plan(&w, Objective::MinEnergy).unwrap();
        let slices = |p: &Plan| -> u32 {
            p.assignments[0].profile.split('g').next().unwrap().parse().unwrap()
        };
        assert!(slices(&energy_plan) <= slices(&tput_plan));
        assert!(energy_plan.assignments[0].power_w <= tput_plan.assignments[0].power_w);
    }

    #[test]
    fn empty_workloads_rejected() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        assert!(sched.plan(&[], Objective::MaxThroughput).is_none());
    }

    #[test]
    fn oom_workload_excluded_from_small_slices() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let big = SloWorkload::best_effort(WorkloadSpec::training(
            lookup("bert-large").unwrap(),
            128,
            128,
        ));
        let plan = sched.plan(&[big], Objective::MaxThroughput).unwrap();
        // Must land on an instance with enough FB (>= 3g.40gb).
        assert!(["3g.40gb", "4g.40gb", "7g.80gb"].contains(&plan.assignments[0].profile));
    }
}
