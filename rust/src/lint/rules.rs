//! The determinism rule engine: repo-specific checks over the token
//! stream produced by [`super::lexer`].
//!
//! Rules (IDs as used in findings and `lint:allow`):
//!
//! - `map-iteration` — no `HashMap`/`HashSet` *iteration* in deterministic
//!   modules. Construction and point lookups are fine; order-dependent
//!   traversal (`for … in map`, `.iter()`, `.keys()`, `.values()`,
//!   `.drain()`, `.retain()`, …) is not, because the iteration order is
//!   randomized per process and would leak into checksummed outcomes.
//! - `wall-clock` — no `Instant::now`/`SystemTime`/`.elapsed()` outside
//!   the sanctioned wall-clock-only files; wall-derived values must stay
//!   out of every checksum and fingerprint.
//! - `unstable-sort` — no `sort_unstable_by`/`sort_unstable_by_key` in
//!   deterministic modules unless the comparator is visibly total
//!   (`total_cmp`). Plain `sort_unstable()` is exempt: the `Ord` bound
//!   makes equal elements indistinguishable.
//! - `float-order` — no `partial_cmp` in deterministic modules: on NaN it
//!   returns `None`, so comparators either panic or silently reorder. Use
//!   `f64::total_cmp`, or annotate a deliberate NaN-guarding `expect`.
//! - `ambient-entropy` — no `rand::`/`thread_rng`/`OsRng`/`RandomState`
//!   anywhere: the only randomness source is the seeded `util::prng::Prng`.
//! - `panic-budget` — `.unwrap()`/`.expect()`/`panic!`/indexing counts per
//!   engine-hot-path module, ratcheted by `lint-budget.toml`.
//! - `debug-assert-effect` — no side-effectful expressions inside
//!   `debug_assert!` family macros (they vanish in release builds).
//! - `allow-syntax` — malformed `lint:allow` comments (unknown rule id,
//!   missing or empty `reason="…"`).
//!
//! Suppression: `// lint:allow(rule-id, reason="why this is sound")` on
//! the offending line, or alone on the line immediately above it. The
//! reason is mandatory. `panic-budget` and `allow-syntax` findings cannot
//! be suppressed inline — the budget file is the former's mechanism.

use super::config::{BudgetEntry, BudgetTable, LintConfig};
use super::lexer::{lex, Comment, Tok, TokKind};
use super::{Finding, RuleId, Severity};
use std::collections::{BTreeMap, BTreeSet};

/// Rust keywords: never treated as indexable expressions or as bindable
/// hash-container names.
const KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move",
    "mut", "pub", "ref", "return", "static", "struct", "super", "trait", "true", "type",
    "unsafe", "use", "where", "while", "yield",
];

/// Order-dependent traversal methods on hash containers.
const ITER_METHODS: &[&str] = &[
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "into_keys", "into_values",
    "drain", "retain",
];

/// Wrapper tokens walked over between a binding's `:` and the hash type
/// (`cache: Option<HashMap<…>>`, `m: &mut std::collections::HashMap<…>`).
const TYPE_WRAPPERS: &[&str] = &[
    "Option", "Vec", "Box", "Rc", "Arc", "RefCell", "Cell", "Mutex", "RwLock", "std",
    "collections", "mut",
];

/// Wrapper tokens walked over between a binding's `=` and the hash
/// constructor (`m = Some(HashMap::new())`).
const CTOR_WRAPPERS: &[&str] = &["Some", "Ok", "Box", "Arc", "Rc", "RefCell", "Mutex", "RwLock"];

/// Compound-assignment and assignment operators: side effects inside
/// `debug_assert!` arguments. Comparison operators (`==`, `<=`, …) lex as
/// single joined tokens, so a bare `=` here really is an assignment.
const ASSIGN_OPS: &[&str] =
    &["=", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<=", ">>="];

/// Mutating method names: side effects inside `debug_assert!` arguments.
const MUTATING_METHODS: &[&str] = &[
    "push", "push_back", "push_front", "push_str", "insert", "remove", "pop", "pop_back",
    "pop_front", "drain", "clear", "extend", "truncate", "retain", "swap", "sort", "sort_by",
    "sort_by_key", "sort_unstable", "sort_unstable_by", "sort_unstable_by_key", "dedup",
    "append", "split_off", "take", "replace", "set", "fill", "resize",
];

fn is_keyword(name: &str) -> bool {
    KEYWORDS.contains(&name)
}

/// Lint one file. `path` is the forward-slash path used for module
/// classification; `budget` is the parsed ratchet table, if any.
pub fn check_source(
    path: &str,
    src: &str,
    cfg: &LintConfig,
    budget: Option<&BudgetTable>,
) -> Vec<Finding> {
    let lexed = lex(src);
    let lines: Vec<&str> = src.lines().collect();
    let excerpt = |line: u32| -> String {
        let text = lines.get(line.saturating_sub(1) as usize).map_or("", |l| l.trim());
        let mut out: String = text.chars().take(80).collect();
        if text.chars().count() > 80 {
            out.push('…');
        }
        out
    };

    let (suppressions, mut findings) = collect_suppressions(path, &lexed.comments, &lexed.toks);
    for f in &mut findings {
        f.excerpt = excerpt(f.line);
    }

    let toks = &lexed.toks;
    let deterministic = cfg.is_deterministic(path);
    let mut raw: Vec<(u32, RuleId, String)> = Vec::new();

    if deterministic {
        rule_map_iteration(toks, &mut raw);
        rule_unstable_sort(toks, &mut raw);
        rule_float_order(toks, &mut raw);
    }
    if !cfg.is_wallclock_allowed(path) {
        rule_wall_clock(toks, &mut raw);
    }
    rule_ambient_entropy(toks, &mut raw);
    rule_debug_assert_effect(toks, &mut raw);

    // Dedupe (a `for` over `.keys()` hits two patterns) and apply the
    // inline suppressions.
    let mut seen: BTreeSet<(u32, &'static str)> = BTreeSet::new();
    for (line, rule, message) in raw {
        if !seen.insert((line, rule.as_str())) {
            continue;
        }
        if suppressions.get(&line).is_some_and(|rules| rules.contains(rule.as_str())) {
            continue;
        }
        findings.push(Finding {
            file: path.to_string(),
            line,
            rule,
            severity: Severity::Error,
            message,
            excerpt: excerpt(line),
        });
    }

    // Panic budget: module-level counts against the checked-in ratchet.
    if let Some(key) = cfg.budget_key(path) {
        let actual = count_budget(toks);
        match budget.and_then(|t| t.entry_for(path)) {
            None => findings.push(Finding {
                file: path.to_string(),
                line: 1,
                rule: RuleId::PanicBudget,
                severity: Severity::Error,
                message: format!(
                    "hot-path module has no [budget.\"{key}\"] entry in lint-budget.toml \
                     (actual: unwrap={} expect={} panic={} index={})",
                    actual.unwrap, actual.expect, actual.panic, actual.index
                ),
                excerpt: String::new(),
            }),
            Some((_, limit)) => {
                for (name, have) in actual.counters() {
                    let cap = limit.get(name).unwrap_or(0);
                    if have > cap {
                        findings.push(Finding {
                            file: path.to_string(),
                            line: 1,
                            rule: RuleId::PanicBudget,
                            severity: Severity::Error,
                            message: format!(
                                "{name} count {have} exceeds the ratcheted budget {cap}; \
                                 remove the new {name} or justify lowering the bar"
                            ),
                            excerpt: String::new(),
                        });
                    } else if have < cap {
                        findings.push(Finding {
                            file: path.to_string(),
                            line: 1,
                            rule: RuleId::PanicBudget,
                            severity: Severity::Warning,
                            message: format!(
                                "{name} count {have} is below the budget {cap}: tighten \
                                 lint-budget.toml to {have} to lock in the improvement"
                            ),
                            excerpt: String::new(),
                        });
                    }
                }
            }
        }
    }

    findings.sort_by(|a, b| {
        a.line.cmp(&b.line).then_with(|| a.rule.as_str().cmp(b.rule.as_str()))
    });
    findings
}

/// Parse every `lint:allow` comment into a line → rule-set map, emitting
/// `allow-syntax` findings for malformed ones. A trailing comment covers
/// its own line; a leading (stand-alone) comment covers the next line
/// that carries any token, so stacked allows compose.
fn collect_suppressions(
    path: &str,
    comments: &[Comment],
    toks: &[Tok],
) -> (BTreeMap<u32, BTreeSet<&'static str>>, Vec<Finding>) {
    let mut map: BTreeMap<u32, BTreeSet<&'static str>> = BTreeMap::new();
    let mut findings = Vec::new();
    for c in comments {
        match parse_allow(&c.text) {
            Ok(None) => {}
            Ok(Some(rule)) => {
                let covered = if c.leading {
                    toks.iter().map(|t| t.line).find(|&l| l > c.line).unwrap_or(c.line)
                } else {
                    c.line
                };
                map.entry(covered).or_default().insert(rule.as_str());
            }
            Err(msg) => findings.push(Finding {
                file: path.to_string(),
                line: c.line,
                rule: RuleId::AllowSyntax,
                severity: Severity::Error,
                message: msg,
                excerpt: String::new(),
            }),
        }
    }
    (map, findings)
}

/// Parse one comment. `Ok(None)`: not a `lint:allow` comment at all.
/// `Err`: it tried to be one and is malformed.
fn parse_allow(text: &str) -> Result<Option<RuleId>, String> {
    let t = text.trim();
    if !t.starts_with("lint:allow") {
        return Ok(None);
    }
    let rest = &t["lint:allow".len()..];
    let inner = rest
        .strip_prefix('(')
        .and_then(|r| r.find(')').map(|end| &r[..end]))
        .ok_or_else(|| "lint:allow needs the form lint:allow(rule-id, reason=\"…\")".to_string())?;
    let (id, tail) = inner
        .split_once(',')
        .ok_or_else(|| "lint:allow is missing the mandatory reason=\"…\"".to_string())?;
    let id = id.trim();
    let rule = RuleId::parse(id)
        .ok_or_else(|| format!("lint:allow names unknown rule `{id}`"))?;
    if !rule.suppressible() {
        return Err(format!("rule `{id}` cannot be suppressed inline"));
    }
    let reason = tail
        .trim()
        .strip_prefix("reason=")
        .ok_or_else(|| "lint:allow is missing the mandatory reason=\"…\"".to_string())?;
    let reason = reason
        .strip_prefix('"')
        .and_then(|r| r.strip_suffix('"'))
        .ok_or_else(|| "lint:allow reason must be a double-quoted string".to_string())?;
    if reason.trim().is_empty() {
        return Err("lint:allow reason must not be empty".to_string());
    }
    Ok(Some(rule))
}

/// D1 — order-dependent traversal of `HashMap`/`HashSet`.
fn rule_map_iteration(toks: &[Tok], out: &mut Vec<(u32, RuleId, String)>) {
    let names = collect_hash_names(toks);
    // (a) iteration methods invoked on a tracked name.
    for i in 0..toks.len().saturating_sub(3) {
        if toks[i].kind == TokKind::Ident
            && names.contains(toks[i].text.as_str())
            && toks[i + 1].is_punct(".")
            && toks[i + 2].kind == TokKind::Ident
            && ITER_METHODS.contains(&toks[i + 2].text.as_str())
            && toks[i + 3].is_punct("(")
        {
            out.push((
                toks[i].line,
                RuleId::MapIteration,
                format!(
                    "`{}.{}()` traverses a hash container in randomized order; use a \
                     BTreeMap/Vec or sort the keys first",
                    toks[i].text, toks[i + 2].text
                ),
            ));
        }
    }
    // (b) `for … in <expr mentioning a tracked name> {`.
    let mut i = 0;
    while i < toks.len() {
        if toks[i].is_ident("for") {
            // Find the `in` of this loop header (bounded: a genuine loop
            // header is short; `impl X for Y` never has one).
            let mut k = i + 1;
            let mut found_in = None;
            while k < toks.len() && k - i < 24 {
                if toks[k].is_ident("in") {
                    found_in = Some(k);
                    break;
                }
                if toks[k].is_punct("{") {
                    break;
                }
                k += 1;
            }
            if let Some(start) = found_in {
                let mut j = start + 1;
                while j < toks.len() && !toks[j].is_punct("{") {
                    if toks[j].kind == TokKind::Ident && names.contains(toks[j].text.as_str()) {
                        out.push((
                            toks[j].line,
                            RuleId::MapIteration,
                            format!(
                                "`for … in {}` traverses a hash container in randomized \
                                 order; use a BTreeMap/Vec or sort the keys first",
                                toks[j].text
                            ),
                        ));
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
        }
        i += 1;
    }
}

/// Names bound to `HashMap`/`HashSet` in this file, via type annotations
/// (`name: HashMap<…>`, struct fields, fn params) or constructors
/// (`name = HashMap::new()`).
fn collect_hash_names(toks: &[Tok]) -> BTreeSet<&str> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident("HashMap") || toks[i].is_ident("HashSet")) {
            continue;
        }
        // Type-annotation form: walk back over wrappers to a `:`.
        let mut j = i;
        while j > 0 {
            let t = &toks[j - 1];
            let is_wrapper = (t.kind == TokKind::Punct && matches!(t.text.as_str(), "<" | "&" | "::"))
                || (t.kind == TokKind::Ident && TYPE_WRAPPERS.contains(&t.text.as_str()))
                || t.kind == TokKind::Lifetime;
            if !is_wrapper {
                break;
            }
            j -= 1;
        }
        if j >= 2 && toks[j - 1].is_punct(":") {
            let cand = &toks[j - 2];
            if cand.kind == TokKind::Ident && !is_keyword(&cand.text) {
                names.insert(cand.text.as_str());
                continue;
            }
        }
        // Constructor form: walk back over call wrappers to an `=`.
        let mut j = i;
        while j > 0 {
            let t = &toks[j - 1];
            let is_wrapper = t.is_punct("(")
                || (t.kind == TokKind::Ident && CTOR_WRAPPERS.contains(&t.text.as_str()));
            if !is_wrapper {
                break;
            }
            j -= 1;
        }
        if j >= 2 && toks[j - 1].is_punct("=") {
            let cand = &toks[j - 2];
            if cand.kind == TokKind::Ident && !is_keyword(&cand.text) {
                names.insert(cand.text.as_str());
            }
        }
    }
    names
}

/// D2 — wall-clock reads outside the sanctioned files.
fn rule_wall_clock(toks: &[Tok], out: &mut Vec<(u32, RuleId, String)>) {
    for i in 0..toks.len() {
        if toks[i].is_ident("Instant")
            && i + 2 < toks.len()
            && toks[i + 1].is_punct("::")
            && toks[i + 2].is_ident("now")
        {
            out.push((
                toks[i].line,
                RuleId::WallClock,
                "`Instant::now()` reads the wall clock; wall-derived values must never \
                 reach a checksum or fingerprint"
                    .to_string(),
            ));
        }
        if toks[i].is_ident("SystemTime") || toks[i].is_ident("UNIX_EPOCH") {
            out.push((
                toks[i].line,
                RuleId::WallClock,
                format!("`{}` reads ambient time; use the simulated clock", toks[i].text),
            ));
        }
        if toks[i].is_punct(".")
            && i + 2 < toks.len()
            && toks[i + 1].is_ident("elapsed")
            && toks[i + 2].is_punct("(")
        {
            out.push((
                toks[i + 1].line,
                RuleId::WallClock,
                "`.elapsed()` derives a wall-clock duration; keep it out of \
                 deterministic state"
                    .to_string(),
            ));
        }
    }
}

/// D3a — unstable sorts whose comparator is not visibly total.
fn rule_unstable_sort(toks: &[Tok], out: &mut Vec<(u32, RuleId, String)>) {
    for i in 0..toks.len().saturating_sub(2) {
        if !toks[i].is_punct(".") {
            continue;
        }
        let name = &toks[i + 1];
        if !(name.is_ident("sort_unstable_by") || name.is_ident("sort_unstable_by_key")) {
            continue;
        }
        if !toks[i + 2].is_punct("(") {
            continue;
        }
        // Scan the argument list for a visibly total comparator.
        let mut depth = 1usize;
        let mut j = i + 3;
        let mut total = false;
        while j < toks.len() && depth > 0 {
            if toks[j].is_punct("(") {
                depth += 1;
            } else if toks[j].is_punct(")") {
                depth -= 1;
            } else if toks[j].is_ident("total_cmp") {
                total = true;
            }
            j += 1;
        }
        if !total {
            out.push((
                name.line,
                RuleId::UnstableSort,
                format!(
                    "`.{}()` with a comparator that is not visibly total: equal or \
                     NaN-ordered keys make the result order nondeterministic",
                    name.text
                ),
            ));
        }
    }
}

/// D3b — `partial_cmp` in deterministic modules.
fn rule_float_order(toks: &[Tok], out: &mut Vec<(u32, RuleId, String)>) {
    for t in toks {
        if t.is_ident("partial_cmp") {
            out.push((
                t.line,
                RuleId::FloatOrder,
                "`partial_cmp` is not total on NaN; use `f64::total_cmp`, or annotate a \
                 deliberate NaN-guarding `expect`"
                    .to_string(),
            ));
        }
    }
}

/// D4 — ambient entropy sources.
fn rule_ambient_entropy(toks: &[Tok], out: &mut Vec<(u32, RuleId, String)>) {
    for i in 0..toks.len() {
        let t = &toks[i];
        let hit = matches!(
            t.text.as_str(),
            "thread_rng" | "from_entropy" | "OsRng" | "getrandom" | "RandomState"
        ) && t.kind == TokKind::Ident;
        let rand_path =
            t.is_ident("rand") && i + 1 < toks.len() && toks[i + 1].is_punct("::");
        if hit || rand_path {
            out.push((
                t.line,
                RuleId::AmbientEntropy,
                format!(
                    "`{}` draws ambient entropy; all randomness must come from the \
                     seeded util::prng::Prng",
                    t.text
                ),
            ));
        }
    }
}

/// D6 — side effects inside `debug_assert!` family macros.
fn rule_debug_assert_effect(toks: &[Tok], out: &mut Vec<(u32, RuleId, String)>) {
    for i in 0..toks.len().saturating_sub(2) {
        let name = &toks[i];
        let nargs = if name.is_ident("debug_assert") {
            1
        } else if name.is_ident("debug_assert_eq") || name.is_ident("debug_assert_ne") {
            2
        } else {
            continue;
        };
        if !(toks[i + 1].is_punct("!") && toks[i + 2].is_punct("(")) {
            continue;
        }
        // Walk the asserted arguments (not the trailing format message,
        // where `=` legitimately appears in named format args).
        let mut depth = 1i32;
        let mut commas = 0usize;
        let mut j = i + 3;
        while j < toks.len() && depth > 0 && commas < nargs {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => depth -= 1,
                    "," if depth == 1 => commas += 1,
                    op if depth >= 1 && ASSIGN_OPS.contains(&op) => {
                        out.push((
                            name.line,
                            RuleId::DebugAssertEffect,
                            format!(
                                "assignment inside `{}!` vanishes in release builds",
                                name.text
                            ),
                        ));
                    }
                    _ => {}
                }
            } else if t.kind == TokKind::Ident
                && MUTATING_METHODS.contains(&t.text.as_str())
                && j > 0
                && toks[j - 1].is_punct(".")
                && j + 1 < toks.len()
                && toks[j + 1].is_punct("(")
            {
                out.push((
                    name.line,
                    RuleId::DebugAssertEffect,
                    format!(
                        "`.{}()` mutates inside `{}!` and vanishes in release builds",
                        t.text, name.text
                    ),
                ));
            }
            j += 1;
        }
    }
}

/// D5 — panic-budget counters for one file, skipping `#[cfg(test)]` items.
pub fn count_budget(toks: &[Tok]) -> BudgetEntry {
    let skip = cfg_test_ranges(toks);
    let skipped = |i: usize| skip.iter().any(|&(a, b)| i >= a && i <= b);
    let mut e = BudgetEntry::default();
    for i in 0..toks.len() {
        if skipped(i) {
            continue;
        }
        let t = &toks[i];
        // `.unwrap(` / `.expect(`
        if t.kind == TokKind::Ident
            && i > 0
            && toks[i - 1].is_punct(".")
            && i + 1 < toks.len()
            && toks[i + 1].is_punct("(")
        {
            if t.text == "unwrap" {
                e.unwrap += 1;
            } else if t.text == "expect" {
                e.expect += 1;
            }
        }
        // `panic!(`
        if t.is_ident("panic") && i + 1 < toks.len() && toks[i + 1].is_punct("!") {
            e.panic += 1;
        }
        // Index expressions: `[` directly after an indexable expression.
        if t.is_punct("[") && i > 0 {
            let prev = &toks[i - 1];
            let indexable = match prev.kind {
                TokKind::Ident => !is_keyword(&prev.text),
                TokKind::Num => true,
                TokKind::Punct => matches!(prev.text.as_str(), ")" | "]" | "?"),
                _ => false,
            };
            if indexable {
                e.index += 1;
            }
        }
    }
    e
}

/// Token index ranges covered by `#[cfg(test)]` items (inline test mods,
/// test-only helpers). Budget counters skip these: the ratchet measures
/// hot-path production code, not assertions in tests.
fn cfg_test_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let is_cfg_test = toks[i].is_punct("#")
            && toks[i + 1].is_punct("[")
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is_punct("(")
            && toks[i + 4].is_ident("test")
            && toks[i + 5].is_punct(")")
            && toks[i + 6].is_punct("]");
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + 7;
        // Skip any further attributes between the cfg and the item.
        while j + 1 < toks.len() && toks[j].is_punct("#") && toks[j + 1].is_punct("[") {
            let mut depth = 0i32;
            j += 1;
            while j < toks.len() {
                if toks[j].is_punct("[") {
                    depth += 1;
                } else if toks[j].is_punct("]") {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // The item ends at the first top-level `;` or the matching `}` of
        // its first brace block (covers mods, fns, impls, use-decls).
        let mut depth = 0i32;
        let mut end = j;
        while end < toks.len() {
            let t = &toks[end];
            if t.is_punct("{") {
                depth += 1;
            } else if t.is_punct("}") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if t.is_punct(";") && depth == 0 {
                break;
            }
            end += 1;
        }
        ranges.push((start, end.min(toks.len().saturating_sub(1))));
        i = end + 1;
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LintConfig {
        LintConfig::default()
    }

    fn check(path: &str, src: &str) -> Vec<Finding> {
        check_source(path, src, &cfg(), None)
    }

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule.as_str()).collect()
    }

    #[test]
    fn map_iteration_fires_on_traversal_not_construction() {
        let src = "fn f() {\n\
                   let mut m: HashMap<u32, u32> = HashMap::new();\n\
                   m.insert(1, 2);\n\
                   let v = m.get(&1);\n\
                   for (k, val) in &m { use_it(k, val); }\n\
                   let total: u32 = m.values().sum();\n\
                   }\n";
        let fs = check("src/cluster/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["map-iteration", "map-iteration"]);
        assert_eq!(fs[0].line, 5, "for-loop traversal");
        assert_eq!(fs[1].line, 6, ".values() traversal");
    }

    #[test]
    fn map_iteration_tracks_fields_params_and_set_constructors() {
        let src = "struct S { cache: HashMap<String, u32> }\n\
                   fn g(seen: &HashSet<u32>, s: &S) {\n\
                   for k in seen.iter() { touch(k); }\n\
                   let c = s.cache.keys().count();\n\
                   }\n";
        let fs = check("src/sweep/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["map-iteration", "map-iteration"]);
        assert_eq!(fs[0].line, 3);
        assert_eq!(fs[1].line, 4);
    }

    #[test]
    fn map_iteration_silent_outside_deterministic_modules() {
        let src = "fn f(m: &HashMap<u32, u32>) { for k in m.keys() { t(k); } }\n";
        assert!(check("src/mig/x.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_fires_and_respects_allowlist() {
        let src = "fn f() {\n\
                   let t0 = std::time::Instant::now();\n\
                   let dt = t0.elapsed().as_secs_f64();\n\
                   let s = SystemTime::now();\n\
                   }\n";
        let fs = check("src/cluster/x.rs", src);
        assert_eq!(rules_of(&fs), vec!["wall-clock", "wall-clock", "wall-clock"]);
        assert_eq!(fs[0].line, 2);
        assert_eq!(fs[1].line, 3);
        assert_eq!(fs[2].line, 4);
        assert!(check("benches/x.rs", src).is_empty(), "benches are sanctioned");
        assert!(check("src/main.rs", src).is_empty(), "the CLI is sanctioned");
    }

    #[test]
    fn unstable_sort_exempts_visibly_total_comparators() {
        let bad = "fn f(v: &mut Vec<f64>) { v.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let fs = check("src/cluster/x.rs", bad);
        assert_eq!(rules_of(&fs), vec!["float-order", "unstable-sort"]);
        let good = "fn f(v: &mut Vec<f64>) { v.sort_unstable_by(f64::total_cmp); }\n";
        assert!(check("src/cluster/x.rs", good).is_empty());
        let plain = "fn f(v: &mut Vec<u32>) { v.sort_unstable(); }\n";
        assert!(check("src/cluster/x.rs", plain).is_empty(), "Ord-bounded sort is exempt");
    }

    #[test]
    fn ambient_entropy_fires_everywhere() {
        let src = "fn f() { let r = rand::thread_rng(); }\n";
        let fs = check("src/mig/x.rs", src);
        assert!(rules_of(&fs).contains(&"ambient-entropy"));
    }

    #[test]
    fn debug_assert_effect_catches_mutation_not_comparison() {
        let bad = "fn f(v: &mut Vec<u32>) { debug_assert!(v.pop().is_some()); }\n";
        assert_eq!(rules_of(&check("src/mig/x.rs", bad)), vec!["debug-assert-effect"]);
        let bad2 = "fn f(mut x: u32) { debug_assert!({ x += 1; x > 0 }); }\n";
        assert_eq!(rules_of(&check("src/mig/x.rs", bad2)), vec!["debug-assert-effect"]);
        let good = "fn f(x: u32) { debug_assert!(x >= 1, \"x = {x}\"); }\n";
        assert!(check("src/mig/x.rs", good).is_empty(), ">= is not an assignment");
        let fmt_arg = "fn f(x: u32) { debug_assert_eq!(x, 1, \"ctx {y}\", y = 2); }\n";
        assert!(check("src/mig/x.rs", fmt_arg).is_empty(), "named format args are fine");
    }

    #[test]
    fn suppression_trailing_and_leading() {
        let trailing = "fn f() { let t = std::time::Instant::now(); } \
                        // lint:allow(wall-clock, reason=\"wall-only probe\")\n";
        assert!(check("src/cluster/x.rs", trailing).is_empty());
        let leading = "fn f() {\n\
                       // lint:allow(wall-clock, reason=\"wall-only probe\")\n\
                       let t = std::time::Instant::now();\n\
                       }\n";
        assert!(check("src/cluster/x.rs", leading).is_empty());
        // The allow covers only its own line.
        let elsewhere = "fn f() {\n\
                         // lint:allow(wall-clock, reason=\"wall-only probe\")\n\
                         let a = 1;\n\
                         let t = std::time::Instant::now();\n\
                         }\n";
        assert_eq!(rules_of(&check("src/cluster/x.rs", elsewhere)), vec!["wall-clock"]);
    }

    #[test]
    fn allow_without_reason_is_a_finding() {
        let src = "fn f() {\n\
                   // lint:allow(wall-clock)\n\
                   let t = std::time::Instant::now();\n\
                   }\n";
        let rules = rules_of(&check("src/cluster/x.rs", src));
        assert!(rules.contains(&"allow-syntax"), "missing reason must be flagged");
        assert!(rules.contains(&"wall-clock"), "malformed allow must not suppress");
    }

    #[test]
    fn allow_with_unknown_rule_is_a_finding() {
        let src = "// lint:allow(no-such-rule, reason=\"x\")\nfn f() {}\n";
        assert_eq!(rules_of(&check("src/cluster/x.rs", src)), vec!["allow-syntax"]);
    }

    #[test]
    fn rules_never_fire_inside_literals_or_comments() {
        let src = "fn f() {\n\
                   let a = \"Instant::now() thread_rng()\";\n\
                   let b = r#\"for k in m.keys() { SystemTime }\"#;\n\
                   // Instant::now() in a comment\n\
                   /* SystemTime::now() in a block comment */\n\
                   }\n";
        assert!(check("src/cluster/x.rs", src).is_empty());
    }

    #[test]
    fn budget_counts_skip_cfg_test_items() {
        let src = "fn hot(v: &[u32]) -> u32 {\n\
                   let x = v[0];\n\
                   let y = maybe().unwrap();\n\
                   let z = other().expect(\"z\");\n\
                   if x == 0 { panic!(\"zero\"); }\n\
                   x + y + z\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   #[test]\n\
                   fn t() { assert_eq!(hot(&[1]).unwrap(), 1); let q = arr[0]; }\n\
                   }\n";
        let counts = count_budget(&lex(src).toks);
        assert_eq!(counts.unwrap, 1, "test-mod unwrap not counted");
        assert_eq!(counts.expect, 1);
        assert_eq!(counts.panic, 1);
        assert_eq!(counts.index, 1, "slice index in hot code only");
    }

    #[test]
    fn budget_ignores_attributes_types_and_macros() {
        let src = "#[rustfmt::skip]\n\
                   fn f(xs: &[f64; 4]) -> Vec<f64> {\n\
                   let v = vec![1.0, 2.0];\n\
                   let s = &xs[..2];\n\
                   let first = v[0] + s[1] + point().0[2];\n\
                   v\n\
                   }\n";
        let counts = count_budget(&lex(src).toks);
        // xs[..2], v[0], s[1], .0[2] — not the attribute, array type or
        // vec! macro brackets.
        assert_eq!(counts.index, 4);
        assert_eq!(counts.unwrap + counts.expect + counts.panic, 0);
    }

    #[test]
    fn budget_findings_ratchet_both_ways() {
        use super::super::config::parse_budget;
        let src = "fn hot() { maybe().unwrap(); }\n";
        let cfg = cfg();
        let path = "src/cluster/engine.rs";
        let over = parse_budget("[budget.\"src/cluster/engine.rs\"]\nunwrap = 0\n").unwrap();
        let fs = check_source(path, src, &cfg, Some(&over));
        assert_eq!(rules_of(&fs), vec!["panic-budget"]);
        assert_eq!(fs[0].severity, Severity::Error);
        let exact = parse_budget("[budget.\"src/cluster/engine.rs\"]\nunwrap = 1\n").unwrap();
        assert!(check_source(path, src, &cfg, Some(&exact)).is_empty());
        let stale = parse_budget("[budget.\"src/cluster/engine.rs\"]\nunwrap = 5\n").unwrap();
        let fs = check_source(path, src, &cfg, Some(&stale));
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].severity, Severity::Warning, "stale budget is a warning");
        let missing = parse_budget("").unwrap();
        let fs = check_source(path, src, &cfg, Some(&missing));
        assert_eq!(rules_of(&fs), vec!["panic-budget"], "budgeted module must have an entry");
    }

    #[test]
    fn budget_not_applied_to_unbudgeted_files() {
        let src = "fn hot() { maybe().unwrap(); }\n";
        assert!(check("src/cluster/telemetry.rs", src).is_empty());
    }
}
