//! Orchestrator-level properties.
//!
//! Three contracts from the orchestrator work: (a) every plan the
//! exhaustive optimizer emits respects each workload's SLO, (b) every
//! layout any repartitioning policy proposes passes the MIG placement
//! rules, and (c) orchestrator sweeps are bitwise-deterministic at any
//! worker count. Plus the headline benchmark claim: under a saturating
//! diurnal peak the reactive policy beats the static whole-trace-average
//! baseline.

use migperf::mig::gpu::GpuModel;
use migperf::mig::placement::PlacementEngine;
use migperf::models::zoo;
use migperf::orchestrator::{
    OrchestratorConfig, PolicyKind, ReconfigCost, ServiceConfig,
};
use migperf::prop_assert;
use migperf::scheduler::{DemandWorkload, Objective, Scheduler, SloWorkload};
use migperf::sweep::{self, SweepEngine};
use migperf::util::proptest::{check_with, Config, Gen};
use migperf::workload::arrival::ArrivalSpec;
use migperf::workload::spec::WorkloadSpec;

fn random_gpu(g: &mut Gen) -> GpuModel {
    *g.pick(&[GpuModel::A100_80GB, GpuModel::A30_24GB])
}

fn random_model(g: &mut Gen) -> &'static migperf::models::zoo::ModelDesc {
    let names = ["resnet18", "resnet50", "distilbert", "bert-base"];
    zoo::lookup(g.pick(&names)).unwrap()
}

/// (a) Every optimizer plan honours each workload's SLO.
#[test]
fn prop_optimizer_plans_respect_slos() {
    check_with(Config { cases: 60, ..Default::default() }, |g: &mut Gen| {
        let gpu = random_gpu(g);
        let sched = Scheduler::new(gpu);
        let mut ws: Vec<SloWorkload> = Vec::new();
        if g.bool() {
            let batch = 1 << g.below(6);
            ws.push(SloWorkload::best_effort(WorkloadSpec::training(
                random_model(g),
                batch as u32,
                128,
            )));
        }
        let services = 1 + g.below(3) as usize;
        for _ in 0..services {
            let batch = 1 << g.below(5);
            let slo_ms = g.f64(2.0, 120.0);
            ws.push(SloWorkload::with_slo(
                WorkloadSpec::inference(random_model(g), batch as u32, 128),
                slo_ms,
            ));
        }
        let objective = if g.bool() {
            Objective::MaxThroughput
        } else {
            Objective::MinEnergy
        };
        if let Some(plan) = sched.plan(&ws, objective) {
            for a in &plan.assignments {
                if let Some(slo) = ws[a.workload].slo_ms {
                    prop_assert!(
                        a.latency_ms <= slo,
                        "assignment blows its SLO: {a:?} vs slo {slo} (plan {:?})",
                        plan.layout
                    );
                }
            }
            prop_assert!(
                plan.assignments.len() == ws.len(),
                "every workload must be placed: {} of {}",
                plan.assignments.len(),
                ws.len()
            );
        }
        Ok(())
    });
}

/// (b-1) Every layout the demand planner (the core of every orchestrator
/// policy) proposes passes the placement rules.
#[test]
fn prop_demand_plans_pass_placement_rules() {
    check_with(Config { cases: 60, ..Default::default() }, |g: &mut Gen| {
        let gpu = random_gpu(g);
        let sched = Scheduler::new(gpu);
        let engine = PlacementEngine::new(gpu);
        let mut ws: Vec<DemandWorkload> = Vec::new();
        if g.bool() {
            ws.push(DemandWorkload::training(WorkloadSpec::training(
                random_model(g),
                16,
                128,
            )));
        }
        let services = 1 + g.below(3) as usize;
        for _ in 0..services {
            let batch = 1 << g.below(5);
            ws.push(DemandWorkload::service(
                WorkloadSpec::inference(random_model(g), batch as u32, 128),
                g.f64(5.0, 150.0),
                g.f64(0.0, 400.0),
            ));
        }
        let rho_max = g.f64(0.3, 0.95);
        if let Some(plan) = sched.plan_for_demand(&ws, rho_max) {
            if let Err(e) = engine.check_layout(&plan.layout.placements) {
                return Err(format!("invalid layout {:?}: {e}", plan.layout.profile_names()));
            }
            // Assignments are injective over instances.
            let mut seen = vec![false; plan.layout.len()];
            for a in &plan.assignments {
                prop_assert!(!seen[a.instance], "instance double-booked: {:?}", plan.assignments);
                seen[a.instance] = true;
            }
        }
        Ok(())
    });
}

fn diurnal_scenario(policy: PolicyKind, peak_rate: f64, seed: u64) -> OrchestratorConfig {
    let bert = zoo::lookup("bert-base").unwrap();
    let service = ServiceConfig {
        spec: WorkloadSpec::inference(bert, 8, 128),
        slo_ms: 40.0,
        arrival: ArrivalSpec::Diurnal { base_rate: 6.0, peak_rate, period_s: 240.0 },
    };
    OrchestratorConfig {
        gpu: GpuModel::A100_80GB,
        train: Some(WorkloadSpec::training(bert, 32, 128)),
        services: vec![service.clone(), service],
        policy,
        cost: ReconfigCost::default(),
        duration_s: 480.0,
        window_s: 10.0,
        rho_max: 0.75,
        seed,
    }
}

fn all_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::parse("static").unwrap(),
        PolicyKind::parse("reactive").unwrap(),
        PolicyKind::parse("predictive").unwrap(),
    ]
}

/// (b-2) End to end: every layout adopted by any policy over a full
/// diurnal run passes the placement rules.
#[test]
fn orchestrator_adopted_layouts_are_valid_for_every_policy() {
    let engine = PlacementEngine::new(GpuModel::A100_80GB);
    for policy in all_policies() {
        let out = diurnal_scenario(policy.clone(), 60.0, 7).run().unwrap();
        assert!(!out.layouts.is_empty());
        for layout in &out.layouts {
            engine.check_layout(&layout.placements).unwrap_or_else(|e| {
                let names = layout.profile_names();
                panic!("{}: invalid adopted layout {names:?}: {e}", policy.name())
            });
        }
    }
}

/// (c) Orchestrator sweeps are bitwise-deterministic at 1/2/4/16 workers.
#[test]
fn orchestrator_sweep_bitwise_deterministic_across_worker_counts() {
    let mut grid: Vec<OrchestratorConfig> = Vec::new();
    for policy in all_policies() {
        for seed in [2024u64, 2025u64] {
            grid.push(diurnal_scenario(policy.clone(), 60.0, seed));
        }
    }
    let baseline = sweep::run_orchestrator(&SweepEngine::new(1), &grid).unwrap();
    for workers in [2usize, 4, 16] {
        let outs = sweep::run_orchestrator(&SweepEngine::new(workers), &grid).unwrap();
        assert_eq!(outs.len(), baseline.len());
        for (a, b) in baseline.iter().zip(&outs) {
            assert_eq!(a.policy, b.policy, "workers={workers}");
            assert_eq!(a.arrived, b.arrived, "workers={workers}");
            assert_eq!(a.completed, b.completed, "workers={workers}");
            assert_eq!(a.train_steps, b.train_steps, "workers={workers}");
            assert_eq!(a.reconfigurations, b.reconfigurations, "workers={workers}");
            assert_eq!(
                a.goodput_rps.to_bits(),
                b.goodput_rps.to_bits(),
                "workers={workers}"
            );
            assert_eq!(
                a.slo_violation_frac.to_bits(),
                b.slo_violation_frac.to_bits(),
                "workers={workers}"
            );
            assert_eq!(
                a.pooled.p99_latency_ms.to_bits(),
                b.pooled.p99_latency_ms.to_bits(),
                "workers={workers}"
            );
            assert_eq!(
                a.reconfig_downtime_s.to_bits(),
                b.reconfig_downtime_s.to_bits(),
                "workers={workers}"
            );
            assert_eq!(a.decisions.len(), b.decisions.len(), "workers={workers}");
            for (da, db) in a.decisions.iter().zip(&b.decisions) {
                assert_eq!(da.t.to_bits(), db.t.to_bits(), "workers={workers}");
                assert_eq!(da.downtime_s.to_bits(), db.downtime_s.to_bits());
                assert_eq!(da.to, db.to);
            }
        }
    }
}

/// The acceptance comparison: at a saturating diurnal peak the reactive
/// policy must achieve strictly higher goodput or a strictly lower
/// SLO-violation fraction than the static whole-trace-average baseline.
#[test]
fn reactive_beats_static_baseline_at_saturating_peak() {
    let st = diurnal_scenario(PolicyKind::parse("static").unwrap(), 60.0, 2024).run().unwrap();
    let re = diurnal_scenario(PolicyKind::parse("reactive").unwrap(), 60.0, 2024).run().unwrap();
    assert_eq!(st.reconfigurations, 0);
    assert!(re.reconfigurations > 0, "the diurnal peak must force repartitions");
    assert!(
        re.goodput_rps > st.goodput_rps || re.slo_violation_frac < st.slo_violation_frac,
        "reactive (goodput {:.1} rps, viol {:.3}) must beat static (goodput {:.1} rps, viol {:.3})",
        re.goodput_rps,
        re.slo_violation_frac,
        st.goodput_rps,
        st.slo_violation_frac
    );
}
