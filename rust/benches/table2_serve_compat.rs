//! Table 2: serving-framework compatibility with MIG.
//!
//! Regenerates the paper's Table 2: three serving frameworks on a 2-GI
//! A30 — every one serves on MIG 0, none finds MIG 1 — plus the docker
//! workaround demonstration the paper describes in §4.6.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::frameworks::docker::ContainerHost;
use migperf::frameworks::run_serving_matrix;
use migperf::mig::controller::MigController;
use migperf::mig::gpu::GpuModel;
use migperf::util::table::Table;

fn main() {
    banner("Table 2", "Serving framework compatibility with MIG (2-GI A30)");
    let rows = run_serving_matrix();
    let mut t =
        Table::new(&["Serving framework", "Version", "Serving on MIG 0", "Serving on MIG 1"]);
    for r in &rows {
        t.row(&[
            r.framework.to_string(),
            r.version.to_string(),
            if r.works_on_mig0 { "Yes" } else { "No" }.to_string(),
            if r.works_on_mig1 { "Yes" } else { "Device not found" }.to_string(),
        ]);
    }
    println!("\n{}", t.render());

    shape_check("3 serving frameworks probed", rows.len() == 3);
    shape_check(
        "all serve on MIG 0, none finds MIG 1",
        rows.iter().all(|r| r.works_on_mig0 && !r.works_on_mig1),
    );

    // §4.6 workaround: container binding reaches MIG 1.
    let mut ctl = MigController::new(GpuModel::A30_24GB);
    ctl.enable_mig().unwrap();
    let a = ctl.create_instance("1g.6gb").unwrap();
    let b = ctl.create_instance("1g.6gb").unwrap();
    ctl.create_default_ci(a).unwrap();
    ctl.create_default_ci(b).unwrap();
    let mut host = ContainerHost::new();
    host.bind(&ctl, "triton-mig1", b).unwrap();
    let devs = host.devices_in(&ctl, "triton-mig1").unwrap();
    shape_check(
        "docker binding makes MIG 1 servable (paper §4.6 workaround)",
        devs.len() == 1 && devs[0].mig_uuid.as_deref().unwrap().contains("/1/"),
    );
    // …but reconfiguration requires the stop/unbind/resize/rebind dance.
    let refused = host.destroy_gi(&mut ctl, b).is_err();
    shape_check(
        "bound GI cannot be reconfigured while the container runs (§4.6 friction)",
        refused,
    );
    println!("\ndemonstrated: docker-bound container reaches MIG 1; live reconfiguration refused.");
}
