// Lint fixture (never compiled): order-dependent hash traversal in a
// deterministic module. Expected: map-iteration errors on lines 7 and
// 10. Construction and the point lookup on line 6 must NOT fire.

pub fn order_leak(m: &HashMap<u32, u32>, seen: &HashSet<u32>) -> u32 {
    let mut acc = *m.get(&1).unwrap_or(&0);
    for (k, v) in m.iter() {
        acc += k + v;
    }
    for x in seen {
        acc += x;
    }
    acc
}
