//! Deterministic failure injection for the fleet simulator.
//!
//! The ROADMAP's post-fleet item: "GPU/instance crashes mid-run, router
//! health checks, request retries with budgets — measure goodput under
//! partial outages." A [`FaultPlan`] is a *schedule*, not a live random
//! process: every crash and recovery time is fixed before the simulation
//! starts (either written out explicitly or drawn once from the seeded
//! MTBF/MTTR generator), so a fault plan is plain config data and fleet
//! sweeps keep the bit-identical-at-any-worker-count contract — the crash
//! schedule travels with the [`FleetConfig`](super::FleetConfig) into the
//! sweep grid exactly like an arrival spec does.
//!
//! Two crash granularities are modelled:
//!
//! * **GPU crash** (`class: None`) — the whole GPU goes dark: every
//!   replica's queued and in-flight requests are dumped, the training
//!   step in flight is lost, and the router health check excludes the GPU
//!   until recovery (in *both* repartition modes — a crashed GPU is not a
//!   reconfiguring one);
//! * **instance crash** (`class: Some(c)`) — only class `c`'s replica on
//!   that GPU dies; the GPU keeps serving its other classes and training.
//!
//! Dumped requests carry a per-request retry budget: within budget they
//! are re-dispatched through the router (keeping their original arrival
//! timestamps, so latency spans the outage), beyond it they are lost
//! (`lost_in_crash`). A retry-storm guard caps how many requests a single
//! crash may re-admit; the overflow is shed (`failed_requests`). The
//! engine extends its conservation invariant across all of it:
//! `completed + failed + lost_in_crash = admitted`.

use crate::util::prng::Prng;

/// Default per-request retry budget after a crash.
pub const DEFAULT_RETRY_BUDGET: u32 = 1;

/// One scheduled fault: a GPU- or instance-level crash at `t` lasting
/// `down_s` simulated seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultInjection {
    /// Crash time, simulated seconds (must land inside the run horizon).
    pub t: f64,
    /// Fleet index of the affected GPU.
    pub gpu: usize,
    /// `None` crashes the whole GPU; `Some(c)` crashes only class `c`'s
    /// replica on that GPU.
    pub class: Option<usize>,
    /// Seconds until recovery. `f64::INFINITY` models a permanent
    /// failure: the GPU (or replica) never comes back within the run.
    pub down_s: f64,
}

impl FaultInjection {
    /// Recovery time of this fault (`+inf` for permanent failures).
    pub fn end(&self) -> f64 {
        self.t + self.down_s
    }
}

/// A deterministic crash/recovery schedule plus the ingress retry policy.
///
/// Plain data: clone freely into sweep grids. The default plan is empty
/// (no faults), which leaves the engine's behavior bit-identical to a
/// build without failure injection.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// The crash schedule, in injection order (sorted by time).
    pub injections: Vec<FaultInjection>,
    /// How many times a request dumped by a crash may be re-dispatched
    /// before it is counted `lost_in_crash`.
    pub retry_budget: u32,
    /// Retry-storm guard: the maximum number of requests a single crash
    /// event may re-admit at the ingress; overflow is shed and counted
    /// `failed_requests`. `u64::MAX` disables the guard.
    pub storm_guard: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            injections: Vec::new(),
            retry_budget: DEFAULT_RETRY_BUDGET,
            storm_guard: u64::MAX,
        }
    }
}

impl FaultPlan {
    /// The empty plan: no faults injected.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when no fault is scheduled.
    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }

    /// Builder-style retry budget override.
    pub fn with_retries(mut self, retry_budget: u32) -> Self {
        self.retry_budget = retry_budget;
        self
    }

    /// Builder-style storm-guard override (`u64::MAX` = unbounded).
    pub fn with_storm_guard(mut self, storm_guard: u64) -> Self {
        self.storm_guard = storm_guard;
        self
    }

    /// Stochastic whole-GPU crash schedule: per GPU, alternating
    /// exponential up-times (mean `mtbf_s`) and down-times (mean
    /// `mttr_s`) drawn once from the seeded PRNG. The same
    /// `(n_gpus, duration_s, mtbf_s, mttr_s, seed)` tuple always yields
    /// the same schedule, and successive faults on a GPU never overlap by
    /// construction, so the result validates and sweeps deterministically.
    pub fn from_mtbf(
        n_gpus: usize,
        duration_s: f64,
        mtbf_s: f64,
        mttr_s: f64,
        seed: u64,
    ) -> FaultPlan {
        assert!(
            mtbf_s.is_finite() && mtbf_s > 0.0,
            "mtbf_s {mtbf_s} must be positive and finite"
        );
        assert!(
            mttr_s.is_finite() && mttr_s > 0.0,
            "mttr_s {mttr_s} must be positive and finite"
        );
        assert!(
            duration_s.is_finite() && duration_s > 0.0,
            "duration_s {duration_s} must be positive and finite"
        );
        let mut injections = Vec::new();
        let mut seeder = Prng::new(seed);
        for gpu in 0..n_gpus {
            let mut rng = seeder.split();
            let mut t = rng.exponential(1.0 / mtbf_s);
            while t < duration_s {
                // Strictly positive repair times keep per-GPU faults
                // non-overlapping (validate() enforces the same).
                let down_s = rng.exponential(1.0 / mttr_s).max(1e-9);
                injections.push(FaultInjection { t, gpu, class: None, down_s });
                t += down_s + rng.exponential(1.0 / mtbf_s);
            }
        }
        // Total order independent of generation order: by time, then GPU.
        injections.sort_by(|a, b| {
            // lint:allow(float-order, reason="expect is a deliberate NaN guard: a NaN fault time must panic loudly, not order silently")
            a.t.partial_cmp(&b.t).expect("finite fault times").then(a.gpu.cmp(&b.gpu))
        });
        FaultPlan { injections, ..FaultPlan::default() }
    }

    /// Reject schedules the engine cannot execute: out-of-range targets,
    /// crash times outside the arrival horizon, non-positive repair
    /// times, and overlapping faults on the same GPU (the engine's
    /// crash/recovery bookkeeping assumes at most one open fault per
    /// GPU at a time, regardless of granularity).
    pub fn validate(
        &self,
        n_gpus: usize,
        n_classes: usize,
        duration_s: f64,
    ) -> Result<(), String> {
        for (i, inj) in self.injections.iter().enumerate() {
            if !(inj.t.is_finite() && inj.t >= 0.0 && inj.t < duration_s) {
                return Err(format!(
                    "fault {i}: t = {} must lie in [0, duration_s = {duration_s})",
                    inj.t
                ));
            }
            if inj.down_s <= 0.0 || inj.down_s.is_nan() {
                return Err(format!(
                    "fault {i}: down_s = {} must be positive (infinity = permanent)",
                    inj.down_s
                ));
            }
            if inj.gpu >= n_gpus {
                return Err(format!(
                    "fault {i}: gpu {} out of range (fleet size {n_gpus})",
                    inj.gpu
                ));
            }
            if let Some(c) = inj.class {
                if c >= n_classes {
                    return Err(format!(
                        "fault {i}: class {c} out of range ({n_classes} classes)"
                    ));
                }
            }
        }
        for gpu in 0..n_gpus {
            let mut per: Vec<&FaultInjection> =
                self.injections.iter().filter(|f| f.gpu == gpu).collect();
            // lint:allow(float-order, reason="expect is a deliberate NaN guard: a NaN fault time must panic loudly, not order silently")
            per.sort_by(|a, b| a.t.partial_cmp(&b.t).expect("finite fault times"));
            for w in per.windows(2) {
                if w[0].end() > w[1].t {
                    return Err(format!(
                        "faults on gpu {gpu} overlap: [{}, {}) and [{}, {})",
                        w[0].t,
                        w[0].end(),
                        w[1].t,
                        w[1].end()
                    ));
                }
            }
        }
        Ok(())
    }
}

/// One *executed* fault, as recorded by the engine — the fault timeline
/// exported alongside the decision log.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    /// Crash time, simulated seconds.
    pub t: f64,
    /// Fleet index of the affected GPU.
    pub gpu: usize,
    /// `None` for a whole-GPU crash, `Some(c)` for an instance crash.
    pub class: Option<usize>,
    /// Scheduled outage length (`+inf` = permanent).
    pub down_s: f64,
    /// Requests dumped by this crash whose retry budget was exhausted.
    pub lost: u64,
    /// Requests dumped by this crash and re-admitted at the ingress.
    pub retried: u64,
    /// Requests shed by the retry-storm guard at this crash.
    pub shed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mtbf_schedules_are_deterministic_per_seed() {
        let a = FaultPlan::from_mtbf(4, 1000.0, 100.0, 10.0, 7);
        let b = FaultPlan::from_mtbf(4, 1000.0, 100.0, 10.0, 7);
        assert_eq!(a, b, "same seed must yield the same schedule");
        assert!(!a.is_empty(), "mtbf << duration must schedule crashes");
        let c = FaultPlan::from_mtbf(4, 1000.0, 100.0, 10.0, 8);
        assert_ne!(a, c, "different seeds must diverge");
    }

    #[test]
    fn mtbf_schedules_validate_and_sort() {
        let p = FaultPlan::from_mtbf(3, 500.0, 60.0, 15.0, 42);
        p.validate(3, 2, 500.0).expect("generated schedules are valid");
        assert!(
            p.injections.windows(2).all(|w| w[0].t <= w[1].t),
            "injections sorted by time"
        );
        assert!(p.injections.iter().all(|f| f.class.is_none()));
        assert!(p.injections.iter().all(|f| f.t < 500.0 && f.down_s > 0.0));
    }

    #[test]
    fn validate_rejects_degenerate_schedules() {
        let ok = FaultInjection { t: 10.0, gpu: 0, class: None, down_s: 5.0 };
        let plan = |inj: Vec<FaultInjection>| FaultPlan { injections: inj, ..FaultPlan::default() };
        assert!(plan(vec![ok]).validate(2, 2, 100.0).is_ok());
        // Out-of-range GPU and class.
        assert!(plan(vec![FaultInjection { gpu: 2, ..ok }]).validate(2, 2, 100.0).is_err());
        assert!(plan(vec![FaultInjection { class: Some(2), ..ok }]).validate(2, 2, 100.0).is_err());
        // Crash outside the horizon, negative time, NaN.
        assert!(plan(vec![FaultInjection { t: 100.0, ..ok }]).validate(2, 2, 100.0).is_err());
        assert!(plan(vec![FaultInjection { t: -1.0, ..ok }]).validate(2, 2, 100.0).is_err());
        assert!(plan(vec![FaultInjection { t: f64::NAN, ..ok }]).validate(2, 2, 100.0).is_err());
        // Zero / NaN repair times.
        assert!(plan(vec![FaultInjection { down_s: 0.0, ..ok }]).validate(2, 2, 100.0).is_err());
        assert!(
            plan(vec![FaultInjection { down_s: f64::NAN, ..ok }]).validate(2, 2, 100.0).is_err()
        );
        // Permanent failures are fine.
        assert!(plan(vec![FaultInjection { down_s: f64::INFINITY, ..ok }])
            .validate(2, 2, 100.0)
            .is_ok());
    }

    #[test]
    fn validate_rejects_overlapping_faults_on_one_gpu() {
        let plan = FaultPlan {
            injections: vec![
                FaultInjection { t: 10.0, gpu: 0, class: None, down_s: 20.0 },
                FaultInjection { t: 15.0, gpu: 0, class: Some(0), down_s: 1.0 },
            ],
            ..FaultPlan::default()
        };
        assert!(plan.validate(2, 2, 100.0).is_err(), "overlap on gpu 0");
        // The same two faults on different GPUs are fine.
        let plan = FaultPlan {
            injections: vec![
                FaultInjection { t: 10.0, gpu: 0, class: None, down_s: 20.0 },
                FaultInjection { t: 15.0, gpu: 1, class: Some(0), down_s: 1.0 },
            ],
            ..FaultPlan::default()
        };
        assert!(plan.validate(2, 2, 100.0).is_ok());
        // A permanent failure blocks any later fault on that GPU.
        let plan = FaultPlan {
            injections: vec![
                FaultInjection { t: 10.0, gpu: 0, class: None, down_s: f64::INFINITY },
                FaultInjection { t: 90.0, gpu: 0, class: None, down_s: 1.0 },
            ],
            ..FaultPlan::default()
        };
        assert!(plan.validate(1, 2, 100.0).is_err());
    }

    #[test]
    fn builders_and_defaults() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert_eq!(p.retry_budget, DEFAULT_RETRY_BUDGET);
        assert_eq!(p.storm_guard, u64::MAX);
        let p = p.with_retries(3).with_storm_guard(100);
        assert_eq!(p.retry_budget, 3);
        assert_eq!(p.storm_guard, 100);
        let inj = FaultInjection { t: 5.0, gpu: 1, class: None, down_s: f64::INFINITY };
        assert_eq!(inj.end(), f64::INFINITY);
    }
}
