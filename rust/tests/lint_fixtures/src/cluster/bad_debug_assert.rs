// Lint fixture (never compiled): side effects inside debug_assert!
// (they vanish in release builds). Expected: debug-assert-effect errors
// on lines 6 and 7; the pure comparison on line 8 must NOT fire.

pub fn check(v: &mut Vec<u32>, mut x: u32) {
    debug_assert!(v.pop().is_some());
    debug_assert!({ x += 1; x > 0 });
    debug_assert!(x >= 1, "x = {x}");
}
