//! Benchmark workload specification.

use crate::models::cost::{infer_cost, train_cost, Precision, StepCost};
use crate::models::zoo::ModelDesc;

/// Training or inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Forward + backward + optimizer step.
    Training,
    /// Forward only.
    Inference,
}

/// A fully specified benchmark workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Model under test.
    pub model: &'static ModelDesc,
    /// Batch size per step/request.
    pub batch: u32,
    /// Sequence length (transformers) or input side (CNNs, informational).
    pub seq: u32,
    /// Numeric precision.
    pub precision: Precision,
    /// Training or inference.
    pub kind: WorkloadKind,
}

impl WorkloadSpec {
    /// Inference workload with the paper's defaults (fp16).
    pub fn inference(model: &'static ModelDesc, batch: u32, seq: u32) -> Self {
        WorkloadSpec {
            model,
            batch,
            seq,
            precision: Precision::Half,
            kind: WorkloadKind::Inference,
        }
    }

    /// Training workload with the paper's defaults (fp16).
    pub fn training(model: &'static ModelDesc, batch: u32, seq: u32) -> Self {
        WorkloadSpec { model, batch, seq, precision: Precision::Half, kind: WorkloadKind::Training }
    }

    /// Analytic cost of one step of this workload.
    pub fn step_cost(&self) -> StepCost {
        match self.kind {
            WorkloadKind::Training => train_cost(self.model, self.batch, self.seq, self.precision),
            WorkloadKind::Inference => infer_cost(self.model, self.batch, self.seq, self.precision),
        }
    }

    /// Report label, e.g. `bert-base/train/b32/s128`.
    pub fn label(&self) -> String {
        let kind = match self.kind {
            WorkloadKind::Training => "train",
            WorkloadKind::Inference => "infer",
        };
        format!("{}/{}/b{}/s{}", self.model.name, kind, self.batch, self.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::lookup;

    #[test]
    fn label_format() {
        let s = WorkloadSpec::inference(lookup("bert-base").unwrap(), 8, 128);
        assert_eq!(s.label(), "bert-base/infer/b8/s128");
    }

    #[test]
    fn kind_routes_cost() {
        let m = lookup("resnet50").unwrap();
        let i = WorkloadSpec::inference(m, 8, 224).step_cost();
        let t = WorkloadSpec::training(m, 8, 224).step_cost();
        assert!(t.flops > i.flops * 2.5);
    }
}
