//! Minimal JSON value model, serializer and parser.
//!
//! The offline toolchain has no `serde`, so MIGPerf carries its own JSON
//! implementation. It is used for: the AOT artifact manifest written by
//! `python/compile/aot.py`, benchmark task configs, and the JSONL results
//! exporter. Supports the full JSON grammar minus exotic number forms
//! (numbers are parsed as `f64`, which is also what jax's manifest needs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Borrow as object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Borrow as array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow as string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to i64.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// Boolean value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; null is the conventional degradation.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset in the input where the error occurred.
    pub offset: usize,
    /// Human-readable cause.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multi-byte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid UTF-8")),
                        };
                        if start + width > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + width])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = start + width;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number '{text}'")))
    }
}

/// Compact serialization; `Json::to_string()` (via [`ToString`]) is the
/// canonical way to serialize a document on one line.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        f.write_str(&out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(v.to_string(), src);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("name", "bert-base".into()),
            ("layers", 12i64.into()),
            ("shapes", Json::Arr(vec![8i64.into(), 128i64.into()])),
        ]);
        let reparsed = parse(&v.to_pretty()).unwrap();
        assert_eq!(reparsed, v);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
        let round = parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo — ünïcode\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ünïcode"));
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn numbers_scientific() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn integer_formatting_has_no_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn errors_report_offset() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6, "offset={}", e.offset);
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{\"a\": 1,}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("{} garbage").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap().to_string(), "[]");
        assert_eq!(parse("{}").unwrap().to_string(), "{}");
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" \n\t{ \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn accessor_type_mismatches_return_none() {
        let v = parse("[1]").unwrap();
        assert!(v.as_obj().is_none());
        assert!(v.as_str().is_none());
        assert!(v.as_f64().is_none());
        assert!(v.get("x").is_none());
        assert!(Json::Null.as_bool().is_none());
    }
}
