"""AOT pipeline tests: lowering produces parseable HLO text + a coherent
manifest, and the lowered computations agree with direct JAX execution."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts():
    """Build artifacts once into a temp dir (module-scoped: lowering all
    entries takes a few seconds)."""
    d = tempfile.mkdtemp(prefix="migperf-aot-test-")
    entries = aot.build_entries(d)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"version": 1, "entries": entries}, f)
    return d, entries


class TestManifest:
    def test_entry_inventory(self, artifacts):
        _, entries = artifacts
        names = {e["name"] for e in entries}
        assert {"bert_tiny_infer_b1", "bert_tiny_infer_b4", "bert_tiny_infer_b8",
                "bert_tiny_train_b8", "resnet_tiny_infer_b1", "resnet_tiny_infer_b8"} <= names

    def test_hlo_files_exist_and_are_text(self, artifacts):
        d, entries = artifacts
        for e in entries:
            path = os.path.join(d, e["hlo_file"])
            assert os.path.exists(path), e["name"]
            head = open(path).read(200)
            assert "HloModule" in head, f"{e['name']} missing HloModule header"

    def test_train_entry_params_contract(self, artifacts):
        d, entries = artifacts
        e = next(x for x in entries if x["name"] == "bert_tiny_train_b8")
        specs = model.bert_param_specs(model.TINY_BERT)
        assert e["num_param_inputs"] == len(specs)
        assert e["num_outputs"] == 1 + len(specs)
        # Params blob length equals sum of spec sizes.
        blob = np.fromfile(os.path.join(d, e["params_file"]), dtype=np.float32)
        expect = sum(int(np.prod(s)) for _, s in specs)
        assert blob.size == expect
        # Input list = params + tokens + targets.
        assert len(e["inputs"]) == len(specs) + 2
        assert e["inputs"][-2]["dtype"] == "i32"

    def test_flops_positive_and_ordered(self, artifacts):
        _, entries = artifacts
        by_name = {e["name"]: e["flops"] for e in entries}
        assert all(f > 0 for f in by_name.values())
        assert by_name["bert_tiny_infer_b8"] > by_name["bert_tiny_infer_b1"]
        assert by_name["bert_tiny_train_b8"] > by_name["bert_tiny_infer_b8"]


class TestLoweredNumerics:
    """Execute the lowered HLO via jax's own runtime and compare with the
    direct python call — proves the lowering is faithful before rust ever
    touches it."""

    def test_infer_entry_matches_direct_call(self, artifacts):
        cfg = model.TINY_BERT
        params = model.bert_init(cfg, seed=0)
        tokens = jax.random.randint(
            jax.random.PRNGKey(0), (4, cfg.max_seq), 0, cfg.vocab, dtype=jnp.int32
        )
        direct = model.bert_infer_pooled(params, tokens, cfg)
        # Recreate the closed-over function exactly as aot.py does.
        fn = lambda t: (model.bert_infer_pooled(params, t, cfg),)
        lowered_out = jax.jit(fn)(tokens)[0]
        np.testing.assert_allclose(direct, lowered_out, rtol=1e-5, atol=1e-5)

    def test_train_entry_loss_decreases_over_steps(self, artifacts):
        cfg = model.TINY_BERT
        params = model.bert_init(cfg, seed=0)
        key = jax.random.PRNGKey(1)
        tokens, targets = model.synthetic_batch(key, 8, cfg)
        losses = []
        for _ in range(8):
            loss, params = model.bert_train_step(params, tokens, targets, cfg)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses

    def test_hlo_text_reparses_with_xla_client(self, artifacts):
        # The text must round-trip through XLA's HLO parser (what the rust
        # side's from_text_file does).
        d, entries = artifacts
        from jax._src.lib import xla_client as xc

        path = os.path.join(d, entries[0]["hlo_file"])
        text = open(path).read()
        # jax's bundled client can rebuild a computation from HLO text.
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None
