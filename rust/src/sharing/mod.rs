//! GPU-sharing technologies compared against MIG.
//!
//! The paper's GPU-sharing characterization (§4.5) pits MIG's physical
//! isolation against NVIDIA MPS (software sharing). [`mps`] implements the
//! MPS contention model; [`timeslice`] adds the classic time-slicing
//! baseline (plain CUDA context switching) as an ablation beyond the
//! paper, since it is the default when neither MIG nor MPS is configured.

pub mod mps;
pub mod timeslice;

pub use mps::MpsModel;
pub use timeslice::TimeSliceModel;
