//! End-to-end tests for `migperf lint`: the repo itself must lint clean
//! under `--strict` (the same invariant the CI gate enforces), the
//! checked-in fixtures must produce their exact file:line findings, and
//! the budget ratchet file must match the actual counts.
//!
//! Fixtures live under `tests/lint_fixtures/src/cluster/` so the path
//! substring classifies them as deterministic modules; the directory is
//! excluded from directory walks and never compiled by cargo.

use migperf::lint::config::{parse_budget, LintConfig};
use migperf::lint::lexer::lex;
use migperf::lint::rules::count_budget;
use migperf::lint::{report, run_paths, Report, Severity};

const FIXTURES: &str = "tests/lint_fixtures/src/cluster";

fn lint<S: AsRef<str>>(paths: &[S], strict: bool) -> Report {
    let cfg = LintConfig::default();
    let owned: Vec<String> = paths.iter().map(|p| p.as_ref().to_string()).collect();
    run_paths(&owned, "lint-budget.toml", strict, &cfg).expect("lint run")
}

fn findings_of(rep: &Report) -> Vec<(u32, &'static str)> {
    rep.findings.iter().map(|f| (f.line, f.rule.as_str())).collect()
}

#[test]
fn repo_lints_clean_under_strict() {
    let rep = lint(&["src"], true);
    let shown: Vec<String> = rep
        .findings
        .iter()
        .map(|f| format!("{}:{} {} — {}", f.file, f.line, f.rule.as_str(), f.message))
        .collect();
    assert!(!rep.failed(), "repo must lint clean at HEAD:\n{}", shown.join("\n"));
    assert!(rep.files_scanned > 50, "src walk found only {} files", rep.files_scanned);
}

#[test]
fn nightly_scope_lints_clean() {
    // The nightly job widens the walk to benches/ and tests/; both must
    // already be clean (fixtures are excluded from directory walks).
    let rep = lint(&["src", "benches", "tests"], true);
    let shown: Vec<String> = rep
        .findings
        .iter()
        .map(|f| format!("{}:{} {} — {}", f.file, f.line, f.rule.as_str(), f.message))
        .collect();
    assert!(!rep.failed(), "nightly lint scope must be clean:\n{}", shown.join("\n"));
}

#[test]
fn walker_skips_fixtures_but_lints_explicit_files() {
    let rep = lint(&["tests"], false);
    assert!(rep.files_scanned > 0);
    // Known-bad fixtures under tests/ must not poison the directory walk…
    assert!(!rep.failed(), "fixtures leaked into the tests/ walk");
    // …while naming a fixture directly always lints it.
    let direct = lint(&[&format!("{FIXTURES}/bad_wall_clock.rs")], false);
    assert!(direct.failed());
}

#[test]
fn fixture_wall_clock_exact_findings() {
    let rep = lint(&[&format!("{FIXTURES}/bad_wall_clock.rs")], false);
    assert_eq!(
        findings_of(&rep),
        vec![(5, "wall-clock"), (6, "wall-clock"), (7, "wall-clock")]
    );
}

#[test]
fn fixture_map_iteration_exact_findings() {
    let rep = lint(&[&format!("{FIXTURES}/bad_map_iteration.rs")], false);
    assert_eq!(findings_of(&rep), vec![(7, "map-iteration"), (10, "map-iteration")]);
}

#[test]
fn fixture_unstable_sort_exact_findings() {
    let rep = lint(&[&format!("{FIXTURES}/bad_unstable_sort.rs")], false);
    assert_eq!(findings_of(&rep), vec![(6, "float-order"), (6, "unstable-sort")]);
}

#[test]
fn fixture_entropy_exact_findings() {
    let rep = lint(&[&format!("{FIXTURES}/bad_entropy.rs")], false);
    assert_eq!(findings_of(&rep), vec![(5, "ambient-entropy"), (6, "ambient-entropy")]);
}

#[test]
fn fixture_debug_assert_exact_findings() {
    let rep = lint(&[&format!("{FIXTURES}/bad_debug_assert.rs")], false);
    assert_eq!(
        findings_of(&rep),
        vec![(6, "debug-assert-effect"), (7, "debug-assert-effect")]
    );
}

#[test]
fn fixture_allow_without_reason_is_itself_a_finding() {
    let rep = lint(&[&format!("{FIXTURES}/bad_allow_syntax.rs")], false);
    assert_eq!(
        findings_of(&rep),
        vec![
            (6, "allow-syntax"),  // missing reason
            (7, "wall-clock"),    // the malformed allow suppressed nothing
            (9, "allow-syntax"),  // unknown rule id
            (11, "allow-syntax"), // empty reason
        ]
    );
}

#[test]
fn fixture_suppressed_and_hostile_are_clean() {
    for name in ["suppressed_ok.rs", "hostile_strings.rs"] {
        let rep = lint(&[&format!("{FIXTURES}/{name}")], true);
        let shown: Vec<String> = rep
            .findings
            .iter()
            .map(|f| format!("{}:{} {}", f.file, f.line, f.rule.as_str()))
            .collect();
        assert!(rep.findings.is_empty(), "{name} must be clean: {shown:?}");
    }
}

#[test]
fn budget_file_matches_actual_counts() {
    // The acceptance criterion in one test: every entry in the checked-in
    // ratchet equals the count the auditor computes today, so the gate
    // can neither silently loosen nor go stale.
    let text = std::fs::read_to_string("lint-budget.toml").expect("ratchet file");
    let table = parse_budget(&text).expect("ratchet parses");
    let cfg = LintConfig::default();
    assert_eq!(table.entries.len(), cfg.budget_modules.len());
    for module in &cfg.budget_modules {
        let src = std::fs::read_to_string(module).expect(module);
        let actual = count_budget(&lex(&src).toks);
        let (_, entry) = table.entry_for(module).expect("entry for budgeted module");
        assert_eq!(
            actual, *entry,
            "{module}: lint-budget.toml is stale; update it to the actual counts"
        );
    }
}

#[test]
fn json_report_roundtrips() {
    use migperf::util::json;
    let rep = lint(&[&format!("{FIXTURES}/bad_wall_clock.rs")], true);
    let doc = json::parse(&report::render_json(&rep)).expect("valid json");
    assert_eq!(doc.get("errors").and_then(json::Json::as_i64), Some(3));
    assert_eq!(doc.get("failed").and_then(json::Json::as_bool), Some(true));
    let by_rule = doc.get("findings_by_rule").expect("rule counts");
    assert_eq!(by_rule.get("wall-clock").and_then(json::Json::as_i64), Some(3));
}

#[test]
fn every_finding_is_error_severity_on_bad_fixtures() {
    let rep = lint(&[&format!("{FIXTURES}/bad_allow_syntax.rs")], false);
    assert!(rep.findings.iter().all(|f| f.severity == Severity::Error));
    assert!(rep.failed(), "errors must fail even without --strict");
}
