//! Multi-server inference serving simulation.
//!
//! Drives the paper's GPU-sharing experiments (§4.5, Appendix C): `n`
//! inference servers share one physical GPU either as MIG instances
//! (physical isolation) or as MPS client processes (software sharing).
//! Two load modes:
//!
//! * **closed-loop** — every server issues its next batch immediately
//!   (Figs 4–7: latency vs batch size / model size);
//! * **open-loop** — Poisson request arrivals per server at a configured
//!   rate, FIFO queueing (Figs 10–11: tail latency vs arrival rate).
//!
//! The service-time model is the roofline estimate for the server's
//! resource; in MPS mode, per-request interference from `sharing::mps` is
//! layered on top with the *current number of busy co-runners*.

use crate::metrics::collector::{MetricsCollector, RunSummary};
use crate::models::cost::StepCost;
use crate::sharing::mps::MpsModel;
use crate::simgpu::desim::Des;
use crate::simgpu::energy::EnergyModel;
use crate::simgpu::perfmodel::{PerfError, PerfModel};
use crate::simgpu::resource::ExecResource;
use crate::util::prng::Prng;

use super::spec::WorkloadSpec;

/// How the co-located servers share the GPU.
#[derive(Debug, Clone)]
pub enum SharingMode {
    /// Each server owns a MIG GI with the given resource.
    Mig(Vec<ExecResource>),
    /// All servers are MPS clients on one whole GPU.
    Mps {
        /// The whole-GPU resource requests execute on.
        gpu: ExecResource,
        /// Number of client processes.
        n_clients: u32,
        /// Interference model.
        model: MpsModel,
    },
}

impl SharingMode {
    /// Number of servers.
    pub fn servers(&self) -> usize {
        match self {
            SharingMode::Mig(v) => v.len(),
            SharingMode::Mps { n_clients, .. } => *n_clients as usize,
        }
    }
}

/// Load generation mode.
#[derive(Debug, Clone)]
pub enum LoadMode {
    /// Closed loop: each server re-issues immediately; value = requests
    /// per server.
    Closed {
        /// Requests each server issues.
        requests_per_server: u64,
    },
    /// Open loop: Poisson arrivals at `rate` requests/s per server; run
    /// until `requests_per_server` have been *issued* per server.
    OpenPoisson {
        /// Per-server arrival rate, requests/second.
        rate: f64,
        /// Requests each server receives.
        requests_per_server: u64,
    },
    /// Open loop replaying recorded traces, one per server (index-aligned;
    /// servers beyond the trace list reuse the last trace). Lets a MIG run
    /// and an MPS run be driven by the *identical* request sequence.
    Replay {
        /// Arrival traces (absolute timestamps).
        traces: Vec<crate::workload::trace::Trace>,
    },
}

/// One serving simulation (plain data: clone freely to build sweep grids).
#[derive(Debug, Clone)]
pub struct ServingSim {
    /// Sharing configuration.
    pub mode: SharingMode,
    /// Load configuration.
    pub load: LoadMode,
    /// Workload each request carries.
    pub spec: WorkloadSpec,
    /// PRNG seed for arrivals + interference.
    pub seed: u64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival { server: usize },
    Done { server: usize },
}

struct ServerState {
    queue: std::collections::VecDeque<f64>, // arrival timestamps
    busy: bool,
    issued: u64,
    in_service_since: f64,
}

/// Result of a serving simulation: per-server summaries plus the pooled
/// latency summary the paper's figures report.
#[derive(Debug)]
pub struct ServingOutcome {
    /// Pooled over all servers.
    pub pooled: RunSummary,
    /// One summary per server.
    pub per_server: Vec<RunSummary>,
}

impl ServingSim {
    /// Run the simulation to completion.
    pub fn run(&self) -> Result<ServingOutcome, PerfError> {
        let pm = PerfModel::default();
        let em = EnergyModel::default();
        let n = self.mode.servers();
        let cost = self.spec.step_cost();

        // Pre-validate fit and pre-compute isolated estimates.
        let isolated: Vec<_> = match &self.mode {
            SharingMode::Mig(resources) => resources
                .iter()
                .map(|r| pm.step(r, &cost))
                .collect::<Result<Vec<_>, _>>()?,
            SharingMode::Mps { gpu, n_clients, .. } => {
                let est = pm.step(gpu, &cost)?;
                vec![est; *n_clients as usize]
            }
        };

        let mut rng = Prng::new(self.seed);
        let mut arrival_rngs: Vec<Prng> = (0..n).map(|_| rng.split()).collect();
        let mut interference_rng = rng.split();

        let mut des: Des<Ev> = Des::new();
        let mut servers: Vec<ServerState> = (0..n)
            .map(|_| ServerState {
                queue: std::collections::VecDeque::new(),
                busy: false,
                issued: 0,
                in_service_since: 0.0,
            })
            .collect();
        let mut collectors: Vec<MetricsCollector> = (0..n)
            .map(|i| MetricsCollector::new(format!("{}#{}", self.spec.label(), i)))
            .collect();

        // §Perf: per-server request targets resolved once up front — the
        // hot loop used to re-match the LoadMode enum on every event.
        let targets: Vec<u64> = (0..n)
            .map(|s| match &self.load {
                LoadMode::Closed { requests_per_server } => *requests_per_server,
                LoadMode::OpenPoisson { requests_per_server, .. } => *requests_per_server,
                LoadMode::Replay { traces } => traces[s.min(traces.len() - 1)].len() as u64,
            })
            .collect();
        // §Perf: count of currently-busy servers, maintained O(1) at
        // service start/end — `start_service` used to scan all servers per
        // request to price MPS interference.
        let mut busy_count: u32 = 0;
        // §Perf: Replay streams arrivals lazily through these per-server
        // cursors, keeping the event heap at O(servers) entries instead of
        // preloading all O(total requests) trace timestamps.
        let mut replay_next: Vec<usize> = vec![0; n];

        // Seed initial arrivals.
        for s in 0..n {
            match &self.load {
                LoadMode::Closed { .. } => des.schedule_at(0.0, Ev::Arrival { server: s }),
                LoadMode::OpenPoisson { rate, .. } => {
                    let gap = arrival_rngs[s].exponential(*rate);
                    des.schedule_at(gap, Ev::Arrival { server: s });
                }
                LoadMode::Replay { traces } => {
                    assert!(!traces.is_empty(), "Replay mode needs at least one trace");
                    let trace = &traces[s.min(traces.len() - 1)];
                    if let Some(&t0) = trace.timestamps().first() {
                        des.schedule_at(t0, Ev::Arrival { server: s });
                        replay_next[s] = 1;
                    }
                }
            }
        }

        // Main event loop. (Manual loop rather than run_until: we need
        // mutable access to the surrounding state.)
        while let Some((t, ev)) = des.next() {
            match ev {
                Ev::Arrival { server } => {
                    let target = targets[server];
                    let st = &mut servers[server];
                    if st.issued >= target {
                        continue;
                    }
                    st.issued += 1;
                    st.queue.push_back(t);
                    // Schedule the next arrival.
                    match &self.load {
                        LoadMode::Closed { .. } => {} // next issued on completion
                        LoadMode::OpenPoisson { rate, .. } => {
                            if st.issued < target {
                                let gap = arrival_rngs[server].exponential(*rate);
                                des.schedule_in(gap, Ev::Arrival { server });
                            }
                        }
                        LoadMode::Replay { traces } => {
                            let trace = &traces[server.min(traces.len() - 1)];
                            if let Some(&tn) = trace.timestamps().get(replay_next[server]) {
                                replay_next[server] += 1;
                                des.schedule_at(tn, Ev::Arrival { server });
                            }
                        }
                    }
                    if !servers[server].busy {
                        self.start_service(
                            &mut des,
                            &mut servers,
                            server,
                            t,
                            &isolated,
                            &cost,
                            busy_count,
                            &mut interference_rng,
                        );
                        busy_count += 1;
                    }
                }
                Ev::Done { server } => {
                    let started_at =
                        servers[server].queue.pop_front().expect("done without request");
                    servers[server].busy = false;
                    busy_count -= 1;
                    let latency_ms = (t - started_at) * 1e3;
                    collectors[server].record_completion(t, latency_ms, self.spec.batch as u64);
                    let service_s = t - servers[server].in_service_since;
                    let res_for_energy = self.resource_of(server);
                    let energy = em.power_w(res_for_energy, isolated[server].gract) * service_s;
                    collectors[server].record_energy(energy);
                    collectors[server].record_gract(isolated[server].gract);
                    collectors[server].record_fb(isolated[server].fb_bytes);
                    // Closed loop: immediately issue the next request.
                    if matches!(self.load, LoadMode::Closed { .. })
                        && servers[server].issued < targets[server]
                    {
                        des.schedule_in(0.0, Ev::Arrival { server });
                    }
                    // Serve the next queued request, if any.
                    if !servers[server].queue.is_empty() {
                        self.start_service(
                            &mut des,
                            &mut servers,
                            server,
                            t,
                            &isolated,
                            &cost,
                            busy_count,
                            &mut interference_rng,
                        );
                        busy_count += 1;
                    }
                }
            }
        }

        let per_server: Vec<RunSummary> = collectors.iter().map(|c| c.summarize()).collect();
        // Exact pooling: merge the per-server latency histograms/moments
        // so pooled p50/p99 are true pooled percentiles.
        let pooled = pool_collectors(&self.spec.label(), &collectors, &per_server);
        Ok(ServingOutcome { pooled, per_server })
    }

    fn resource_of(&self, server: usize) -> &ExecResource {
        match &self.mode {
            SharingMode::Mig(v) => &v[server],
            SharingMode::Mps { gpu, .. } => gpu,
        }
    }

    /// Start serving `server`'s head-of-queue request. `busy_others` is
    /// the caller-maintained count of *other* currently-busy servers
    /// (`server` itself must not be busy yet) — an O(1) counter replacing
    /// the per-request O(n) scan over all servers.
    #[allow(clippy::too_many_arguments)]
    fn start_service(
        &self,
        des: &mut Des<Ev>,
        servers: &mut [ServerState],
        server: usize,
        now: f64,
        isolated: &[crate::simgpu::perfmodel::StepEstimate],
        cost: &StepCost,
        busy_others: u32,
        rng: &mut Prng,
    ) {
        debug_assert!(!servers[server].busy);
        let service_s = match &self.mode {
            SharingMode::Mig(_) => isolated[server].seconds,
            SharingMode::Mps { gpu, model, .. } => {
                model.request_time(&isolated[server], cost, gpu, busy_others, rng)
            }
        };
        servers[server].busy = true;
        servers[server].in_service_since = now;
        des.schedule_in(service_s, Ev::Done { server });
    }
}

/// Exact pooled summary from the per-server collectors: the latency
/// histograms and Welford moments are merged, so pooled p50/p99/std are
/// true pooled statistics (within histogram precision) rather than the
/// max-of-p99 approximation [`pool_summaries`] falls back to when only
/// summaries survive. Aggregate throughput stays the sum of per-server
/// rates and energy the sum of per-server energy, matching what the
/// paper's figures report.
pub fn pool_collectors(
    label: &str,
    collectors: &[MetricsCollector],
    per_server: &[RunSummary],
) -> RunSummary {
    let mut pooled = MetricsCollector::pooled(label, collectors).summarize();
    // Each server is its own serving instance with its own measurement
    // window: the figures' aggregate throughput is the sum of per-server
    // rates, and the experiment duration is the longest server window.
    pooled.throughput = per_server.iter().map(|s| s.throughput).sum();
    pooled.duration_s = per_server.iter().map(|s| s.duration_s).fold(0.0, f64::max);
    pooled
}

/// Merge per-server summaries into one pooled summary (weighted means;
/// p99 approximated by the max of per-server p99s, which is exact when
/// servers are statistically identical and conservative otherwise).
/// Prefer [`pool_collectors`] when the collectors are still available —
/// it produces exact pooled percentiles.
pub fn pool_summaries(label: &str, parts: &[RunSummary]) -> RunSummary {
    let total: u64 = parts.iter().map(|p| p.completed).sum();
    let w = |f: fn(&RunSummary) -> f64| -> f64 {
        if total == 0 {
            return 0.0;
        }
        parts.iter().map(|p| f(p) * p.completed as f64).sum::<f64>() / total as f64
    };
    RunSummary {
        label: label.to_string(),
        completed: total,
        avg_latency_ms: w(|p| p.avg_latency_ms),
        std_latency_ms: w(|p| p.std_latency_ms),
        p50_latency_ms: w(|p| p.p50_latency_ms),
        p99_latency_ms: parts.iter().map(|p| p.p99_latency_ms).fold(0.0, f64::max),
        max_latency_ms: parts.iter().map(|p| p.max_latency_ms).fold(0.0, f64::max),
        throughput: parts.iter().map(|p| p.throughput).sum(),
        mean_gract: w(|p| p.mean_gract),
        peak_fb_mib: parts.iter().map(|p| p.peak_fb_mib).fold(0.0, f64::max),
        energy_j: parts.iter().map(|p| p.energy_j).sum(),
        duration_s: parts.iter().map(|p| p.duration_s).fold(0.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::GpuModel;
    use crate::mig::profile::lookup as gi_lookup;
    use crate::models::zoo::lookup;

    fn mig_mode(n: usize) -> SharingMode {
        let p = gi_lookup(GpuModel::A30_24GB, "1g.6gb").unwrap();
        SharingMode::Mig(
            (0..n).map(|_| ExecResource::from_gi(GpuModel::A30_24GB, p)).collect(),
        )
    }

    fn mps_mode(n: u32) -> SharingMode {
        SharingMode::Mps {
            gpu: ExecResource::whole_gpu(GpuModel::A30_24GB),
            n_clients: n,
            model: MpsModel::default(),
        }
    }

    fn sim(mode: SharingMode, load: LoadMode, batch: u32) -> ServingOutcome {
        ServingSim {
            mode,
            load,
            spec: WorkloadSpec::inference(lookup("resnet50").unwrap(), batch, 224),
            seed: 2024,
        }
        .run()
        .unwrap()
    }

    #[test]
    fn closed_loop_completes_all_requests() {
        let out = sim(mig_mode(4), LoadMode::Closed { requests_per_server: 200 }, 8);
        assert_eq!(out.pooled.completed, 800);
        for s in &out.per_server {
            assert_eq!(s.completed, 200);
        }
    }

    #[test]
    fn fig5_mig_tail_beats_mps_at_batch8() {
        // Paper Fig 5: at batch 8, MIG p99 well below MPS p99, and MIG is
        // more stable.
        let mig = sim(mig_mode(2), LoadMode::Closed { requests_per_server: 1500 }, 8);
        let mps = sim(mps_mode(2), LoadMode::Closed { requests_per_server: 1500 }, 8);
        assert!(
            mps.pooled.p99_latency_ms > mig.pooled.p99_latency_ms * 1.3,
            "MPS p99 {} must exceed MIG p99 {}",
            mps.pooled.p99_latency_ms,
            mig.pooled.p99_latency_ms
        );
        assert!(mps.pooled.std_latency_ms > mig.pooled.std_latency_ms);
    }

    #[test]
    fn fig4_mps_avg_close_to_mig_small_batch() {
        // Paper Fig 4: average latency almost the same at batch 1.
        let mig = sim(mig_mode(2), LoadMode::Closed { requests_per_server: 1000 }, 1);
        let mps = sim(mps_mode(2), LoadMode::Closed { requests_per_server: 1000 }, 1);
        let ratio = mps.pooled.avg_latency_ms / mig.pooled.avg_latency_ms;
        assert!(ratio < 1.6, "small-batch MPS/MIG avg ratio {ratio}");
    }

    #[test]
    fn mig_isolation_is_deterministic() {
        let a = sim(mig_mode(4), LoadMode::Closed { requests_per_server: 100 }, 8);
        // All requests identical and isolated → p99 == p50.
        let spread = a.pooled.p99_latency_ms / a.pooled.p50_latency_ms;
        assert!(spread < 1.05, "MIG closed-loop spread {spread}");
    }

    #[test]
    fn open_loop_low_rate_latency_near_service_time() {
        let out = sim(
            mig_mode(4),
            LoadMode::OpenPoisson { rate: 5.0, requests_per_server: 500 },
            1,
        );
        // At low utilization, queueing is negligible: avg ≈ p50.
        let r = out.pooled.avg_latency_ms / out.pooled.p50_latency_ms;
        assert!(r < 1.5, "low-rate ratio {r}");
    }

    #[test]
    fn open_loop_saturation_explodes_latency() {
        let lo = sim(
            mig_mode(4),
            LoadMode::OpenPoisson { rate: 2.0, requests_per_server: 400 },
            1,
        );
        let hi = sim(
            mig_mode(4),
            LoadMode::OpenPoisson { rate: 2000.0, requests_per_server: 400 },
            1,
        );
        assert!(
            hi.pooled.p99_latency_ms > lo.pooled.p99_latency_ms * 3.0,
            "saturated p99 {} vs unloaded {}",
            hi.pooled.p99_latency_ms,
            lo.pooled.p99_latency_ms
        );
    }

    #[test]
    fn pooled_percentiles_are_exact_across_heterogeneous_servers() {
        // Two fast 2g.12gb servers + two slow 1g.6gb servers, closed loop:
        // each MIG server's latency is a constant, so the pooled
        // distribution is bimodal with equal mass. The exact pooled p99
        // must sit at the slow servers' level, and the pooled max must be
        // the true max — properties the old weighted-mean/max-of-p99
        // pooling only approximated.
        let p_small = gi_lookup(GpuModel::A30_24GB, "1g.6gb").unwrap();
        let p_big = gi_lookup(GpuModel::A30_24GB, "2g.12gb").unwrap();
        let mode = SharingMode::Mig(vec![
            ExecResource::from_gi(GpuModel::A30_24GB, p_big),
            ExecResource::from_gi(GpuModel::A30_24GB, p_big),
            ExecResource::from_gi(GpuModel::A30_24GB, p_small),
            ExecResource::from_gi(GpuModel::A30_24GB, p_small),
        ]);
        let out = sim(mode, LoadMode::Closed { requests_per_server: 200 }, 8);
        let slow_p99 = out.per_server[2].p99_latency_ms;
        let rel = (out.pooled.p99_latency_ms / slow_p99 - 1.0).abs();
        assert!(
            rel < 0.03,
            "pooled p99 {} vs slow-server p99 {slow_p99}",
            out.pooled.p99_latency_ms
        );
        let true_max =
            out.per_server.iter().map(|s| s.max_latency_ms).fold(0.0, f64::max);
        assert_eq!(out.pooled.max_latency_ms, true_max);
        // p50 must land between the fast and slow modes, not at their
        // count-weighted mean only by accident: with equal mass the median
        // interpolation stays within the [fast, slow] envelope.
        assert!(out.pooled.p50_latency_ms <= slow_p99 * 1.01);
        assert!(out.pooled.p50_latency_ms >= out.per_server[0].p50_latency_ms * 0.99);
    }

    #[test]
    fn pooled_throughput_is_sum() {
        let out = sim(mig_mode(4), LoadMode::Closed { requests_per_server: 100 }, 4);
        let sum: f64 = out.per_server.iter().map(|s| s.throughput).sum();
        assert!((out.pooled.throughput - sum).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sim(mps_mode(4), LoadMode::Closed { requests_per_server: 300 }, 8);
        let b = sim(mps_mode(4), LoadMode::Closed { requests_per_server: 300 }, 8);
        assert_eq!(a.pooled.p99_latency_ms, b.pooled.p99_latency_ms);
        assert_eq!(a.pooled.avg_latency_ms, b.pooled.avg_latency_ms);
    }

    #[test]
    fn replay_drives_identical_arrivals_across_modes() {
        // The point of trace replay: a MIG run and an MPS run see the
        // exact same request sequence, so differences are purely the
        // sharing technology.
        use crate::workload::arrival::PoissonArrival;
        use crate::workload::trace::Trace;
        let traces: Vec<Trace> = (0..2)
            .map(|i| Trace::capture(&mut PoissonArrival::new(50.0, 900 + i), 300))
            .collect();
        let spec = WorkloadSpec::inference(lookup("resnet50").unwrap(), 2, 224);
        let mig = ServingSim {
            mode: mig_mode(2),
            load: LoadMode::Replay { traces: traces.clone() },
            spec: spec.clone(),
            seed: 1,
        }
        .run()
        .unwrap();
        let mps = ServingSim {
            mode: mps_mode(2),
            load: LoadMode::Replay { traces: traces.clone() },
            spec,
            seed: 1,
        }
        .run()
        .unwrap();
        assert_eq!(mig.pooled.completed, 600);
        assert_eq!(mps.pooled.completed, 600);
        // Same duration window (same arrivals), different tails.
        assert!(mps.pooled.p99_latency_ms != mig.pooled.p99_latency_ms);
    }

    #[test]
    fn replay_reuses_last_trace_for_extra_servers() {
        use crate::workload::arrival::PoissonArrival;
        use crate::workload::trace::Trace;
        let trace = Trace::capture(&mut PoissonArrival::new(30.0, 5), 100);
        let out = ServingSim {
            mode: mig_mode(4),
            load: LoadMode::Replay { traces: vec![trace] },
            spec: WorkloadSpec::inference(lookup("resnet18").unwrap(), 1, 224),
            seed: 1,
        }
        .run()
        .unwrap();
        assert_eq!(out.pooled.completed, 400, "each of 4 servers replays the trace");
    }

    #[test]
    fn oom_rejected_upfront() {
        let p = gi_lookup(GpuModel::A30_24GB, "1g.6gb").unwrap();
        let mode = SharingMode::Mig(vec![ExecResource::from_gi(GpuModel::A30_24GB, p)]);
        let r = ServingSim {
            mode,
            load: LoadMode::Closed { requests_per_server: 1 },
            spec: WorkloadSpec::inference(lookup("bert-large").unwrap(), 256, 512),
            seed: 1,
        }
        .run();
        assert!(r.is_err());
    }
}
