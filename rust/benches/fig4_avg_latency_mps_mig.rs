//! Fig 4: average latency, MIG vs MPS, ResNet18/ResNet50 vs batch size.
//!
//! Paper §4.5: "MPS can have a very similar performance to that of MIG
//! when the batch size is small"; variance grows with batch.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::mig::profile::lookup as gi_lookup;
use migperf::models::zoo;
use migperf::sharing::mps::MpsModel;
use migperf::simgpu::resource::ExecResource;
use migperf::sweep::{self, SweepEngine};
use migperf::util::table::{fmt_num, Table};
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;

const BATCHES: &[u32] = &[1, 2, 4, 8, 16, 32];
const TENANTS: u32 = 2;
const REQUESTS: u64 = 1500;
const MODELS: &[&str] = &["resnet18", "resnet50"];

fn sim(model: &str, batch: u32, mig: bool) -> ServingSim {
    let gpu = GpuModel::A30_24GB;
    let spec = WorkloadSpec::inference(zoo::lookup(model).unwrap(), batch, 224);
    let mode = if mig {
        let p = gi_lookup(gpu, "2g.12gb").unwrap();
        SharingMode::Mig(vec![ExecResource::from_gi(gpu, p); TENANTS as usize])
    } else {
        SharingMode::Mps {
            gpu: ExecResource::whole_gpu(gpu),
            n_clients: TENANTS,
            model: MpsModel::default(),
        }
    };
    ServingSim { mode, load: LoadMode::Closed { requests_per_server: REQUESTS }, spec, seed: 44 }
}

fn main() {
    banner("Figure 4", "average latency MIG vs MPS (A30, 2 tenants)");
    // Whole (model × batch × mode) grid in one parallel sweep; the row
    // order below indexes back into the fixed grid order.
    let mut sims = Vec::new();
    for model in MODELS {
        for &b in BATCHES {
            sims.push(sim(model, b, true));
            sims.push(sim(model, b, false));
        }
    }
    let outs = sweep::run_serving(&SweepEngine::from_env(), &sims).expect("fig4 sims");

    let mut ratios_small = Vec::new();
    let mut ratios_large = Vec::new();
    for (mi, model) in MODELS.iter().enumerate() {
        let mut t = Table::new(&["batch", "MIG avg_ms", "MPS avg_ms", "MPS std_ms", "MPS/MIG"]);
        for (bi, &b) in BATCHES.iter().enumerate() {
            let base = (mi * BATCHES.len() + bi) * 2;
            let mig = &outs[base].pooled;
            let mps = &outs[base + 1].pooled;
            let ratio = mps.avg_latency_ms / mig.avg_latency_ms;
            if b <= 2 {
                ratios_small.push(ratio);
            }
            if b >= 16 {
                ratios_large.push(mps.std_latency_ms / mps.avg_latency_ms);
            }
            t.row(&[
                b.to_string(),
                fmt_num(mig.avg_latency_ms),
                fmt_num(mps.avg_latency_ms),
                fmt_num(mps.std_latency_ms),
                fmt_num(ratio),
            ]);
        }
        println!("\n({}) {model}:\n{}", if *model == "resnet18" { "a" } else { "b" }, t.render());
    }
    println!();
    shape_check(
        "MPS average ≈ MIG at small batch (Fig 4)",
        ratios_small.iter().all(|&r| r < 1.5),
    );
    shape_check(
        "MPS deviation grows at large batch (Fig 4)",
        ratios_large.iter().all(|&cv| cv > 0.05),
    );
}
