//! Quickstart: partition a GPU, run a small benchmark, print the report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the paper's §3.1 user workflow end-to-end: enable MIG via the
//! controller, partition an A100 into three differently-sized instances,
//! profile BERT-base inference across them with a batch sweep, and render
//! the report the visualizer would show.

use migperf::mig::controller::MigController;
use migperf::mig::gpu::GpuModel;
use migperf::profiler::session::ProfileSession;
use migperf::profiler::task::{BenchTask, SweepAxis};
use migperf::util::table::sparkline;
use migperf::workload::spec::WorkloadKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. MIG Controller: enable MIG and inspect what fits (paper §3.2).
    let mut ctl = MigController::new(GpuModel::A100_80GB);
    ctl.enable_mig()?;
    println!("MIG enabled on {}", ctl.model());
    let gi = ctl.create_instance("3g.40gb")?;
    println!(
        "created {} at memory slice {} → uuid {}",
        ctl.instance(gi)?.profile.name,
        ctl.instance(gi)?.start,
        ctl.instance(gi)?.uuid
    );
    let still: Vec<&str> = ctl.available_profiles().iter().map(|p| p.name).collect();
    println!("profiles still placeable next to it: {still:?}\n");
    ctl.reset();

    // 2. MIG Profiler: benchmark BERT-base inference across GI sizes.
    let task = BenchTask {
        name: "quickstart: bert-base inference on A100 GIs".into(),
        gpu: GpuModel::A100_80GB,
        gi_profiles: vec!["1g.10gb".into(), "2g.20gb".into(), "7g.80gb".into()],
        model: "bert-base".into(),
        kind: WorkloadKind::Inference,
        batch: 8,
        seq: 128,
        sweep: SweepAxis::Batch(vec![1, 2, 4, 8, 16, 32]),
        iterations: 200,
        layout: Default::default(),
    };
    let report = ProfileSession::default().run(&task)?;
    println!("{}", report.render_table());

    // 3. Visualizer: latency-vs-batch sparkline per instance.
    println!("avg latency vs batch (▁=low █=high):");
    for (inst, pts) in report.series(|s| s.avg_latency_ms, false) {
        let ys: Vec<f64> = pts.iter().map(|&(_, y)| y).collect();
        println!("  {inst:>8}  {}", sparkline(&ys));
    }
    println!("\nNote how the 1g instance's latency climbs with batch while 7g stays flat");
    println!("(paper Fig 3a). Run `cargo bench` to regenerate every figure.");
    Ok(())
}
