//! The command grammar and its total compiler.
//!
//! A [`CommandSeq`] is an abstract fleet scenario: setup commands fix
//! the pre-`t=0` configuration (fleet size, tenant weights, router,
//! overload knobs — last occurrence wins, wherever it sits in the
//! sequence), and timeline commands play out on a virtual clock that
//! only [`Command::AdvanceTime`] moves. [`CommandSeq::compile`] lowers
//! the sequence to a concrete [`FleetConfig`]:
//!
//! * arrivals become an [`ArrivalSpec::Replay`] trace per class, so the
//!   reference model knows the *exact* per-class arrival count;
//! * crash/recover pairs become a [`FaultPlan`] (a crash with no later
//!   recovery is permanent, `down_s = ∞`);
//! * repartitions become a [`FleetPolicyKind::Scripted`] schedule.
//!
//! The compiler is **total**: every sequence compiles to a config that
//! passes [`FleetConfig::validate`]. Out-of-range indices wrap,
//! parameters clamp to sane windows, a crash on an already-down GPU is
//! dropped (the fault plan allows one open fault per GPU), a recover
//! with nothing open is dropped, and per-class traces are thinned until
//! their mean rate is plannable. Totality means validity is closed
//! under command deletion and parameter shrinking — the shrinker can
//! never wander out of the valid space, which is what makes delete-chunk
//! minimization sound.

use crate::cluster::engine::{FleetConfig, RepartitionMode, RequestClass};
use crate::cluster::faults::{FaultInjection, FaultPlan};
use crate::cluster::overload::{OverloadPolicy, ShedDiscipline, DEFAULT_BREAKER_PROBES};
use crate::cluster::policy::{FleetPolicyKind, ScriptedRepartition};
use crate::cluster::router::RouterKind;
use crate::cluster::telemetry::TelemetryConfig;
use crate::cluster::tenancy::Tenant;
use crate::mig::gpu::GpuModel;
use crate::models::zoo::lookup;
use crate::orchestrator::ReconfigCost;
use crate::workload::arrival::ArrivalSpec;
use crate::workload::spec::WorkloadSpec;

/// Number of request classes every compiled scenario serves (one per
/// tenant: `gold` owns class 0, `bronze` class 1).
pub const N_CLASSES: usize = 2;
/// Observation-window (policy tick) length, seconds.
pub const WINDOW_S: f64 = 5.0;
/// Quiet margin appended after the last scripted moment, seconds — keeps
/// `window_s < duration_s` and leaves room to drain.
pub const MARGIN_S: f64 = 10.0;
/// Per-class mean-rate ceiling (requests/s): traces are thinned to stay
/// below it so the initial fleet plan is always feasible, even re-split
/// under the most skewed tenant weights the grammar allows.
pub const RATE_CAP_RPS: f64 = 20.0;

/// One abstract step of a fleet scenario. `Debug` output doubles as the
/// repro syntax: `Command::{:?}` is valid Rust construction code.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// Setup: fleet size (clamped to 1..=3 A100s), last wins.
    ResizeFleet {
        /// Number of GPUs.
        gpus: usize,
    },
    /// Setup: tenant weights (clamped to [0.5, 4]), last wins.
    RetuneTenants {
        /// Weight of tenant `gold` (class 0).
        gold: f64,
        /// Weight of tenant `bronze` (class 1).
        bronze: f64,
    },
    /// Setup: repartition discipline, last wins.
    SetRolling {
        /// `true` = rolling drain, `false` = in-place.
        rolling: bool,
    },
    /// Setup: router choice as an index (mod 4: round-robin,
    /// least-loaded, affinity, weighted-fair), last wins.
    SetRouter {
        /// Router index.
        router: u8,
    },
    /// Setup: bounded queues + deadlines, last wins. `queue_cap` 0 =
    /// unbounded (clamped to ≤ 16); `deadline_mult` < 1 disables
    /// deadlines (else clamped to [1, 10]).
    SetOverload {
        /// Per-replica queue bound (0 = unbounded).
        queue_cap: usize,
        /// Deadline = arrival + mult × SLO (0 disables).
        deadline_mult: f64,
        /// `true` = drop-oldest, `false` = reject-newest.
        drop_oldest: bool,
    },
    /// Setup: tenant-weighted brownout threshold, last wins.
    /// Non-positive disables; else clamped to [0.05, 1].
    SetBrownout {
        /// Shed-pressure fraction that escalates the ladder.
        threshold: f64,
    },
    /// Setup: per-GPU ingress breaker, last wins. Non-positive
    /// `threshold` disables; else clamped to [0.05, 1]; `probes`
    /// clamped to 1..=16.
    SetBreaker {
        /// Shed-fraction trip threshold.
        threshold: f64,
        /// Half-open probe budget.
        probes: u64,
    },
    /// Timeline: advance the virtual clock (clamped to [0.5, 60] s).
    AdvanceTime {
        /// Seconds to advance.
        dt_s: f64,
    },
    /// Timeline: `n` requests of `class` evenly spaced over the next
    /// `over_s` seconds (class wraps mod 2, `n` clamps to 1..=200,
    /// `over_s` to [0.1, 30]). Does not advance the clock.
    ArriveBurst {
        /// Request class.
        class: usize,
        /// Burst size.
        n: u64,
        /// Burst span, seconds.
        over_s: f64,
    },
    /// Timeline: whole-GPU crash at the current clock (gpu wraps mod
    /// fleet size; dropped if that GPU already has an open fault).
    /// Permanent unless a later [`Command::Recover`] closes it.
    CrashGpu {
        /// Fleet index.
        gpu: usize,
    },
    /// Timeline: instance-level crash of `class`'s replica on `gpu`
    /// (same wrapping/drop rules as [`Command::CrashGpu`]).
    CrashInstance {
        /// Fleet index.
        gpu: usize,
        /// Crashed class.
        class: usize,
    },
    /// Timeline: close the open fault on `gpu` at the current clock
    /// (dropped when nothing is open there, or when the clock has not
    /// advanced past the crash — recovery must be strictly later).
    Recover {
        /// Fleet index.
        gpu: usize,
    },
    /// Timeline: scripted repartition of `gpu` at the first policy tick
    /// at or after the current clock, sized for the template demand
    /// scaled by `rate_scale` (clamped to [0.25, 2]).
    Repartition {
        /// Fleet index.
        gpu: usize,
        /// Demand multiplier the new plan is sized for.
        rate_scale: f64,
    },
}

/// A seeded command sequence: the unit the generator emits, the shrinker
/// minimizes, and the regression corpus pins.
#[derive(Debug, Clone, PartialEq)]
pub struct CommandSeq {
    /// Seed the sequence was generated from (recorded for the repro; the
    /// compiled config also uses it as the engine seed).
    pub seed: u64,
    /// The commands, in play order.
    pub commands: Vec<Command>,
}

/// A compiled scenario: the concrete config plus the schedule facts the
/// reference model checks against.
#[derive(Debug, Clone)]
pub struct Compiled {
    /// The runnable fleet config (always passes `validate()`).
    pub config: FleetConfig,
    /// Per-class replay traces (the exact expected arrivals).
    pub times: Vec<Vec<f64>>,
    /// Scripted repartition count (upper bound on executed decisions).
    pub scripted: usize,
}

fn clamp_f(v: f64, lo: f64, hi: f64) -> f64 {
    if v.is_finite() {
        v.clamp(lo, hi)
    } else {
        lo
    }
}

impl CommandSeq {
    /// Lower the sequence to a concrete, always-valid fleet config. See
    /// the module docs for the totality rules.
    pub fn compile(&self) -> Compiled {
        // Pass 1 — setup, last occurrence wins.
        let mut n_gpus: usize = 2;
        let mut gold_w: f64 = 1.0;
        let mut bronze_w: f64 = 1.0;
        let mut rolling = true;
        let mut router = RouterKind::LeastLoaded;
        let mut overload = OverloadPolicy::none();
        for cmd in &self.commands {
            match *cmd {
                Command::ResizeFleet { gpus } => n_gpus = gpus.clamp(1, 3),
                Command::RetuneTenants { gold, bronze } => {
                    gold_w = clamp_f(gold, 0.5, 4.0);
                    bronze_w = clamp_f(bronze, 0.5, 4.0);
                }
                Command::SetRolling { rolling: r } => rolling = r,
                Command::SetRouter { router: r } => {
                    router = match r % 4 {
                        0 => RouterKind::RoundRobin,
                        1 => RouterKind::LeastLoaded,
                        2 => RouterKind::Affinity { spill: 2 },
                        _ => RouterKind::WeightedFair,
                    };
                }
                Command::SetOverload { queue_cap, deadline_mult, drop_oldest } => {
                    overload.queue_cap = queue_cap.min(16);
                    overload.deadline_mult = if deadline_mult.is_finite() && deadline_mult >= 1.0
                    {
                        deadline_mult.clamp(1.0, 10.0)
                    } else {
                        0.0
                    };
                    overload.shed = if drop_oldest {
                        ShedDiscipline::DropOldest
                    } else {
                        ShedDiscipline::RejectNewest
                    };
                }
                Command::SetBrownout { threshold } => {
                    overload.brownout_threshold = if threshold.is_finite() && threshold > 0.0 {
                        threshold.clamp(0.05, 1.0)
                    } else {
                        f64::INFINITY
                    };
                }
                Command::SetBreaker { threshold, probes } => {
                    if threshold.is_finite() && threshold > 0.0 {
                        overload.breaker_threshold = threshold.clamp(0.05, 1.0);
                        overload.breaker_probes = probes.clamp(1, 16);
                    } else {
                        overload.breaker_threshold = f64::INFINITY;
                        overload.breaker_probes = DEFAULT_BREAKER_PROBES;
                    }
                }
                _ => {}
            }
        }

        // Pass 2 — the timeline: arrivals, faults, scripted repartitions
        // on the virtual clock.
        let mut clock: f64 = 0.0;
        let mut times: Vec<Vec<f64>> = vec![Vec::new(); N_CLASSES];
        let mut injections: Vec<FaultInjection> = Vec::new();
        // Per-GPU open fault: (injection index, crash time). The fault
        // plan allows at most one open fault per GPU regardless of
        // granularity.
        let mut open: Vec<Option<(usize, f64)>> = vec![None; n_gpus];
        let mut script: Vec<ScriptedRepartition> = Vec::new();
        for cmd in &self.commands {
            match *cmd {
                Command::AdvanceTime { dt_s } => clock += clamp_f(dt_s, 0.5, 60.0),
                Command::ArriveBurst { class, n, over_s } => {
                    let c = class % N_CLASSES;
                    let n = n.clamp(1, 200);
                    let span = clamp_f(over_s, 0.1, 30.0);
                    // Evenly spaced over [clock, clock + span]; clamped
                    // monotone against whatever an earlier, longer burst
                    // already appended.
                    let mut last = times[c].last().copied().unwrap_or(0.0);
                    for i in 0..n {
                        let t = clock + span * (i as f64) / (n as f64);
                        last = last.max(t);
                        times[c].push(last);
                    }
                }
                Command::CrashGpu { gpu } => {
                    let g = gpu % n_gpus;
                    if open[g].is_none() {
                        open[g] = Some((injections.len(), clock));
                        injections.push(FaultInjection {
                            t: clock,
                            gpu: g,
                            class: None,
                            down_s: f64::INFINITY,
                        });
                    }
                }
                Command::CrashInstance { gpu, class } => {
                    let g = gpu % n_gpus;
                    if open[g].is_none() {
                        open[g] = Some((injections.len(), clock));
                        injections.push(FaultInjection {
                            t: clock,
                            gpu: g,
                            class: Some(class % N_CLASSES),
                            down_s: f64::INFINITY,
                        });
                    }
                }
                Command::Recover { gpu } => {
                    let g = gpu % n_gpus;
                    if let Some((idx, t0)) = open[g] {
                        if clock > t0 {
                            injections[idx].down_s = clock - t0;
                            open[g] = None;
                        }
                    }
                }
                Command::Repartition { gpu, rate_scale } => {
                    script.push(ScriptedRepartition {
                        at_t: clock,
                        gpu: gpu % n_gpus,
                        rate_scale: clamp_f(rate_scale, 0.25, 2.0),
                    });
                }
                _ => {}
            }
        }

        // Thin each trace until its whole-trace mean rate is plannable
        // (halving keeps the trace monotone and terminates: a length-1
        // trace has mean rate ≤ 1).
        for trace in &mut times {
            while mean_rate(trace) > RATE_CAP_RPS && trace.len() > 1 {
                let kept: Vec<f64> =
                    trace.iter().copied().enumerate().filter(|(i, _)| i % 2 == 0).map(|(_, t)| t)
                        .collect();
                *trace = kept;
            }
        }

        // Horizon: past the last scripted moment AND the last arrival, so
        // every replayed timestamp is inside the arrival horizon and the
        // model's per-class counts are exact.
        let last_arrival =
            times.iter().filter_map(|t| t.last().copied()).fold(0.0_f64, f64::max);
        let duration_s = clock.max(last_arrival) + MARGIN_S;

        let bert = lookup("bert-base").expect("bert-base is in the model zoo");
        let classes: Vec<RequestClass> = times
            .iter()
            .map(|t| RequestClass {
                spec: WorkloadSpec::inference(bert, 8, 128),
                slo_ms: 40.0,
                arrival: ArrivalSpec::Replay { times: t.clone() },
            })
            .collect();
        let policy = if script.is_empty() {
            FleetPolicyKind::Static
        } else {
            FleetPolicyKind::Scripted(script.clone())
        };
        let config = FleetConfig {
            gpus: vec![GpuModel::A100_80GB; n_gpus],
            train: None,
            classes,
            tenants: vec![
                Tenant::new("gold", gold_w, vec![0]),
                Tenant::new("bronze", bronze_w, vec![1]),
            ],
            router,
            policy,
            mode: if rolling { RepartitionMode::Rolling } else { RepartitionMode::InPlace },
            cost: ReconfigCost::default(),
            duration_s,
            window_s: WINDOW_S,
            rho_max: 0.75,
            faults: FaultPlan { injections, ..FaultPlan::default() },
            overload,
            telemetry: TelemetryConfig::timelines(WINDOW_S),
            seed: self.seed,
        };
        Compiled { config, times, scripted: script.len() }
    }
}

/// Whole-trace mean rate of a replay trace (the planner's sizing input);
/// mirrors `ArrivalSpec::Replay::mean_rate`.
fn mean_rate(times: &[f64]) -> f64 {
    match times.last() {
        Some(&last) => times.len() as f64 / last.max(1.0),
        None => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_sequence_compiles_to_a_valid_idle_scenario() {
        let seq = CommandSeq { seed: 1, commands: Vec::new() };
        let c = seq.compile();
        c.config.validate().expect("empty scenario must validate");
        assert_eq!(c.config.gpus.len(), 2);
        assert_eq!(c.config.classes.len(), N_CLASSES);
        assert!(c.config.faults.is_empty());
        assert_eq!(c.scripted, 0);
        assert_eq!(c.config.policy, FleetPolicyKind::Static);
        assert!(c.config.duration_s > c.config.window_s);
    }

    #[test]
    fn compiler_is_total_over_hostile_parameters() {
        // Extreme / non-finite parameters clamp rather than error, and
        // the result still validates.
        let seq = CommandSeq {
            seed: 9,
            commands: vec![
                Command::ResizeFleet { gpus: 0 },
                Command::ResizeFleet { gpus: usize::MAX },
                Command::RetuneTenants { gold: f64::NAN, bronze: -3.0 },
                Command::SetRouter { router: 255 },
                Command::SetOverload {
                    queue_cap: usize::MAX,
                    deadline_mult: f64::INFINITY,
                    drop_oldest: true,
                },
                Command::SetBrownout { threshold: f64::NAN },
                Command::SetBreaker { threshold: 5.0, probes: 0 },
                Command::AdvanceTime { dt_s: f64::NEG_INFINITY },
                Command::ArriveBurst { class: 77, n: 0, over_s: -1.0 },
                Command::CrashGpu { gpu: 12 },
                Command::Recover { gpu: 999 },
                Command::Repartition { gpu: 8, rate_scale: f64::NAN },
            ],
        };
        let c = seq.compile();
        c.config.validate().expect("hostile parameters must clamp, not invalidate");
        assert_eq!(c.config.gpus.len(), 3, "usize::MAX clamps to the fleet ceiling");
        assert_eq!(c.config.overload.queue_cap, 16);
        assert!(c.config.overload.brownout_threshold.is_infinite(), "NaN disables");
        assert_eq!(c.config.overload.breaker_probes, 1, "probes clamp up to 1");
    }

    #[test]
    fn crash_recover_pairs_become_bounded_faults_and_orphans_are_permanent() {
        let seq = CommandSeq {
            seed: 3,
            commands: vec![
                Command::AdvanceTime { dt_s: 10.0 },
                Command::CrashGpu { gpu: 0 },
                Command::CrashGpu { gpu: 0 },       // already open: dropped
                Command::CrashInstance { gpu: 0, class: 1 }, // same GPU open: dropped
                Command::Recover { gpu: 0 },        // same clock as crash: dropped
                Command::AdvanceTime { dt_s: 20.0 },
                Command::Recover { gpu: 0 },        // closes at 30 → down_s = 20
                Command::CrashInstance { gpu: 1, class: 5 }, // class wraps to 1
                Command::Recover { gpu: 2 },        // nothing open on gpu 0 (2 % 2)… dropped? see below
            ],
        };
        let c = seq.compile();
        c.config.validate().unwrap();
        let inj = &c.config.faults.injections;
        assert_eq!(inj.len(), 2, "duplicates on an open GPU are dropped");
        assert_eq!((inj[0].gpu, inj[0].class, inj[0].t), (0, None, 10.0));
        assert_eq!(inj[0].down_s, 20.0, "closed by the strictly-later recover");
        assert_eq!((inj[1].gpu, inj[1].class), (1, Some(1)), "indices wrap");
        // gpu 2 wraps to 0, whose fault was already closed at the same
        // clock — recovery must be strictly later, so the instance fault
        // on gpu 1 stays permanent.
        assert!(inj[1].down_s.is_infinite(), "unclosed crash is permanent");
    }

    #[test]
    fn bursts_stay_monotone_and_rates_are_capped() {
        let seq = CommandSeq {
            seed: 5,
            commands: vec![
                // A long burst followed by an earlier-overlapping one:
                // the trace must stay non-decreasing.
                Command::ArriveBurst { class: 0, n: 50, over_s: 30.0 },
                Command::AdvanceTime { dt_s: 1.0 },
                Command::ArriveBurst { class: 0, n: 200, over_s: 0.1 },
                Command::ArriveBurst { class: 0, n: 200, over_s: 0.1 },
                Command::ArriveBurst { class: 0, n: 200, over_s: 0.1 },
            ],
        };
        let c = seq.compile();
        c.config.validate().unwrap();
        let t = &c.times[0];
        assert!(t.windows(2).all(|w| w[1] >= w[0]), "trace must be non-decreasing");
        assert!(
            mean_rate(t) <= RATE_CAP_RPS,
            "thinning must cap the mean rate, got {}",
            mean_rate(t)
        );
        // Every arrival lies inside the horizon, so the model's count is
        // exact.
        assert!(t.last().unwrap() + MARGIN_S <= c.config.duration_s + 1e-9);
    }
}
