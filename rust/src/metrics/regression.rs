//! Bench-regression comparison: the CI gate behind `migperf bench-check`.
//!
//! CI's bench smoke steps emit machine-readable records
//! (`BENCH_serving.json`, `BENCH_orchestrator.json`, `BENCH_fleet.json`).
//! Before this gate they were write-only — nothing stopped a perf or
//! goodput regression from merging. [`compare`] walks a checked-in
//! baseline document against the current run and fails on:
//!
//! * **wall-clock regressions** — keys that measure wall time
//!   (`wall_s`, `*_serial_s`, `*_parallel_s`, `ns_per_op`) may not exceed
//!   the baseline by more than the relative tolerance (default 25%);
//!   getting *faster* never fails;
//! * **throughput floors** — keys that measure event throughput
//!   (`events_per_sec`, `*_events_per_sec`) are the mirror image: they
//!   may not fall *below* the baseline by more than the wall tolerance;
//!   getting faster never fails. A `null` baseline (the state until a
//!   mega-fleet floor is blessed) keeps the check advisory;
//! * **deterministic drift** — every other pinned number (goodput,
//!   SLO-violation fractions, checksums, grid sizes, config constants) is
//!   simulation output that is bit-reproducible across machines, so any
//!   drift beyond float-noise means behavior changed and must be either
//!   fixed or consciously re-blessed (`migperf bench-check --bless`);
//! * **shape changes** — a pinned key missing from the current run, a
//!   type mismatch, or a pinned array that shrank.
//!
//! Baselines pin exactly what they contain: keys present only in the
//! current run are ignored, so a partial baseline (e.g. wall budgets +
//! structural fields) is valid and can be tightened incrementally.
//! Machine-dependent keys (`workers`, `*speedup`) and `null` baseline
//! values (placeholders awaiting a bless) are always skipped.

use crate::util::json::Json;

/// Comparison tolerances.
#[derive(Debug, Clone)]
pub struct Tolerance {
    /// Maximum relative wall-clock regression before failing (0.25 = 25%).
    pub wall: f64,
    /// Maximum relative drift on deterministic metrics (float noise).
    pub drift: f64,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance { wall: 0.25, drift: 1e-9 }
    }
}

/// One comparison failure, anchored to a JSON path.
#[derive(Debug, Clone)]
pub struct Finding {
    /// JSON path of the offending value (e.g. `$.sweep.fig5_serial_s`).
    pub path: String,
    /// What went wrong.
    pub message: String,
}

/// Result of comparing a current bench record against its baseline.
#[derive(Debug, Clone, Default)]
pub struct Comparison {
    /// Leaf values checked.
    pub checked: usize,
    /// Leaf values skipped (machine-dependent keys, null placeholders).
    pub skipped: usize,
    /// Failures, in document order.
    pub failures: Vec<Finding>,
}

impl Comparison {
    /// True when no pinned value regressed or drifted.
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Keys that are machine- or environment-dependent and never compared.
const SKIP_KEYS: &[&str] = &["workers", "note"];

fn is_skipped(key: &str) -> bool {
    SKIP_KEYS.contains(&key) || key == "speedup" || key.ends_with("_speedup")
}

/// Keys measuring wall time: compared with the relative wall tolerance,
/// one-sided (only slower fails).
fn is_wall_clock(key: &str) -> bool {
    matches!(key, "wall_s" | "serial_s" | "parallel_s" | "ns_per_op")
        || key.ends_with("_wall_s")
        || key.ends_with("_serial_s")
        || key.ends_with("_parallel_s")
}

/// Keys measuring event throughput: compared with the relative wall
/// tolerance, one-sided (only *slower* — i.e. a lower rate — fails).
fn is_throughput_floor(key: &str) -> bool {
    key == "events_per_sec" || key.ends_with("_events_per_sec")
}

/// Compare `current` against `baseline` under `tol`. Only values pinned
/// by the baseline are checked; see the module docs for the rules.
pub fn compare(baseline: &Json, current: &Json, tol: &Tolerance) -> Comparison {
    let mut out = Comparison::default();
    walk(baseline, current, "$", "", tol, &mut out);
    out
}

fn walk(base: &Json, cur: &Json, path: &str, key: &str, tol: &Tolerance, out: &mut Comparison) {
    if is_skipped(key) {
        out.skipped += 1;
        return;
    }
    match (base, cur) {
        // A null baseline value is an explicit "not pinned yet".
        (Json::Null, _) => out.skipped += 1,
        (Json::Obj(bm), Json::Obj(cm)) => {
            for (k, bv) in bm {
                let p = format!("{path}.{k}");
                match cm.get(k) {
                    Some(cv) => walk(bv, cv, &p, k, tol, out),
                    None => out.failures.push(Finding {
                        path: p,
                        message: "pinned metric missing from the current run".into(),
                    }),
                }
            }
        }
        (Json::Arr(ba), Json::Arr(ca)) => {
            if ba.len() > ca.len() {
                out.failures.push(Finding {
                    path: path.to_string(),
                    message: format!(
                        "baseline pins {} entries, current run has only {}",
                        ba.len(),
                        ca.len()
                    ),
                });
                return;
            }
            for (i, bv) in ba.iter().enumerate() {
                walk(bv, &ca[i], &format!("{path}[{i}]"), key, tol, out);
            }
        }
        (Json::Num(b), Json::Num(c)) => {
            out.checked += 1;
            if is_wall_clock(key) {
                if *b > 0.0 && *c > *b * (1.0 + tol.wall) {
                    out.failures.push(Finding {
                        path: path.to_string(),
                        message: format!(
                            "wall-clock regression: {c:.4} vs baseline {b:.4} \
                             (more than +{:.0}% slower)",
                            tol.wall * 100.0
                        ),
                    });
                }
            } else if is_throughput_floor(key) {
                if *b > 0.0 && *c < *b * (1.0 - tol.wall) {
                    out.failures.push(Finding {
                        path: path.to_string(),
                        message: format!(
                            "throughput regression: {c:.1} events/s vs baseline \
                             floor {b:.1} (more than -{:.0}% slower)",
                            tol.wall * 100.0
                        ),
                    });
                }
            } else {
                let rel = (c - b).abs() / b.abs().max(1e-12);
                if rel > tol.drift {
                    out.failures.push(Finding {
                        path: path.to_string(),
                        message: format!(
                            "deterministic metric drifted: {c} vs baseline {b} \
                             (relative {rel:.3e})"
                        ),
                    });
                }
            }
        }
        (Json::Bool(b), Json::Bool(c)) => {
            out.checked += 1;
            if b != c {
                out.failures.push(Finding {
                    path: path.to_string(),
                    message: format!("expected {b}, got {c}"),
                });
            }
        }
        (Json::Str(b), Json::Str(c)) => {
            out.checked += 1;
            if b != c {
                out.failures.push(Finding {
                    path: path.to_string(),
                    message: format!("expected {b:?}, got {c:?}"),
                });
            }
        }
        (b, c) => out.failures.push(Finding {
            path: path.to_string(),
            message: format!("type mismatch: baseline {}, current {}", kind(b), kind(c)),
        }),
    }
}

fn kind(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

/// Render a comparison as a human-readable report.
pub fn render(label: &str, cmp: &Comparison) -> String {
    let mut out = String::new();
    if cmp.passed() {
        out.push_str(&format!(
            "bench-check {label}: OK ({} values checked, {} skipped)\n",
            cmp.checked, cmp.skipped
        ));
    } else {
        out.push_str(&format!(
            "bench-check {label}: FAILED ({} regressions; {} values checked, {} skipped)\n",
            cmp.failures.len(),
            cmp.checked,
            cmp.skipped
        ));
        for f in &cmp.failures {
            out.push_str(&format!("  {}: {}\n", f.path, f.message));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn cmp(base: &str, cur: &str) -> Comparison {
        compare(&parse(base).unwrap(), &parse(cur).unwrap(), &Tolerance::default())
    }

    #[test]
    fn identical_documents_pass() {
        let doc = r#"{"schema": "v1", "smoke": true, "goodput_rps": 42.5, "wall_s": 3.0}"#;
        let c = cmp(doc, doc);
        assert!(c.passed(), "{:?}", c.failures);
        assert_eq!(c.checked, 4);
    }

    #[test]
    fn injected_wall_clock_regression_fails_beyond_tolerance() {
        let base = r#"{"serial_s": 10.0}"#;
        assert!(cmp(base, r#"{"serial_s": 12.0}"#).passed(), "+20% is within 25%");
        let c = cmp(base, r#"{"serial_s": 12.6}"#);
        assert!(!c.passed(), "+26% must fail");
        assert!(c.failures[0].message.contains("wall-clock regression"));
        assert_eq!(c.failures[0].path, "$.serial_s");
    }

    #[test]
    fn wall_clock_speedups_never_fail() {
        assert!(cmp(r#"{"wall_s": 10.0}"#, r#"{"wall_s": 0.5}"#).passed());
        assert!(cmp(r#"{"fig5_parallel_s": 8.0}"#, r#"{"fig5_parallel_s": 2.0}"#).passed());
    }

    #[test]
    fn prefixed_wall_keys_use_wall_tolerance() {
        let c = cmp(r#"{"fig11_serial_s": 4.0}"#, r#"{"fig11_serial_s": 6.0}"#);
        assert!(!c.passed(), "+50% on a prefixed wall key must fail");
    }

    #[test]
    fn throughput_floor_is_one_sided() {
        let base = r#"{"events_per_sec": 1000000.0}"#;
        assert!(cmp(base, r#"{"events_per_sec": 900000.0}"#).passed(), "-10% is within 25%");
        assert!(cmp(base, r#"{"events_per_sec": 5000000.0}"#).passed(), "faster never fails");
        let c = cmp(base, r#"{"events_per_sec": 700000.0}"#);
        assert!(!c.passed(), "-30% must fail");
        assert!(c.failures[0].message.contains("throughput regression"));
        assert_eq!(c.failures[0].path, "$.events_per_sec");
    }

    #[test]
    fn prefixed_throughput_keys_and_null_floors() {
        let c = cmp(r#"{"mega_events_per_sec": 100.0}"#, r#"{"mega_events_per_sec": 10.0}"#);
        assert!(!c.passed(), "suffixed keys use the floor rule");
        let advisory = cmp(r#"{"events_per_sec": null}"#, r#"{"events_per_sec": 1.0}"#);
        assert!(advisory.passed(), "unblessed floor stays advisory");
        assert_eq!(advisory.skipped, 1);
    }

    #[test]
    fn deterministic_drift_fails_even_when_tiny() {
        let base = r#"{"goodput_rps": 100.0}"#;
        assert!(cmp(base, r#"{"goodput_rps": 100.0}"#).passed());
        let c = cmp(base, r#"{"goodput_rps": 100.001}"#);
        assert!(!c.passed(), "1e-5 relative drift is behavior change, not float noise");
        assert!(c.failures[0].message.contains("drifted"));
        // Improvements drift too: the baseline must be re-blessed, not
        // silently outgrown.
        assert!(!cmp(base, r#"{"goodput_rps": 120.0}"#).passed());
    }

    #[test]
    fn nested_paths_are_reported() {
        let base = r#"{"comparison_at_peak": {"static_goodput_rps": 50.0}}"#;
        let cur = r#"{"comparison_at_peak": {"static_goodput_rps": 49.0}}"#;
        let c = cmp(base, cur);
        assert_eq!(c.failures[0].path, "$.comparison_at_peak.static_goodput_rps");
    }

    #[test]
    fn rows_compare_by_index() {
        let base = r#"{"rows": [{"goodput_rps": 10.0}, {"goodput_rps": 20.0}]}"#;
        let ok =
            r#"{"rows": [{"goodput_rps": 10.0}, {"goodput_rps": 20.0}, {"goodput_rps": 9.9}]}"#;
        assert!(cmp(base, ok).passed(), "extra current rows are unpinned");
        let drifted = r#"{"rows": [{"goodput_rps": 10.0}, {"goodput_rps": 21.0}]}"#;
        assert_eq!(cmp(base, drifted).failures[0].path, "$.rows[1].goodput_rps");
        let shrunk = r#"{"rows": [{"goodput_rps": 10.0}]}"#;
        assert!(cmp(base, shrunk).failures[0].message.contains("pins 2 entries"));
    }

    #[test]
    fn missing_pinned_key_fails_and_extra_keys_pass() {
        let c = cmp(r#"{"schema": "v1"}"#, r#"{"other": 1}"#);
        assert!(!c.passed());
        assert!(c.failures[0].message.contains("missing"));
        assert!(cmp(r#"{"a": 1.0}"#, r#"{"a": 1.0, "b": 99.0}"#).passed());
    }

    #[test]
    fn machine_dependent_and_null_values_are_skipped() {
        let base = r#"{"workers": 64, "speedup": 9.0, "fig5_speedup": 3.0,
                       "goodput_rps": null, "note": "human text"}"#;
        let cur = r#"{"workers": 2, "speedup": 1.1, "fig5_speedup": 0.9,
                      "goodput_rps": 55.0, "note": "different"}"#;
        let c = cmp(base, cur);
        assert!(c.passed(), "{:?}", c.failures);
        assert_eq!(c.skipped, 5);
        assert_eq!(c.checked, 0);
    }

    #[test]
    fn schema_and_smoke_flags_are_pinned_exactly() {
        assert!(!cmp(r#"{"schema": "v1"}"#, r#"{"schema": "v2"}"#).passed());
        assert!(!cmp(r#"{"smoke": true}"#, r#"{"smoke": false}"#).passed());
        assert!(!cmp(r#"{"smoke": true}"#, r#"{"smoke": 1}"#).passed(), "type mismatch");
    }

    #[test]
    fn custom_tolerance_is_respected() {
        let t = Tolerance { wall: 1.0, drift: 1e-9 };
        let base = parse(r#"{"wall_s": 10.0}"#).unwrap();
        assert!(compare(&base, &parse(r#"{"wall_s": 19.0}"#).unwrap(), &t).passed());
        assert!(!compare(&base, &parse(r#"{"wall_s": 21.0}"#).unwrap(), &t).passed());
    }

    #[test]
    fn render_reports_pass_and_fail() {
        let ok = cmp(r#"{"a": 1.0}"#, r#"{"a": 1.0}"#);
        assert!(render("BENCH_x", &ok).contains("OK"));
        let bad = cmp(r#"{"a": 1.0}"#, r#"{"a": 2.0}"#);
        let report = render("BENCH_x", &bad);
        assert!(report.contains("FAILED"));
        assert!(report.contains("$.a"));
    }
}
