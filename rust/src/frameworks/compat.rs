//! Framework registry and the compatibility matrix (Tables 1–2).
//!
//! Each framework is modelled by how it discovers devices through the
//! simulated CUDA runtime and how it reports the result. The quirk the
//! paper records in Table 1 — PyTorch 1.13 reporting a *visible device
//! count of 0* while still training fine on MIG 0 — comes from PyTorch
//! counting only non-MIG devices in that version, and is reproduced here.

use crate::mig::controller::MigController;
use crate::mig::gpu::GpuModel;

use super::cuda::{enumerate, ProcessEnv};

/// Framework category.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameworkKind {
    /// Training framework (Table 1).
    Training,
    /// Serving framework (Table 2).
    Serving,
}

/// A DL framework under compatibility test.
#[derive(Debug, Clone)]
pub struct Framework {
    /// Name as reported in the paper.
    pub name: &'static str,
    /// Version the paper tested.
    pub version: &'static str,
    /// Training or serving.
    pub kind: FrameworkKind,
    /// Whether this framework's device-count API counts MIG devices.
    /// (PyTorch 1.13's `torch.cuda.device_count()` returned 0 on MIG.)
    counts_mig_devices: bool,
}

/// The paper's Table 1 frameworks.
#[rustfmt::skip]
pub static TRAINING_FRAMEWORKS: &[Framework] = &[
    Framework { name: "PyTorch", version: "1.13.0", kind: FrameworkKind::Training, counts_mig_devices: false },
    Framework { name: "TensorFlow", version: "2.11.0", kind: FrameworkKind::Training, counts_mig_devices: true },
    Framework { name: "MxNet", version: "1.9.1", kind: FrameworkKind::Training, counts_mig_devices: true },
    Framework { name: "PaddlePaddle", version: "2.4.1", kind: FrameworkKind::Training, counts_mig_devices: true },
];

/// The paper's Table 2 frameworks.
#[rustfmt::skip]
pub static SERVING_FRAMEWORKS: &[Framework] = &[
    Framework { name: "TensorFlow Serving", version: "2.8.4", kind: FrameworkKind::Serving, counts_mig_devices: true },
    Framework { name: "Triton Inference Server", version: "21.09", kind: FrameworkKind::Serving, counts_mig_devices: true },
    Framework { name: "Ray Serve", version: "2.2.0", kind: FrameworkKind::Serving, counts_mig_devices: true },
];

/// Result of probing one framework against a MIG layout.
#[derive(Debug, Clone, PartialEq)]
pub struct CompatResult {
    /// Framework name.
    pub framework: &'static str,
    /// Framework version.
    pub version: &'static str,
    /// What the framework's device-count API reports.
    pub visible_device_count: u32,
    /// Can it run a workload on MIG 0?
    pub works_on_mig0: bool,
    /// Can it run a workload on MIG 1 (without container binding)?
    pub works_on_mig1: bool,
}

impl Framework {
    /// Probe this framework on a host with the given GPU controllers.
    pub fn probe(&self, controllers: &[&MigController]) -> CompatResult {
        let devices = enumerate(controllers, &ProcessEnv::default());
        let mig_devices: Vec<_> = devices.iter().filter(|d| d.mig_uuid.is_some()).collect();
        let visible_device_count = if self.counts_mig_devices {
            devices.len() as u32
        } else {
            // PyTorch 1.13 behaviour: MIG devices not counted.
            (devices.len() - mig_devices.len()) as u32
        };
        // A workload runs on MIG k iff a default process can reach that
        // instance: only ever MIG 0.
        let works_on_mig0 = devices
            .iter()
            .any(|d| d.mig_uuid.as_deref().map(|u| u.contains("/0/")).unwrap_or(true));
        let works_on_mig1 = devices
            .iter()
            .any(|d| d.mig_uuid.as_deref().map(|u| u.contains("/1/")).unwrap_or(false));
        CompatResult {
            framework: self.name,
            version: self.version,
            visible_device_count,
            works_on_mig0,
            works_on_mig1,
        }
    }
}

/// Build the paper's Table 1 setup: an A30 with two 1g.6gb GIs (CIs
/// created), and probe every training framework.
pub fn run_training_matrix() -> Vec<CompatResult> {
    let ctl = two_gi_a30();
    TRAINING_FRAMEWORKS.iter().map(|f| f.probe(&[&ctl])).collect()
}

/// Build the paper's Table 2 setup and probe every serving framework.
pub fn run_serving_matrix() -> Vec<CompatResult> {
    let ctl = two_gi_a30();
    SERVING_FRAMEWORKS.iter().map(|f| f.probe(&[&ctl])).collect()
}

fn two_gi_a30() -> MigController {
    let mut c = MigController::new(GpuModel::A30_24GB);
    c.enable_mig().expect("fresh controller");
    let a = c.create_instance("1g.6gb").expect("first GI");
    let b = c.create_instance("1g.6gb").expect("second GI");
    c.create_default_ci(a).expect("CI 0");
    c.create_default_ci(b).expect("CI 1");
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape() {
        let rows = run_training_matrix();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.works_on_mig0, "{} must train on MIG 0", r.framework);
            assert!(!r.works_on_mig1, "{} must NOT see MIG 1", r.framework);
        }
    }

    #[test]
    fn table1_pytorch_counts_zero() {
        // The paper's PyTorch row: visible device count 0, still trains.
        let rows = run_training_matrix();
        let pt = rows.iter().find(|r| r.framework == "PyTorch").unwrap();
        assert_eq!(pt.visible_device_count, 0);
        assert!(pt.works_on_mig0);
    }

    #[test]
    fn table1_others_count_one() {
        let rows = run_training_matrix();
        for name in ["TensorFlow", "MxNet", "PaddlePaddle"] {
            let r = rows.iter().find(|r| r.framework == name).unwrap();
            assert_eq!(r.visible_device_count, 1, "{name}");
        }
    }

    #[test]
    fn table2_shape() {
        let rows = run_serving_matrix();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.works_on_mig0, "{} must serve on MIG 0", r.framework);
            assert!(!r.works_on_mig1, "{}: device not found on MIG 1", r.framework);
        }
    }

    #[test]
    fn versions_match_paper() {
        assert!(TRAINING_FRAMEWORKS.iter().any(|f| f.name == "PyTorch" && f.version == "1.13.0"));
        assert!(SERVING_FRAMEWORKS
            .iter()
            .any(|f| f.name == "Triton Inference Server" && f.version == "21.09"));
    }

    #[test]
    fn without_mig_framework_sees_whole_gpu() {
        let ctl = MigController::new(GpuModel::A30_24GB);
        let r = TRAINING_FRAMEWORKS[1].probe(&[&ctl]);
        assert_eq!(r.visible_device_count, 1);
        assert!(r.works_on_mig0, "whole GPU counts as usable");
    }
}
