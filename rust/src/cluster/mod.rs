//! Multi-GPU fleet simulation: MIG orchestration at cluster scale.
//!
//! MIGPerf characterizes workloads on a *single* partitioned GPU; the
//! paper's stated goal — orchestrating hybrid training and inference at
//! production scale — plays out across a fleet of MIG-capable GPUs,
//! where serving DNNs becomes a reconfigurable machine scheduling problem
//! (Tan et al., 2021) and MISO-style layout search (Li et al., 2022) is
//! lifted from one device to many. This subsystem supplies that scale
//! jump on top of the existing DES, serving simulation and single-GPU
//! orchestrator:
//!
//! * [`engine`] — N GPUs in one simulation: fleet-wide request classes,
//!   per-GPU MIG layouts from [`crate::mig::enumerate`] via the fleet
//!   demand packer ([`crate::scheduler::plan_fleet_for_demand`]), and
//!   rolling vs in-place reconfiguration disciplines with an explicit
//!   drain → churn → resume cost;
//! * [`router`] — deterministic fleet-level request routing
//!   (round-robin, least-loaded, locality/affinity) behind the
//!   [`RoutePolicy`] trait;
//! * [`policy`] — fleet repartitioning policies behind [`FleetPolicy`],
//!   extending the single-GPU [`Policy`](crate::orchestrator::Policy)
//!   idea with the *which GPU* dimension;
//! * [`faults`] — deterministic failure injection: seed-driven GPU and
//!   instance crash schedules ([`FaultPlan`]), ingress retry budgets and
//!   the retry-storm guard, measured as goodput under partial outages;
//! * [`tenancy`] — multi-tenant fairness: [`Tenant`]s group request
//!   classes under SLO weights, the [`WeightedFair`] router enforces
//!   them at the ingress via deficit round-robin, the demand planners
//!   provision tenant weight × capacity weight, and `FleetOutcome`
//!   reports per-tenant accounting plus Jain's fairness index over
//!   weight-normalized goodput;
//! * [`overload`] — SLO-aware overload protection: per-request deadlines
//!   derived from each class's SLO, bounded per-replica queues with
//!   pluggable shed disciplines ([`ShedDiscipline`]), tenant-weighted
//!   brownout under fleet-wide pressure, and a per-GPU ingress circuit
//!   breaker with half-open probing — extending conservation to
//!   `completed + failed + lost_in_crash + shed_overload = arrived`;
//! * [`telemetry`] — deterministic observability: windowed per-GPU/
//!   per-class time-series (queue depth, busy fraction, arrivals,
//!   completions, shed split, breaker/brownout state, per-tenant
//!   goodput) plus per-instance DCGM GRACT/FBUSD/POWER timelines and
//!   1-in-N sampled request lifecycle spans, exportable as Prometheus,
//!   CSV, JSONL, and Chrome trace-event (Perfetto) documents — strictly
//!   observational, so telemetry-off runs stay byte-identical and
//!   telemetry-on payloads join the bitwise-determinism checksums;
//! * [`mega`] — sharded mega-fleet runs: one huge [`FleetConfig`] is
//!   decomposed into per-shard sub-fleets (contiguous GPU partition,
//!   arrival rates scaled by the shard's GPU fraction), the shards run
//!   across sweep workers, and the outcomes merge in deterministic
//!   shard order — how the `migperf fleet --mega` events/sec scaling
//!   figure is produced at 1024 GPUs;
//! * fleet sweeps fan out through [`crate::sweep::run_fleet`] with the
//!   engine's bitwise-determinism guarantee intact (a crash schedule is
//!   config data, so faulted grids stay bit-identical too — and so are a
//!   tenant set, an overload policy, and a telemetry config).

pub mod engine;
pub mod faults;
pub mod mega;
pub mod overload;
pub mod policy;
pub mod router;
pub mod telemetry;
pub mod tenancy;

pub use engine::{
    EngineInspector, EngineProbe, FleetConfig, FleetDecision, FleetError, FleetOutcome,
    NoopInspector, RepartitionMode, RequestClass,
};
pub use faults::{FaultInjection, FaultPlan, FaultRecord, DEFAULT_RETRY_BUDGET};
pub use overload::{
    BreakerState, OverloadGuard, OverloadPolicy, ShedCause, ShedDiscipline,
    DEFAULT_BREAKER_PROBES,
};
pub use mega::{merge_outcomes, shard_config, MegaPlan};
pub use policy::{
    FleetAction, FleetCtx, FleetObs, FleetPolicy, FleetPolicyImpl, FleetPolicyKind, FleetReactive,
    FleetScripted, FleetStatic, GpuObs, ScriptedRepartition,
};
pub use router::{
    Affinity, GpuHealth, LeastLoaded, RoundRobin, RoutePolicy, Router, RouterKind, WeightedFair,
    DEFAULT_AFFINITY_SPILL, DRR_CREDIT_CAP,
};
pub use telemetry::{
    chrome_trace, spans_to_jsonl, FleetTelemetry, SpanEvent, SpanKind, TelemetryConfig,
};
pub use tenancy::{
    jain_index, parse_tenants, tenant_of_classes, validate_tenants, Tenant, TenantOutcome,
};
