//! Workload drivers: the "workload performer" half of the paper's MIG
//! Profiler (§3.2).
//!
//! [`spec`] describes a benchmark workload; [`training`] runs training
//! steps on a simulated instance; [`serving`] runs single- and
//! multi-server inference on the discrete-event simulator (closed-loop
//! for the sharing comparison, open-loop Poisson for the arrival-rate
//! appendix experiments); [`arrival`] generates request streams;
//! [`batcher`] implements the dynamic batcher used by the serving
//! examples.

pub mod arrival;
pub mod batcher;
pub mod serving;
pub mod spec;
pub mod trace;
pub mod training;

pub use spec::{WorkloadKind, WorkloadSpec};
