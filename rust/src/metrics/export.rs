//! Results exporters: CSV, JSONL and Prometheus exposition format.
//!
//! The paper's exporter "can format the saved performance results so they
//! can be demonstrated with different performance analysis tools" (§3.2) —
//! specifically Prometheus and notebook tooling. Each exporter here
//! serializes run summaries, raw time series, optimizer plans, or
//! orchestrator decision logs.

use std::fmt::Write as _;

use crate::cluster::{FaultRecord, FleetDecision, TenantOutcome};
use crate::orchestrator::Decision;
use crate::scheduler::{Assignment, Plan};
use crate::util::json::Json;
use crate::util::timeseries::{Series, SeriesSet};

use super::collector::RunSummary;

/// CSV header used by [`summaries_to_csv`].
pub const SUMMARY_CSV_HEADER: &str = "label,completed,avg_latency_ms,std_latency_ms,p50_latency_ms,p99_latency_ms,max_latency_ms,throughput,mean_gract,peak_fb_mib,energy_j,duration_s";

/// Serialize run summaries as CSV (with header).
pub fn summaries_to_csv(rows: &[RunSummary]) -> String {
    let mut out = String::from(SUMMARY_CSV_HEADER);
    out.push('\n');
    for r in rows {
        let _ = writeln!(
            out,
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.3},{:.3},{:.6}",
            csv_escape(&r.label),
            r.completed,
            r.avg_latency_ms,
            r.std_latency_ms,
            r.p50_latency_ms,
            r.p99_latency_ms,
            r.max_latency_ms,
            r.throughput,
            r.mean_gract,
            r.peak_fb_mib,
            r.energy_j,
            r.duration_s,
        );
    }
    out
}

/// CSV header used by [`fleet_summaries_to_csv`].
pub const FLEET_SUMMARY_CSV_HEADER: &str = "label,completed,avg_latency_ms,std_latency_ms,p50_latency_ms,p99_latency_ms,max_latency_ms,throughput,mean_gract,peak_fb_mib,energy_j,duration_s,events_processed,events_per_sec";

/// Serialize fleet run summaries as CSV, extending [`summaries_to_csv`]
/// with the per-run DES event accounting: each row carries the pooled
/// summary plus `(events_processed, events_per_sec)`. `events_processed`
/// is deterministic for a config/seed; `events_per_sec` is wall-clock
/// derived and excluded from every determinism check.
pub fn fleet_summaries_to_csv(rows: &[(RunSummary, u64, f64)]) -> String {
    let mut out = String::from(FLEET_SUMMARY_CSV_HEADER);
    out.push('\n');
    for (r, events, eps) in rows {
        let _ = writeln!(
            out,
            "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.3},{:.3},{:.6},{},{:.1}",
            csv_escape(&r.label),
            r.completed,
            r.avg_latency_ms,
            r.std_latency_ms,
            r.p50_latency_ms,
            r.p99_latency_ms,
            r.max_latency_ms,
            r.throughput,
            r.mean_gract,
            r.peak_fb_mib,
            r.energy_j,
            r.duration_s,
            events,
            eps,
        );
    }
    out
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// One JSON object per line, one line per summary (JSONL).
pub fn summaries_to_jsonl(rows: &[RunSummary]) -> String {
    let mut out = String::new();
    for r in rows {
        out.push_str(&summary_to_json(r).to_string());
        out.push('\n');
    }
    out
}

/// A run summary as a JSON object.
pub fn summary_to_json(r: &RunSummary) -> Json {
    Json::obj(vec![
        ("label", r.label.as_str().into()),
        ("completed", (r.completed as i64).into()),
        ("avg_latency_ms", r.avg_latency_ms.into()),
        ("std_latency_ms", r.std_latency_ms.into()),
        ("p50_latency_ms", r.p50_latency_ms.into()),
        ("p99_latency_ms", r.p99_latency_ms.into()),
        ("max_latency_ms", r.max_latency_ms.into()),
        ("throughput", r.throughput.into()),
        ("mean_gract", r.mean_gract.into()),
        ("peak_fb_mib", r.peak_fb_mib.into()),
        ("energy_j", r.energy_j.into()),
        ("duration_s", r.duration_s.into()),
    ])
}

/// CSV header used by [`assignments_to_csv`].
pub const ASSIGNMENT_CSV_HEADER: &str =
    "workload,profile,latency_ms,throughput,goodput,power_w";

/// Serialize optimizer assignments as CSV (with header).
pub fn assignments_to_csv(rows: &[Assignment]) -> String {
    let mut out = String::from(ASSIGNMENT_CSV_HEADER);
    out.push('\n');
    for a in rows {
        let _ = writeln!(
            out,
            "{},{},{:.6},{:.6},{:.6},{:.3}",
            a.workload, a.profile, a.latency_ms, a.throughput, a.goodput, a.power_w,
        );
    }
    out
}

/// An optimizer assignment as a JSON object.
pub fn assignment_to_json(a: &Assignment) -> Json {
    Json::obj(vec![
        ("workload", (a.workload as i64).into()),
        ("profile", a.profile.into()),
        ("latency_ms", a.latency_ms.into()),
        ("throughput", a.throughput.into()),
        ("goodput", a.goodput.into()),
        ("power_w", a.power_w.into()),
    ])
}

/// A complete optimizer plan (layout + assignments + score) as JSON.
pub fn plan_to_json(p: &Plan) -> Json {
    Json::obj(vec![
        ("layout", Json::Arr(p.layout.iter().map(|&n| n.into()).collect())),
        ("score", p.score.into()),
        ("assignments", Json::Arr(p.assignments.iter().map(assignment_to_json).collect())),
    ])
}

/// CSV header used by [`decisions_to_csv`].
pub const DECISION_CSV_HEADER: &str = "t,from,to,churn,downtime_s,reason";

/// Serialize an orchestrator decision log as CSV (with header).
pub fn decisions_to_csv(rows: &[Decision]) -> String {
    let mut out = String::from(DECISION_CSV_HEADER);
    out.push('\n');
    for d in rows {
        let _ = writeln!(
            out,
            "{:.6},{},{},{},{:.6},{}",
            d.t,
            csv_escape(&d.from),
            csv_escape(&d.to),
            d.churn,
            d.downtime_s,
            csv_escape(&d.reason),
        );
    }
    out
}

/// One orchestrator decision as a JSON object.
pub fn decision_to_json(d: &Decision) -> Json {
    Json::obj(vec![
        ("t", d.t.into()),
        ("from", d.from.as_str().into()),
        ("to", d.to.as_str().into()),
        ("churn", (d.churn as i64).into()),
        ("downtime_s", d.downtime_s.into()),
        ("reason", d.reason.as_str().into()),
    ])
}

/// A whole decision log as a JSON array.
pub fn decisions_to_json(rows: &[Decision]) -> Json {
    Json::Arr(rows.iter().map(decision_to_json).collect())
}

/// CSV header used by [`fleet_decisions_to_csv`].
pub const FLEET_DECISION_CSV_HEADER: &str = "t,gpu,from,to,churn,downtime_s,migrated,reason";

/// Serialize a fleet decision log as CSV (with header).
pub fn fleet_decisions_to_csv(rows: &[FleetDecision]) -> String {
    let mut out = String::from(FLEET_DECISION_CSV_HEADER);
    out.push('\n');
    for d in rows {
        let _ = writeln!(
            out,
            "{:.6},{},{},{},{},{:.6},{},{}",
            d.t,
            d.gpu,
            csv_escape(&d.from),
            csv_escape(&d.to),
            d.churn,
            d.downtime_s,
            d.migrated,
            csv_escape(&d.reason),
        );
    }
    out
}

/// One fleet decision as a JSON object.
pub fn fleet_decision_to_json(d: &FleetDecision) -> Json {
    Json::obj(vec![
        ("t", d.t.into()),
        ("gpu", (d.gpu as i64).into()),
        ("from", d.from.as_str().into()),
        ("to", d.to.as_str().into()),
        ("churn", (d.churn as i64).into()),
        ("downtime_s", d.downtime_s.into()),
        ("migrated", (d.migrated as i64).into()),
        ("reason", d.reason.as_str().into()),
    ])
}

/// A whole fleet decision log as a JSON array.
pub fn fleet_decisions_to_json(rows: &[FleetDecision]) -> Json {
    Json::Arr(rows.iter().map(fleet_decision_to_json).collect())
}

/// CSV header used by [`fault_records_to_csv`]. `class` is `gpu` for a
/// whole-GPU crash, the class index for an instance crash; `down_s` is
/// `inf` for permanent failures.
pub const FAULT_CSV_HEADER: &str = "t,gpu,class,down_s,lost,retried,shed";

/// Serialize an executed fault timeline as CSV (with header).
pub fn fault_records_to_csv(rows: &[FaultRecord]) -> String {
    let mut out = String::from(FAULT_CSV_HEADER);
    out.push('\n');
    for r in rows {
        let class = r.class.map(|c| c.to_string()).unwrap_or_else(|| "gpu".into());
        let down = if r.down_s.is_finite() {
            format!("{:.6}", r.down_s)
        } else {
            "inf".into()
        };
        let _ = writeln!(
            out,
            "{:.6},{},{},{},{},{},{}",
            r.t, r.gpu, class, down, r.lost, r.retried, r.shed,
        );
    }
    out
}

/// One executed fault as a JSON object (`class` is `null` for a
/// whole-GPU crash; `down_s` is `null` for permanent failures, which
/// JSON numbers cannot represent).
pub fn fault_record_to_json(r: &FaultRecord) -> Json {
    Json::obj(vec![
        ("t", r.t.into()),
        ("gpu", (r.gpu as i64).into()),
        ("class", r.class.map(|c| Json::Num(c as f64)).unwrap_or(Json::Null)),
        (
            "down_s",
            if r.down_s.is_finite() {
                r.down_s.into()
            } else {
                Json::Null
            },
        ),
        ("lost", (r.lost as i64).into()),
        ("retried", (r.retried as i64).into()),
        ("shed", (r.shed as i64).into()),
    ])
}

/// A whole fault timeline as a JSON array.
pub fn fault_records_to_json(rows: &[FaultRecord]) -> Json {
    Json::Arr(rows.iter().map(fault_record_to_json).collect())
}

/// CSV header used by [`tenant_outcomes_to_csv`].
pub const TENANT_CSV_HEADER: &str = "run,tenant,weight,arrived,completed,slo_violations,\
failed,lost_in_crash,retried,shed_deadline,shed_capacity,shed_brownout,goodput_rps,\
norm_goodput_rps";

/// Serialize per-tenant fleet accounting as CSV (with header). Each row
/// carries its run label so a whole sweep's tenant tables can share one
/// document.
pub fn tenant_outcomes_to_csv(rows: &[(String, TenantOutcome)]) -> String {
    let mut out = String::from(TENANT_CSV_HEADER);
    out.push('\n');
    for (run, t) in rows {
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{},{},{},{},{},{},{:.6},{:.6}",
            csv_escape(run),
            csv_escape(&t.name),
            t.weight,
            t.arrived,
            t.completed,
            t.slo_violations,
            t.failed,
            t.lost_in_crash,
            t.retried,
            t.shed_deadline,
            t.shed_capacity,
            t.shed_brownout,
            t.goodput_rps,
            t.norm_goodput_rps,
        );
    }
    out
}

/// One tenant's accounting as a JSON object.
pub fn tenant_outcome_to_json(t: &TenantOutcome) -> Json {
    Json::obj(vec![
        ("name", t.name.as_str().into()),
        ("weight", t.weight.into()),
        (
            "classes",
            Json::Arr(t.classes.iter().map(|&c| Json::Num(c as f64)).collect()),
        ),
        ("arrived", (t.arrived as i64).into()),
        ("completed", (t.completed as i64).into()),
        ("slo_violations", (t.slo_violations as i64).into()),
        ("failed", (t.failed as i64).into()),
        ("lost_in_crash", (t.lost_in_crash as i64).into()),
        ("retried", (t.retried as i64).into()),
        ("shed_deadline", (t.shed_deadline as i64).into()),
        ("shed_capacity", (t.shed_capacity as i64).into()),
        ("shed_brownout", (t.shed_brownout as i64).into()),
        ("goodput_rps", t.goodput_rps.into()),
        ("slo_violation_frac", t.slo_violation_frac.into()),
        ("norm_goodput_rps", t.norm_goodput_rps.into()),
    ])
}

/// A run's per-tenant accounting as a JSON array (tenant order).
pub fn tenant_outcomes_to_json(rows: &[TenantOutcome]) -> Json {
    Json::Arr(rows.iter().map(tenant_outcome_to_json).collect())
}

/// Serialize a time-series set in Prometheus exposition format, using the
/// series' tags as labels and timestamps in milliseconds.
pub fn series_to_prometheus(set: &SeriesSet) -> String {
    let mut out = String::new();
    let mut seen_names: Vec<&str> = Vec::new();
    for s in set.all() {
        if !seen_names.contains(&s.name.as_str()) {
            let _ = writeln!(out, "# TYPE migperf_{} gauge", s.name);
            seen_names.push(&s.name);
        }
        let labels = render_labels(s);
        for p in s.points() {
            let _ =
                writeln!(out, "migperf_{}{} {} {}", s.name, labels, p.value, (p.t * 1e3) as i64);
        }
    }
    out
}

fn render_labels(s: &Series) -> String {
    if s.tags.is_empty() {
        return String::new();
    }
    // Exposition format escapes backslash, double-quote, and line-feed
    // in label values (backslash first so the others stay unambiguous).
    let escape = |v: &str| v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
    let inner: Vec<String> =
        s.tags.iter().map(|(k, v)| format!("{k}=\"{}\"", escape(v))).collect();
    format!("{{{}}}", inner.join(","))
}

/// Serialize raw series as long-format CSV: `metric,instance,t,value`.
pub fn series_to_csv(set: &SeriesSet) -> String {
    let mut out = String::from("metric,tags,t,value\n");
    for s in set.all() {
        let tags: Vec<String> = s.tags.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let tagstr = tags.join(";");
        for p in s.points() {
            let _ = writeln!(out, "{},{},{:.6},{:.6}", s.name, csv_escape(&tagstr), p.t, p.value);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use crate::util::timeseries::Series;

    fn summary(label: &str) -> RunSummary {
        RunSummary {
            label: label.to_string(),
            completed: 10,
            avg_latency_ms: 5.5,
            std_latency_ms: 0.5,
            p50_latency_ms: 5.0,
            p99_latency_ms: 9.0,
            max_latency_ms: 10.0,
            throughput: 100.0,
            mean_gract: 0.9,
            peak_fb_mib: 2048.0,
            energy_j: 42.0,
            duration_s: 1.0,
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let out = summaries_to_csv(&[summary("a"), summary("b")]);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("label,completed"));
        assert!(lines[1].starts_with("a,10,"));
    }

    #[test]
    fn fleet_csv_appends_event_columns() {
        let out = fleet_summaries_to_csv(&[(summary("a"), 1234, 56789.25)]);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].ends_with("duration_s,events_processed,events_per_sec"));
        assert!(lines[1].ends_with(",1234,56789.2"));
    }

    #[test]
    fn csv_escapes_commas() {
        let out = summaries_to_csv(&[summary("bert,base")]);
        assert!(out.contains("\"bert,base\""));
    }

    #[test]
    fn jsonl_parses_back() {
        let out = summaries_to_jsonl(&[summary("x")]);
        let v = json::parse(out.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("label").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("completed").unwrap().as_i64(), Some(10));
        assert_eq!(v.get("energy_j").unwrap().as_f64(), Some(42.0));
    }

    #[test]
    fn prometheus_format() {
        let mut set = SeriesSet::new();
        let mut s = Series::new("gract").with_tag("instance", "1g.10gb");
        s.push(1.0, 0.75);
        set.add(s);
        let out = series_to_prometheus(&set);
        assert!(out.contains("# TYPE migperf_gract gauge"));
        assert!(out.contains("migperf_gract{instance=\"1g.10gb\"} 0.75 1000"));
    }

    #[test]
    fn prometheus_escapes_hostile_label_values() {
        let mut set = SeriesSet::new();
        let mut s = Series::new("gract").with_tag("instance", "a\\b\"c\nd");
        s.push(0.0, 1.0);
        set.add(s);
        let out = series_to_prometheus(&set);
        // Backslash, quote, and newline must all be escaped — and the
        // data line must stay a single line.
        assert!(out.contains("instance=\"a\\\\b\\\"c\\nd\""));
        let data_lines: Vec<&str> =
            out.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).collect();
        assert_eq!(data_lines.len(), 1);
    }

    #[test]
    fn prometheus_type_line_emitted_once_per_metric() {
        let mut set = SeriesSet::new();
        for inst in ["a", "b"] {
            let mut s = Series::new("gract").with_tag("instance", inst);
            s.push(0.0, 0.5);
            set.add(s);
        }
        let out = series_to_prometheus(&set);
        assert_eq!(out.matches("# TYPE migperf_gract").count(), 1);
    }

    #[test]
    fn assignments_export_csv_and_json() {
        use crate::mig::gpu::GpuModel;
        use crate::models::zoo::lookup;
        use crate::scheduler::{Objective, Scheduler, SloWorkload};
        use crate::workload::spec::WorkloadSpec;
        let sched = Scheduler::new(GpuModel::A30_24GB);
        let w = [SloWorkload::with_slo(
            WorkloadSpec::inference(lookup("resnet50").unwrap(), 4, 224),
            1000.0,
        )];
        let plan = sched.plan(&w, Objective::MaxThroughput).unwrap();
        let csv = assignments_to_csv(&plan.assignments);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], ASSIGNMENT_CSV_HEADER);
        assert_eq!(lines.len(), 1 + plan.assignments.len());
        assert!(lines[1].starts_with("0,"), "{csv}");
        let doc = plan_to_json(&plan);
        let parsed = json::parse(&doc.to_string()).unwrap();
        assert_eq!(
            parsed.get("layout").unwrap().as_arr().unwrap().len(),
            plan.layout.len()
        );
        let a0 = &parsed.get("assignments").unwrap().as_arr().unwrap()[0];
        assert_eq!(a0.get("workload").unwrap().as_i64(), Some(0));
        assert!(a0.get("goodput").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn decision_log_export_csv_and_json() {
        use crate::orchestrator::Decision;
        let d = Decision {
            t: 120.0,
            from: "4g.40gb+2g.20gb+1g.10gb".into(),
            to: "2g.20gb+2g.20gb+3g.40gb".into(),
            reason: "window rates [55.1, 54.2] req/s, p99 [61.0, 22.0] ms".into(),
            churn: 6,
            downtime_s: 3.25,
        };
        let csv = decisions_to_csv(std::slice::from_ref(&d));
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], DECISION_CSV_HEADER);
        assert!(lines[1].contains("4g.40gb+2g.20gb+1g.10gb"));
        assert!(lines[1].contains("\"window rates"), "comma-bearing reason must be quoted: {csv}");
        let doc = decisions_to_json(std::slice::from_ref(&d));
        let parsed = json::parse(&doc.to_string()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("churn").unwrap().as_i64(), Some(6));
        assert_eq!(row.get("downtime_s").unwrap().as_f64(), Some(3.25));
        assert_eq!(
            row.get("to").unwrap().as_str(),
            Some("2g.20gb+2g.20gb+3g.40gb")
        );
        assert!(decisions_to_csv(&[]).lines().count() == 1, "empty log is just the header");
    }

    #[test]
    fn fleet_decision_log_export_csv_and_json() {
        use crate::cluster::FleetDecision;
        let d = FleetDecision {
            t: 88.0,
            gpu: 3,
            from: "4g.40gb+2g.20gb+1g.10gb".into(),
            to: "3g.40gb+3g.40gb+1g.10gb".into(),
            reason: "gpu 3: window rates [57.2, 58.9] req/s, p99 [61.0, 59.4] ms".into(),
            churn: 4,
            downtime_s: 2.75,
            migrated: 17,
        };
        let csv = fleet_decisions_to_csv(std::slice::from_ref(&d));
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], FLEET_DECISION_CSV_HEADER);
        assert!(lines[1].starts_with("88.000000,3,"), "{csv}");
        assert!(lines[1].contains("\"gpu 3: window rates"), "reason must be quoted: {csv}");
        let doc = fleet_decisions_to_json(std::slice::from_ref(&d));
        let parsed = json::parse(&doc.to_string()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("gpu").unwrap().as_i64(), Some(3));
        assert_eq!(row.get("migrated").unwrap().as_i64(), Some(17));
        assert_eq!(row.get("downtime_s").unwrap().as_f64(), Some(2.75));
        assert!(fleet_decisions_to_csv(&[]).lines().count() == 1, "empty log is just the header");
    }

    #[test]
    fn fault_timeline_export_csv_and_json() {
        use crate::cluster::FaultRecord;
        let rows = [
            FaultRecord {
                t: 42.5,
                gpu: 1,
                class: None,
                down_s: 30.0,
                lost: 3,
                retried: 17,
                shed: 2,
            },
            FaultRecord {
                t: 80.0,
                gpu: 0,
                class: Some(1),
                down_s: f64::INFINITY,
                lost: 0,
                retried: 5,
                shed: 0,
            },
        ];
        let csv = fault_records_to_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], FAULT_CSV_HEADER);
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with("42.500000,1,gpu,30.000000,3,17,2"), "{csv}");
        assert!(lines[2].starts_with("80.000000,0,1,inf,0,5,0"), "{csv}");
        let doc = fault_records_to_json(&rows);
        let parsed = json::parse(&doc.to_string()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("gpu").unwrap().as_i64(), Some(1));
        assert!(
            matches!(arr[0].get("class"), Some(Json::Null)),
            "whole-GPU crash has null class"
        );
        assert_eq!(arr[0].get("down_s").unwrap().as_f64(), Some(30.0));
        assert_eq!(arr[0].get("retried").unwrap().as_i64(), Some(17));
        assert_eq!(arr[1].get("class").unwrap().as_f64(), Some(1.0));
        assert!(
            matches!(arr[1].get("down_s"), Some(Json::Null)),
            "permanent outage is null in JSON"
        );
        assert_eq!(fault_records_to_csv(&[]).lines().count(), 1, "empty log is just the header");
    }

    #[test]
    fn tenant_accounting_export_csv_and_json() {
        use crate::cluster::TenantOutcome;
        let t = TenantOutcome {
            name: "gold".into(),
            weight: 3.0,
            classes: vec![0, 2],
            arrived: 1000,
            completed: 990,
            slo_violations: 40,
            failed: 6,
            lost_in_crash: 4,
            retried: 12,
            shed_deadline: 7,
            shed_capacity: 2,
            shed_brownout: 1,
            goodput_rps: 9.5,
            slo_violation_frac: 40.0 / 990.0,
            norm_goodput_rps: 9.5 / 3.0,
        };
        let csv = tenant_outcomes_to_csv(&[("rolling/seed2024".to_string(), t.clone())]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], TENANT_CSV_HEADER);
        assert!(
            lines[1].starts_with("rolling/seed2024,gold,3,1000,990,40,6,4,12,7,2,1,"),
            "{csv}"
        );
        let doc = tenant_outcomes_to_json(std::slice::from_ref(&t));
        let parsed = json::parse(&doc.to_string()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("name").unwrap().as_str(), Some("gold"));
        assert_eq!(row.get("weight").unwrap().as_f64(), Some(3.0));
        assert_eq!(row.get("classes").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(row.get("arrived").unwrap().as_i64(), Some(1000));
        assert_eq!(row.get("lost_in_crash").unwrap().as_i64(), Some(4));
        assert_eq!(row.get("shed_deadline").unwrap().as_i64(), Some(7));
        assert_eq!(row.get("shed_capacity").unwrap().as_i64(), Some(2));
        assert_eq!(row.get("shed_brownout").unwrap().as_i64(), Some(1));
        assert_eq!(row.get("goodput_rps").unwrap().as_f64(), Some(9.5));
        assert_eq!(
            tenant_outcomes_to_csv(&[]).lines().count(),
            1,
            "empty accounting is just the header"
        );
    }

    #[test]
    fn series_csv_long_format() {
        let mut set = SeriesSet::new();
        let mut s = Series::new("power_w").with_tag("gi", "2g.20gb");
        s.push(0.5, 120.0);
        set.add(s);
        let out = series_to_csv(&set);
        assert!(out.contains("power_w,gi=2g.20gb,0.500000,120.000000"));
    }
}
