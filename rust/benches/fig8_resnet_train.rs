//! Fig 8 (appendix): ResNet-50 training on A100 GPU instances vs batch
//! size — throughput, GRACT, memory, energy.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, maybe_write_csv, print_series, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::profiler::session::ProfileSession;
use migperf::profiler::task::{BenchTask, SweepAxis};
use migperf::workload::spec::WorkloadKind;

fn main() {
    banner("Figure 8", "ResNet-50 training on A100 GIs vs batch size (appendix B)");
    let task = BenchTask {
        name: "fig8".into(),
        gpu: GpuModel::A100_80GB,
        gi_profiles: vec![
            "1g.10gb".into(),
            "2g.20gb".into(),
            "3g.40gb".into(),
            "7g.80gb".into(),
        ],
        model: "resnet50".into(),
        kind: WorkloadKind::Training,
        batch: 32,
        seq: 224,
        sweep: SweepAxis::Batch(vec![8, 16, 32, 64, 128]),
        iterations: 100,
        layout: Default::default(),
    };
    let report = ProfileSession::default().run(&task).expect("fig8 session");
    print_series(&report, "(a) throughput img/s", |s| s.throughput, "batch", false);
    print_series(&report, "(b) GRACT", |s| s.mean_gract, "batch", false);
    print_series(&report, "(c) FB used MiB", |s| s.peak_fb_mib, "batch", false);
    print_series(&report, "(d) energy J (100 steps)", |s| s.energy_j, "batch", false);
    maybe_write_csv("fig8", &report);
    println!();

    let get = |inst: &str, batch: u32, f: fn(&migperf::metrics::collector::RunSummary) -> f64| {
        report
            .rows()
            .iter()
            .find(|r| r.instance == inst && r.batch == batch)
            .map(|r| f(&r.summary))
            .unwrap()
    };
    shape_check(
        "1g throughput saturates (Fig 8a)",
        get("1g.10gb", 128, |s| s.throughput) / get("1g.10gb", 32, |s| s.throughput) < 1.2,
    );
    shape_check(
        "larger GI → higher throughput at batch 64 (Fig 8a)",
        get("7g.80gb", 64, |s| s.throughput) > get("1g.10gb", 64, |s| s.throughput) * 2.0,
    );
    shape_check(
        "larger GI → less energy (Fig 8d)",
        get("7g.80gb", 32, |s| s.energy_j) < get("1g.10gb", 32, |s| s.energy_j),
    );
    // ResNet-50 activations dominate: training batch 128 must OOM on 1g.
    let oom_row = report
        .rows()
        .iter()
        .find(|r| r.instance == "1g.10gb" && r.batch == 128);
    shape_check(
        "ResNet-50 b128 training does not fit 1g.10gb (skipped as OOM)",
        oom_row.map(|r| r.skipped.is_some()).unwrap_or(false),
    );
}
