//! Simulated CUDA runtime device enumeration on MIG.
//!
//! The key (and at the time of the paper, surprising) semantics:
//!
//! * with MIG **disabled**, each physical GPU enumerates as one device;
//! * with MIG **enabled**, a process can address **at most one** MIG
//!   compute instance — by default the first CI of the first GI
//!   ("MIG 0"). Other GIs exist but are invisible to the process unless
//!   `CUDA_VISIBLE_DEVICES` pins it to exactly one MIG UUID;
//! * pinning to a MIG UUID makes *that* instance device 0 and hides
//!   everything else.

use crate::mig::controller::MigController;

/// A device visible to one process, as the CUDA runtime would report it.
#[derive(Debug, Clone, PartialEq)]
pub struct VisibleDevice {
    /// CUDA device ordinal within the process.
    pub ordinal: u32,
    /// Device name string.
    pub name: String,
    /// MIG UUID if this is a MIG instance.
    pub mig_uuid: Option<String>,
}

/// Per-process CUDA environment (the subset that matters here).
#[derive(Debug, Clone, Default)]
pub struct ProcessEnv {
    /// `CUDA_VISIBLE_DEVICES`, if set: either GPU ordinals or MIG UUIDs.
    pub cuda_visible_devices: Option<String>,
}

/// Enumerate devices for a process, given the state of the GPU(s).
///
/// `controllers` is the host's GPU set (one controller per physical GPU).
pub fn enumerate(controllers: &[&MigController], env: &ProcessEnv) -> Vec<VisibleDevice> {
    // Explicit MIG-UUID pinning: expose exactly the named instances (CUDA
    // actually honors only the first MIG UUID; we model that too).
    if let Some(visible) = &env.cuda_visible_devices {
        let mut out = Vec::new();
        for token in visible.split(',').map(str::trim) {
            if token.starts_with("MIG-") {
                for ctl in controllers {
                    for gi in ctl.list_instances() {
                        if gi.uuid == token && !gi.compute_instances.is_empty() {
                            out.push(VisibleDevice {
                                ordinal: out.len() as u32,
                                name: format!("{} ({})", ctl.model(), gi.profile.name),
                                mig_uuid: Some(gi.uuid.clone()),
                            });
                        }
                    }
                }
                // CUDA limitation: only the FIRST MIG device is usable.
                if !out.is_empty() {
                    return out.into_iter().take(1).collect();
                }
            } else if let Ok(ord) = token.parse::<usize>() {
                if let Some(ctl) = controllers.get(ord) {
                    out.extend(enumerate_one(ctl, out.len() as u32));
                }
            }
        }
        return out;
    }
    // Default: walk physical GPUs in order.
    let mut out = Vec::new();
    for ctl in controllers {
        out.extend(enumerate_one(ctl, out.len() as u32));
        // With MIG enabled anywhere, CUDA stops after the first MIG
        // instance: a process cannot address more than one.
        if ctl.mig_enabled() && !out.is_empty() {
            return out;
        }
    }
    out
}

fn enumerate_one(ctl: &MigController, base_ordinal: u32) -> Vec<VisibleDevice> {
    if !ctl.mig_enabled() {
        return vec![VisibleDevice {
            ordinal: base_ordinal,
            name: ctl.model().to_string(),
            mig_uuid: None,
        }];
    }
    // MIG on: only the first GI that has a CI is visible, as "MIG 0".
    for gi in ctl.list_instances() {
        if !gi.compute_instances.is_empty() {
            return vec![VisibleDevice {
                ordinal: base_ordinal,
                name: format!("{} ({})", ctl.model(), gi.profile.name),
                mig_uuid: Some(gi.uuid.clone()),
            }];
        }
    }
    Vec::new() // MIG on but no GI/CI: nothing to enumerate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::GpuModel;

    fn two_gi_a30() -> MigController {
        let mut c = MigController::new(GpuModel::A30_24GB);
        c.enable_mig().unwrap();
        let a = c.create_instance("1g.6gb").unwrap();
        let b = c.create_instance("1g.6gb").unwrap();
        c.create_default_ci(a).unwrap();
        c.create_default_ci(b).unwrap();
        c
    }

    #[test]
    fn mig_disabled_enumerates_whole_gpu() {
        let c = MigController::new(GpuModel::A30_24GB);
        let devs = enumerate(&[&c], &ProcessEnv::default());
        assert_eq!(devs.len(), 1);
        assert!(devs[0].mig_uuid.is_none());
    }

    #[test]
    fn paper_table1_only_mig0_visible() {
        // Two GIs exist, but a default process sees at most MIG 0.
        let c = two_gi_a30();
        let devs = enumerate(&[&c], &ProcessEnv::default());
        assert_eq!(devs.len(), 1, "only one MIG device per process");
        let uuid = devs[0].mig_uuid.as_ref().unwrap();
        assert!(uuid.contains("/0/"), "must be the first GI: {uuid}");
    }

    #[test]
    fn pinning_reaches_mig1() {
        let c = two_gi_a30();
        let gi1_uuid = c.list_instances()[1].uuid.clone();
        let env = ProcessEnv { cuda_visible_devices: Some(gi1_uuid.clone()) };
        let devs = enumerate(&[&c], &env);
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].mig_uuid.as_deref(), Some(gi1_uuid.as_str()));
    }

    #[test]
    fn pinning_two_uuids_only_first_usable() {
        let c = two_gi_a30();
        let u0 = c.list_instances()[0].uuid.clone();
        let u1 = c.list_instances()[1].uuid.clone();
        let env = ProcessEnv { cuda_visible_devices: Some(format!("{u0},{u1}")) };
        let devs = enumerate(&[&c], &env);
        assert_eq!(devs.len(), 1, "CUDA exposes only the first MIG instance");
        assert_eq!(devs[0].mig_uuid.as_deref(), Some(u0.as_str()));
    }

    #[test]
    fn gi_without_ci_is_invisible() {
        let mut c = MigController::new(GpuModel::A30_24GB);
        c.enable_mig().unwrap();
        c.create_instance("1g.6gb").unwrap(); // no CI
        let devs = enumerate(&[&c], &ProcessEnv::default());
        assert!(devs.is_empty());
    }

    #[test]
    fn multi_gpu_without_mig() {
        let a = MigController::for_gpu(GpuModel::A30_24GB, 0);
        let b = MigController::for_gpu(GpuModel::A30_24GB, 1);
        let devs = enumerate(&[&a, &b], &ProcessEnv::default());
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[1].ordinal, 1);
    }

    #[test]
    fn ordinal_selection() {
        let a = MigController::for_gpu(GpuModel::A30_24GB, 0);
        let b = MigController::for_gpu(GpuModel::A30_24GB, 1);
        let env = ProcessEnv { cuda_visible_devices: Some("1".into()) };
        let devs = enumerate(&[&a, &b], &env);
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].ordinal, 0, "pinned device becomes ordinal 0");
    }
}
