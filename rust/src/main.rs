//! `migperf` CLI: partition GPUs, run benchmarks, compare sharing modes,
//! probe framework compatibility, export results.

use std::process::ExitCode;

use migperf::coordinator::{Client, Coordinator};
use migperf::frameworks::{run_serving_matrix, run_training_matrix};
use migperf::metrics::export;
use migperf::mig::controller::MigController;
use migperf::mig::gpu::GpuModel;
use migperf::models::zoo;
use migperf::profiler::session::ProfileSession;
use migperf::profiler::task::{BenchTask, SweepAxis};
use migperf::util::argparse::{render_help, Args, OptSpec};
use migperf::util::table::Table;
use migperf::workload::spec::WorkloadKind;

const BOOL_FLAGS: &[&str] =
    &["help", "json", "csv", "real", "decisions", "bless", "faults", "strict"];

fn main() -> ExitCode {
    let args = match Args::parse(std::env::args().skip(1), BOOL_FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.command.as_deref() {
        Some("partition") => cmd_partition(&args),
        Some("bench") => cmd_bench(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("compat") => cmd_compat(&args),
        Some("profiles") => cmd_profiles(&args),
        Some("suite") => cmd_suite(&args),
        Some("plan") => cmd_plan(&args),
        Some("orchestrate") => cmd_orchestrate(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("fuzz") => cmd_fuzz(&args),
        Some("bench-check") => cmd_bench_check(&args),
        Some("layouts") => cmd_layouts(&args),
        Some("lint") => cmd_lint(&args),
        Some("version") => {
            println!("migperf {}", migperf::version());
            Ok(())
        }
        _ => {
            print_usage();
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "migperf {} — MIG benchmark framework\n\n\
         USAGE:\n  migperf <COMMAND> [OPTIONS]\n\n\
         COMMANDS:\n  \
         partition   validate and show a MIG partition layout\n  \
         profiles    list GI profiles for a GPU model\n  \
         bench       run a training/inference benchmark sweep\n  \
         sweep       parallel serving-config sweep (model × batch × mode × rate × seed)\n  \
         compat      framework compatibility matrix (paper Tables 1–2)\n  \
         suite       run a JSON task suite through the coordinator\n  \
         layouts     enumerate all valid maximal MIG layouts\n  \
         plan        optimize a hybrid train+serve partition (paper §5)\n  \
         orchestrate online repartitioning policies under diurnal load\n  \
         fleet       multi-GPU fleet simulation (policy × router × fleet-size grids)\n  \
         fuzz        model-based fuzzing of the fleet engine (random command sequences)\n  \
         bench-check compare a bench record against its checked-in baseline\n  \
         lint        determinism-aware static analysis over the repo's own sources\n  \
         version     print the version\n\n\
         Run `migperf <COMMAND> --help` for command options.",
        migperf::version()
    );
}

fn parse_gpu(args: &Args) -> Result<GpuModel, String> {
    let name = args.str_or("gpu", "a100");
    GpuModel::parse(&name).ok_or_else(|| format!("unknown GPU '{name}' (use a100 or a30)"))
}

fn cmd_profiles(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help("migperf", "profiles", "List GI profiles for a GPU model", &[OptSpec {
                name: "gpu",
                value: "MODEL",
                help: "GPU model (a100 | a30)",
                default: Some("a100"),
            }])
        );
        return Ok(());
    }
    let gpu = parse_gpu(args)?;
    let mut t = Table::new(&["profile", "compute", "memory_gib", "max_count", "placements"]);
    for p in migperf::mig::profile::profiles_for(gpu) {
        t.row(&[
            p.name.to_string(),
            p.slice_notation(gpu),
            format!("{:.2}", p.memory_gib),
            p.max_count.to_string(),
            format!("{:?}", p.placements),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help("migperf", "partition", "Validate and show a MIG partition", &[
                OptSpec { name: "gpu", value: "MODEL", help: "GPU model", default: Some("a100") },
                OptSpec {
                    name: "gi",
                    value: "P1,P2,...",
                    help: "comma-separated GI profiles to create",
                    default: Some("1g.10gb"),
                },
            ])
        );
        return Ok(());
    }
    let gpu = parse_gpu(args)?;
    let profiles: Vec<String> =
        args.str_or("gi", "1g.10gb").split(',').map(str::to_string).collect();
    let mut ctl = MigController::new(gpu);
    ctl.enable_mig().map_err(|e| e.to_string())?;
    for p in &profiles {
        ctl.create_instance(p).map_err(|e| e.to_string())?;
    }
    let mut t = Table::new(&["gi", "profile", "slices", "memory_gib", "uuid"]);
    for gi in ctl.list_instances() {
        t.row(&[
            format!("{}", gi.id.0),
            gi.profile.name.to_string(),
            gi.profile.slice_notation(gpu),
            format!("{:.2}", gi.profile.memory_gib),
            gi.uuid.clone(),
        ]);
    }
    println!("{}", t.render());
    let avail: Vec<&str> = ctl.available_profiles().iter().map(|p| p.name).collect();
    println!("still placeable: {avail:?}");
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help("migperf", "bench", "Run a benchmark sweep on MIG instances", &[
                OptSpec { name: "gpu", value: "MODEL", help: "GPU model", default: Some("a100") },
                OptSpec { name: "model", value: "NAME", help: "model from the zoo", default: Some("bert-base") },
                OptSpec { name: "kind", value: "K", help: "training | inference", default: Some("inference") },
                OptSpec { name: "gi", value: "P1,P2", help: "GI profiles (one instance each)", default: Some("1g.10gb,7g.80gb") },
                OptSpec { name: "batch", value: "B1,B2", help: "batch-size sweep", default: Some("1,8,32") },
                OptSpec { name: "seq", value: "S", help: "sequence length", default: Some("128") },
                OptSpec { name: "iters", value: "N", help: "steps/requests per point", default: Some("100") },
                OptSpec { name: "workers", value: "N", help: "sweep worker threads (0 = auto)", default: Some("0") },
                OptSpec { name: "json", value: "", help: "emit JSON instead of a table", default: None },
                OptSpec { name: "csv", value: "", help: "emit CSV instead of a table", default: None },
                OptSpec { name: "leaderboard", value: "FILE", help: "append results to a leaderboard JSON and print rankings", default: None },
            ])
        );
        return Ok(());
    }
    let gpu = parse_gpu(args)?;
    let model = args.str_or("model", "bert-base");
    if zoo::lookup(&model).is_none() {
        let names: Vec<&str> = zoo::ZOO.iter().map(|m| m.name).collect();
        return Err(format!("unknown model '{model}'; available: {names:?}"));
    }
    let kind = match args.str_or("kind", "inference").as_str() {
        "training" | "train" => WorkloadKind::Training,
        "inference" | "infer" => WorkloadKind::Inference,
        other => return Err(format!("unknown kind '{other}'")),
    };
    let default_gi = match gpu {
        GpuModel::A100_80GB => "1g.10gb,7g.80gb",
        GpuModel::A30_24GB => "1g.6gb,4g.24gb",
    };
    let gi_profiles: Vec<String> =
        args.str_or("gi", default_gi).split(',').map(str::to_string).collect();
    let batches = args.list_or("batch", &[1u32, 8, 32]).map_err(|e| e.to_string())?;
    let task = BenchTask {
        name: format!("{model}-{:?}", kind).to_lowercase(),
        gpu,
        gi_profiles,
        model,
        kind,
        batch: batches[0],
        seq: args.parse_or("seq", 128u32).map_err(|e| e.to_string())?,
        sweep: SweepAxis::Batch(batches),
        iterations: args.parse_or("iters", 100u64).map_err(|e| e.to_string())?,
        layout: Default::default(),
    };
    let workers: usize = args.parse_or("workers", 0usize).map_err(|e| e.to_string())?;
    let mut session = ProfileSession::default();
    if workers > 0 {
        session = session.with_engine(migperf::sweep::SweepEngine::new(workers));
    }
    let report = session.run(&task).map_err(|e| e.to_string())?;
    if let Some(board_path) = args.get("leaderboard") {
        use migperf::leaderboard::{Entry, Leaderboard, Rank};
        let path = std::path::Path::new(board_path);
        let mut board = if path.exists() {
            Leaderboard::load(path)?
        } else {
            Leaderboard::new()
        };
        let workload = match task.kind {
            WorkloadKind::Training => "training",
            WorkloadKind::Inference => "inference",
        };
        for r in report.rows().iter().filter(|r| r.skipped.is_none()) {
            board.submit(Entry {
                submitter: "migperf-cli".into(),
                model: task.model.clone(),
                workload: workload.into(),
                device: format!("{}/{}", args.str_or("gpu", "a100"), r.instance),
                batch: r.batch,
                summary: r.summary.clone(),
            });
        }
        board.save(path).map_err(|e| e.to_string())?;
        println!("{}", board.render_markdown(&task.model, workload, Rank::Throughput));
    }
    if args.flag("json") {
        println!("{}", report.to_json().to_pretty());
    } else if args.flag("csv") {
        let rows: Vec<_> = report.rows().iter().map(|r| r.summary.clone()).collect();
        print!("{}", export::summaries_to_csv(&rows));
    } else {
        println!("{}", report.render_table());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help(
                "migperf",
                "sweep",
                "Fan a serving-configuration grid across the parallel sweep engine",
                &[
                    OptSpec { name: "gpu", value: "MODEL", help: "GPU model (a100 | a30)", default: Some("a30") },
                    OptSpec { name: "model", value: "M1,M2", help: "models from the zoo", default: Some("resnet50") },
                    OptSpec { name: "batch", value: "B1,B2", help: "batch sizes", default: Some("1,8") },
                    OptSpec { name: "mode", value: "mig,mps", help: "sharing modes", default: Some("mig,mps") },
                    OptSpec { name: "rate", value: "R1,R2", help: "req/s per server (0 = closed loop)", default: Some("0") },
                    OptSpec { name: "tenants", value: "N", help: "co-located servers", default: Some("2") },
                    OptSpec { name: "gi", value: "P", help: "MIG profile per tenant", default: None },
                    OptSpec { name: "requests", value: "N", help: "requests per server per point", default: Some("500") },
                    OptSpec { name: "seeds", value: "N", help: "replication seeds per point", default: Some("1") },
                    OptSpec { name: "seed", value: "S", help: "base seed", default: Some("2024") },
                    OptSpec { name: "seq", value: "S", help: "sequence length / image size", default: Some("224") },
                    OptSpec { name: "workers", value: "N", help: "worker threads (0 = auto)", default: Some("0") },
                    OptSpec { name: "json", value: "", help: "emit JSON instead of a table", default: None },
                ]
            )
        );
        return Ok(());
    }
    use migperf::sharing::mps::MpsModel;
    use migperf::simgpu::resource::ExecResource;
    use migperf::sweep::SweepEngine;
    use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
    use migperf::workload::spec::WorkloadSpec;

    let gpu = {
        let name = args.str_or("gpu", "a30");
        GpuModel::parse(&name).ok_or_else(|| format!("unknown GPU '{name}' (use a100 or a30)"))?
    };
    let models: Vec<String> = args
        .str_or("model", "resnet50")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    for m in &models {
        if zoo::lookup(m).is_none() {
            let names: Vec<&str> = zoo::ZOO.iter().map(|d| d.name).collect();
            return Err(format!("unknown model '{m}'; available: {names:?}"));
        }
    }
    let batches: Vec<u32> = args.list_or("batch", &[1u32, 8]).map_err(|e| e.to_string())?;
    let modes: Vec<String> = args
        .str_or("mode", "mig,mps")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let rates: Vec<f64> = args.list_or("rate", &[0.0f64]).map_err(|e| e.to_string())?;
    let tenants: u32 = args.parse_or("tenants", 2u32).map_err(|e| e.to_string())?;
    let requests: u64 = args.parse_or("requests", 500u64).map_err(|e| e.to_string())?;
    let nseeds: usize = args.parse_or("seeds", 1usize).map_err(|e| e.to_string())?;
    let base_seed: u64 = args.parse_or("seed", 2024u64).map_err(|e| e.to_string())?;
    let seq: u32 = args.parse_or("seq", 224u32).map_err(|e| e.to_string())?;
    let workers: usize = args.parse_or("workers", 0usize).map_err(|e| e.to_string())?;

    // Build (and rule-check) the MIG partition once, if any point needs it.
    let mig_resources: Vec<ExecResource> = if modes.iter().any(|m| m == "mig") {
        let default_gi = match gpu {
            GpuModel::A100_80GB => "1g.10gb",
            GpuModel::A30_24GB => {
                if tenants <= 2 {
                    "2g.12gb"
                } else {
                    "1g.6gb"
                }
            }
        };
        let profile = args.str_or("gi", default_gi);
        let mut ctl = MigController::new(gpu);
        ctl.enable_mig().map_err(|e| e.to_string())?;
        let gis = ctl.partition_uniform(&profile, tenants).map_err(|e| e.to_string())?;
        gis.iter()
            .map(|id| ExecResource::from_gi(gpu, ctl.instance(*id).unwrap().profile))
            .collect()
    } else {
        Vec::new()
    };

    // Materialize the grid in row-major order: the fixed point order is
    // what makes the sweep deterministic at any worker count.
    let seed_list = migperf::sweep::seeds(base_seed, nseeds.max(1));
    let mut sims: Vec<ServingSim> = Vec::new();
    let mut meta: Vec<(String, u32, String, f64, u64)> = Vec::new();
    for model in &models {
        let desc = zoo::lookup(model).unwrap();
        for &batch in &batches {
            for mode in &modes {
                let sharing = match mode.as_str() {
                    "mig" => SharingMode::Mig(mig_resources.clone()),
                    "mps" => SharingMode::Mps {
                        gpu: ExecResource::whole_gpu(gpu),
                        n_clients: tenants,
                        model: MpsModel::default(),
                    },
                    other => return Err(format!("unknown sharing mode '{other}' (mig|mps)")),
                };
                for &rate in &rates {
                    let load = if rate > 0.0 {
                        LoadMode::OpenPoisson { rate, requests_per_server: requests }
                    } else {
                        LoadMode::Closed { requests_per_server: requests }
                    };
                    for &seed in &seed_list {
                        sims.push(ServingSim {
                            mode: sharing.clone(),
                            load: load.clone(),
                            spec: WorkloadSpec::inference(desc, batch, seq),
                            seed,
                        });
                        meta.push((model.clone(), batch, mode.clone(), rate, seed));
                    }
                }
            }
        }
    }

    let engine = if workers > 0 {
        SweepEngine::new(workers)
    } else {
        SweepEngine::from_env()
    };
    #[allow(clippy::disallowed_methods)] // CLI wall timing, never checksummed
    let started = std::time::Instant::now();
    let outs = migperf::sweep::run_serving(&engine, &sims).map_err(|e| e.to_string())?;
    let wall_s = started.elapsed().as_secs_f64();

    if args.flag("json") {
        use migperf::util::json::Json;
        let rows: Vec<Json> = meta
            .iter()
            .zip(&outs)
            .map(|((model, batch, mode, rate, seed), out)| {
                Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("batch", Json::Num(*batch as f64)),
                    ("mode", Json::Str(mode.clone())),
                    ("rate", Json::Num(*rate)),
                    ("seed", Json::Num(*seed as f64)),
                    ("completed", Json::Num(out.pooled.completed as f64)),
                    ("avg_latency_ms", Json::Num(out.pooled.avg_latency_ms)),
                    ("p50_latency_ms", Json::Num(out.pooled.p50_latency_ms)),
                    ("p99_latency_ms", Json::Num(out.pooled.p99_latency_ms)),
                    ("throughput", Json::Num(out.pooled.throughput)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("grid_points", Json::Num(sims.len() as f64)),
            ("workers", Json::Num(engine.workers() as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("rows", Json::Arr(rows)),
        ]);
        println!("{}", doc.to_pretty());
    } else {
        let mut t = Table::new(&[
            "model", "batch", "mode", "rate", "seed", "p50_ms", "p99_ms", "tput",
        ]);
        for ((model, batch, mode, rate, seed), out) in meta.iter().zip(&outs) {
            t.row(&[
                model.clone(),
                batch.to_string(),
                mode.clone(),
                if *rate > 0.0 {
                    format!("{rate}")
                } else {
                    "closed".into()
                },
                seed.to_string(),
                format!("{:.2}", out.pooled.p50_latency_ms),
                format!("{:.2}", out.pooled.p99_latency_ms),
                format!("{:.1}", out.pooled.throughput),
            ]);
        }
        println!("{}", t.render());
        println!(
            "{} grid points on {} workers in {:.2}s",
            sims.len(),
            engine.workers(),
            wall_s
        );
    }
    Ok(())
}

fn cmd_compat(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!("Reproduce the paper's framework-compatibility matrix (Tables 1–2).");
        return Ok(());
    }
    let mut t1 = Table::new(&[
        "Training framework",
        "Version",
        "Visible device count",
        "Training on MIG 0",
        "Training on MIG 1",
    ]);
    for r in run_training_matrix() {
        t1.row(&[
            r.framework.to_string(),
            r.version.to_string(),
            r.visible_device_count.to_string(),
            if r.works_on_mig0 { "Yes" } else { "No" }.to_string(),
            if r.works_on_mig1 { "Yes" } else { "No device" }
                .to_string(),
        ]);
    }
    println!("Table 1. Training framework compatibility with MIG.\n{}", t1.render());
    let mut t2 =
        Table::new(&["Serving framework", "Version", "Serving on MIG 0", "Serving on MIG 1"]);
    for r in run_serving_matrix() {
        t2.row(&[
            r.framework.to_string(),
            r.version.to_string(),
            if r.works_on_mig0 { "Yes" } else { "No" }.to_string(),
            if r.works_on_mig1 { "Yes" } else { "Device not found" }
                .to_string(),
        ]);
    }
    println!("Table 2. Serving framework compatibility with MIG.\n{}", t2.render());
    Ok(())
}

fn cmd_layouts(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!("Enumerate every valid maximal MIG layout for --gpu (a100|a30).");
        return Ok(());
    }
    let gpu = parse_gpu(args)?;
    let layouts = migperf::mig::enumerate::maximal_layouts(gpu);
    let mut t = Table::new(&["#", "layout", "instances", "compute slices"]);
    for (i, l) in layouts.iter().enumerate() {
        t.row(&[
            i.to_string(),
            l.profile_names().join(" + "),
            l.len().to_string(),
            format!("{}/{}", l.compute_slices(), gpu.spec().compute_slices),
        ]);
    }
    println!("{}", t.render());
    println!("{} maximal layouts on {}", layouts.len(), gpu);
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help("migperf", "plan", "Optimize a hybrid train+serve MIG partition", &[
                OptSpec { name: "gpu", value: "MODEL", help: "GPU model", default: Some("a100") },
                OptSpec { name: "train", value: "MODEL:BATCH", help: "training workload", default: Some("bert-base:32") },
                OptSpec { name: "serve", value: "MODEL:BATCH:SLO_MS,...", help: "inference services", default: Some("resnet50:4:15,resnet50:4:15") },
                OptSpec { name: "objective", value: "O", help: "throughput | energy", default: Some("throughput") },
            ])
        );
        return Ok(());
    }
    use migperf::scheduler::{Objective, Scheduler, SloWorkload};
    use migperf::workload::spec::WorkloadSpec;
    let gpu = parse_gpu(args)?;
    let mut workloads = Vec::new();
    let parse_model = |name: &str| {
        zoo::lookup(name).ok_or_else(|| format!("unknown model '{name}'"))
    };
    let train = args.str_or("train", "bert-base:32");
    if !train.is_empty() && train != "none" {
        let (m, b) = train.split_once(':').ok_or("train format: MODEL:BATCH")?;
        let batch: u32 = b.parse().map_err(|_| "bad train batch")?;
        let spec = WorkloadSpec::training(parse_model(m)?, batch, 128);
        workloads.push(SloWorkload::best_effort(spec));
    }
    let serve = args.str_or("serve", "resnet50:4:15,resnet50:4:15");
    for svc in serve.split(',').filter(|s| !s.is_empty()) {
        let parts: Vec<&str> = svc.split(':').collect();
        if parts.len() != 3 {
            return Err("serve format: MODEL:BATCH:SLO_MS".into());
        }
        let batch: u32 = parts[1].parse().map_err(|_| "bad serve batch")?;
        let slo: f64 = parts[2].parse().map_err(|_| "bad SLO")?;
        workloads.push(SloWorkload::with_slo(
            WorkloadSpec::inference(parse_model(parts[0])?, batch, 224),
            slo,
        ));
    }
    let objective = match args.str_or("objective", "throughput").as_str() {
        "throughput" => Objective::MaxThroughput,
        "energy" => Objective::MinEnergy,
        o => return Err(format!("unknown objective '{o}'")),
    };
    let sched = Scheduler::new(gpu);
    match sched.plan(&workloads, objective) {
        None => {
            println!("no feasible plan: SLOs or memory cannot be satisfied on {gpu}");
            Err("infeasible".into())
        }
        Some(plan) => {
            println!("layout: {:?}\n", plan.layout);
            let mut t =
                Table::new(&["workload", "profile", "latency_ms", "tput", "goodput", "power_w"]);
            for a in &plan.assignments {
                let w = &workloads[a.workload];
                t.row(&[
                    w.spec.label()
                        + &w.slo_ms.map(|s| format!(" (SLO {s}ms)")).unwrap_or_default(),
                    a.profile.to_string(),
                    format!("{:.2}", a.latency_ms),
                    format!("{:.1}", a.throughput),
                    format!("{:.1}", a.goodput),
                    format!("{:.1}", a.power_w),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
    }
}

fn cmd_orchestrate(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help(
                "migperf",
                "orchestrate",
                "Compare online MIG repartitioning policies under time-varying load",
                &[
                    OptSpec { name: "gpu", value: "MODEL", help: "GPU model (a100 | a30)", default: Some("a100") },
                    OptSpec { name: "policy", value: "P1,P2", help: "static | reactive | predictive | all", default: Some("all") },
                    OptSpec { name: "train", value: "MODEL:BATCH", help: "co-located training job (none to disable)", default: Some("bert-base:32") },
                    OptSpec { name: "serve", value: "MODEL:BATCH:SLO_MS,...", help: "inference services", default: Some("bert-base:8:40,bert-base:8:40") },
                    OptSpec { name: "base-rate", value: "R", help: "diurnal trough rate, req/s per service", default: Some("6") },
                    OptSpec { name: "peak-rate", value: "R", help: "diurnal peak rate (== base for flat Poisson)", default: Some("60") },
                    OptSpec { name: "period", value: "S", help: "diurnal period, seconds", default: Some("600") },
                    OptSpec { name: "duration", value: "S", help: "simulated run length, seconds", default: Some("1200") },
                    OptSpec { name: "window", value: "S", help: "observation window / policy tick, seconds", default: Some("20") },
                    OptSpec { name: "rho", value: "F", help: "planner utilization bound in (0,1)", default: Some("0.75") },
                    OptSpec { name: "churn", value: "S", help: "seconds per instance destroyed/created", default: Some("0.5") },
                    OptSpec { name: "restore", value: "S", help: "training checkpoint-restore penalty, seconds", default: Some("5") },
                    OptSpec { name: "seq", value: "S", help: "sequence length / image size for services", default: Some("128") },
                    OptSpec { name: "seeds", value: "N", help: "replication seeds per policy", default: Some("1") },
                    OptSpec { name: "seed", value: "S", help: "base seed", default: Some("2024") },
                    OptSpec { name: "workers", value: "N", help: "sweep worker threads (0 = auto)", default: Some("0") },
                    OptSpec { name: "json", value: "", help: "emit JSON (with decision logs)", default: None },
                    OptSpec { name: "csv", value: "", help: "emit pooled summaries as CSV", default: None },
                    OptSpec { name: "decisions", value: "", help: "also print per-run decision logs", default: None },
                ]
            )
        );
        return Ok(());
    }
    use migperf::orchestrator::{OrchestratorConfig, PolicyKind, ReconfigCost, ServiceConfig};
    use migperf::sweep::SweepEngine;
    use migperf::util::json::Json;
    use migperf::workload::arrival::ArrivalSpec;
    use migperf::workload::spec::WorkloadSpec;

    let gpu = parse_gpu(args)?;
    let policy_arg = args.str_or("policy", "all");
    let policies: Vec<PolicyKind> = if policy_arg == "all" {
        vec![
            PolicyKind::parse("static").unwrap(),
            PolicyKind::parse("reactive").unwrap(),
            PolicyKind::parse("predictive").unwrap(),
        ]
    } else {
        policy_arg
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|name| {
                PolicyKind::parse(name)
                    .ok_or_else(|| format!("unknown policy '{name}' (static|reactive|predictive)"))
            })
            .collect::<Result<_, _>>()?
    };
    if policies.is_empty() {
        return Err("no policy selected".into());
    }
    let parse_model =
        |name: &str| zoo::lookup(name).ok_or_else(|| format!("unknown model '{name}'"));
    let train = {
        let t = args.str_or("train", "bert-base:32");
        if t.is_empty() || t == "none" {
            None
        } else {
            let (m, b) = t.split_once(':').ok_or("train format: MODEL:BATCH")?;
            let batch: u32 = b.parse().map_err(|_| "bad train batch")?;
            Some(WorkloadSpec::training(parse_model(m)?, batch, 128))
        }
    };
    let base_rate: f64 = args.parse_or("base-rate", 6.0f64).map_err(|e| e.to_string())?;
    let peak_rate: f64 = args.parse_or("peak-rate", 60.0f64).map_err(|e| e.to_string())?;
    let period_s: f64 = args.parse_or("period", 600.0f64).map_err(|e| e.to_string())?;
    let arrival = if peak_rate > base_rate {
        ArrivalSpec::Diurnal { base_rate, peak_rate, period_s }
    } else if peak_rate == base_rate {
        ArrivalSpec::Poisson { rate: base_rate }
    } else {
        return Err(format!(
            "--peak-rate {peak_rate} must be at least --base-rate {base_rate}"
        ));
    };
    let seq: u32 = args.parse_or("seq", 128u32).map_err(|e| e.to_string())?;
    let mut services = Vec::new();
    for svc in args
        .str_or("serve", "bert-base:8:40,bert-base:8:40")
        .split(',')
        .filter(|s| !s.is_empty())
    {
        let parts: Vec<&str> = svc.split(':').collect();
        if parts.len() != 3 {
            return Err("serve format: MODEL:BATCH:SLO_MS".into());
        }
        let batch: u32 = parts[1].parse().map_err(|_| "bad serve batch")?;
        let slo_ms: f64 = parts[2].parse().map_err(|_| "bad SLO")?;
        services.push(ServiceConfig {
            spec: WorkloadSpec::inference(parse_model(parts[0])?, batch, seq),
            slo_ms,
            arrival: arrival.clone(),
        });
    }
    let cost = ReconfigCost {
        instance_churn_s: args.parse_or("churn", 0.5f64).map_err(|e| e.to_string())?,
        train_restore_s: args.parse_or("restore", 5.0f64).map_err(|e| e.to_string())?,
    };
    let duration_s: f64 = args.parse_or("duration", 1200.0f64).map_err(|e| e.to_string())?;
    let window_s: f64 = args.parse_or("window", 20.0f64).map_err(|e| e.to_string())?;
    let rho_max: f64 = args.parse_or("rho", 0.75f64).map_err(|e| e.to_string())?;
    let nseeds: usize = args.parse_or("seeds", 1usize).map_err(|e| e.to_string())?;
    let base_seed: u64 = args.parse_or("seed", 2024u64).map_err(|e| e.to_string())?;
    let workers: usize = args.parse_or("workers", 0usize).map_err(|e| e.to_string())?;

    // Policy × seed grid in row-major order (the determinism anchor).
    let seed_list = migperf::sweep::seeds(base_seed, nseeds.max(1));
    let mut runs: Vec<OrchestratorConfig> = Vec::new();
    for policy in &policies {
        for &seed in &seed_list {
            runs.push(OrchestratorConfig {
                gpu,
                train: train.clone(),
                services: services.clone(),
                policy: policy.clone(),
                cost: cost.clone(),
                duration_s,
                window_s,
                rho_max,
                seed,
            });
        }
    }
    let engine = if workers > 0 {
        SweepEngine::new(workers)
    } else {
        SweepEngine::from_env()
    };
    #[allow(clippy::disallowed_methods)] // CLI wall timing, never checksummed
    let started = std::time::Instant::now();
    let outs = migperf::sweep::run_orchestrator(&engine, &runs).map_err(|e| e.to_string())?;
    let wall_s = started.elapsed().as_secs_f64();

    if args.flag("json") {
        let rows: Vec<Json> = runs
            .iter()
            .zip(&outs)
            .map(|(cfg, out)| {
                Json::obj(vec![
                    ("policy", Json::Str(out.policy.to_string())),
                    ("seed", Json::Num(cfg.seed as f64)),
                    ("arrived", Json::Num(out.arrived as f64)),
                    ("completed", Json::Num(out.completed as f64)),
                    ("goodput_rps", Json::Num(out.goodput_rps)),
                    ("slo_violation_frac", Json::Num(out.slo_violation_frac)),
                    ("p99_latency_ms", Json::Num(out.pooled.p99_latency_ms)),
                    ("train_samples_per_s", Json::Num(out.train_samples_per_s)),
                    ("reconfigurations", Json::Num(out.reconfigurations as f64)),
                    ("reconfig_downtime_s", Json::Num(out.reconfig_downtime_s)),
                    ("decisions", export::decisions_to_json(&out.decisions)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::Str("migperf-orchestrate/v1".into())),
            ("gpu", Json::Str(format!("{gpu}"))),
            ("duration_s", Json::Num(duration_s)),
            ("window_s", Json::Num(window_s)),
            ("workers", Json::Num(engine.workers() as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("rows", Json::Arr(rows)),
        ]);
        println!("{}", doc.to_pretty());
    } else if args.flag("csv") {
        let rows: Vec<_> = runs
            .iter()
            .zip(&outs)
            .map(|(cfg, out)| {
                let mut s = out.pooled.clone();
                s.label = format!("{}/seed{}", out.policy, cfg.seed);
                s
            })
            .collect();
        print!("{}", export::summaries_to_csv(&rows));
    } else {
        let mut t = Table::new(&[
            "policy",
            "seed",
            "arrived",
            "completed",
            "goodput_rps",
            "viol_%",
            "p99_ms",
            "train_sps",
            "reconf",
            "downtime_s",
        ]);
        for (cfg, out) in runs.iter().zip(&outs) {
            t.row(&[
                out.policy.to_string(),
                cfg.seed.to_string(),
                out.arrived.to_string(),
                out.completed.to_string(),
                format!("{:.1}", out.goodput_rps),
                format!("{:.2}", out.slo_violation_frac * 100.0),
                format!("{:.1}", out.pooled.p99_latency_ms),
                format!("{:.1}", out.train_samples_per_s),
                out.reconfigurations.to_string(),
                format!("{:.1}", out.reconfig_downtime_s),
            ]);
        }
        println!("{}", t.render());
        println!(
            "{} runs on {} workers in {:.2}s",
            runs.len(),
            engine.workers(),
            wall_s
        );
        if args.flag("decisions") {
            for (cfg, out) in runs.iter().zip(&outs) {
                if out.decisions.is_empty() {
                    continue;
                }
                println!("\ndecision log — {} (seed {}):", out.policy, cfg.seed);
                print!("{}", export::decisions_to_csv(&out.decisions));
            }
        }
    }
    Ok(())
}

/// Parse `--crash` entries: `GPU[.CLASS]@T+DOWN`, comma-separated.
/// `DOWN` is seconds until recovery, or `inf` for a permanent failure.
fn parse_crash_list(spec: &str) -> Result<Vec<migperf::cluster::FaultInjection>, String> {
    let mut out = Vec::new();
    for item in spec.split(',').filter(|s| !s.is_empty()) {
        let err = || format!("crash '{item}': expected GPU[.CLASS]@T+DOWN");
        let (target, rest) = item.split_once('@').ok_or_else(err)?;
        let (t, down) = rest.split_once('+').ok_or_else(err)?;
        let (gpu, class) = match target.split_once('.') {
            Some((g, c)) => {
                (g.parse().map_err(|_| err())?, Some(c.parse().map_err(|_| err())?))
            }
            None => (target.parse().map_err(|_| err())?, None),
        };
        let t: f64 = t.parse().map_err(|_| err())?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("crash '{item}': time {t} must be finite and non-negative"));
        }
        let down_s: f64 = if down == "inf" {
            f64::INFINITY
        } else {
            down.parse().map_err(|_| err())?
        };
        if down_s.is_nan() || down_s <= 0.0 {
            return Err(format!(
                "crash '{item}': downtime must be positive seconds or 'inf'"
            ));
        }
        out.push(migperf::cluster::FaultInjection { t, gpu, class, down_s });
    }
    if out.is_empty() {
        return Err("--crash needs at least one entry".into());
    }
    Ok(out)
}

/// Assemble the overload-protection policy from `--queue-cap`,
/// `--deadline-mult`, `--shed`, `--brownout`, `--breaker` and
/// `--breaker-probes`. The CLI uses `0` as the "off" value for both
/// thresholds; the engine encodes "off" as `+inf`.
fn parse_overload_policy(args: &Args) -> Result<migperf::cluster::OverloadPolicy, String> {
    use migperf::cluster::{OverloadPolicy, ShedDiscipline, DEFAULT_BREAKER_PROBES};
    let queue_cap: usize = args.parse_or("queue-cap", 0usize).map_err(|e| e.to_string())?;
    let deadline_mult: f64 = args.parse_or("deadline-mult", 0.0f64).map_err(|e| e.to_string())?;
    let shed_arg = args.str_or("shed", "reject");
    let shed = ShedDiscipline::parse(&shed_arg)
        .ok_or_else(|| format!("unknown shed discipline '{shed_arg}' (reject|drop)"))?;
    let threshold = |name: &str| -> Result<f64, String> {
        let v: f64 = args.parse_or(name, 0.0f64).map_err(|e| e.to_string())?;
        if v == 0.0 {
            Ok(f64::INFINITY) // disabled
        } else {
            Ok(v)
        }
    };
    let policy = OverloadPolicy {
        queue_cap,
        shed,
        deadline_mult,
        brownout_threshold: threshold("brownout")?,
        breaker_threshold: threshold("breaker")?,
        breaker_probes: args
            .parse_or("breaker-probes", DEFAULT_BREAKER_PROBES)
            .map_err(|e| e.to_string())?,
    };
    policy.validate()?;
    Ok(policy)
}

fn cmd_fleet(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help(
                "migperf",
                "fleet",
                "Simulate a multi-GPU MIG fleet: routing, fleet-wide demand packing, \
                 rolling vs in-place repartitioning",
                &[
                    OptSpec { name: "gpu", value: "MODEL", help: "GPU model for homogeneous fleets (a100 | a30)", default: Some("a100") },
                    OptSpec { name: "fleet", value: "N1,N2", help: "fleet sizes to sweep (homogeneous)", default: Some("4") },
                    OptSpec { name: "gpus", value: "M1,M2", help: "explicit heterogeneous fleet (overrides --gpu/--fleet)", default: None },
                    OptSpec { name: "policy", value: "P1,P2", help: "static | reactive | all", default: Some("all") },
                    OptSpec { name: "router", value: "R1,R2", help: "rr | least | affinity | wf | all", default: Some("least") },
                    OptSpec { name: "mode", value: "M1,M2", help: "rolling | inplace | both", default: Some("rolling") },
                    OptSpec { name: "train", value: "MODEL:BATCH", help: "training job replicated per GPU (none to disable)", default: Some("bert-base:32") },
                    OptSpec { name: "classes", value: "MODEL:BATCH:SLO_MS,...", help: "fleet-wide request classes", default: Some("bert-base:8:40,bert-base:8:40") },
                    OptSpec { name: "tenants", value: "N:W:C[;...]", help: "weighted tenants over class indices, NAME:WEIGHT:CLASS[,CLASS...] joined by ';' (quote it); enables the tenant-weighted demand split and per-tenant reporting (in --csv mode the per-tenant document is emitted under --decisions)", default: None },
                    OptSpec { name: "base-rate", value: "R", help: "diurnal trough rate per GPU per class, req/s (fleet stream = rate × fleet size)", default: Some("6") },
                    OptSpec { name: "peak-rate", value: "R", help: "diurnal peak rate per GPU per class (== base for flat Poisson)", default: Some("60") },
                    OptSpec { name: "period", value: "S", help: "diurnal period, seconds", default: Some("600") },
                    OptSpec { name: "duration", value: "S", help: "simulated run length, seconds", default: Some("600") },
                    OptSpec { name: "window", value: "S", help: "observation window / policy tick, seconds", default: Some("10") },
                    OptSpec { name: "rho", value: "F", help: "planner utilization bound in (0,1)", default: Some("0.75") },
                    OptSpec { name: "churn", value: "S", help: "seconds per instance destroyed/created", default: Some("0.5") },
                    OptSpec { name: "restore", value: "S", help: "training checkpoint-restore penalty, seconds", default: Some("5") },
                    OptSpec { name: "seq", value: "S", help: "sequence length / image size for classes", default: Some("128") },
                    OptSpec { name: "faults", value: "", help: "sweep failure-injection levels: no-faults plus one level per --mtbf value", default: None },
                    OptSpec { name: "mtbf", value: "S1,S2", help: "per-GPU mean time between failures, seconds (each value = one availability level)", default: Some("240,120") },
                    OptSpec { name: "mttr", value: "S", help: "mean time to repair per crash, seconds", default: Some("30") },
                    OptSpec { name: "crash", value: "LIST", help: "explicit crash schedule GPU[.CLASS]@T+DOWN[,...] (DOWN in seconds, inf = permanent); overrides --faults/--mtbf", default: None },
                    OptSpec { name: "retries", value: "N", help: "per-request retry budget after a crash", default: Some("1") },
                    OptSpec { name: "storm-cap", value: "N", help: "max requests re-admitted per crash (0 = unlimited)", default: Some("0") },
                    OptSpec { name: "queue-cap", value: "N", help: "bound each replica queue to N requests (0 = unbounded)", default: Some("0") },
                    OptSpec { name: "deadline-mult", value: "F", help: "shed requests older than F x their class SLO (0 = no deadlines)", default: Some("0") },
                    OptSpec { name: "shed", value: "D", help: "discipline for full queues: reject (newest at admission) | drop (oldest in queue)", default: Some("reject") },
                    OptSpec { name: "brownout", value: "F", help: "brown out lowest-weight tenants when a window sheds > F of its arrivals (0 = off)", default: Some("0") },
                    OptSpec { name: "breaker", value: "F", help: "trip a per-GPU ingress breaker when its window shed fraction exceeds F (0 = off)", default: Some("0") },
                    OptSpec { name: "breaker-probes", value: "N", help: "requests admitted per half-open probe window", default: Some("8") },
                    OptSpec { name: "telemetry", value: "DIR", help: "write per-run windowed time-series into DIR as Prometheus text (.prom) and CSV (.csv) exports", default: None },
                    OptSpec { name: "telemetry-interval", value: "S", help: "telemetry window / DCGM sampling interval, simulated seconds", default: Some("1") },
                    OptSpec { name: "trace", value: "FILE", help: "write sampled request lifecycle spans as Chrome trace-event JSON (load in Perfetto); a compact FILE.jsonl rides along", default: None },
                    OptSpec { name: "trace-sample", value: "N", help: "trace one request in every N, by arrival id", default: Some("1") },
                    OptSpec { name: "seeds", value: "N", help: "replication seeds per grid point", default: Some("1") },
                    OptSpec { name: "seed", value: "S", help: "base seed", default: Some("2024") },
                    OptSpec { name: "workers", value: "N", help: "sweep worker threads (0 = auto)", default: Some("0") },
                    OptSpec { name: "mega", value: "N", help: "mega-fleet mode: shard each run into N contiguous sub-fleets across the workers and merge deterministically (0 = one shard per worker); incompatible with --telemetry/--trace", default: None },
                    OptSpec { name: "json", value: "", help: "emit JSON (with decision logs and fault timelines)", default: None },
                    OptSpec { name: "csv", value: "", help: "emit pooled summaries as CSV", default: None },
                    OptSpec { name: "decisions", value: "", help: "also print per-run decision logs and fault timelines", default: None },
                ]
            )
        );
        return Ok(());
    }
    use migperf::cluster::{
        chrome_trace, spans_to_jsonl, FaultPlan, FleetConfig, FleetPolicyKind, RepartitionMode,
        RequestClass, RouterKind, SpanEvent, TelemetryConfig,
    };
    use migperf::orchestrator::ReconfigCost;
    use migperf::sweep::SweepEngine;
    use migperf::util::json::Json;
    use migperf::workload::arrival::ArrivalSpec;
    use migperf::workload::spec::WorkloadSpec;

    let gpu = parse_gpu(args)?;
    let fleets: Vec<Vec<GpuModel>> = match args.get("gpus") {
        Some(list) => {
            let models = list
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|name| {
                    GpuModel::parse(name)
                        .ok_or_else(|| format!("unknown GPU '{name}' (use a100 or a30)"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            if models.is_empty() {
                return Err("--gpus needs at least one model".into());
            }
            vec![models]
        }
        None => {
            let sizes: Vec<usize> = args.list_or("fleet", &[4usize]).map_err(|e| e.to_string())?;
            if sizes.is_empty() || sizes.contains(&0) {
                return Err("--fleet sizes must be positive".into());
            }
            sizes.iter().map(|&n| vec![gpu; n]).collect()
        }
    };
    let policy_arg = args.str_or("policy", "all");
    let policies: Vec<FleetPolicyKind> = if policy_arg == "all" {
        vec![FleetPolicyKind::parse("static").unwrap(), FleetPolicyKind::parse("reactive").unwrap()]
    } else {
        policy_arg
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|name| {
                FleetPolicyKind::parse(name)
                    .ok_or_else(|| format!("unknown policy '{name}' (static|reactive)"))
            })
            .collect::<Result<_, _>>()?
    };
    let router_arg = args.str_or("router", "least");
    let routers: Vec<RouterKind> = if router_arg == "all" {
        vec![
            RouterKind::parse("rr").unwrap(),
            RouterKind::parse("least").unwrap(),
            RouterKind::parse("affinity").unwrap(),
            RouterKind::parse("wf").unwrap(),
        ]
    } else {
        router_arg
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|name| {
                RouterKind::parse(name)
                    .ok_or_else(|| format!("unknown router '{name}' (rr|least|affinity|wf)"))
            })
            .collect::<Result<_, _>>()?
    };
    let mode_arg = args.str_or("mode", "rolling");
    let modes: Vec<RepartitionMode> = if mode_arg == "both" {
        vec![RepartitionMode::Rolling, RepartitionMode::InPlace]
    } else {
        mode_arg
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|name| {
                RepartitionMode::parse(name)
                    .ok_or_else(|| format!("unknown mode '{name}' (rolling|inplace)"))
            })
            .collect::<Result<_, _>>()?
    };
    if policies.is_empty() || routers.is_empty() || modes.is_empty() {
        return Err("empty policy/router/mode selection".into());
    }
    let parse_model =
        |name: &str| zoo::lookup(name).ok_or_else(|| format!("unknown model '{name}'"));
    let train = {
        let t = args.str_or("train", "bert-base:32");
        if t.is_empty() || t == "none" {
            None
        } else {
            let (m, b) = t.split_once(':').ok_or("train format: MODEL:BATCH")?;
            let batch: u32 = b.parse().map_err(|_| "bad train batch")?;
            Some(WorkloadSpec::training(parse_model(m)?, batch, 128))
        }
    };
    let base_rate: f64 = args.parse_or("base-rate", 6.0f64).map_err(|e| e.to_string())?;
    let peak_rate: f64 = args.parse_or("peak-rate", 60.0f64).map_err(|e| e.to_string())?;
    let period_s: f64 = args.parse_or("period", 600.0f64).map_err(|e| e.to_string())?;
    if peak_rate < base_rate {
        return Err(format!("--peak-rate {peak_rate} must be at least --base-rate {base_rate}"));
    }
    let seq: u32 = args.parse_or("seq", 128u32).map_err(|e| e.to_string())?;
    let mut class_specs = Vec::new();
    for cls in args
        .str_or("classes", "bert-base:8:40,bert-base:8:40")
        .split(',')
        .filter(|s| !s.is_empty())
    {
        let parts: Vec<&str> = cls.split(':').collect();
        if parts.len() != 3 {
            return Err("classes format: MODEL:BATCH:SLO_MS".into());
        }
        let batch: u32 = parts[1].parse().map_err(|_| "bad class batch")?;
        let slo_ms: f64 = parts[2].parse().map_err(|_| "bad SLO")?;
        class_specs.push((WorkloadSpec::inference(parse_model(parts[0])?, batch, seq), slo_ms));
    }
    let tenants = match args.get("tenants") {
        Some(spec) => {
            let ts = migperf::cluster::parse_tenants(spec)?;
            migperf::cluster::validate_tenants(&ts, class_specs.len())
                .map_err(|e| format!("--tenants: {e}"))?;
            ts
        }
        None => Vec::new(),
    };
    let cost = ReconfigCost {
        instance_churn_s: args.parse_or("churn", 0.5f64).map_err(|e| e.to_string())?,
        train_restore_s: args.parse_or("restore", 5.0f64).map_err(|e| e.to_string())?,
    };
    let duration_s: f64 = args.parse_or("duration", 600.0f64).map_err(|e| e.to_string())?;
    let window_s: f64 = args.parse_or("window", 10.0f64).map_err(|e| e.to_string())?;
    let rho_max: f64 = args.parse_or("rho", 0.75f64).map_err(|e| e.to_string())?;
    let nseeds: usize = args.parse_or("seeds", 1usize).map_err(|e| e.to_string())?;
    let base_seed: u64 = args.parse_or("seed", 2024u64).map_err(|e| e.to_string())?;
    let workers: usize = args.parse_or("workers", 0usize).map_err(|e| e.to_string())?;

    // Observability: `--telemetry DIR` turns on the windowed timelines
    // and exports them per run; `--trace FILE` turns on span sampling
    // and writes one combined Perfetto-loadable trace. Neither flag
    // changes the simulation or the stdout document.
    let telemetry_dir = args.get("telemetry").map(str::to_string);
    let trace_file = args.get("trace").map(str::to_string);
    let telemetry_interval: f64 =
        args.parse_or("telemetry-interval", 1.0f64).map_err(|e| e.to_string())?;
    let trace_sample: u64 = args.parse_or("trace-sample", 1u64).map_err(|e| e.to_string())?;
    if trace_file.is_some() && trace_sample == 0 {
        return Err("--trace-sample must be at least 1".into());
    }
    let telemetry = TelemetryConfig {
        enabled: telemetry_dir.is_some(),
        interval_s: telemetry_interval,
        trace_sample: if trace_file.is_some() { trace_sample } else { 0 },
    };
    telemetry.validate()?;

    // Mega-fleet mode: instead of fanning whole grid points across the
    // workers, shard each run into contiguous sub-fleets and fan the
    // shards (the 1024-GPU scaling path). The merge drops per-shard
    // telemetry, so the observability flags are rejected up front.
    let mega: Option<usize> = match args.get("mega") {
        Some(v) => Some(v.parse().map_err(|_| format!("bad --mega '{v}'"))?),
        None => None,
    };
    if mega.is_some() && (telemetry_dir.is_some() || trace_file.is_some()) {
        return Err(
            "--mega merges shard outcomes without telemetry; drop --telemetry/--trace".into()
        );
    }

    // Failure-injection axis: no faults by default; `--crash` pins one
    // explicit schedule; `--faults` sweeps no-faults plus one stochastic
    // MTBF/MTTR level per `--mtbf` value (per-seed schedules derive from
    // the run seed, so the grid stays bitwise deterministic).
    enum FaultAxis {
        None,
        Mtbf(f64),
        Explicit(FaultPlan),
    }
    impl FaultAxis {
        fn label(&self) -> String {
            match self {
                FaultAxis::None => "none".into(),
                FaultAxis::Mtbf(m) => format!("mtbf{m:.0}"),
                FaultAxis::Explicit(_) => "plan".into(),
            }
        }
    }
    let overload = parse_overload_policy(args)?;
    let mttr_s: f64 = args.parse_or("mttr", 30.0f64).map_err(|e| e.to_string())?;
    let retries: u32 = args.parse_or("retries", 1u32).map_err(|e| e.to_string())?;
    let storm_cap: u64 = args.parse_or("storm-cap", 0u64).map_err(|e| e.to_string())?;
    let storm_guard = if storm_cap == 0 { u64::MAX } else { storm_cap };
    let fault_axis: Vec<FaultAxis> = if let Some(spec) = args.get("crash") {
        let plan = FaultPlan {
            injections: parse_crash_list(spec)?,
            retry_budget: retries,
            storm_guard,
        };
        vec![FaultAxis::Explicit(plan)]
    } else if args.flag("faults") {
        if !(duration_s.is_finite() && duration_s > 0.0) {
            return Err(format!("--duration {duration_s} must be positive and finite"));
        }
        if !(mttr_s.is_finite() && mttr_s > 0.0) {
            return Err(format!("--mttr {mttr_s} must be positive and finite"));
        }
        let mtbf_list: Vec<f64> =
            args.list_or("mtbf", &[240.0f64, 120.0]).map_err(|e| e.to_string())?;
        let mut axis = vec![FaultAxis::None];
        for &m in &mtbf_list {
            if !(m.is_finite() && m > 0.0) {
                return Err(format!("--mtbf {m} must be positive and finite"));
            }
            axis.push(FaultAxis::Mtbf(m));
        }
        axis
    } else {
        vec![FaultAxis::None]
    };

    // mode × policy × router × fleet × fault-level × seed grid in
    // row-major order (the determinism anchor). Per-GPU rates scale to
    // fleet-wide streams so every fleet size carries a comparable per-GPU
    // load.
    let seed_list = migperf::sweep::seeds(base_seed, nseeds.max(1));
    let mut runs: Vec<FleetConfig> = Vec::new();
    let mut fault_labels: Vec<String> = Vec::new();
    for mode in &modes {
        for policy in &policies {
            for router in &routers {
                for fleet in &fleets {
                    let n = fleet.len() as f64;
                    let arrival = if peak_rate > base_rate {
                        ArrivalSpec::Diurnal {
                            base_rate: base_rate * n,
                            peak_rate: peak_rate * n,
                            period_s,
                        }
                    } else {
                        ArrivalSpec::Poisson { rate: base_rate * n }
                    };
                    let classes: Vec<RequestClass> = class_specs
                        .iter()
                        .map(|(spec, slo_ms)| RequestClass {
                            spec: spec.clone(),
                            slo_ms: *slo_ms,
                            arrival: arrival.clone(),
                        })
                        .collect();
                    for fp in &fault_axis {
                        for &seed in &seed_list {
                            let faults = match fp {
                                FaultAxis::None => FaultPlan::none(),
                                FaultAxis::Mtbf(m) => FaultPlan::from_mtbf(
                                    fleet.len(),
                                    duration_s,
                                    *m,
                                    mttr_s,
                                    seed ^ 0xFA17,
                                )
                                .with_retries(retries)
                                .with_storm_guard(storm_guard),
                                FaultAxis::Explicit(p) => p.clone(),
                            };
                            fault_labels.push(fp.label());
                            runs.push(FleetConfig {
                                gpus: fleet.clone(),
                                train: train.clone(),
                                classes: classes.clone(),
                                tenants: tenants.clone(),
                                router: router.clone(),
                                policy: policy.clone(),
                                mode: *mode,
                                cost: cost.clone(),
                                duration_s,
                                window_s,
                                rho_max,
                                faults,
                                overload,
                                telemetry,
                                seed,
                            });
                        }
                    }
                }
            }
        }
    }
    let engine = if workers > 0 {
        SweepEngine::new(workers)
    } else {
        SweepEngine::from_env()
    };
    #[allow(clippy::disallowed_methods)] // CLI wall timing, never checksummed
    let started = std::time::Instant::now();
    let outs = match mega {
        Some(n) => {
            let shards = if n == 0 { engine.workers() } else { n };
            let mut outs = Vec::with_capacity(runs.len());
            for cfg in &runs {
                outs.push(
                    migperf::sweep::run_mega(&engine, cfg, shards).map_err(|e| e.to_string())?,
                );
            }
            outs
        }
        None => migperf::sweep::run_fleet(&engine, &runs).map_err(|e| e.to_string())?,
    };
    let wall_s = started.elapsed().as_secs_f64();

    let run_label = |out: &migperf::cluster::FleetOutcome, flabel: &str, seed: u64| {
        format!(
            "{}/{}/{}/n{}/{}/seed{}",
            out.mode.name(),
            out.policy,
            out.router,
            out.fleet_size,
            flabel,
            seed
        )
    };

    if let Some(dir) = &telemetry_dir {
        std::fs::create_dir_all(dir).map_err(|e| format!("--telemetry {dir}: {e}"))?;
        for ((cfg, out), flabel) in runs.iter().zip(&outs).zip(&fault_labels) {
            let Some(tel) = out.telemetry.as_ref() else { continue };
            let stem = run_label(out, flabel, cfg.seed).replace('/', "_");
            let prom_path = format!("{dir}/{stem}.prom");
            std::fs::write(&prom_path, export::series_to_prometheus(&tel.series))
                .map_err(|e| format!("{prom_path}: {e}"))?;
            let csv_path = format!("{dir}/{stem}.csv");
            std::fs::write(&csv_path, export::series_to_csv(&tel.series))
                .map_err(|e| format!("{csv_path}: {e}"))?;
        }
    }
    if let Some(path) = &trace_file {
        let labeled: Vec<(String, &[SpanEvent])> = runs
            .iter()
            .zip(&outs)
            .zip(&fault_labels)
            .filter_map(|((cfg, out), flabel)| {
                let tel = out.telemetry.as_ref()?;
                Some((run_label(out, flabel, cfg.seed), tel.spans.as_slice()))
            })
            .collect();
        let entries: Vec<(&str, &[SpanEvent])> =
            labeled.iter().map(|(label, spans)| (label.as_str(), *spans)).collect();
        std::fs::write(path, chrome_trace(&entries)).map_err(|e| format!("{path}: {e}"))?;
        let jsonl: String = labeled.iter().map(|(_, spans)| spans_to_jsonl(spans)).collect();
        let jsonl_path = format!("{path}.jsonl");
        std::fs::write(&jsonl_path, jsonl).map_err(|e| format!("{jsonl_path}: {e}"))?;
    }

    if args.flag("json") {
        let rows: Vec<Json> = runs
            .iter()
            .zip(&outs)
            .zip(&fault_labels)
            .map(|((cfg, out), flabel)| {
                Json::obj(vec![
                    ("mode", Json::Str(out.mode.name().to_string())),
                    ("policy", Json::Str(out.policy.to_string())),
                    ("router", Json::Str(out.router.to_string())),
                    ("fleet_size", Json::Num(out.fleet_size as f64)),
                    ("faults", Json::Str(flabel.clone())),
                    ("seed", Json::Num(cfg.seed as f64)),
                    ("arrived", Json::Num(out.arrived as f64)),
                    ("completed", Json::Num(out.completed as f64)),
                    ("goodput_rps", Json::Num(out.goodput_rps)),
                    ("slo_violation_frac", Json::Num(out.slo_violation_frac)),
                    ("p99_latency_ms", Json::Num(out.pooled.p99_latency_ms)),
                    ("train_samples_per_s", Json::Num(out.train_samples_per_s)),
                    ("reconfigurations", Json::Num(out.reconfigurations as f64)),
                    ("reconfig_downtime_s", Json::Num(out.reconfig_downtime_s)),
                    ("migrated_requests", Json::Num(out.migrated_requests as f64)),
                    ("unavailable_routes", Json::Num(out.unavailable_routes as f64)),
                    ("failed_requests", Json::Num(out.failed_requests as f64)),
                    ("retried_requests", Json::Num(out.retried_requests as f64)),
                    ("lost_in_crash", Json::Num(out.lost_in_crash as f64)),
                    ("shed_overload", Json::Num(out.shed_overload as f64)),
                    ("shed_deadline", Json::Num(out.shed_deadline as f64)),
                    ("shed_capacity", Json::Num(out.shed_capacity as f64)),
                    ("shed_brownout", Json::Num(out.shed_brownout as f64)),
                    ("breaker_trips", Json::Num(out.breaker_trips as f64)),
                    ("breaker_open_s", Json::Num(out.breaker_open_s)),
                    ("gpu_crashes", Json::Num(out.gpu_crashes as f64)),
                    ("instance_crashes", Json::Num(out.instance_crashes as f64)),
                    ("availability", Json::Num(out.availability)),
                    ("fairness_jain", Json::Num(out.fairness_jain)),
                    ("events_processed", Json::Num(out.events_processed as f64)),
                    ("events_per_sec", Json::Num(out.events_per_sec)),
                    ("tenants", export::tenant_outcomes_to_json(&out.tenants)),
                    ("fault_log", export::fault_records_to_json(&out.fault_log)),
                    ("decisions", export::fleet_decisions_to_json(&out.decisions)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::Str("migperf-fleet/v1".into())),
            ("duration_s", Json::Num(duration_s)),
            ("window_s", Json::Num(window_s)),
            ("workers", Json::Num(engine.workers() as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("rows", Json::Arr(rows)),
        ]);
        println!("{}", doc.to_pretty());
    } else if args.flag("csv") {
        let rows: Vec<_> = runs
            .iter()
            .zip(&outs)
            .zip(&fault_labels)
            .map(|((cfg, out), flabel)| {
                let mut s = out.pooled.clone();
                s.label = run_label(out, flabel, cfg.seed);
                (s, out.events_processed, out.events_per_sec)
            })
            .collect();
        print!("{}", export::fleet_summaries_to_csv(&rows));
        // Keep plain `--csv` a single parseable document; the per-tenant
        // accounting follows as a second CSV document (own header) only
        // when --decisions asks for the auxiliary logs.
        if !tenants.is_empty() && args.flag("decisions") {
            let trows: Vec<(String, migperf::cluster::TenantOutcome)> = runs
                .iter()
                .zip(&outs)
                .zip(&fault_labels)
                .flat_map(|((cfg, out), flabel)| {
                    let label = run_label(out, flabel, cfg.seed);
                    out.tenants.iter().map(move |t| (label.clone(), t.clone()))
                })
                .collect();
            println!();
            print!("{}", export::tenant_outcomes_to_csv(&trows));
        }
    } else {
        let mut t = Table::new(&[
            "mode",
            "policy",
            "router",
            "gpus",
            "faults",
            "seed",
            "arrived",
            "goodput_rps",
            "viol_%",
            "p99_ms",
            "jain",
            "reconf",
            "migrated",
            "failed",
            "lost",
            "retried",
            "shed",
            "trips",
            "avail_%",
            "events",
            "ev/s",
        ]);
        for ((cfg, out), flabel) in runs.iter().zip(&outs).zip(&fault_labels) {
            t.row(&[
                out.mode.name().to_string(),
                out.policy.to_string(),
                out.router.to_string(),
                out.fleet_size.to_string(),
                flabel.clone(),
                cfg.seed.to_string(),
                out.arrived.to_string(),
                format!("{:.1}", out.goodput_rps),
                format!("{:.2}", out.slo_violation_frac * 100.0),
                format!("{:.1}", out.pooled.p99_latency_ms),
                format!("{:.3}", out.fairness_jain),
                out.reconfigurations.to_string(),
                out.migrated_requests.to_string(),
                out.failed_requests.to_string(),
                out.lost_in_crash.to_string(),
                out.retried_requests.to_string(),
                out.shed_overload.to_string(),
                out.breaker_trips.to_string(),
                format!("{:.2}", out.availability * 100.0),
                out.events_processed.to_string(),
                format!("{:.0}", out.events_per_sec),
            ]);
        }
        println!("{}", t.render());
        println!("{} runs on {} workers in {:.2}s", runs.len(), engine.workers(), wall_s);
        if !tenants.is_empty() {
            let mut tt = Table::new(&[
                "run",
                "tenant",
                "weight",
                "arrived",
                "completed",
                "viol",
                "failed",
                "lost",
                "shed",
                "goodput_rps",
                "norm_rps",
            ]);
            for ((cfg, out), flabel) in runs.iter().zip(&outs).zip(&fault_labels) {
                let run = run_label(out, flabel, cfg.seed);
                for row in &out.tenants {
                    tt.row(&[
                        run.clone(),
                        row.name.clone(),
                        format!("{}", row.weight),
                        row.arrived.to_string(),
                        row.completed.to_string(),
                        row.slo_violations.to_string(),
                        row.failed.to_string(),
                        row.lost_in_crash.to_string(),
                        (row.shed_deadline + row.shed_capacity + row.shed_brownout).to_string(),
                        format!("{:.1}", row.goodput_rps),
                        format!("{:.2}", row.norm_goodput_rps),
                    ]);
                }
            }
            println!("\nper-tenant accounting (jain = fairness over norm_rps):\n{}", tt.render());
        }
        if args.flag("decisions") {
            for ((cfg, out), flabel) in runs.iter().zip(&outs).zip(&fault_labels) {
                let tag = format!(
                    "{}/{}/{} n{} {} (seed {})",
                    out.mode.name(),
                    out.policy,
                    out.router,
                    out.fleet_size,
                    flabel,
                    cfg.seed
                );
                if !out.decisions.is_empty() {
                    println!("\ndecision log — {tag}:");
                    print!("{}", export::fleet_decisions_to_csv(&out.decisions));
                }
                if !out.fault_log.is_empty() {
                    println!("\nfault timeline — {tag}:");
                    print!("{}", export::fault_records_to_csv(&out.fault_log));
                }
            }
        }
    }
    Ok(())
}

fn cmd_fuzz(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help(
                "migperf",
                "fuzz",
                "Model-based fuzzing of the fleet engine: generate random command \
                 sequences (bursts, crashes, repartitions, overload knobs), replay \
                 each against the engine under live routing/brownout invariants plus \
                 a closed-form reference model, and minimize any failure to a \
                 pasteable repro. Deterministic: the report digest is bitwise-\
                 identical for a given --cases/--seed/--max-cmds at any worker count",
                &[
                    OptSpec { name: "cases", value: "N", help: "command sequences to run", default: Some("50") },
                    OptSpec { name: "seed", value: "S", help: "master PRNG seed", default: Some("7") },
                    OptSpec { name: "max-cmds", value: "K", help: "max commands per sequence", default: Some("24") },
                    OptSpec { name: "workers", value: "W", help: "worker threads (0 = all cores)", default: Some("0") },
                    OptSpec { name: "out", value: "DIR", help: "write failure repros + seeds under DIR", default: None },
                ]
            )
        );
        return Ok(());
    }
    use migperf::sweep::SweepEngine;
    use migperf::testing::run_fuzz;

    let cases: usize = args.parse_or("cases", 50usize).map_err(|e| e.to_string())?;
    let seed: u64 = args.parse_or("seed", 7u64).map_err(|e| e.to_string())?;
    let max_cmds: usize = args.parse_or("max-cmds", 24usize).map_err(|e| e.to_string())?;
    let workers: usize = args.parse_or("workers", 0usize).map_err(|e| e.to_string())?;
    if cases == 0 {
        return Err("--cases must be at least 1".into());
    }
    if max_cmds == 0 {
        return Err("--max-cmds must be at least 1".into());
    }
    let engine = if workers == 0 { SweepEngine::from_env() } else { SweepEngine::new(workers) };
    println!(
        "fuzz: {cases} cases, seed {seed}, up to {max_cmds} commands each, {} workers",
        engine.workers()
    );
    let report = run_fuzz(cases, seed, max_cmds, &engine);
    println!(
        "fuzz: {} / {} cases passed, digest {:016x}",
        report.cases - report.failures.len(),
        report.cases,
        report.digest
    );
    if let Some(dir) = args.get("out").map(str::to_string) {
        std::fs::create_dir_all(&dir).map_err(|e| format!("creating {dir}: {e}"))?;
        let mut doc = String::new();
        doc.push_str(&format!(
            "# migperf fuzz report\ncases: {}\nseed: {}\nmax_cmds: {}\ndigest: {:016x}\n\
             failures: {}\n",
            report.cases,
            report.seed,
            report.max_cmds,
            report.digest,
            report.failures.len()
        ));
        for f in &report.failures {
            doc.push_str(&format!(
                "\n## case {} (case_seed {})\nviolations:\n",
                f.index, f.case_seed
            ));
            for v in &f.violations {
                doc.push_str(&format!("  - {v}\n"));
            }
            doc.push_str("minimized repro (paste into rust/tests/model_regressions.rs):\n");
            doc.push_str(&f.repro);
        }
        let path = format!("{dir}/fuzz_report.txt");
        std::fs::write(&path, doc).map_err(|e| format!("writing {path}: {e}"))?;
        println!("fuzz: report written to {path}");
    }
    if report.failures.is_empty() {
        Ok(())
    } else {
        for f in &report.failures {
            eprintln!("\ncase {} (case_seed {}) failed:", f.index, f.case_seed);
            for v in &f.violations {
                eprintln!("  - {v}");
            }
            eprintln!("minimized repro (paste into rust/tests/model_regressions.rs):");
            eprintln!("{}", f.repro);
        }
        Err(format!(
            "{} of {} fuzz cases violated the model (seed {}; rerun with --cases {} --seed {} \
             --max-cmds {} to reproduce)",
            report.failures.len(),
            report.cases,
            report.seed,
            report.cases,
            report.seed,
            report.max_cmds
        ))
    }
}

fn cmd_bench_check(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help(
                "migperf",
                "bench-check",
                "Compare a bench record against its checked-in baseline (the CI \
                 regression gate): wall-clock keys may regress at most --tolerance, \
                 every other pinned number must match bit-for-bit (determinism)",
                &[
                    OptSpec { name: "baseline", value: "FILE", help: "checked-in baseline JSON", default: None },
                    OptSpec { name: "current", value: "FILE", help: "freshly produced bench JSON", default: None },
                    OptSpec { name: "tolerance", value: "F", help: "max relative wall-clock regression", default: Some("0.25") },
                    OptSpec { name: "bless", value: "", help: "overwrite the baseline with the current record", default: None },
                ]
            )
        );
        return Ok(());
    }
    use migperf::metrics::regression::{compare, render, Tolerance};
    use migperf::util::json;

    let baseline_path = args.required("baseline").map_err(|e| e.to_string())?;
    let current_path = args.required("current").map_err(|e| e.to_string())?;
    let current_doc = std::fs::read_to_string(&current_path)
        .map_err(|e| format!("reading {current_path}: {e}"))?;
    let current = json::parse(&current_doc).map_err(|e| format!("parsing {current_path}: {e}"))?;
    if args.flag("bless") {
        std::fs::write(&baseline_path, &current_doc)
            .map_err(|e| format!("writing {baseline_path}: {e}"))?;
        println!(
            "blessed: {baseline_path} now pins the current record from {current_path} \
             (commit it to tighten the gate)"
        );
        return Ok(());
    }
    let baseline_doc = std::fs::read_to_string(&baseline_path)
        .map_err(|e| format!("reading {baseline_path}: {e}"))?;
    let baseline =
        json::parse(&baseline_doc).map_err(|e| format!("parsing {baseline_path}: {e}"))?;
    let wall: f64 = args.parse_or("tolerance", 0.25f64).map_err(|e| e.to_string())?;
    if !(wall.is_finite() && wall >= 0.0) {
        return Err(format!("--tolerance {wall} must be non-negative and finite"));
    }
    let cmp = compare(&baseline, &current, &Tolerance { wall, ..Tolerance::default() });
    print!("{}", render(&baseline_path, &cmp));
    if cmp.passed() {
        Ok(())
    } else {
        Err(format!(
            "{} bench metric(s) regressed or drifted against {baseline_path}",
            cmp.failures.len()
        ))
    }
}

fn cmd_lint(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help(
                "migperf",
                "lint",
                "Determinism-aware static analysis over the repo's own Rust sources: \
                 hash-map iteration, wall-clock leakage, non-total float ordering, \
                 ambient entropy, panic budgets and side-effectful debug_asserts. \
                 Suppress per-line with `// lint:allow(rule-id, reason=\"...\")`. \
                 Positional PATHS (files or directories) default to `src`.",
                &[
                    OptSpec { name: "strict", value: "", help: "also fail on warnings (stale budget entries)", default: None },
                    OptSpec { name: "format", value: "F", help: "text | json", default: Some("text") },
                    OptSpec { name: "budget", value: "FILE", help: "panic-budget ratchet file", default: Some("lint-budget.toml") },
                ]
            )
        );
        return Ok(());
    }
    use migperf::lint::{config::LintConfig, report, run_paths};

    let strict = args.flag("strict");
    let format = args.str_or("format", "text");
    if format != "text" && format != "json" {
        return Err(format!("--format {format} must be text or json"));
    }
    let budget_path = args.str_or("budget", "lint-budget.toml");
    let mut paths: Vec<String> = args.positional().to_vec();
    if paths.is_empty() {
        paths.push("src".to_string());
    }
    let cfg = LintConfig::default();
    let rep = run_paths(&paths, &budget_path, strict, &cfg)?;
    if format == "json" {
        print!("{}", report::render_json(&rep));
    } else {
        print!("{}", report::render_text(&rep));
    }
    if rep.failed() {
        Err(format!(
            "lint failed: {} error(s), {} warning(s){}",
            rep.errors(),
            rep.warnings(),
            if strict { " (strict)" } else { "" }
        ))
    } else {
        Ok(())
    }
}

fn cmd_suite(args: &Args) -> Result<(), String> {
    if args.flag("help") {
        #[rustfmt::skip]
        println!(
            "{}",
            render_help("migperf", "suite", "Run a JSON task suite through the coordinator", &[
                OptSpec { name: "file", value: "PATH", help: "JSON file: array of tasks", default: None },
                OptSpec { name: "json", value: "", help: "emit JSON reports", default: None },
            ])
        );
        return Ok(());
    }
    let path = args.required("file").map_err(|e| e.to_string())?;
    let doc = std::fs::read_to_string(&path).map_err(|e| format!("reading {path}: {e}"))?;
    let mut coord = Coordinator::paper_testbed();
    let mut client = Client::new(&mut coord);
    let ids = client.submit_suite_json(&doc)?;
    if args.flag("json") {
        println!("{}", client.collect_suite_json(&ids)?);
    } else {
        for id in ids {
            println!("{}", client.collect_rendered(id)?);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet_args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string), &[]).unwrap()
    }

    #[test]
    fn crash_specs_parse_the_documented_grammar() {
        let plan = parse_crash_list("1@30+20,0.1@45+inf").unwrap();
        assert_eq!(plan.len(), 2);
        assert_eq!((plan[0].gpu, plan[0].class), (1, None));
        assert_eq!((plan[0].t, plan[0].down_s), (30.0, 20.0));
        assert_eq!((plan[1].gpu, plan[1].class), (0, Some(1)));
        assert!(plan[1].down_s.is_infinite());
    }

    #[test]
    fn malformed_crash_specs_error_instead_of_panicking() {
        for bad in [
            "",            // no entries
            "1@30",        // missing downtime
            "1+20",        // missing @T
            "x@30+20",     // non-numeric GPU
            "1.z@30+20",   // non-numeric class
            "-1@30+20",    // negative GPU index
            "1@-5+20",     // negative crash time
            "1@inf+20",    // non-finite crash time
            "1@NaN+20",    // NaN crash time
            "1@30+0",      // zero downtime
            "1@30+-3",     // negative downtime
            "1@30+NaN",    // NaN downtime
            "1@30+forever" // non-numeric downtime
        ] {
            let res = parse_crash_list(bad);
            assert!(res.is_err(), "'{bad}' must be rejected, got {res:?}");
            assert!(!res.unwrap_err().is_empty(), "'{bad}' needs a message");
        }
    }

    #[test]
    fn malformed_tenant_specs_error_instead_of_panicking() {
        for bad in ["", "gold", "gold:3", "gold:x:0", "gold:3:", "gold:3:x", ":3:0"] {
            assert!(
                migperf::cluster::parse_tenants(bad).is_err(),
                "'{bad}' must be rejected"
            );
        }
        // Weights that parse but are degenerate fall to validate_tenants,
        // which cmd_fleet runs right after parsing.
        let ts = migperf::cluster::parse_tenants("gold:NaN:0").unwrap();
        assert!(migperf::cluster::validate_tenants(&ts, 1).is_err(), "NaN weight");
    }

    #[test]
    fn overload_flags_default_to_disabled() {
        let p = parse_overload_policy(&fleet_args("")).unwrap();
        assert_eq!(p, migperf::cluster::OverloadPolicy::none());
        assert!(p.is_disabled());
    }

    #[test]
    fn overload_flags_parse_and_zero_disables_thresholds() {
        let p = parse_overload_policy(&fleet_args(
            "--queue-cap 8 --deadline-mult 2.5 --shed drop --brownout 0.2 \
             --breaker 0.5 --breaker-probes 4",
        ))
        .unwrap();
        assert_eq!(p.queue_cap, 8);
        assert_eq!(p.deadline_mult, 2.5);
        assert_eq!(p.shed, migperf::cluster::ShedDiscipline::DropOldest);
        assert_eq!(p.brownout_threshold, 0.2);
        assert_eq!(p.breaker_threshold, 0.5);
        assert_eq!(p.breaker_probes, 4);
        let off = parse_overload_policy(&fleet_args("--brownout 0 --breaker 0")).unwrap();
        assert!(off.brownout_threshold.is_infinite(), "0 means off");
        assert!(off.breaker_threshold.is_infinite(), "0 means off");
    }

    #[test]
    fn malformed_overload_flags_error_instead_of_panicking() {
        for bad in [
            "--queue-cap -1",
            "--queue-cap many",
            "--deadline-mult -2",
            "--deadline-mult inf",
            "--deadline-mult soon",
            "--shed everything",
            "--brownout -0.5",
            "--brownout 1.5",
            "--brownout NaN",
            "--breaker -1",
            "--breaker 2",
            "--breaker 0.5 --breaker-probes 0",
            "--breaker-probes -3",
        ] {
            let res = parse_overload_policy(&fleet_args(bad));
            assert!(res.is_err(), "'{bad}' must be rejected, got {res:?}");
        }
    }
}
