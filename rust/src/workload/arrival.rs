//! Request arrival processes.
//!
//! The appendix experiments (Figs 10–11) "send asynchronous requests to
//! each server simultaneously with different request workloads (i.e.,
//! request arrival rate)". This module generates those streams: Poisson
//! (exponential gaps), uniform (fixed gaps), bursty (Markov-modulated
//! on/off) and diurnal (sinusoidally rate-modulated, for the online
//! orchestrator) arrivals, all on the deterministic PRNG. It also holds
//! the short-horizon [`RateForecaster`] the predictive repartitioning
//! policy drives proactive resizes with.

use crate::util::prng::Prng;

/// Why an arrival process could not be constructed: a rate or dwell
/// parameter that would produce NaN/degenerate inter-arrival times (and
/// choke any downstream rate estimator) is rejected up front.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalError {
    /// Parameter at fault (e.g. `"poisson rate"`).
    pub param: &'static str,
    /// Offending value.
    pub value: f64,
    /// What the parameter must satisfy.
    pub requirement: &'static str,
}

impl std::fmt::Display for ArrivalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid {} {}: {}", self.param, self.value, self.requirement)
    }
}

impl std::error::Error for ArrivalError {}

/// Require a strictly positive, finite parameter.
fn positive_finite(param: &'static str, value: f64) -> Result<(), ArrivalError> {
    if value.is_finite() && value > 0.0 {
        Ok(())
    } else {
        Err(ArrivalError { param, value, requirement: "must be positive and finite" })
    }
}

/// An arrival process that yields inter-arrival gaps (seconds).
pub trait Arrival {
    /// Next gap before the following request.
    fn next_gap(&mut self) -> f64;
    /// Mean request rate (requests/second) of the process.
    fn rate(&self) -> f64;
}

/// Poisson process: exponential inter-arrival gaps at a fixed rate.
#[derive(Debug)]
pub struct PoissonArrival {
    rate: f64,
    rng: Prng,
}

impl PoissonArrival {
    /// Poisson process with `rate` requests/second; rejects non-positive
    /// or non-finite rates.
    pub fn try_new(rate: f64, seed: u64) -> Result<Self, ArrivalError> {
        positive_finite("poisson rate", rate)?;
        Ok(PoissonArrival { rate, rng: Prng::new(seed) })
    }

    /// Poisson process with `rate` requests/second.
    ///
    /// # Panics
    /// On a non-positive or non-finite rate (see [`PoissonArrival::try_new`]).
    pub fn new(rate: f64, seed: u64) -> Self {
        Self::try_new(rate, seed).unwrap_or_else(|e| panic!("{e}"))
    }
}

impl Arrival for PoissonArrival {
    fn next_gap(&mut self) -> f64 {
        self.rng.exponential(self.rate)
    }
    fn rate(&self) -> f64 {
        self.rate
    }
}

/// Deterministic uniform arrivals (fixed gap).
#[derive(Debug)]
pub struct UniformArrival {
    gap: f64,
}

impl UniformArrival {
    /// Uniform arrivals at `rate` requests/second; rejects non-positive
    /// or non-finite rates.
    pub fn try_new(rate: f64) -> Result<Self, ArrivalError> {
        positive_finite("uniform rate", rate)?;
        Ok(UniformArrival { gap: 1.0 / rate })
    }

    /// Uniform arrivals at `rate` requests/second.
    ///
    /// # Panics
    /// On a non-positive or non-finite rate (see [`UniformArrival::try_new`]).
    pub fn new(rate: f64) -> Self {
        Self::try_new(rate).unwrap_or_else(|e| panic!("{e}"))
    }
}

impl Arrival for UniformArrival {
    fn next_gap(&mut self) -> f64 {
        self.gap
    }
    fn rate(&self) -> f64 {
        1.0 / self.gap
    }
}

/// Markov-modulated on/off burst process: alternates between a burst state
/// (high rate) and an idle state (low rate), with exponential dwell times.
/// Extension beyond the paper for stress-testing batching policies.
#[derive(Debug)]
pub struct BurstyArrival {
    high_rate: f64,
    low_rate: f64,
    mean_dwell_s: f64,
    in_burst: bool,
    state_left_s: f64,
    rng: Prng,
}

impl BurstyArrival {
    /// Bursty process alternating between `high_rate` and `low_rate`
    /// (requests/s), with exponential state dwell of mean `mean_dwell_s`;
    /// rejects non-positive / non-finite rates, `high_rate <= low_rate`,
    /// and `mean_dwell_s <= 0`.
    pub fn try_new(
        high_rate: f64,
        low_rate: f64,
        mean_dwell_s: f64,
        seed: u64,
    ) -> Result<Self, ArrivalError> {
        positive_finite("bursty low_rate", low_rate)?;
        if !high_rate.is_finite() || high_rate <= low_rate {
            return Err(ArrivalError {
                param: "bursty high_rate",
                value: high_rate,
                requirement: "must be finite and exceed low_rate",
            });
        }
        positive_finite("bursty mean_dwell_s", mean_dwell_s)?;
        let mut rng = Prng::new(seed);
        let dwell = rng.exponential(1.0 / mean_dwell_s);
        Ok(BurstyArrival {
            high_rate,
            low_rate,
            mean_dwell_s,
            in_burst: true,
            state_left_s: dwell,
            rng,
        })
    }

    /// Bursty process alternating between `high_rate` and `low_rate`.
    ///
    /// # Panics
    /// On invalid parameters (see [`BurstyArrival::try_new`]).
    pub fn new(high_rate: f64, low_rate: f64, mean_dwell_s: f64, seed: u64) -> Self {
        Self::try_new(high_rate, low_rate, mean_dwell_s, seed).unwrap_or_else(|e| panic!("{e}"))
    }
}

impl Arrival for BurstyArrival {
    fn next_gap(&mut self) -> f64 {
        let rate = if self.in_burst {
            self.high_rate
        } else {
            self.low_rate
        };
        let gap = self.rng.exponential(rate);
        self.state_left_s -= gap;
        if self.state_left_s <= 0.0 {
            self.in_burst = !self.in_burst;
            self.state_left_s = self.rng.exponential(1.0 / self.mean_dwell_s);
        }
        gap
    }
    fn rate(&self) -> f64 {
        // Long-run average with symmetric dwell times.
        (self.high_rate + self.low_rate) / 2.0
    }
}

/// Diurnal non-homogeneous Poisson process: the instantaneous rate follows
/// a sinusoid between `base_rate` (at t = 0 and every full period) and
/// `peak_rate` (at half period), generated by thinning against the peak
/// rate. This is the time-varying load the online MIG orchestrator
/// repartitions under: calm troughs, a ramp, a peak that overloads a
/// statically sized layout.
#[derive(Debug)]
pub struct DiurnalArrival {
    base_rate: f64,
    peak_rate: f64,
    period_s: f64,
    t: f64,
    rng: Prng,
}

impl DiurnalArrival {
    /// Diurnal process cycling between `base_rate` and `peak_rate`
    /// (requests/s) with period `period_s`; rejects non-positive /
    /// non-finite parameters and `peak_rate < base_rate`.
    pub fn try_new(
        base_rate: f64,
        peak_rate: f64,
        period_s: f64,
        seed: u64,
    ) -> Result<Self, ArrivalError> {
        positive_finite("diurnal base_rate", base_rate)?;
        if !peak_rate.is_finite() || peak_rate < base_rate {
            return Err(ArrivalError {
                param: "diurnal peak_rate",
                value: peak_rate,
                requirement: "must be finite and at least base_rate",
            });
        }
        positive_finite("diurnal period_s", period_s)?;
        Ok(DiurnalArrival { base_rate, peak_rate, period_s, t: 0.0, rng: Prng::new(seed) })
    }

    /// Diurnal process cycling between `base_rate` and `peak_rate`.
    ///
    /// # Panics
    /// On invalid parameters (see [`DiurnalArrival::try_new`]).
    pub fn new(base_rate: f64, peak_rate: f64, period_s: f64, seed: u64) -> Self {
        Self::try_new(base_rate, peak_rate, period_s, seed).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Instantaneous arrival rate at absolute time `t` (requests/s).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mid = (self.base_rate + self.peak_rate) / 2.0;
        let amp = (self.peak_rate - self.base_rate) / 2.0;
        let phase = 2.0 * std::f64::consts::PI * t / self.period_s - std::f64::consts::FRAC_PI_2;
        mid + amp * phase.sin()
    }
}

impl Arrival for DiurnalArrival {
    fn next_gap(&mut self) -> f64 {
        // Lewis–Shedler thinning: candidate gaps at the peak rate,
        // accepted with probability rate(t)/peak. Acceptance probability
        // is bounded below by base/peak > 0, so the loop terminates.
        let start = self.t;
        loop {
            self.t += self.rng.exponential(self.peak_rate);
            if self.rng.chance(self.rate_at(self.t) / self.peak_rate) {
                return self.t - start;
            }
        }
    }
    fn rate(&self) -> f64 {
        // Long-run average of the sinusoid.
        (self.base_rate + self.peak_rate) / 2.0
    }
}

/// Trace replay: emits a fixed, pre-computed list of arrival timestamps
/// and then goes silent (infinite gap). Unlike the stochastic processes
/// this makes the *exact* arrival count and every timestamp knowable in
/// advance, which is what the model-based testing harness needs to write
/// closed-form conservation expectations; it is also the natural carrier
/// for real production traces.
#[derive(Debug)]
pub struct ReplayArrival {
    times: Vec<f64>,
    idx: usize,
    t: f64,
}

impl ReplayArrival {
    /// Replay of `times` (absolute seconds, non-decreasing, finite,
    /// non-negative); rejects anything else. An empty trace is valid and
    /// yields no arrivals.
    pub fn try_new(times: Vec<f64>) -> Result<Self, ArrivalError> {
        for (i, &t) in times.iter().enumerate() {
            if !t.is_finite() || t < 0.0 {
                return Err(ArrivalError {
                    param: "replay time",
                    value: t,
                    requirement: "must be finite and non-negative",
                });
            }
            if i > 0 && t < times[i - 1] {
                return Err(ArrivalError {
                    param: "replay time",
                    value: t,
                    requirement: "must be non-decreasing",
                });
            }
        }
        Ok(ReplayArrival { times, idx: 0, t: 0.0 })
    }

    /// Replay of `times`.
    ///
    /// # Panics
    /// On unordered, negative or non-finite times (see
    /// [`ReplayArrival::try_new`]).
    pub fn new(times: Vec<f64>) -> Self {
        Self::try_new(times).unwrap_or_else(|e| panic!("{e}"))
    }
}

impl Arrival for ReplayArrival {
    fn next_gap(&mut self) -> f64 {
        match self.times.get(self.idx) {
            Some(&next) => {
                let gap = next - self.t;
                self.t = next;
                self.idx += 1;
                gap
            }
            // Trace exhausted: an infinite gap ends the stream (every
            // consumer guards scheduling on `gap.is_finite()`).
            None => f64::INFINITY,
        }
    }
    fn rate(&self) -> f64 {
        replay_mean_rate(&self.times)
    }
}

/// Mean rate of a replay trace: count over span (with a 1 s floor so a
/// sub-second trace does not report an absurd rate), 0 for an empty one.
fn replay_mean_rate(times: &[f64]) -> f64 {
    match times.last() {
        Some(&last) => times.len() as f64 / last.max(1.0),
        None => 0.0,
    }
}

/// Plain-data description of an arrival process, cloneable into sweep
/// grids; [`ArrivalSpec::build`] materializes the seeded process.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Homogeneous Poisson at `rate` requests/s.
    Poisson {
        /// Mean arrival rate, requests/s.
        rate: f64,
    },
    /// Fixed-gap arrivals at `rate` requests/s.
    Uniform {
        /// Arrival rate, requests/s.
        rate: f64,
    },
    /// Markov-modulated on/off bursts.
    Bursty {
        /// Burst-state rate, requests/s.
        high_rate: f64,
        /// Idle-state rate, requests/s.
        low_rate: f64,
        /// Mean exponential dwell per state, seconds.
        mean_dwell_s: f64,
    },
    /// Sinusoidal diurnal load between `base_rate` and `peak_rate`.
    Diurnal {
        /// Trough rate, requests/s.
        base_rate: f64,
        /// Peak rate, requests/s.
        peak_rate: f64,
        /// Cycle length, seconds.
        period_s: f64,
    },
    /// Exact trace replay: the listed absolute timestamps, then silence.
    Replay {
        /// Absolute arrival times, seconds, non-decreasing.
        times: Vec<f64>,
    },
}

impl ArrivalSpec {
    /// Validate the parameters without building the process.
    pub fn validate(&self) -> Result<(), ArrivalError> {
        self.build(0).map(|_| ())
    }

    /// Whole-trace mean rate (requests/s) — what a static, offline
    /// optimizer sizes for.
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalSpec::Poisson { rate } | ArrivalSpec::Uniform { rate } => *rate,
            ArrivalSpec::Bursty { high_rate, low_rate, .. } => (high_rate + low_rate) / 2.0,
            ArrivalSpec::Diurnal { base_rate, peak_rate, .. } => (base_rate + peak_rate) / 2.0,
            ArrivalSpec::Replay { times } => replay_mean_rate(times),
        }
    }

    /// Build the seeded process as an enum-dispatched [`ArrivalProcess`]
    /// (no heap allocation, no vtable in the per-arrival hot path).
    pub fn build(&self, seed: u64) -> Result<ArrivalProcess, ArrivalError> {
        Ok(match self {
            ArrivalSpec::Poisson { rate } => {
                ArrivalProcess::Poisson(PoissonArrival::try_new(*rate, seed)?)
            }
            ArrivalSpec::Uniform { rate } => {
                ArrivalProcess::Uniform(UniformArrival::try_new(*rate)?)
            }
            ArrivalSpec::Bursty { high_rate, low_rate, mean_dwell_s } => ArrivalProcess::Bursty(
                BurstyArrival::try_new(*high_rate, *low_rate, *mean_dwell_s, seed)?,
            ),
            ArrivalSpec::Diurnal { base_rate, peak_rate, period_s } => ArrivalProcess::Diurnal(
                DiurnalArrival::try_new(*base_rate, *peak_rate, *period_s, seed)?,
            ),
            ArrivalSpec::Replay { times } => {
                ArrivalProcess::Replay(ReplayArrival::try_new(times.clone())?)
            }
        })
    }
}

/// A built arrival process with enum dispatch: the DES hot loops pull one
/// gap per arrival, so a vtable call (plus the pointer chase of a
/// `Box<dyn Arrival>`) per request is pure overhead. The enum keeps the
/// process inline in the engine's `Vec` and lets the compiler inline the
/// per-variant samplers. [`Arrival`] stays implemented for generic
/// consumers (trace capture, tests).
#[derive(Debug)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson.
    Poisson(PoissonArrival),
    /// Fixed-gap arrivals.
    Uniform(UniformArrival),
    /// Markov-modulated on/off bursts.
    Bursty(BurstyArrival),
    /// Sinusoidal diurnal load (Lewis–Shedler thinning).
    Diurnal(DiurnalArrival),
    /// Exact trace replay.
    Replay(ReplayArrival),
}

impl ArrivalProcess {
    /// Next gap before the following request.
    #[inline]
    pub fn next_gap(&mut self) -> f64 {
        match self {
            ArrivalProcess::Poisson(p) => p.next_gap(),
            ArrivalProcess::Uniform(p) => p.next_gap(),
            ArrivalProcess::Bursty(p) => p.next_gap(),
            ArrivalProcess::Diurnal(p) => p.next_gap(),
            ArrivalProcess::Replay(p) => p.next_gap(),
        }
    }

    /// Mean request rate (requests/second) of the process.
    pub fn rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson(p) => Arrival::rate(p),
            ArrivalProcess::Uniform(p) => Arrival::rate(p),
            ArrivalProcess::Bursty(p) => Arrival::rate(p),
            ArrivalProcess::Diurnal(p) => Arrival::rate(p),
            ArrivalProcess::Replay(p) => Arrival::rate(p),
        }
    }
}

impl Arrival for ArrivalProcess {
    fn next_gap(&mut self) -> f64 {
        ArrivalProcess::next_gap(self)
    }
    fn rate(&self) -> f64 {
        ArrivalProcess::rate(self)
    }
}

/// Short-horizon arrival-rate forecaster: Holt's linear (double)
/// exponential smoothing over windowed rate observations. The predictive
/// orchestration policy feeds it one rate estimate per observation window
/// and asks for the rate `h` windows ahead, so it can resize *before* a
/// diurnal ramp crests rather than after the SLO is already blown.
#[derive(Debug, Clone)]
pub struct RateForecaster {
    alpha: f64,
    beta: f64,
    level: f64,
    trend: f64,
    observations: u64,
}

impl RateForecaster {
    /// Forecaster with level gain `alpha` in `(0, 1]` and trend gain
    /// `beta` in `[0, 1]`.
    pub fn new(alpha: f64, beta: f64) -> Self {
        assert!(
            alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
            "forecaster alpha {alpha} must be in (0, 1]"
        );
        assert!(
            beta.is_finite() && (0.0..=1.0).contains(&beta),
            "forecaster beta {beta} must be in [0, 1]"
        );
        RateForecaster { alpha, beta, level: 0.0, trend: 0.0, observations: 0 }
    }

    /// Feed one windowed rate observation (requests/s). Non-finite or
    /// negative observations are ignored rather than poisoning the state.
    ///
    /// The smoothed level is clamped at zero: on a steep decaying ramp
    /// Holt's recursion (`level + trend` with a deeply negative trend)
    /// can otherwise push the internal level below zero, and a negative
    /// level leaks out of [`RateForecaster::level`] into demand inputs
    /// that must be non-negative — `plan_for_demand` sizes for the rate
    /// and the arrival constructors reject non-positive rates outright.
    pub fn observe(&mut self, rate: f64) {
        if !rate.is_finite() || rate < 0.0 {
            return;
        }
        if self.observations == 0 {
            self.level = rate;
            self.trend = 0.0;
        } else {
            let prev_level = self.level;
            self.level =
                (self.alpha * rate + (1.0 - self.alpha) * (self.level + self.trend)).max(0.0);
            self.trend = self.beta * (self.level - prev_level) + (1.0 - self.beta) * self.trend;
        }
        self.observations += 1;
    }

    /// Forecast the rate `horizon` observation windows ahead, clamped to
    /// be non-negative: Holt's linear trend extrapolates *negative* rates
    /// on a downward ramp, and a negative rate fed into
    /// `plan_for_demand` / `DemandWorkload` would hit the arrival
    /// validation that rejects non-positive rates. With no observations
    /// yet, returns 0.
    pub fn forecast(&self, horizon: f64) -> f64 {
        (self.level + self.trend * horizon).max(0.0)
    }

    /// Current smoothed level (requests/s).
    pub fn level(&self) -> f64 {
        self.level
    }

    /// Number of observations absorbed.
    pub fn observations(&self) -> u64 {
        self.observations
    }
}

/// Materialize the first `n` arrival timestamps of a process.
pub fn arrival_times(process: &mut dyn Arrival, n: usize) -> Vec<f64> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += process.next_gap();
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let mut p = PoissonArrival::new(50.0, 42);
        let times = arrival_times(&mut p, 20_000);
        let measured = times.len() as f64 / times.last().unwrap();
        assert!((measured - 50.0).abs() / 50.0 < 0.03, "measured rate {measured}");
    }

    #[test]
    fn poisson_gaps_are_variable() {
        let mut p = PoissonArrival::new(10.0, 7);
        let gaps: Vec<f64> = (0..1000).map(|_| p.next_gap()).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        // Exponential: std ≈ mean.
        assert!((var.sqrt() / mean - 1.0).abs() < 0.1);
    }

    #[test]
    fn uniform_gaps_are_constant() {
        let mut u = UniformArrival::new(4.0);
        assert_eq!(u.next_gap(), 0.25);
        assert_eq!(u.next_gap(), 0.25);
        assert_eq!(u.rate(), 4.0);
    }

    #[test]
    fn bursty_alternates() {
        let mut b = BurstyArrival::new(100.0, 1.0, 0.5, 3);
        let times = arrival_times(&mut b, 5000);
        // Average rate should sit strictly between low and high.
        let measured = times.len() as f64 / times.last().unwrap();
        assert!(measured > 1.0 && measured < 100.0, "rate {measured}");
    }

    #[test]
    fn arrivals_strictly_increasing() {
        let mut p = PoissonArrival::new(20.0, 11);
        let times = arrival_times(&mut p, 500);
        assert!(times.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn deterministic_with_seed() {
        let a = arrival_times(&mut PoissonArrival::new(5.0, 9), 100);
        let b = arrival_times(&mut PoissonArrival::new(5.0, 9), 100);
        assert_eq!(a, b);
    }

    #[test]
    fn constructors_reject_degenerate_rates() {
        assert!(PoissonArrival::try_new(0.0, 1).is_err());
        assert!(PoissonArrival::try_new(-3.0, 1).is_err());
        assert!(PoissonArrival::try_new(f64::NAN, 1).is_err());
        assert!(PoissonArrival::try_new(f64::INFINITY, 1).is_err());
        assert!(UniformArrival::try_new(f64::NEG_INFINITY).is_err());
        assert!(BurstyArrival::try_new(10.0, 0.0, 1.0, 1).is_err(), "low_rate must be positive");
        assert!(BurstyArrival::try_new(1.0, 2.0, 1.0, 1).is_err(), "high must exceed low");
        assert!(BurstyArrival::try_new(10.0, 1.0, 0.0, 1).is_err(), "mean_dwell_s <= 0");
        assert!(BurstyArrival::try_new(10.0, 1.0, f64::NAN, 1).is_err());
        assert!(DiurnalArrival::try_new(0.0, 10.0, 60.0, 1).is_err());
        assert!(DiurnalArrival::try_new(10.0, 5.0, 60.0, 1).is_err(), "peak below base");
        assert!(DiurnalArrival::try_new(5.0, 10.0, 0.0, 1).is_err(), "period must be positive");
        let e = PoissonArrival::try_new(f64::NAN, 1).unwrap_err();
        assert!(e.to_string().contains("poisson rate"), "{e}");
    }

    #[test]
    #[should_panic(expected = "invalid poisson rate")]
    fn panicking_constructor_names_the_parameter() {
        let _ = PoissonArrival::new(0.0, 7);
    }

    #[test]
    fn diurnal_rate_profile_and_mean() {
        let d = DiurnalArrival::new(10.0, 90.0, 600.0, 5);
        assert!((d.rate_at(0.0) - 10.0).abs() < 1e-9, "trough at t=0");
        assert!((d.rate_at(300.0) - 90.0).abs() < 1e-9, "peak at half period");
        assert!((d.rate_at(600.0) - 10.0).abs() < 1e-6, "back to trough");
        assert_eq!(d.rate(), 50.0);
        // Measured long-run rate over many periods approaches the mean.
        let mut d = DiurnalArrival::new(10.0, 90.0, 10.0, 5);
        let times = arrival_times(&mut d, 30_000);
        let measured = times.len() as f64 / times.last().unwrap();
        assert!((measured - 50.0).abs() / 50.0 < 0.05, "measured rate {measured}");
        assert!(times.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn diurnal_is_deterministic_per_seed() {
        let a = arrival_times(&mut DiurnalArrival::new(5.0, 50.0, 60.0, 11), 500);
        let b = arrival_times(&mut DiurnalArrival::new(5.0, 50.0, 60.0, 11), 500);
        assert_eq!(a, b);
    }

    #[test]
    fn spec_builds_and_reports_means() {
        let specs = [
            (ArrivalSpec::Poisson { rate: 8.0 }, 8.0),
            (ArrivalSpec::Uniform { rate: 4.0 }, 4.0),
            (ArrivalSpec::Bursty { high_rate: 30.0, low_rate: 10.0, mean_dwell_s: 1.0 }, 20.0),
            (ArrivalSpec::Diurnal { base_rate: 6.0, peak_rate: 60.0, period_s: 600.0 }, 33.0),
        ];
        for (spec, mean) in specs {
            spec.validate().unwrap();
            assert_eq!(spec.mean_rate(), mean, "{spec:?}");
            let mut p = spec.build(3).unwrap();
            assert!(p.next_gap() > 0.0);
        }
        assert!(ArrivalSpec::Poisson { rate: f64::NAN }.validate().is_err());
        assert!(ArrivalSpec::Diurnal { base_rate: 1.0, peak_rate: 0.5, period_s: 60.0 }
            .validate()
            .is_err());
    }

    #[test]
    fn replay_yields_exact_times_then_infinity() {
        let trace = vec![0.5, 0.5, 2.0, 7.25];
        let mut r = ReplayArrival::new(trace.clone());
        let mut t = 0.0;
        let mut seen = Vec::new();
        loop {
            let gap = r.next_gap();
            if !gap.is_finite() {
                break;
            }
            assert!(gap >= 0.0, "gaps never negative, got {gap}");
            t += gap;
            seen.push(t);
        }
        assert_eq!(seen, trace, "replay reproduces the trace exactly");
        // Stays exhausted.
        assert!(r.next_gap().is_infinite());
        assert!((r.rate() - 4.0 / 7.25).abs() < 1e-12);
    }

    #[test]
    fn replay_rejects_bad_traces_and_handles_degenerate_ones() {
        assert!(ReplayArrival::try_new(vec![1.0, 0.5]).is_err(), "unordered");
        assert!(ReplayArrival::try_new(vec![-1.0]).is_err(), "negative");
        assert!(ReplayArrival::try_new(vec![f64::NAN]).is_err(), "NaN");
        assert!(ReplayArrival::try_new(vec![f64::INFINITY]).is_err(), "infinite");
        // Empty trace: valid, zero rate, immediately exhausted.
        let mut empty = ReplayArrival::new(Vec::new());
        assert_eq!(empty.rate(), 0.0);
        assert!(empty.next_gap().is_infinite());
        // Sub-second trace: the 1 s span floor keeps the rate sane.
        let spec = ArrivalSpec::Replay { times: vec![0.1, 0.2] };
        assert_eq!(spec.mean_rate(), 2.0);
        spec.validate().unwrap();
        assert!(ArrivalSpec::Replay { times: vec![3.0, 1.0] }.validate().is_err());
    }

    #[test]
    fn forecaster_tracks_constant_and_ramp() {
        let mut f = RateForecaster::new(0.5, 0.3);
        assert_eq!(f.forecast(2.0), 0.0, "no observations yet");
        for _ in 0..30 {
            f.observe(42.0);
        }
        assert!((f.level() - 42.0).abs() < 1e-6);
        assert!((f.forecast(3.0) - 42.0).abs() < 1e-3, "constant series has no trend");
        // Linear ramp: the forecast must lead the latest observation.
        let mut f = RateForecaster::new(0.5, 0.3);
        let mut last = 0.0;
        for i in 0..60 {
            last = 10.0 + 2.0 * i as f64;
            f.observe(last);
        }
        assert!(f.forecast(2.0) > last, "forecast {} must lead ramp {last}", f.forecast(2.0));
        assert_eq!(f.observations(), 60);
        // Garbage observations are ignored.
        f.observe(f64::NAN);
        f.observe(-5.0);
        assert_eq!(f.observations(), 60);
    }

    #[test]
    fn decaying_ramp_never_forecasts_negative_rates() {
        // Regression: Holt's raw extrapolation of a steep downward ramp
        // is deeply negative (trend ≈ −10/window once the series bottoms
        // out at 0), and a negative rate fed into plan_for_demand /
        // DemandWorkload hits the non-positive-rate rejection paths.
        let mut f = RateForecaster::new(0.5, 0.3);
        for i in 0..40 {
            f.observe((200.0 - 10.0 * i as f64).max(0.0));
            assert!(f.level() >= 0.0, "level went negative at step {i}: {}", f.level());
        }
        for h in [0.5, 1.0, 2.0, 10.0, 1e3] {
            let fc = f.forecast(h);
            assert!(fc >= 0.0, "horizon {h}: forecast {fc} must clamp at zero");
            assert!(fc.is_finite());
        }
        // The clamped forecast stays a valid planner demand: sizing for
        // it must not trip the validation panic path.
        use crate::mig::gpu::GpuModel;
        use crate::scheduler::{DemandWorkload, Scheduler};
        use crate::workload::spec::WorkloadSpec;
        let bert = crate::models::zoo::lookup("bert-base").unwrap();
        let ws = vec![DemandWorkload::service(
            WorkloadSpec::inference(bert, 8, 128),
            40.0,
            f.forecast(2.0),
        )];
        let sched = Scheduler::new(GpuModel::A100_80GB);
        assert!(
            sched.plan_for_demand(&ws, 0.75).is_some(),
            "a zero-demand service must still be plannable"
        );
    }
}
