//! `migperf lint` — a std-only, dependency-free source auditor that
//! enforces the repo's bitwise-determinism contract statically.
//!
//! The dynamic layers (the model-based fuzzer, the equivalence tests)
//! only catch a determinism hazard after a seed happens to trigger it.
//! This pass catches whole hazard classes at the source level: unordered
//! hash-map traversal, wall-clock leakage into checksummed metrics,
//! non-total float comparators, ambient entropy, and panic-surface creep
//! in engine hot paths.
//!
//! Layout:
//! - [`lexer`] — a small Rust tokenizer (strings, chars, raw strings,
//!   nested block comments) so rules never fire inside literals.
//! - [`config`] — which paths carry the contract, the sanctioned
//!   wall-clock files, the budgeted hot-path modules, and the
//!   `lint-budget.toml` ratchet parser.
//! - [`rules`] — the rule engine (IDs `map-iteration`, `wall-clock`,
//!   `unstable-sort`, `float-order`, `ambient-entropy`, `panic-budget`,
//!   `debug-assert-effect`, `allow-syntax`).
//! - [`report`] — grep-style text and machine-readable JSON rendering.
//!
//! Suppression is per-line and must carry a reason:
//! `// lint:allow(rule-id, reason="why this is sound")`.

pub mod config;
pub mod lexer;
pub mod report;
pub mod rules;

use config::{parse_budget, BudgetTable, LintConfig};
use std::path::Path;

/// Stable identifiers for every rule, as written in findings and in
/// `lint:allow(...)` comments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// D1 — order-dependent `HashMap`/`HashSet` traversal in
    /// deterministic modules.
    MapIteration,
    /// D2 — `Instant::now`/`SystemTime`/`.elapsed()` outside sanctioned
    /// wall-clock files.
    WallClock,
    /// D3a — `sort_unstable_by`/`_by_key` without a visibly total
    /// comparator in deterministic modules.
    UnstableSort,
    /// D3b — `partial_cmp` in deterministic modules.
    FloatOrder,
    /// D4 — ambient entropy (`rand::`, `thread_rng`, `OsRng`, …).
    AmbientEntropy,
    /// D5 — unwrap/expect/panic/index counts above the checked-in
    /// ratchet for an engine hot-path module.
    PanicBudget,
    /// D6 — side-effectful expressions inside `debug_assert!` macros.
    DebugAssertEffect,
    /// Malformed `lint:allow` comment (unknown rule, missing reason).
    AllowSyntax,
}

impl RuleId {
    /// All rules, in catalog order.
    pub const ALL: [RuleId; 8] = [
        RuleId::MapIteration,
        RuleId::WallClock,
        RuleId::UnstableSort,
        RuleId::FloatOrder,
        RuleId::AmbientEntropy,
        RuleId::PanicBudget,
        RuleId::DebugAssertEffect,
        RuleId::AllowSyntax,
    ];

    /// The kebab-case id used in findings and `lint:allow`.
    pub fn as_str(self) -> &'static str {
        match self {
            RuleId::MapIteration => "map-iteration",
            RuleId::WallClock => "wall-clock",
            RuleId::UnstableSort => "unstable-sort",
            RuleId::FloatOrder => "float-order",
            RuleId::AmbientEntropy => "ambient-entropy",
            RuleId::PanicBudget => "panic-budget",
            RuleId::DebugAssertEffect => "debug-assert-effect",
            RuleId::AllowSyntax => "allow-syntax",
        }
    }

    /// Parse a kebab-case rule id.
    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::ALL.into_iter().find(|r| r.as_str() == s)
    }

    /// Whether `lint:allow` may suppress this rule. The panic budget is
    /// governed by `lint-budget.toml` instead, and a malformed allow
    /// must never be able to hide itself.
    pub fn suppressible(self) -> bool {
        !matches!(self, RuleId::PanicBudget | RuleId::AllowSyntax)
    }
}

/// Finding severity. Everything is an error except a stale (too-loose)
/// budget entry, which is a warning — and still fails under `--strict`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Fails the lint in every mode.
    Error,
    /// Fails the lint only under `--strict`.
    Warning,
}

/// One lint finding: location, rule, severity, human message, and the
/// trimmed offending source line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Forward-slash path as scanned.
    pub file: String,
    /// 1-based line number.
    pub line: u32,
    /// Which rule fired.
    pub rule: RuleId,
    /// Error or warning.
    pub severity: Severity,
    /// What is wrong and what to do instead.
    pub message: String,
    /// The trimmed source line (first 80 chars), empty for file-level
    /// findings.
    pub excerpt: String,
}

/// The result of a lint run over a set of paths.
#[derive(Debug, Clone)]
pub struct Report {
    /// All findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Whether the run was strict (warnings fail too).
    pub strict: bool,
}

impl Report {
    /// Number of error-severity findings.
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    /// Number of warning-severity findings.
    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// Whether this run should exit nonzero.
    pub fn failed(&self) -> bool {
        self.errors() > 0 || (self.strict && self.warnings() > 0)
    }
}

/// Lint the given paths (files are linted as-is; directories are walked
/// recursively for `.rs` files, skipping `walk_excludes`). The budget
/// file is optional overall but mandatory as soon as a budgeted module
/// is scanned.
pub fn run_paths(
    paths: &[String],
    budget_path: &str,
    strict: bool,
    cfg: &LintConfig,
) -> Result<Report, String> {
    let budget: Option<BudgetTable> = match std::fs::read_to_string(budget_path) {
        Ok(text) => {
            Some(parse_budget(&text).map_err(|e| format!("{budget_path}: {e}"))?)
        }
        Err(_) => None,
    };

    let mut files: Vec<String> = Vec::new();
    for p in paths {
        let norm = p.replace('\\', "/");
        let path = Path::new(&norm);
        if path.is_dir() {
            walk(path, cfg, &mut files)?;
        } else if path.is_file() {
            // Explicitly listed files are always linted, even under an
            // excluded directory — CI smoke-tests known-bad fixtures.
            files.push(norm);
        } else {
            return Err(format!("lint: no such file or directory: {p}"));
        }
    }
    files.sort();
    files.dedup();

    let mut findings: Vec<Finding> = Vec::new();
    for file in &files {
        let src = std::fs::read_to_string(file).map_err(|e| format!("{file}: {e}"))?;
        findings.extend(rules::check_source(file, &src, cfg, budget.as_ref()));
    }
    findings.sort_by(|a, b| {
        a.file
            .cmp(&b.file)
            .then_with(|| a.line.cmp(&b.line))
            .then_with(|| a.rule.as_str().cmp(b.rule.as_str()))
    });
    Ok(Report { findings, files_scanned: files.len(), strict })
}

/// Recursive directory walk in sorted name order (deterministic report
/// ordering regardless of readdir order).
fn walk(dir: &Path, cfg: &LintConfig, out: &mut Vec<String>) -> Result<(), String> {
    let mut entries: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("{}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for entry in entries {
        let norm = entry.to_string_lossy().replace('\\', "/");
        if cfg.walk_excludes.iter().any(|x| norm.contains(x.as_str())) {
            continue;
        }
        if entry.is_dir() {
            walk(&entry, cfg, out)?;
        } else if norm.ends_with(".rs") {
            out.push(norm);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_roundtrip() {
        for rule in RuleId::ALL {
            assert_eq!(RuleId::parse(rule.as_str()), Some(rule));
        }
        assert_eq!(RuleId::parse("no-such-rule"), None);
    }

    #[test]
    fn budget_and_allow_syntax_are_not_suppressible() {
        assert!(!RuleId::PanicBudget.suppressible());
        assert!(!RuleId::AllowSyntax.suppressible());
        assert!(RuleId::WallClock.suppressible());
        assert!(RuleId::MapIteration.suppressible());
    }

    #[test]
    fn report_failure_semantics() {
        let warn = Finding {
            file: "f.rs".to_string(),
            line: 1,
            rule: RuleId::PanicBudget,
            severity: Severity::Warning,
            message: String::new(),
            excerpt: String::new(),
        };
        let lenient = Report { findings: vec![warn.clone()], files_scanned: 1, strict: false };
        assert!(!lenient.failed(), "warnings pass in default mode");
        let strict = Report { findings: vec![warn], files_scanned: 1, strict: true };
        assert!(strict.failed(), "warnings fail under --strict");
    }

    #[test]
    fn missing_path_is_an_error() {
        let cfg = LintConfig::default();
        let paths = vec!["definitely/not/a/path.rs".to_string()];
        assert!(run_paths(&paths, "lint-budget.toml", false, &cfg).is_err());
    }
}
