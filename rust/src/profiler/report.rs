//! Benchmark report: the structured result of a profiling session.

use crate::metrics::collector::RunSummary;
use crate::metrics::export::summary_to_json;
use crate::util::json::Json;
use crate::util::table::{fmt_num, Table};

/// One row: a (instance, batch, seq) point and its summary.
#[derive(Debug, Clone)]
pub struct ReportRow {
    /// Instance label (GI profile or sharing mode).
    pub instance: String,
    /// Batch size at this point.
    pub batch: u32,
    /// Sequence length at this point.
    pub seq: u32,
    /// Aggregated metrics.
    pub summary: RunSummary,
    /// If set, the point did not run (e.g. OOM) and this explains why.
    pub skipped: Option<String>,
}

impl ReportRow {
    /// A skipped point (OOM etc.) with an empty summary.
    pub fn skipped(instance: String, batch: u32, seq: u32, reason: String) -> Self {
        ReportRow {
            instance,
            batch,
            seq,
            summary: RunSummary {
                label: String::new(),
                completed: 0,
                avg_latency_ms: 0.0,
                std_latency_ms: 0.0,
                p50_latency_ms: 0.0,
                p99_latency_ms: 0.0,
                max_latency_ms: 0.0,
                throughput: 0.0,
                mean_gract: 0.0,
                peak_fb_mib: 0.0,
                energy_j: 0.0,
                duration_s: 0.0,
            },
            skipped: Some(reason),
        }
    }
}

/// Full report for one benchmark task.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Task name.
    pub name: String,
    rows: Vec<ReportRow>,
}

impl BenchReport {
    /// Empty report for a task.
    pub fn new(name: &str) -> Self {
        BenchReport { name: name.to_string(), rows: Vec::new() }
    }

    /// Append a row.
    pub fn push(&mut self, row: ReportRow) {
        self.rows.push(row);
    }

    /// All rows.
    pub fn rows(&self) -> &[ReportRow] {
        &self.rows
    }

    /// Rows for one instance label, in sweep order.
    pub fn for_instance(&self, instance: &str) -> Vec<&ReportRow> {
        self.rows.iter().filter(|r| r.instance == instance).collect()
    }

    /// Distinct instance labels, in first-appearance order.
    pub fn instances(&self) -> Vec<&str> {
        let mut seen = Vec::new();
        for r in &self.rows {
            if !seen.contains(&r.instance.as_str()) {
                seen.push(r.instance.as_str());
            }
        }
        seen
    }

    /// Extract one metric as a series per instance: `(instance, [(x, y)])`
    /// with `x` = batch (or seq when sweeping seq).
    pub fn series(
        &self,
        metric: impl Fn(&RunSummary) -> f64,
        x_is_seq: bool,
    ) -> Vec<(String, Vec<(u32, f64)>)> {
        self.instances()
            .into_iter()
            .map(|inst| {
                let pts = self
                    .for_instance(inst)
                    .into_iter()
                    .filter(|r| r.skipped.is_none())
                    .map(|r| (if x_is_seq { r.seq } else { r.batch }, metric(&r.summary)))
                    .collect();
                (inst.to_string(), pts)
            })
            .collect()
    }

    /// Render as an aligned text table.
    pub fn render_table(&self) -> String {
        let mut t = Table::new(&[
            "instance", "batch", "seq", "avg_ms", "p99_ms", "tput", "gract", "fb_mib",
            "energy_j", "note",
        ]);
        for r in &self.rows {
            if let Some(reason) = &r.skipped {
                t.row(&[
                    r.instance.clone(),
                    r.batch.to_string(),
                    r.seq.to_string(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    reason.clone(),
                ]);
            } else {
                let s = &r.summary;
                t.row(&[
                    r.instance.clone(),
                    r.batch.to_string(),
                    r.seq.to_string(),
                    fmt_num(s.avg_latency_ms),
                    fmt_num(s.p99_latency_ms),
                    fmt_num(s.throughput),
                    fmt_num(s.mean_gract),
                    fmt_num(s.peak_fb_mib),
                    fmt_num(s.energy_j),
                    String::new(),
                ]);
            }
        }
        format!("== {} ==\n{}", self.name, t.render())
    }

    /// Serialize to JSON (array of row objects under the task name).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("instance", Json::from(r.instance.as_str())),
                    ("batch", (r.batch as i64).into()),
                    ("seq", (r.seq as i64).into()),
                    ("summary", summary_to_json(&r.summary)),
                ];
                if let Some(reason) = &r.skipped {
                    fields.push(("skipped", reason.as_str().into()));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![("task", self.name.as_str().into()), ("rows", Json::Arr(rows))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(inst: &str, batch: u32, tput: f64) -> ReportRow {
        let mut r = ReportRow::skipped(inst.to_string(), batch, 128, String::new());
        r.skipped = None;
        r.summary.throughput = tput;
        r.summary.completed = 1;
        r
    }

    #[test]
    fn instances_dedup_in_order() {
        let mut rep = BenchReport::new("t");
        rep.push(row("a", 1, 1.0));
        rep.push(row("b", 1, 2.0));
        rep.push(row("a", 2, 3.0));
        assert_eq!(rep.instances(), vec!["a", "b"]);
        assert_eq!(rep.for_instance("a").len(), 2);
    }

    #[test]
    fn series_extraction() {
        let mut rep = BenchReport::new("t");
        rep.push(row("a", 8, 100.0));
        rep.push(row("a", 16, 150.0));
        rep.push(ReportRow::skipped("a".into(), 32, 128, "oom".into()));
        let s = rep.series(|x| x.throughput, false);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].1, vec![(8, 100.0), (16, 150.0)]); // skipped omitted
    }

    #[test]
    fn table_marks_skipped() {
        let mut rep = BenchReport::new("t");
        rep.push(ReportRow::skipped("1g.10gb".into(), 64, 128, "out of memory".into()));
        let out = rep.render_table();
        assert!(out.contains("out of memory"));
        assert!(out.contains("== t =="));
    }

    #[test]
    fn json_shape() {
        let mut rep = BenchReport::new("fig2");
        rep.push(row("a", 8, 100.0));
        let j = rep.to_json();
        assert_eq!(j.get("task").unwrap().as_str(), Some("fig2"));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
