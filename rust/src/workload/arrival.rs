//! Request arrival processes.
//!
//! The appendix experiments (Figs 10–11) "send asynchronous requests to
//! each server simultaneously with different request workloads (i.e.,
//! request arrival rate)". This module generates those streams: Poisson
//! (exponential gaps), uniform (fixed gaps) and bursty (Markov-modulated
//! on/off) arrivals, all on the deterministic PRNG.

use crate::util::prng::Prng;

/// An arrival process that yields inter-arrival gaps (seconds).
pub trait Arrival {
    /// Next gap before the following request.
    fn next_gap(&mut self) -> f64;
    /// Mean request rate (requests/second) of the process.
    fn rate(&self) -> f64;
}

/// Poisson process: exponential inter-arrival gaps at a fixed rate.
#[derive(Debug)]
pub struct PoissonArrival {
    rate: f64,
    rng: Prng,
}

impl PoissonArrival {
    /// Poisson process with `rate` requests/second.
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!(rate > 0.0);
        PoissonArrival { rate, rng: Prng::new(seed) }
    }
}

impl Arrival for PoissonArrival {
    fn next_gap(&mut self) -> f64 {
        self.rng.exponential(self.rate)
    }
    fn rate(&self) -> f64 {
        self.rate
    }
}

/// Deterministic uniform arrivals (fixed gap).
#[derive(Debug)]
pub struct UniformArrival {
    gap: f64,
}

impl UniformArrival {
    /// Uniform arrivals at `rate` requests/second.
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        UniformArrival { gap: 1.0 / rate }
    }
}

impl Arrival for UniformArrival {
    fn next_gap(&mut self) -> f64 {
        self.gap
    }
    fn rate(&self) -> f64 {
        1.0 / self.gap
    }
}

/// Markov-modulated on/off burst process: alternates between a burst state
/// (high rate) and an idle state (low rate), with exponential dwell times.
/// Extension beyond the paper for stress-testing batching policies.
#[derive(Debug)]
pub struct BurstyArrival {
    high_rate: f64,
    low_rate: f64,
    mean_dwell_s: f64,
    in_burst: bool,
    state_left_s: f64,
    rng: Prng,
}

impl BurstyArrival {
    /// Bursty process alternating between `high_rate` and `low_rate`
    /// (requests/s), with exponential state dwell of mean `mean_dwell_s`.
    pub fn new(high_rate: f64, low_rate: f64, mean_dwell_s: f64, seed: u64) -> Self {
        assert!(high_rate > low_rate && low_rate > 0.0 && mean_dwell_s > 0.0);
        let mut rng = Prng::new(seed);
        let dwell = rng.exponential(1.0 / mean_dwell_s);
        BurstyArrival {
            high_rate,
            low_rate,
            mean_dwell_s,
            in_burst: true,
            state_left_s: dwell,
            rng,
        }
    }
}

impl Arrival for BurstyArrival {
    fn next_gap(&mut self) -> f64 {
        let rate = if self.in_burst { self.high_rate } else { self.low_rate };
        let gap = self.rng.exponential(rate);
        self.state_left_s -= gap;
        if self.state_left_s <= 0.0 {
            self.in_burst = !self.in_burst;
            self.state_left_s = self.rng.exponential(1.0 / self.mean_dwell_s);
        }
        gap
    }
    fn rate(&self) -> f64 {
        // Long-run average with symmetric dwell times.
        (self.high_rate + self.low_rate) / 2.0
    }
}

/// Materialize the first `n` arrival timestamps of a process.
pub fn arrival_times(process: &mut dyn Arrival, n: usize) -> Vec<f64> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += process.next_gap();
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let mut p = PoissonArrival::new(50.0, 42);
        let times = arrival_times(&mut p, 20_000);
        let measured = times.len() as f64 / times.last().unwrap();
        assert!((measured - 50.0).abs() / 50.0 < 0.03, "measured rate {measured}");
    }

    #[test]
    fn poisson_gaps_are_variable() {
        let mut p = PoissonArrival::new(10.0, 7);
        let gaps: Vec<f64> = (0..1000).map(|_| p.next_gap()).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        // Exponential: std ≈ mean.
        assert!((var.sqrt() / mean - 1.0).abs() < 0.1);
    }

    #[test]
    fn uniform_gaps_are_constant() {
        let mut u = UniformArrival::new(4.0);
        assert_eq!(u.next_gap(), 0.25);
        assert_eq!(u.next_gap(), 0.25);
        assert_eq!(u.rate(), 4.0);
    }

    #[test]
    fn bursty_alternates() {
        let mut b = BurstyArrival::new(100.0, 1.0, 0.5, 3);
        let times = arrival_times(&mut b, 5000);
        // Average rate should sit strictly between low and high.
        let measured = times.len() as f64 / times.last().unwrap();
        assert!(measured > 1.0 && measured < 100.0, "rate {measured}");
    }

    #[test]
    fn arrivals_strictly_increasing() {
        let mut p = PoissonArrival::new(20.0, 11);
        let times = arrival_times(&mut p, 500);
        assert!(times.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn deterministic_with_seed() {
        let a = arrival_times(&mut PoissonArrival::new(5.0, 9), 100);
        let b = arrival_times(&mut PoissonArrival::new(5.0, 9), 100);
        assert_eq!(a, b);
    }
}
