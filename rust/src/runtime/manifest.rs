//! AOT artifact manifest.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing
//! every lowered HLO entry point: file name, input tensor specs, output
//! arity and the analytic FLOPs of the step (used for calibration). It
//! also dumps initial parameters for the training entry point as raw
//! little-endian f32 in `artifacts/<name>.params.bin`. This module parses
//! that manifest.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Element type of a tensor input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" | "float32" => Some(DType::F32),
            "i32" | "int32" => Some(DType::I32),
            _ => None,
        }
    }

    /// Bytes per element.
    pub fn size(&self) -> usize {
        4
    }
}

/// One tensor argument of an entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Argument name (informational).
    pub name: String,
    /// Shape, row-major.
    pub shape: Vec<i64>,
    /// Element dtype.
    pub dtype: DType,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<i64>() as usize
    }
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone)]
pub struct EntryPoint {
    /// Entry name, e.g. `bert_tiny_infer_b8`.
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub hlo_file: String,
    /// Input tensor specs, in call order. For training entries the
    /// parameter tensors come first, then the data batch.
    pub inputs: Vec<TensorSpec>,
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
    /// Analytic FLOPs of one execution (for calibration).
    pub flops: f64,
    /// Parameter-initialization blob, if this entry trains.
    pub params_file: Option<String>,
    /// Number of leading inputs that are parameters (training entries).
    pub num_param_inputs: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// All entry points.
    pub entries: Vec<EntryPoint>,
}

/// Manifest errors.
#[derive(Debug)]
pub enum ManifestError {
    /// File could not be read.
    Io(PathBuf, std::io::Error),
    /// JSON was malformed.
    Json(json::ParseError),
    /// Schema violation.
    Schema(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(path, e) => {
                write!(f, "cannot read manifest at {}: {e}", path.display())
            }
            ManifestError::Json(e) => write!(f, "manifest JSON invalid: {e}"),
            ManifestError::Schema(msg) => write!(f, "manifest schema error: {msg}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(_, e) => Some(e),
            ManifestError::Json(e) => Some(e),
            ManifestError::Schema(_) => None,
        }
    }
}

impl From<json::ParseError> for ManifestError {
    fn from(e: json::ParseError) -> Self {
        ManifestError::Json(e)
    }
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text =
            std::fs::read_to_string(&path).map_err(|e| ManifestError::Io(path.clone(), e))?;
        let v = json::parse(&text)?;
        Self::from_json(dir, &v)
    }

    /// Parse from an already-loaded JSON document.
    pub fn from_json(dir: PathBuf, v: &Json) -> Result<Manifest, ManifestError> {
        let schema = |m: &str| ManifestError::Schema(m.to_string());
        let entries_json =
            v.get("entries").and_then(Json::as_arr).ok_or_else(|| schema("missing 'entries'"))?;
        let mut entries = Vec::new();
        for e in entries_json {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| schema("entry missing 'name'"))?
                .to_string();
            let hlo_file = e
                .get("hlo_file")
                .and_then(Json::as_str)
                .ok_or_else(|| schema("entry missing 'hlo_file'"))?
                .to_string();
            let inputs_json = e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| schema("entry missing 'inputs'"))?;
            let mut inputs = Vec::new();
            for i in inputs_json {
                let iname =
                    i.get("name").and_then(Json::as_str).unwrap_or("arg").to_string();
                let dtype_s = i
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or_else(|| schema("input missing 'dtype'"))?;
                let dtype = DType::parse(dtype_s)
                    .ok_or_else(|| schema(&format!("unsupported dtype '{dtype_s}'")))?;
                let shape = i
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| schema("input missing 'shape'"))?
                    .iter()
                    .map(|d| d.as_i64().ok_or_else(|| schema("non-integer dim")))
                    .collect::<Result<Vec<_>, _>>()?;
                inputs.push(TensorSpec { name: iname, shape, dtype });
            }
            entries.push(EntryPoint {
                name,
                hlo_file,
                inputs,
                num_outputs: e.get("num_outputs").and_then(Json::as_i64).unwrap_or(1) as usize,
                flops: e.get("flops").and_then(Json::as_f64).unwrap_or(0.0),
                params_file: e.get("params_file").and_then(Json::as_str).map(str::to_string),
                num_param_inputs: e.get("num_param_inputs").and_then(Json::as_i64).unwrap_or(0)
                    as usize,
            });
        }
        Ok(Manifest { dir, entries })
    }

    /// Find an entry by name.
    pub fn entry(&self, name: &str) -> Option<&EntryPoint> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &EntryPoint) -> PathBuf {
        self.dir.join(&entry.hlo_file)
    }

    /// Absolute path of an entry's params blob, if any.
    pub fn params_path(&self, entry: &EntryPoint) -> Option<PathBuf> {
        entry.params_file.as_ref().map(|f| self.dir.join(f))
    }
}

/// Read a raw little-endian f32 blob (the params file format).
pub fn read_f32_blob(path: &Path) -> std::io::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("f32 blob length {} not a multiple of 4", bytes.len()),
        ));
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "entries": [
        {"name": "bert_tiny_infer_b4",
         "hlo_file": "bert_tiny_infer_b4.hlo.txt",
         "inputs": [{"name": "tokens", "dtype": "i32", "shape": [4, 32]}],
         "num_outputs": 1, "flops": 123456.0},
        {"name": "bert_tiny_train_b8",
         "hlo_file": "bert_tiny_train_b8.hlo.txt",
         "inputs": [
            {"name": "w0", "dtype": "f32", "shape": [64, 64]},
            {"name": "tokens", "dtype": "i32", "shape": [8, 32]}],
         "num_outputs": 2, "flops": 1e6,
         "params_file": "bert_tiny.params.bin", "num_param_inputs": 1}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let v = json::parse(DOC).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/a"), &v).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("bert_tiny_infer_b4").unwrap();
        assert_eq!(e.inputs[0].dtype, DType::I32);
        assert_eq!(e.inputs[0].elements(), 128);
        assert_eq!(e.num_outputs, 1);
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn training_entry_has_params() {
        let v = json::parse(DOC).unwrap();
        let m = Manifest::from_json(PathBuf::from("/x"), &v).unwrap();
        let e = m.entry("bert_tiny_train_b8").unwrap();
        assert_eq!(e.num_param_inputs, 1);
        assert_eq!(m.params_path(e).unwrap(), PathBuf::from("/x/bert_tiny.params.bin"));
        assert_eq!(m.hlo_path(e), PathBuf::from("/x/bert_tiny_train_b8.hlo.txt"));
    }

    #[test]
    fn schema_errors() {
        let bad = json::parse(r#"{"entries": [{"name": "x"}]}"#).unwrap();
        assert!(Manifest::from_json(PathBuf::new(), &bad).is_err());
        let no_entries = json::parse("{}").unwrap();
        assert!(Manifest::from_json(PathBuf::new(), &no_entries).is_err());
    }

    #[test]
    fn f32_blob_roundtrip() {
        let dir = std::env::temp_dir().join("migperf-test-blob");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let vals = [1.5f32, -2.25, 0.0, 3.0e-5];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(read_f32_blob(&path).unwrap(), vals);
        std::fs::write(&path, [0u8; 5]).unwrap();
        assert!(read_f32_blob(&path).is_err());
    }
}
