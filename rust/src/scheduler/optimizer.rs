//! Exhaustive MIG partition optimizer.

use crate::mig::enumerate::{maximal_layouts, Layout};
use crate::mig::gpu::GpuModel;
use crate::mig::profile::profiles_for;
use crate::simgpu::energy::EnergyModel;
use crate::simgpu::perfmodel::{PerfModel, StepEstimate};
use crate::simgpu::resource::ExecResource;
use crate::workload::spec::WorkloadSpec;

/// A workload to place, with an optional latency SLO (inference).
#[derive(Debug, Clone)]
pub struct SloWorkload {
    /// The workload.
    pub spec: WorkloadSpec,
    /// Per-step latency budget in milliseconds (None for training /
    /// best-effort jobs).
    pub slo_ms: Option<f64>,
}

impl SloWorkload {
    /// Best-effort workload (no SLO).
    pub fn best_effort(spec: WorkloadSpec) -> Self {
        SloWorkload { spec, slo_ms: None }
    }

    /// Latency-bound workload.
    pub fn with_slo(spec: WorkloadSpec, slo_ms: f64) -> Self {
        SloWorkload { spec, slo_ms: Some(slo_ms) }
    }
}

/// Optimization objective.
///
/// Under [`Objective::MaxThroughput`], SLO-bound workloads contribute
/// *goodput*: their throughput counts only up to the rate their SLO
/// demands (`batch / slo`), because serving a request faster than its
/// deadline adds no value. Best-effort workloads (training) contribute
/// raw throughput. This is what makes the optimizer hand the big slice
/// to training in the paper's hybrid scenario instead of gold-plating an
/// inference service that was already meeting its SLO.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Maximize summed goodput (samples/s, SLO-capped) across workloads.
    MaxThroughput,
    /// Minimize summed power draw while meeting SLOs.
    MinEnergy,
}

/// One placement decision in a plan.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// Index into the submitted workload list.
    pub workload: usize,
    /// GI profile name the workload got.
    pub profile: &'static str,
    /// Predicted per-step latency, ms.
    pub latency_ms: f64,
    /// Predicted throughput, samples/s.
    pub throughput: f64,
    /// SLO-capped goodput, samples/s (== throughput for best-effort).
    pub goodput: f64,
    /// Predicted power draw, W.
    pub power_w: f64,
}

/// A complete scheduling plan.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Chosen layout (profile names in offset order).
    pub layout: Vec<&'static str>,
    /// Workload → instance assignments.
    pub assignments: Vec<Assignment>,
    /// Objective score (higher is better; energy objective is negated).
    pub score: f64,
}

/// A workload with an observed or forecast demand rate — the online
/// orchestrator's planning input. SLO services carry `demand_rps`
/// (requests/s); best-effort training jobs carry neither an SLO nor a
/// demand and are valued by raw throughput.
#[derive(Debug, Clone)]
pub struct DemandWorkload {
    /// The workload.
    pub spec: WorkloadSpec,
    /// Per-request latency budget, ms (None for best-effort jobs).
    pub slo_ms: Option<f64>,
    /// Demand rate to size for, requests/s (None for best-effort jobs).
    pub demand_rps: Option<f64>,
}

impl DemandWorkload {
    /// Latency-bound service with a demand rate.
    pub fn service(spec: WorkloadSpec, slo_ms: f64, demand_rps: f64) -> Self {
        DemandWorkload { spec, slo_ms: Some(slo_ms), demand_rps: Some(demand_rps) }
    }

    /// Best-effort workload (training): no SLO, no demand cap.
    pub fn training(spec: WorkloadSpec) -> Self {
        DemandWorkload { spec, slo_ms: None, demand_rps: None }
    }
}

/// One workload → instance decision in a demand-aware plan.
#[derive(Debug, Clone)]
pub struct RateAssignment {
    /// Index into the submitted workload list.
    pub workload: usize,
    /// Index into the plan layout's placements.
    pub instance: usize,
    /// GI profile name of that instance.
    pub profile: &'static str,
    /// Isolated per-request/step latency, ms.
    pub service_ms: f64,
    /// Predicted sojourn including M/D/1 queueing at the demand rate, ms.
    pub latency_ms: f64,
    /// Predicted utilization ρ = demand × service time (1.0 for
    /// best-effort jobs, which run back-to-back).
    pub utilization: f64,
    /// Samples/s credited to the plan score (demand-capped goodput for
    /// services, raw throughput for best-effort jobs).
    pub value: f64,
}

/// A demand-aware plan over a concrete layout (with placements, so the
/// orchestrator can validate it and diff instance churn against the
/// previous layout).
#[derive(Debug, Clone)]
pub struct RatePlan {
    /// Chosen layout.
    pub layout: Layout,
    /// Workload → instance assignments.
    pub assignments: Vec<RateAssignment>,
    /// Summed assignment value (samples/s).
    pub score: f64,
}

impl RatePlan {
    /// Profile names in offset order.
    pub fn profile_names(&self) -> Vec<&'static str> {
        self.layout.profile_names()
    }

    /// Instance index assigned to `workload`, if any.
    pub fn instance_of(&self, workload: usize) -> Option<usize> {
        self.assignments.iter().find(|a| a.workload == workload).map(|a| a.instance)
    }
}

/// The optimizer.
#[derive(Debug)]
pub struct Scheduler {
    /// GPU being partitioned.
    pub gpu: GpuModel,
    /// Performance model used for predictions.
    pub perf: PerfModel,
    /// Energy model used for power predictions.
    pub energy: EnergyModel,
}

impl Scheduler {
    /// Scheduler with default models.
    pub fn new(gpu: GpuModel) -> Self {
        Scheduler { gpu, perf: PerfModel::default(), energy: EnergyModel::default() }
    }

    /// Find the best plan for `workloads` under `objective`.
    ///
    /// Returns `None` when no layout can host every workload within its
    /// SLO (and memory). Exhaustive over layouts × assignments; workload
    /// counts in the paper's scenarios are ≤ 7, so the assignment search
    /// (distinct instances, best-profile-first) stays tiny.
    pub fn plan(&self, workloads: &[SloWorkload], objective: Objective) -> Option<Plan> {
        if workloads.is_empty() {
            return None;
        }
        let mut best: Option<Plan> = None;
        for layout in maximal_layouts(self.gpu) {
            if layout.len() < workloads.len() {
                continue; // not enough instances
            }
            if let Some(plan) = self.best_assignment(&layout, workloads, objective) {
                let better = match &best {
                    None => true,
                    Some(b) => plan.score > b.score,
                };
                if better {
                    best = Some(plan);
                }
            }
        }
        best
    }

    /// Best assignment of workloads onto a specific layout, or None if
    /// some workload cannot meet its SLO anywhere.
    fn best_assignment(
        &self,
        layout: &Layout,
        workloads: &[SloWorkload],
        objective: Objective,
    ) -> Option<Plan> {
        // Predict each workload on each distinct instance of the layout.
        let resources: Vec<ExecResource> = layout
            .placements
            .iter()
            .map(|p| ExecResource::from_gi(self.gpu, p.profile))
            .collect();
        // candidates[w][i] = Some(assignment) if workload w fits instance i.
        let candidates: Vec<Vec<Option<Assignment>>> = workloads
            .iter()
            .enumerate()
            .map(|(wi, w)| {
                resources
                    .iter()
                    .enumerate()
                    .map(|(ri, res)| {
                        let est = self.perf.step(res, &w.spec.step_cost()).ok()?;
                        let latency_ms = est.seconds * 1e3;
                        let throughput = w.spec.batch as f64 / est.seconds;
                        let goodput = match w.slo_ms {
                            Some(slo) => {
                                if latency_ms > slo {
                                    return None;
                                }
                                // Value saturates at the SLO-demanded rate.
                                throughput.min(w.spec.batch as f64 * 1e3 / slo)
                            }
                            None => throughput,
                        };
                        Some(Assignment {
                            workload: wi,
                            profile: layout.placements[ri].profile.name,
                            latency_ms,
                            throughput,
                            goodput,
                            power_w: self.energy.marginal_power_w(res, est.gract),
                        })
                    })
                    .collect()
            })
            .collect();

        // Branch-and-bound over injective assignments (≤7! worst case,
        // but layouts have ≤7 instances and pruning cuts hard).
        let mut used = vec![false; resources.len()];
        let mut chosen: Vec<Assignment> = Vec::new();
        let mut best: Option<(f64, Vec<Assignment>)> = None;
        Self::search(&candidates, objective, 0, &mut used, &mut chosen, &mut best);
        let (score, assignments) = best?;
        Some(Plan { layout: layout.profile_names(), assignments, score })
    }

    fn score_of(a: &Assignment, objective: Objective) -> f64 {
        match objective {
            Objective::MaxThroughput => a.goodput,
            Objective::MinEnergy => -a.power_w,
        }
    }

    /// Queueing-aware candidate for one demand workload on one instance:
    /// `None` when the workload does not fit (OOM) or — for SLO services —
    /// when the instance cannot sustain `demand_rps` within the SLO.
    ///
    /// Feasibility uses an M/D/1 sojourn estimate: utilization
    /// `ρ = demand × service_time` must stay at or below `rho_max`, and
    /// the predicted latency `service × (1 + ρ / (2(1 − ρ)))` must stay
    /// within the SLO. The assignment's value is demand-capped goodput
    /// (samples/s) for services and raw throughput for best-effort jobs.
    fn rate_candidate(
        &self,
        wi: usize,
        w: &DemandWorkload,
        ri: usize,
        res: &ExecResource,
        profile: &'static str,
        rho_max: f64,
    ) -> Option<RateAssignment> {
        let est = self.perf.step(res, &w.spec.step_cost()).ok()?;
        Self::rate_candidate_from_est(wi, w, ri, profile, est, rho_max)
    }

    fn rate_candidate_from_est(
        wi: usize,
        w: &DemandWorkload,
        ri: usize,
        profile: &'static str,
        est: StepEstimate,
        rho_max: f64,
    ) -> Option<RateAssignment> {
        let service_ms = est.seconds * 1e3;
        match w.slo_ms {
            Some(slo) => {
                let demand = w.demand_rps.unwrap_or(0.0).max(0.0);
                let rho = demand * est.seconds;
                if rho > rho_max {
                    return None;
                }
                let latency_ms = service_ms * (1.0 + rho / (2.0 * (1.0 - rho)));
                if latency_ms > slo {
                    return None;
                }
                Some(RateAssignment {
                    workload: wi,
                    instance: ri,
                    profile,
                    service_ms,
                    latency_ms,
                    utilization: rho,
                    // rho <= rho_max already caps demand at the instance's
                    // sustainable rate, so the full demand is creditable.
                    value: demand * w.spec.batch as f64,
                })
            }
            None => Some(RateAssignment {
                workload: wi,
                instance: ri,
                profile,
                service_ms,
                latency_ms: service_ms,
                utilization: 1.0, // best-effort jobs run back-to-back
                value: w.spec.batch as f64 / est.seconds,
            }),
        }
    }

    /// Find the best layout + assignment for demand-rated workloads —
    /// the online orchestrator's planning primitive (MISO-style: candidate
    /// layouts come from [`maximal_layouts`], each scored with the
    /// roofline performance model under the supplied demand rates).
    ///
    /// Returns `None` when no maximal layout can host every workload
    /// within memory, SLO and the `rho_max` utilization bound.
    pub fn plan_for_demand(
        &self,
        workloads: &[DemandWorkload],
        rho_max: f64,
    ) -> Option<RatePlan> {
        if workloads.is_empty() || !(0.0..1.0).contains(&rho_max) || rho_max <= 0.0 {
            return None;
        }
        // Memoize the roofline estimate per (workload, GI profile): it
        // depends only on the profile, not on where the instance sits in a
        // layout, and the online policies re-run this whole search every
        // observation window.
        let profiles = profiles_for(self.gpu);
        let est_memo: Vec<Vec<Option<StepEstimate>>> = workloads
            .iter()
            .map(|w| {
                let cost = w.spec.step_cost();
                profiles
                    .iter()
                    .map(|p| self.perf.step(&ExecResource::from_gi(self.gpu, p), &cost).ok())
                    .collect()
            })
            .collect();
        let profile_index = |name: &'static str| {
            profiles.iter().position(|p| p.name == name).expect("profile from this GPU's table")
        };
        let mut best: Option<RatePlan> = None;
        for layout in maximal_layouts(self.gpu) {
            if layout.len() < workloads.len() {
                continue;
            }
            let candidates: Vec<Vec<Option<RateAssignment>>> = workloads
                .iter()
                .enumerate()
                .map(|(wi, w)| {
                    layout
                        .placements
                        .iter()
                        .enumerate()
                        .map(|(ri, pl)| {
                            let est = est_memo[wi][profile_index(pl.profile.name)]?;
                            Self::rate_candidate_from_est(wi, w, ri, pl.profile.name, est, rho_max)
                        })
                        .collect()
                })
                .collect();
            let mut used = vec![false; layout.len()];
            let mut chosen: Vec<RateAssignment> = Vec::new();
            let mut found: Option<(f64, Vec<RateAssignment>)> = None;
            Self::search_rate(&candidates, 0, &mut used, &mut chosen, &mut found);
            if let Some((score, assignments)) = found {
                let better = best.as_ref().map(|b| score > b.score).unwrap_or(true);
                if better {
                    best = Some(RatePlan { layout, assignments, score });
                }
            }
        }
        best
    }

    /// Re-score an existing plan's assignments under (new) demand rates.
    ///
    /// Returns `(score, feasible)`: `feasible` is false when some SLO
    /// service no longer meets its latency/utilization bound on its
    /// current instance — the orchestrator's repartition trigger. The
    /// score stays finite in that case by crediting the instance's
    /// sustainable goodput instead of the full demand.
    pub fn evaluate_plan(
        &self,
        plan: &RatePlan,
        workloads: &[DemandWorkload],
        rho_max: f64,
    ) -> (f64, bool) {
        let mut score = 0.0;
        let mut feasible = true;
        for a in &plan.assignments {
            let Some(w) = workloads.get(a.workload) else {
                feasible = false;
                continue;
            };
            let res = ExecResource::from_gi(self.gpu, plan.layout.placements[a.instance].profile);
            match self.rate_candidate(a.workload, w, a.instance, &res, a.profile, rho_max) {
                Some(c) => score += c.value,
                None => {
                    feasible = false;
                    if let Ok(est) = self.perf.step(&res, &w.spec.step_cost()) {
                        let capacity_rps = rho_max / est.seconds;
                        let demand = w.demand_rps.unwrap_or(0.0).max(0.0);
                        score += demand.min(capacity_rps) * w.spec.batch as f64;
                    }
                }
            }
        }
        (score, feasible)
    }

    fn search_rate(
        candidates: &[Vec<Option<RateAssignment>>],
        w: usize,
        used: &mut [bool],
        chosen: &mut Vec<RateAssignment>,
        best: &mut Option<(f64, Vec<RateAssignment>)>,
    ) {
        if w == candidates.len() {
            let score: f64 = chosen.iter().map(|a| a.value).sum();
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                *best = Some((score, chosen.clone()));
            }
            return;
        }
        for (ri, cand) in candidates[w].iter().enumerate() {
            if used[ri] {
                continue;
            }
            if let Some(a) = cand {
                used[ri] = true;
                chosen.push(a.clone());
                Self::search_rate(candidates, w + 1, used, chosen, best);
                chosen.pop();
                used[ri] = false;
            }
        }
    }

    fn search(
        candidates: &[Vec<Option<Assignment>>],
        objective: Objective,
        w: usize,
        used: &mut [bool],
        chosen: &mut Vec<Assignment>,
        best: &mut Option<(f64, Vec<Assignment>)>,
    ) {
        if w == candidates.len() {
            let score: f64 = chosen.iter().map(|a| Self::score_of(a, objective)).sum();
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                *best = Some((score, chosen.clone()));
            }
            return;
        }
        for (ri, cand) in candidates[w].iter().enumerate() {
            if used[ri] {
                continue;
            }
            if let Some(a) = cand {
                used[ri] = true;
                chosen.push(a.clone());
                Self::search(candidates, objective, w + 1, used, chosen, best);
                chosen.pop();
                used[ri] = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::lookup;
    use crate::workload::spec::WorkloadSpec;

    fn bert_train() -> SloWorkload {
        SloWorkload::best_effort(WorkloadSpec::training(lookup("bert-base").unwrap(), 32, 128))
    }

    fn resnet_serve(slo_ms: f64) -> SloWorkload {
        SloWorkload::with_slo(WorkloadSpec::inference(lookup("resnet50").unwrap(), 4, 224), slo_ms)
    }

    #[test]
    fn paper_hybrid_scenario_produces_mixed_layout() {
        // §1's motivating setup: train + two inference services on one
        // A100. The optimizer should give training the big slice.
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let workloads = [bert_train(), resnet_serve(20.0), resnet_serve(20.0)];
        let plan = sched.plan(&workloads, Objective::MaxThroughput).expect("feasible");
        assert_eq!(plan.assignments.len(), 3);
        // Training gets the largest instance in the plan.
        let train_profile = plan.assignments.iter().find(|a| a.workload == 0).unwrap().profile;
        for a in &plan.assignments {
            let train_slices: u32 = train_profile.split('g').next().unwrap().parse().unwrap();
            let this: u32 = a.profile.split('g').next().unwrap().parse().unwrap();
            assert!(train_slices >= this, "training must own the biggest slice: {plan:?}");
        }
        // All SLOs met by construction.
        for a in plan.assignments.iter().filter(|a| a.workload > 0) {
            assert!(a.latency_ms <= 20.0);
        }
    }

    #[test]
    fn single_training_job_gets_whole_gpu() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let plan = sched.plan(&[bert_train()], Objective::MaxThroughput).unwrap();
        assert_eq!(plan.assignments[0].profile, "7g.80gb");
        assert_eq!(plan.layout, vec!["7g.80gb"]);
    }

    #[test]
    fn infeasible_slo_returns_none() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        // 0.01 ms SLO is physically impossible (launch overhead alone is
        // 0.45 ms).
        assert!(sched.plan(&[resnet_serve(0.01)], Objective::MaxThroughput).is_none());
    }

    #[test]
    fn too_many_workloads_for_device() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        let ws: Vec<_> = (0..5).map(|_| resnet_serve(1000.0)).collect();
        assert!(sched.plan(&ws, Objective::MaxThroughput).is_none(), "A30 has at most 4 GIs");
    }

    #[test]
    fn four_services_land_on_four_slices() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        let ws: Vec<_> = (0..4).map(|_| resnet_serve(1000.0)).collect();
        let plan = sched.plan(&ws, Objective::MaxThroughput).unwrap();
        assert_eq!(plan.layout, vec!["1g.6gb"; 4]);
    }

    #[test]
    fn energy_objective_prefers_smaller_slices() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let w = [resnet_serve(1000.0)];
        let tput_plan = sched.plan(&w, Objective::MaxThroughput).unwrap();
        let energy_plan = sched.plan(&w, Objective::MinEnergy).unwrap();
        let slices = |p: &Plan| -> u32 {
            p.assignments[0].profile.split('g').next().unwrap().parse().unwrap()
        };
        assert!(slices(&energy_plan) <= slices(&tput_plan));
        assert!(energy_plan.assignments[0].power_w <= tput_plan.assignments[0].power_w);
    }

    #[test]
    fn empty_workloads_rejected() {
        let sched = Scheduler::new(GpuModel::A30_24GB);
        assert!(sched.plan(&[], Objective::MaxThroughput).is_none());
    }

    fn demand_set(rate: f64) -> Vec<DemandWorkload> {
        let bert = lookup("bert-base").unwrap();
        vec![
            DemandWorkload::training(WorkloadSpec::training(bert, 32, 128)),
            DemandWorkload::service(WorkloadSpec::inference(bert, 8, 128), 40.0, rate),
            DemandWorkload::service(WorkloadSpec::inference(bert, 8, 128), 40.0, rate),
        ]
    }

    #[test]
    fn demand_plan_gives_training_the_big_slice_at_low_demand() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let plan = sched.plan_for_demand(&demand_set(10.0), 0.75).expect("feasible");
        assert_eq!(plan.assignments.len(), 3);
        let train_inst = plan.instance_of(0).unwrap();
        let train_slices = plan.layout.placements[train_inst].profile.compute_slices;
        for a in &plan.assignments {
            let slices = plan.layout.placements[a.instance].profile.compute_slices;
            assert!(train_slices >= slices, "training must own the biggest slice: {plan:?}");
        }
        for a in plan.assignments.iter().filter(|a| a.workload > 0) {
            assert!(a.latency_ms <= 40.0, "SLO respected: {a:?}");
            assert!(a.utilization <= 0.75);
        }
    }

    #[test]
    fn demand_plan_upsizes_services_under_load() {
        // At high demand the small slice can no longer sustain the rate:
        // every service must land on a bigger instance, and training (the
        // only best-effort job) is the one that shrinks.
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let calm = sched.plan_for_demand(&demand_set(10.0), 0.75).unwrap();
        let peak = sched.plan_for_demand(&demand_set(60.0), 0.75).unwrap();
        assert!(peak.layout != calm.layout, "peak demand must force a different layout");
        let min_service_slices = |p: &RatePlan| {
            p.assignments
                .iter()
                .filter(|a| a.workload > 0)
                .map(|a| p.layout.placements[a.instance].profile.compute_slices)
                .min()
                .unwrap()
        };
        assert!(min_service_slices(&peak) > min_service_slices(&calm));
        let train_slices = |p: &RatePlan| {
            p.layout.placements[p.instance_of(0).unwrap()].profile.compute_slices
        };
        assert!(train_slices(&peak) < train_slices(&calm));
    }

    #[test]
    fn demand_plan_infeasible_when_rate_exceeds_any_instance() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        assert!(sched.plan_for_demand(&demand_set(100_000.0), 0.75).is_none());
        assert!(sched.plan_for_demand(&[], 0.75).is_none());
        assert!(sched.plan_for_demand(&demand_set(10.0), 0.0).is_none(), "degenerate rho_max");
        assert!(sched.plan_for_demand(&demand_set(10.0), 1.5).is_none(), "rho_max must be < 1");
    }

    #[test]
    fn evaluate_plan_flags_overload_without_changing_layout() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let calm_ws = demand_set(10.0);
        let plan = sched.plan_for_demand(&calm_ws, 0.75).unwrap();
        let (calm_score, calm_ok) = sched.evaluate_plan(&plan, &calm_ws, 0.75);
        assert!(calm_ok, "plan must be feasible at the demand it was built for");
        assert!((calm_score - plan.score).abs() < 1e-9, "evaluate matches plan score");
        let (peak_score, peak_ok) = sched.evaluate_plan(&plan, &demand_set(60.0), 0.75);
        assert!(!peak_ok, "calm layout must be flagged infeasible at peak demand");
        assert!(peak_score.is_finite());
    }

    #[test]
    fn oom_workload_excluded_from_small_slices() {
        let sched = Scheduler::new(GpuModel::A100_80GB);
        let big = SloWorkload::best_effort(WorkloadSpec::training(
            lookup("bert-large").unwrap(),
            128,
            128,
        ));
        let plan = sched.plan(&[big], Objective::MaxThroughput).unwrap();
        // Must land on an instance with enough FB (>= 3g.40gb).
        assert!(["3g.40gb", "4g.40gb", "7g.80gb"].contains(&plan.assignments[0].profile));
    }
}
