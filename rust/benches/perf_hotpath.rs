//! L3 hot-path microbenchmarks (no criterion offline — first-party timing
//! harness with warmup, repetitions and ns/op reporting).
//!
//! Covers the paths the profiler and serving simulator hammer: roofline
//! pricing, DES event processing, latency-histogram recording, MPS
//! request pricing, serving simulation end-to-end, and (when artifacts
//! exist) real PJRT execution of the tiny models. Used by the §Perf pass
//! in EXPERIMENTS.md.

use std::time::Instant;

use migperf::metrics::collector::MetricsCollector;
use migperf::mig::gpu::GpuModel;
use migperf::mig::profile::lookup as gi_lookup;
use migperf::models::cost::{infer_cost, Precision};
use migperf::models::zoo;
use migperf::sharing::mps::MpsModel;
use migperf::simgpu::desim::Des;
use migperf::simgpu::perfmodel::PerfModel;
use migperf::simgpu::resource::ExecResource;
use migperf::util::prng::Prng;
use migperf::util::stats::LatencyHistogram;
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;

/// Time `f` over `iters` iterations after `warmup` iterations; returns
/// ns/op. A black-box consume of the result prevents dead-code deletion.
fn bench<T>(name: &str, warmup: u64, iters: u64, mut f: impl FnMut(u64) -> T) -> f64 {
    let mut sink = 0u64;
    for i in 0..warmup {
        sink = sink.wrapping_add(consume(&f(i)));
    }
    let start = Instant::now();
    for i in 0..iters {
        sink = sink.wrapping_add(consume(&f(i)));
    }
    let elapsed = start.elapsed().as_nanos() as f64;
    let ns_op = elapsed / iters as f64;
    println!("{name:<44} {:>12.1} ns/op   ({iters} iters, sink {sink:x})", ns_op);
    ns_op
}

fn consume<T>(t: &T) -> u64 {
    // Read one byte of the value so the optimizer must materialize it.
    let p = t as *const T as *const u8;
    if std::mem::size_of::<T>() == 0 {
        0
    } else {
        unsafe { std::ptr::read_volatile(p) as u64 }
    }
}

fn main() {
    println!("== perf_hotpath: L3 microbenchmarks ==\n");
    let pm = PerfModel::default();
    let m = zoo::lookup("bert-base").unwrap();
    let res = ExecResource::from_gi(
        GpuModel::A100_80GB,
        gi_lookup(GpuModel::A100_80GB, "2g.20gb").unwrap(),
    );
    let cost = infer_cost(m, 8, 128, Precision::Half);

    bench("roofline step pricing", 1_000, 1_000_000, |_| pm.step(&res, &cost).unwrap());

    bench("analytic cost construction", 1_000, 1_000_000, |i| {
        infer_cost(m, 1 + (i % 64) as u32, 128, Precision::Half)
    });

    let mut hist = LatencyHistogram::for_latency_ms();
    let mut rng = Prng::new(1);
    // Pre-generate samples so the PRNG's transcendental calls don't mask
    // the histogram cost being measured.
    let samples: Vec<f64> = (0..65536).map(|_| rng.lognormal(1.0, 0.5)).collect();
    bench("latency histogram record", 10_000, 5_000_000, |i| {
        hist.record(samples[(i & 0xffff) as usize]);
    });
    bench("latency histogram p99", 100, 200_000, |_| hist.percentile(99.0));

    let mps = MpsModel::default();
    let whole = ExecResource::whole_gpu(GpuModel::A30_24GB);
    let isolated = pm.step(&whole, &cost).unwrap();
    let mut rng2 = Prng::new(2);
    bench("MPS request pricing (stochastic)", 10_000, 2_000_000, |_| {
        mps.request_time(&isolated, &cost, &whole, 3, &mut rng2)
    });

    bench("DES schedule+pop", 1_000, 200_000, |i| {
        let mut des: Des<u32> = Des::new();
        for k in 0..16u32 {
            des.schedule_at((i % 97) as f64 + k as f64, k);
        }
        let mut last = 0;
        while let Some((_, e)) = des.next() {
            last = e;
        }
        last
    });

    bench("metrics collector record+summarize/1k", 10, 2_000, |i| {
        let mut c = MetricsCollector::new("bench");
        for k in 0..1000u64 {
            c.record_completion((i + k) as f64 * 1e-3, 5.0, 1);
        }
        c.summarize().completed
    });

    // End-to-end serving sims (the figure benches' inner loop).
    let spec = WorkloadSpec::inference(zoo::lookup("resnet50").unwrap(), 8, 224);
    let p = gi_lookup(GpuModel::A30_24GB, "1g.6gb").unwrap();
    bench("serving sim MIG 4×500 reqs", 2, 50, |i| {
        ServingSim {
            mode: SharingMode::Mig(vec![
                ExecResource::from_gi(GpuModel::A30_24GB, p);
                4
            ]),
            load: LoadMode::Closed { requests_per_server: 500 },
            spec: spec.clone(),
            seed: i,
        }
        .run()
        .unwrap()
        .pooled
        .completed
    });
    bench("serving sim MPS 4×500 reqs", 2, 50, |i| {
        ServingSim {
            mode: SharingMode::Mps {
                gpu: ExecResource::whole_gpu(GpuModel::A30_24GB),
                n_clients: 4,
                model: MpsModel::default(),
            },
            load: LoadMode::Closed { requests_per_server: 500 },
            spec: spec.clone(),
            seed: i,
        }
        .run()
        .unwrap()
        .pooled
        .completed
    });

    // Real PJRT execution, if artifacts are built.
    if migperf::runtime::artifacts_available() {
        use migperf::runtime::executor::{Engine, HostTensor};
        use migperf::runtime::Manifest;
        let manifest = Manifest::load(migperf::runtime::artifacts_dir()).unwrap();
        let e = manifest.entry("bert_tiny_infer_b4").unwrap();
        let mut engine = Engine::cpu().unwrap();
        engine.load_hlo_text(&e.name, &manifest.hlo_path(e)).unwrap();
        let seq = e.inputs[0].shape[1];
        let mut rng3 = Prng::new(3);
        let tokens: Vec<i32> = (0..4 * seq).map(|_| rng3.below(512) as i32).collect();
        let input = HostTensor::I32(tokens, vec![4, seq]);
        bench("PJRT real exec bert_tiny_infer_b4", 3, 100, |_| {
            engine.execute(&e.name, std::slice::from_ref(&input)).unwrap().outputs.len()
        });
    } else {
        println!("(PJRT bench skipped: run `make artifacts` first)");
    }
    println!("\ndone.");
}
