//! Minimal property-based testing harness.
//!
//! No `proptest`/`quickcheck` offline, so this module provides the core of
//! the idea: run a property over many PRNG-generated cases and, on
//! failure, greedily shrink the failing input before reporting. Generation
//! is driven by [`Gen`], a thin wrapper over [`Prng`] with size-aware
//! helpers. Tests across the crate use [`check`] for invariants like
//! "every accepted MIG layout fits in the slice budget" or "simulated
//! latency is monotone in batch size".

use crate::util::prng::Prng;

/// Case generator handed to properties.
pub struct Gen {
    rng: Prng,
    /// Soft bound on the magnitude of generated sizes; grows over the run
    /// so early cases are small (easier to debug) and later ones stress.
    pub size: usize,
}

impl Gen {
    /// Internal: construct with explicit seed and size.
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Prng::new(seed), size }
    }

    /// Uniform u64 below `n`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    /// Uniform usize in `[0, size]` (the canonical "small size" draw).
    pub fn small(&mut self) -> usize {
        self.rng.below(self.size as u64 + 1) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_inclusive(lo, hi)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Bernoulli.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// Vector of values from a element generator, length ≤ `size`.
    pub fn vec<T>(&mut self, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.small();
        (0..n).map(|_| f(self)).collect()
    }

    /// Access the raw PRNG (for distributions).
    pub fn rng(&mut self) -> &mut Prng {
        &mut self.rng
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`, so a failure report's seed
    /// reproduces that exact case.
    pub seed: u64,
    /// Maximum `Gen::size` reached at the last case.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0x4d49_4750, max_size: 64 }
    }
}

/// Run `prop` over `cfg.cases` generated cases; panic with the seed and
/// message of the first failure. Properties draw their own inputs from the
/// supplied [`Gen`], which makes failures reproducible from the seed alone.
pub fn check_with(cfg: Config, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    for i in 0..cfg.cases {
        let size = 1 + (cfg.max_size * i) / cfg.cases.max(1);
        let seed = cfg.seed.wrapping_add(i as u64);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // Re-run nearby smaller sizes with the same seed to present the
            // smallest failing size (a cheap form of shrinking: our
            // generators scale all drawn sizes by `Gen::size`).
            let mut best = (size, msg);
            for s in 1..size {
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    best = (s, m2);
                    break;
                }
            }
            panic!(
                "property failed (seed={seed:#x}, size={}): {}",
                best.0, best.1
            );
        }
    }
}

/// [`check_with`] under the default configuration.
pub fn check(prop: impl FnMut(&mut Gen) -> PropResult) {
    check_with(Config::default(), prop);
}

/// Helper macro: turn a boolean with context into a `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check_with(Config { cases: 50, ..Default::default() }, |g| {
            n += 1;
            let x = g.int(0, 100);
            prop_assert!(x >= 0 && x <= 100, "x out of range: {x}");
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(|g| {
            let v = g.vec(|g| g.int(0, 10));
            prop_assert!(v.len() < 5, "vector too long: {}", v.len());
            Ok(())
        });
    }

    #[test]
    fn sizes_grow_over_run() {
        let mut max_seen = 0;
        check_with(Config { cases: 100, max_size: 40, ..Default::default() }, |g| {
            max_seen = max_seen.max(g.size);
            Ok(())
        });
        assert!(max_seen >= 39, "max_seen={max_seen}");
    }

    #[test]
    fn same_seed_reproduces_case() {
        let mut a = Gen::new(123, 10);
        let mut b = Gen::new(123, 10);
        let va = a.vec(|g| g.int(0, 1000));
        let vb = b.vec(|g| g.int(0, 1000));
        assert_eq!(va, vb);
    }
}
