//! Inference serving on MIG instances with real model execution.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_mig -- --rate 40 --requests 200
//! ```
//!
//! Serves the AOT-lowered tiny-BERT on four simulated 1g.6gb A30
//! instances behind a dynamic batcher, with Poisson arrivals. Each
//! dispatched batch *really executes* the model through PJRT (numerics
//! verified), while latencies are also priced on the simulated GI so the
//! output reports both: measured CPU wall time and simulated-A30 serving
//! metrics. This is the paper's Appendix C setup (Fig 11) with the actual
//! three-layer stack in the loop.

use migperf::metrics::collector::MetricsCollector;
use migperf::mig::controller::MigController;
use migperf::mig::gpu::GpuModel;
use migperf::models::cost::{infer_cost, Precision};
use migperf::models::zoo;
use migperf::runtime::executor::{Engine, HostTensor};
use migperf::runtime::manifest::Manifest;
use migperf::runtime::{artifacts_available, artifacts_dir};
use migperf::simgpu::perfmodel::PerfModel;
use migperf::simgpu::resource::ExecResource;
use migperf::util::argparse::Args;
use migperf::util::prng::Prng;
use migperf::util::table::{fmt_num, Table};
use migperf::workload::arrival::{Arrival, PoissonArrival};
use migperf::workload::batcher::DynamicBatcher;

const SERVERS: usize = 4;
const MAX_BATCH: usize = 4;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let rate: f64 = args.parse_or("rate", 40.0)?;
    let requests: u64 = args.parse_or("requests", 200u64)?;

    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let manifest = Manifest::load(artifacts_dir())?;
    let entry = manifest.entry("bert_tiny_infer_b4").expect("infer entry");
    let mut engine = Engine::cpu()?;
    engine.load_hlo_text(&entry.name, &manifest.hlo_path(entry))?;
    let seq = entry.inputs[0].shape[1];

    // Partition a real (simulated) A30 into 4×1g.6gb — the paper's Fig 11
    // layout — via the MIG controller, so placement rules are enforced.
    let mut ctl = MigController::new(GpuModel::A30_24GB);
    ctl.enable_mig()?;
    let gis = ctl.partition_uniform("1g.6gb", SERVERS as u32)?;
    println!("partitioned A30 into {} × 1g.6gb: {:?}", SERVERS, gis);
    let res =
        ExecResource::from_gi(GpuModel::A30_24GB, ctl.instance(gis[0])?.profile);
    let pm = PerfModel::default();
    let m = zoo::lookup("bert-base").unwrap();

    // Per-server serving loop: Poisson arrivals → dynamic batcher →
    // real PJRT execution + simulated GI pricing.
    let mut table = Table::new(&[
        "server", "requests", "avg_ms(sim)", "p99_ms(sim)", "mean_batch", "real_exec_ms/req",
    ]);
    let mut rng = Prng::new(9000);
    for (si, gi) in gis.iter().enumerate() {
        let mut arrivals = PoissonArrival::new(rate / SERVERS as f64, 100 + si as u64);
        let mut batcher = DynamicBatcher::new(MAX_BATCH, 0.010);
        let mut collector =
            MetricsCollector::new(format!("server{si}@{}", ctl.instance(*gi)?.uuid));
        let mut t = 0.0; // virtual clock, seconds
        let mut server_free_at: f64 = 0.0;
        let mut issued = 0u64;
        let mut real_exec_s = 0.0;
        let mut batches = 0u64;
        let mut batched_reqs = 0u64;
        while issued < requests {
            t += arrivals.next_gap();
            issued += 1;
            let closed = batcher.offer(t).or_else(|| {
                // Delay rule: check between arrivals.
                batcher.poll(t)
            });
            if let Some(batch) = closed {
                // Real execution of the actual model for this batch
                // (pad to the lowered batch size of 4).
                let mut tokens: Vec<i32> = Vec::with_capacity(4 * seq as usize);
                for _ in 0..4 {
                    tokens.extend((0..seq).map(|_| rng.below(512) as i32));
                }
                let out = engine
                    .execute(&entry.name, &[HostTensor::I32(tokens, vec![4, seq])])?;
                assert!(out.outputs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
                real_exec_s += out.wall_s;
                batches += 1;
                batched_reqs += batch.len() as u64;
                // Simulated service on the 1g.6gb slice.
                let cost = infer_cost(m, batch.len() as u32, 128, Precision::Half);
                let est = pm.step(&res, &cost).expect("fits 1g.6gb");
                let start = server_free_at.max(batch.closed_at);
                let done = start + est.seconds;
                server_free_at = done;
                for r in &batch.requests {
                    collector.record_completion(done, (done - r.arrived_at) * 1e3, 1);
                }
            }
        }
        if let Some(batch) = batcher.flush(t) {
            let cost = infer_cost(m, batch.len() as u32, 128, Precision::Half);
            let est = pm.step(&res, &cost).unwrap();
            let done = server_free_at.max(batch.closed_at) + est.seconds;
            for r in &batch.requests {
                collector.record_completion(done, (done - r.arrived_at) * 1e3, 1);
            }
            batches += 1;
            batched_reqs += batch.len() as u64;
        }
        let s = collector.summarize();
        table.row(&[
            format!("{si}"),
            s.completed.to_string(),
            fmt_num(s.avg_latency_ms),
            fmt_num(s.p99_latency_ms),
            fmt_num(batched_reqs as f64 / batches.max(1) as f64),
            fmt_num(real_exec_s * 1e3 / s.completed.max(1) as f64),
        ]);
    }
    println!(
        "\nserving tiny-BERT on {SERVERS}×1g.6gb (Poisson {rate} req/s total, dynamic batcher ≤{MAX_BATCH}):\n{}",
        table.render()
    );
    println!("every batch executed the real AOT-lowered model via PJRT (finite logits asserted).");
    Ok(())
}
