// Lint fixture (never compiled): ambient entropy. Expected:
// ambient-entropy errors on lines 5 and 6.

pub fn unseeded() -> u64 {
    let mut rng = rand::thread_rng();
    let state = RandomState::new();
    rng.gen::<u64>() ^ state.finish()
}
