// Lint fixture (never compiled): wall-clock reads outside sanctioned
// sites. Expected: wall-clock errors on lines 5, 6 and 7.

pub fn probe() -> f64 {
    let t0 = std::time::Instant::now();
    let dt = t0.elapsed().as_secs_f64();
    let _ = SystemTime::now();
    dt
}
