//! Arrival-trace recording and replay.
//!
//! Production serving studies replay recorded request traces rather than
//! synthetic arrivals. This module closes that loop for MIGPerf: capture
//! the timestamps an [`Arrival`] process generates (or load a trace from
//! a file), then replay it as an arrival process — so an MPS run and a
//! MIG run can be driven by the *identical* request sequence, removing
//! arrival noise from A/B comparisons.
//!
//! Trace file format: one ASCII float (seconds since trace start) per
//! line; `#` lines are comments.

use std::path::Path;

use super::arrival::Arrival;

/// A recorded arrival trace: absolute timestamps, strictly increasing.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    timestamps: Vec<f64>,
}

/// Trace errors.
#[derive(Debug)]
pub enum TraceError {
    /// IO failure.
    Io(std::io::Error),
    /// Malformed line.
    BadLine(usize, String),
    /// Timestamps must strictly increase.
    NotMonotone(usize),
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace IO: {e}"),
            TraceError::BadLine(line, raw) => {
                write!(f, "trace line {line}: '{raw}' is not a timestamp")
            }
            TraceError::NotMonotone(line) => {
                write!(f, "trace not strictly increasing at line {line}")
            }
        }
    }
}

impl std::error::Error for TraceError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TraceError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TraceError {
    fn from(e: std::io::Error) -> Self {
        TraceError::Io(e)
    }
}

impl Trace {
    /// Build from raw timestamps (must be strictly increasing).
    pub fn new(timestamps: Vec<f64>) -> Result<Trace, TraceError> {
        for (i, w) in timestamps.windows(2).enumerate() {
            if w[1] <= w[0] {
                return Err(TraceError::NotMonotone(i + 2));
            }
        }
        Ok(Trace { timestamps })
    }

    /// Capture the first `n` arrivals of a process.
    pub fn capture(process: &mut dyn Arrival, n: usize) -> Trace {
        let mut t = 0.0;
        let timestamps = (0..n)
            .map(|_| {
                t += process.next_gap();
                t
            })
            .collect();
        Trace { timestamps }
    }

    /// Parse the line-per-timestamp file format.
    pub fn parse(text: &str) -> Result<Trace, TraceError> {
        let mut timestamps = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let t: f64 =
                line.parse().map_err(|_| TraceError::BadLine(i + 1, line.to_string()))?;
            timestamps.push(t);
        }
        Trace::new(timestamps)
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Trace, TraceError> {
        Trace::parse(&std::fs::read_to_string(path)?)
    }

    /// Serialize to the file format.
    pub fn render(&self) -> String {
        let mut s = String::from("# migperf arrival trace: one timestamp (s) per line\n");
        for t in &self.timestamps {
            s.push_str(&format!("{t:.9}\n"));
        }
        s
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }

    /// Number of requests.
    pub fn len(&self) -> usize {
        self.timestamps.len()
    }

    /// True when the trace holds no arrivals.
    pub fn is_empty(&self) -> bool {
        self.timestamps.is_empty()
    }

    /// All timestamps.
    pub fn timestamps(&self) -> &[f64] {
        &self.timestamps
    }

    /// Mean arrival rate over the trace span (req/s).
    pub fn mean_rate(&self) -> f64 {
        match self.timestamps.last() {
            Some(&last) if last > 0.0 => self.len() as f64 / last,
            _ => 0.0,
        }
    }

    /// Replay as an [`Arrival`] process. When the trace is exhausted the
    /// replayer keeps returning `f64::INFINITY` gaps (no more arrivals).
    pub fn replay(&self) -> TraceReplay<'_> {
        TraceReplay { trace: self, pos: 0, last: 0.0 }
    }
}

/// Iterator-style arrival process over a recorded trace.
#[derive(Debug)]
pub struct TraceReplay<'a> {
    trace: &'a Trace,
    pos: usize,
    last: f64,
}

impl Arrival for TraceReplay<'_> {
    fn next_gap(&mut self) -> f64 {
        match self.trace.timestamps.get(self.pos) {
            Some(&t) => {
                self.pos += 1;
                let gap = t - self.last;
                self.last = t;
                gap
            }
            None => f64::INFINITY,
        }
    }

    fn rate(&self) -> f64 {
        self.trace.mean_rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::arrival::{arrival_times, PoissonArrival};

    #[test]
    fn capture_and_replay_identical() {
        let mut p = PoissonArrival::new(20.0, 5);
        let trace = Trace::capture(&mut p, 200);
        let mut replay = trace.replay();
        let times = arrival_times(&mut replay, 200);
        for (a, b) in times.iter().zip(trace.timestamps()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn exhausted_replay_returns_infinity() {
        let trace = Trace::new(vec![1.0, 2.0]).unwrap();
        let mut r = trace.replay();
        r.next_gap();
        r.next_gap();
        assert!(r.next_gap().is_infinite());
    }

    #[test]
    fn file_format_roundtrip() {
        let mut p = PoissonArrival::new(5.0, 9);
        let trace = Trace::capture(&mut p, 50);
        let parsed = Trace::parse(&trace.render()).unwrap();
        assert_eq!(parsed.len(), 50);
        for (a, b) in parsed.timestamps().iter().zip(trace.timestamps()) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn parse_skips_comments_and_blank_lines() {
        let t = Trace::parse("# header\n\n0.5\n1.5\n# mid\n2.5\n").unwrap();
        assert_eq!(t.len(), 3);
        assert!((t.mean_rate() - 3.0 / 2.5).abs() < 1e-12);
    }

    #[test]
    fn parse_rejects_garbage_and_non_monotone() {
        assert!(matches!(Trace::parse("abc\n"), Err(TraceError::BadLine(1, _))));
        assert!(matches!(Trace::parse("2.0\n1.0\n"), Err(TraceError::NotMonotone(2))));
        assert!(matches!(Trace::new(vec![1.0, 1.0]), Err(TraceError::NotMonotone(_))));
    }

    #[test]
    fn empty_trace() {
        let t = Trace::new(vec![]).unwrap();
        assert!(t.is_empty());
        assert_eq!(t.mean_rate(), 0.0);
        assert!(t.replay().next_gap().is_infinite());
    }
}
