//! Physical GPU models with MIG support.
//!
//! Encodes the two devices the paper benchmarks (§4.1, Appendix A Table 3):
//! NVIDIA A100-80GB (SXM) and NVIDIA A30. The numbers are the public
//! datasheet values; the simulator (`simgpu::`) treats them as the
//! whole-GPU roofline that GI slices scale down from.

use std::fmt;

/// A MIG-capable GPU model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(non_camel_case_types)]
pub enum GpuModel {
    /// NVIDIA A100 80GB SXM (Ampere GA100).
    A100_80GB,
    /// NVIDIA A30 24GB (Ampere GA100 derivative).
    A30_24GB,
}

/// Static capability description of a GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Marketing name.
    pub name: &'static str,
    /// Model enum this spec describes.
    pub model: GpuModel,
    /// Number of MIG compute slices (GPC groups usable by MIG).
    pub compute_slices: u32,
    /// Number of MIG memory slices.
    pub memory_slices: u32,
    /// Streaming multiprocessors available to MIG slices (per slice × slices).
    pub total_sms: u32,
    /// Total frame buffer in GiB.
    pub memory_gib: f64,
    /// HBM bandwidth, GB/s, whole GPU.
    pub mem_bw_gbps: f64,
    /// Peak dense FP16/BF16 tensor-core throughput, TFLOP/s, whole GPU.
    pub peak_tf16: f64,
    /// Peak FP32 (non-tensor) throughput, TFLOP/s, whole GPU.
    pub peak_tf32: f64,
    /// L2 cache size in MiB, whole GPU.
    pub l2_mib: f64,
    /// Board power limit (TDP), watts.
    pub tdp_w: f64,
    /// Idle board power, watts (drawn even with no work resident).
    pub idle_w: f64,
}

impl GpuModel {
    /// Datasheet specification for this model.
    pub fn spec(&self) -> &'static GpuSpec {
        match self {
            GpuModel::A100_80GB => &A100_SPEC,
            GpuModel::A30_24GB => &A30_SPEC,
        }
    }

    /// All supported models.
    pub fn all() -> &'static [GpuModel] {
        &[GpuModel::A100_80GB, GpuModel::A30_24GB]
    }

    /// Parse from a human name (`a100`, `a100-80gb`, `a30`).
    pub fn parse(s: &str) -> Option<GpuModel> {
        match s.to_ascii_lowercase().as_str() {
            "a100" | "a100-80gb" | "a100_80gb" => Some(GpuModel::A100_80GB),
            "a30" | "a30-24gb" | "a30_24gb" => Some(GpuModel::A30_24GB),
            _ => None,
        }
    }
}

impl fmt::Display for GpuModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.spec().name)
    }
}

/// A100-80GB: 108 SMs on die; MIG exposes 7 compute slices × 14 SMs = 98.
static A100_SPEC: GpuSpec = GpuSpec {
    name: "NVIDIA A100-80GB",
    model: GpuModel::A100_80GB,
    compute_slices: 7,
    memory_slices: 8,
    total_sms: 98,
    memory_gib: 80.0,
    mem_bw_gbps: 2039.0,
    peak_tf16: 312.0,
    peak_tf32: 19.5,
    l2_mib: 40.0,
    tdp_w: 400.0,
    idle_w: 55.0,
};

/// A30: 56 SMs on die; MIG exposes 4 compute slices × 14 SMs = 56.
static A30_SPEC: GpuSpec = GpuSpec {
    name: "NVIDIA A30",
    model: GpuModel::A30_24GB,
    compute_slices: 4,
    memory_slices: 4,
    total_sms: 56,
    memory_gib: 24.0,
    mem_bw_gbps: 933.0,
    peak_tf16: 165.0,
    peak_tf32: 10.3,
    l2_mib: 24.0,
    tdp_w: 165.0,
    idle_w: 30.0,
};

impl GpuSpec {
    /// SMs per compute slice.
    pub fn sms_per_slice(&self) -> u32 {
        self.total_sms / self.compute_slices
    }

    /// GiB of frame buffer per memory slice.
    pub fn gib_per_mem_slice(&self) -> f64 {
        self.memory_gib / self.memory_slices as f64
    }

    /// Bandwidth (GB/s) per memory slice.
    pub fn bw_per_mem_slice(&self) -> f64 {
        self.mem_bw_gbps / self.memory_slices as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_are_consistent() {
        for m in GpuModel::all() {
            let s = m.spec();
            assert_eq!(s.model, *m);
            assert_eq!(s.total_sms % s.compute_slices, 0, "{}: SMs not slice-divisible", s.name);
            assert!(s.peak_tf16 > s.peak_tf32);
            assert!(s.tdp_w > s.idle_w);
            assert!(s.memory_gib > 0.0 && s.mem_bw_gbps > 0.0);
        }
    }

    #[test]
    fn a100_slice_shape() {
        let s = GpuModel::A100_80GB.spec();
        assert_eq!(s.compute_slices, 7);
        assert_eq!(s.memory_slices, 8);
        assert_eq!(s.sms_per_slice(), 14);
        assert!((s.gib_per_mem_slice() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn a30_slice_shape() {
        let s = GpuModel::A30_24GB.spec();
        assert_eq!(s.compute_slices, 4);
        assert_eq!(s.memory_slices, 4);
        assert_eq!(s.sms_per_slice(), 14);
        assert!((s.gib_per_mem_slice() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn parse_names() {
        assert_eq!(GpuModel::parse("A100"), Some(GpuModel::A100_80GB));
        assert_eq!(GpuModel::parse("a100-80gb"), Some(GpuModel::A100_80GB));
        assert_eq!(GpuModel::parse("a30"), Some(GpuModel::A30_24GB));
        assert_eq!(GpuModel::parse("h100"), None);
    }

    #[test]
    fn display_matches_name() {
        assert_eq!(GpuModel::A30_24GB.to_string(), "NVIDIA A30");
    }
}
