//! Calibration of the roofline model against real HLO execution.
//!
//! The figure benches run on the analytic roofline. To anchor that model
//! in reality, the e2e examples execute the *actual* lowered JAX/Pallas
//! graphs on the PJRT CPU client (`runtime::`) and this module maps the
//! measured wall time onto the simulator's A100 baseline.
//!
//! The mapping is a single per-model-family scale factor: for a workload
//! with known FLOPs, `measured_cpu_seconds × (cpu_eff_flops /
//! a100_eff_flops)` predicts the A100 time. The CPU's effective FLOP rate
//! is itself estimated from the measured run, so one real execution both
//! validates numerics end-to-end and pins the simulator's absolute scale.

use crate::models::cost::StepCost;
use crate::simgpu::perfmodel::{PerfModel, StepEstimate};
use crate::simgpu::resource::ExecResource;

/// Result of one calibration run.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Workload label (model / entry-point name).
    pub label: String,
    /// FLOPs of the executed step (analytic, for the tiny model actually run).
    pub flops: f64,
    /// Measured wall seconds per step on the PJRT CPU client.
    pub measured_cpu_s: f64,
    /// Effective CPU FLOP rate implied by the measurement.
    pub cpu_eff_flops: f64,
}

impl Calibration {
    /// Build a calibration from a measured real execution.
    pub fn from_measurement(label: impl Into<String>, flops: f64, measured_cpu_s: f64) -> Self {
        assert!(measured_cpu_s > 0.0 && flops > 0.0);
        Calibration {
            label: label.into(),
            flops,
            measured_cpu_s,
            cpu_eff_flops: flops / measured_cpu_s,
        }
    }

    /// Predicted time for the same step on a simulated resource, using the
    /// roofline's *relative* cost but anchored at the measured absolute
    /// scale: `t_sim(resource) / t_sim(reference_cpu_equiv)` ×
    /// `measured_cpu_s`.
    ///
    /// In practice we express it directly: the simulated resource runs the
    /// step at `eff_flops(resource)`, so the predicted time is
    /// `flops / eff_flops(resource)` — with `eff_flops` taken from the
    /// roofline estimate, which already includes saturation and memory
    /// effects.
    pub fn predict_on(
        &self,
        pm: &PerfModel,
        res: &ExecResource,
        cost: &StepCost,
    ) -> Option<StepEstimate> {
        pm.step(res, cost).ok()
    }

    /// Speedup of the simulated resource over the measured CPU execution
    /// for this workload (how much faster the simulated GI is than the
    /// real CPU run of the tiny model).
    pub fn speedup_vs_cpu(&self, est: &StepEstimate, sim_flops: f64) -> f64 {
        // Normalize by FLOPs: both sides expressed as effective FLOP rates.
        let sim_eff = sim_flops / est.seconds;
        sim_eff / self.cpu_eff_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::GpuModel;
    use crate::models::cost::{infer_cost, Precision};
    use crate::models::zoo;

    #[test]
    fn from_measurement_computes_rate() {
        let c = Calibration::from_measurement("tiny-bert b8", 1e9, 0.5);
        assert!((c.cpu_eff_flops - 2e9).abs() < 1.0);
    }

    #[test]
    fn a100_predicts_faster_than_cpu() {
        // A tiny-BERT step measured at 2 GFLOP/s on CPU must be predicted
        // vastly faster on a simulated full A100.
        let c = Calibration::from_measurement("tiny-bert", 1e9, 0.5);
        let pm = PerfModel::default();
        let res = ExecResource::whole_gpu(GpuModel::A100_80GB);
        let m = zoo::lookup("bert-base").unwrap();
        let cost = infer_cost(m, 8, 128, Precision::Half);
        let est = c.predict_on(&pm, &res, &cost).unwrap();
        let speedup = c.speedup_vs_cpu(&est, cost.flops);
        assert!(speedup > 100.0, "A100 vs CPU speedup {speedup} too small");
    }

    #[test]
    #[should_panic]
    fn zero_measurement_rejected() {
        let _ = Calibration::from_measurement("x", 1e9, 0.0);
    }
}
