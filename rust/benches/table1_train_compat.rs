//! Table 1: training-framework compatibility with MIG.
//!
//! Regenerates the paper's Table 1 on the simulated CUDA runtime: two GIs
//! on an A30, four training frameworks, only MIG 0 ever usable — and the
//! PyTorch-1.13 quirk of reporting a visible-device count of 0.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::frameworks::run_training_matrix;
use migperf::util::table::Table;

fn main() {
    banner("Table 1", "Training framework compatibility with MIG (2-GI A30)");
    let rows = run_training_matrix();
    let mut t = Table::new(&[
        "Training framework",
        "Version",
        "Visible device count",
        "Training on MIG 0",
        "Training on MIG 1",
    ]);
    for r in &rows {
        t.row(&[
            r.framework.to_string(),
            r.version.to_string(),
            r.visible_device_count.to_string(),
            if r.works_on_mig0 { "Yes" } else { "No" }.to_string(),
            if r.works_on_mig1 { "Yes" } else { "No device" }.to_string(),
        ]);
    }
    println!("\n{}", t.render());

    shape_check("4 training frameworks probed", rows.len() == 4);
    shape_check(
        "all frameworks train on MIG 0, none on MIG 1",
        rows.iter().all(|r| r.works_on_mig0 && !r.works_on_mig1),
    );
    let pt = rows.iter().find(|r| r.framework == "PyTorch").unwrap();
    shape_check("PyTorch 1.13 reports visible device count 0", pt.visible_device_count == 0);
    shape_check(
        "TF/MxNet/Paddle report visible device count 1",
        rows.iter().filter(|r| r.framework != "PyTorch").all(|r| r.visible_device_count == 1),
    );
}
