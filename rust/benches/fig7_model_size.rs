//! Fig 7: tail latency of different-sized models, MIG vs MPS, batch 8.
//!
//! Paper §4.5: "both MIG and MPS can support small size models well, but
//! MIG have a lower latency for larger models compared to MPS … This can
//! be attributed to physical isolation."

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::mig::profile::lookup as gi_lookup;
use migperf::models::zoo;
use migperf::sharing::mps::MpsModel;
use migperf::simgpu::resource::ExecResource;
use migperf::sweep::{self, SweepEngine};
use migperf::util::table::{fmt_num, Table};
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;

const MODELS: &[&str] = &["resnet18", "resnet34", "resnet50", "resnet101"];
const BATCH: u32 = 8;
const TENANTS: u32 = 2;
const REQUESTS: u64 = 3000;

fn main() {
    banner("Figure 7", "p99 latency vs model size at batch 8, MIG vs MPS (A30)");
    let gpu = GpuModel::A30_24GB;
    // (model × mode) grid through the parallel sweep engine.
    let p = gi_lookup(gpu, "2g.12gb").unwrap();
    let mut sims = Vec::new();
    for model in MODELS {
        let spec = WorkloadSpec::inference(zoo::lookup(model).unwrap(), BATCH, 224);
        sims.push(ServingSim {
            mode: SharingMode::Mig(vec![ExecResource::from_gi(gpu, p); TENANTS as usize]),
            load: LoadMode::Closed { requests_per_server: REQUESTS },
            spec: spec.clone(),
            seed: 77,
        });
        sims.push(ServingSim {
            mode: SharingMode::Mps {
                gpu: ExecResource::whole_gpu(gpu),
                n_clients: TENANTS,
                model: MpsModel::default(),
            },
            load: LoadMode::Closed { requests_per_server: REQUESTS },
            spec,
            seed: 77,
        });
    }
    let outs = sweep::run_serving(&SweepEngine::from_env(), &sims).expect("fig7 sims");

    let mut t = Table::new(&["model", "params M", "MIG p99_ms", "MPS p99_ms", "MPS/MIG"]);
    let mut ratios = Vec::new();
    for (i, model) in MODELS.iter().enumerate() {
        let desc = zoo::lookup(model).unwrap();
        let mig = &outs[2 * i].pooled;
        let mps = &outs[2 * i + 1].pooled;
        let ratio = mps.p99_latency_ms / mig.p99_latency_ms;
        ratios.push(ratio);
        t.row(&[
            model.to_string(),
            fmt_num(desc.params as f64 / 1e6),
            fmt_num(mig.p99_latency_ms),
            fmt_num(mps.p99_latency_ms),
            fmt_num(ratio),
        ]);
    }
    println!("\n{}", t.render());
    shape_check(
        "MPS/MIG tail gap larger for the largest model than the smallest (Fig 7)",
        ratios.last().unwrap() > &ratios[0],
    );
    shape_check("MIG never loses on tails (Fig 7)", ratios.iter().all(|&r| r >= 1.0));
}
