//! Seeded random command-sequence generation.
//!
//! [`generate`] draws a [`CommandSeq`] from a [`Prng`] seed: same seed,
//! same sequence, bit for bit. The distribution is tilted toward the
//! interesting interactions (bursts and clock advances dominate so
//! traffic actually flows; crash/recover, repartition and overload-knob
//! commands ride on top), but every command the grammar allows is
//! reachable. Continuous parameters are quantized to eighths so pinned
//! repros print as short exact decimal literals (`2.5`, `0.125`) that
//! round-trip through `Debug` unchanged.

use crate::testing::command::{Command, CommandSeq};
use crate::util::prng::Prng;

/// Quantize to a dyadic rational (multiples of 1/8): exact in `f64`, and
/// short in `Debug` output, so shrunken repro strings stay readable.
fn q8(x: f64) -> f64 {
    (x * 8.0).round() / 8.0
}

/// Draw one command. `rng` advances a fixed number of times per draw is
/// *not* guaranteed — determinism comes from the seed, not a stream
/// layout — but the same seed always replays the same choices.
fn draw(rng: &mut Prng) -> Command {
    // Weighted pick: timeline commands (advance/burst) dominate, setup
    // and fault commands share the rest.
    match rng.below(100) {
        // 0..25: advance the clock — without these nothing interleaves.
        0..=24 => Command::AdvanceTime { dt_s: q8(rng.uniform(0.5, 30.0)) },
        // 25..50: traffic.
        25..=49 => Command::ArriveBurst {
            class: rng.below(2) as usize,
            n: 1 + rng.below(120),
            over_s: q8(rng.uniform(0.5, 12.0)),
        },
        // 50..62: faults.
        50..=55 => Command::CrashGpu { gpu: rng.below(3) as usize },
        56..=61 => Command::CrashInstance {
            gpu: rng.below(3) as usize,
            class: rng.below(2) as usize,
        },
        62..=69 => Command::Recover { gpu: rng.below(3) as usize },
        // 70..78: repartitions.
        70..=77 => Command::Repartition {
            gpu: rng.below(3) as usize,
            rate_scale: q8(rng.uniform(0.25, 2.0)),
        },
        // 78..: setup knobs.
        78..=80 => Command::ResizeFleet { gpus: 1 + rng.below(3) as usize },
        81..=83 => Command::RetuneTenants {
            gold: q8(rng.uniform(0.5, 4.0)),
            bronze: q8(rng.uniform(0.5, 4.0)),
        },
        84..=86 => Command::SetRolling { rolling: rng.chance(0.5) },
        87..=89 => Command::SetRouter { router: rng.below(4) as u8 },
        90..=93 => Command::SetOverload {
            queue_cap: rng.below(17) as usize,
            deadline_mult: if rng.chance(0.5) { q8(rng.uniform(1.0, 6.0)) } else { 0.0 },
            drop_oldest: rng.chance(0.5),
        },
        94..=96 => Command::SetBrownout {
            threshold: if rng.chance(0.7) { q8(rng.uniform(0.125, 0.75)).max(0.125) } else { 0.0 },
        },
        _ => Command::SetBreaker {
            threshold: if rng.chance(0.7) { q8(rng.uniform(0.125, 0.75)).max(0.125) } else { 0.0 },
            probes: 1 + rng.below(8),
        },
    }
}

/// Generate a random command sequence from `seed` with at most
/// `max_cmds` commands (at least one; `max_cmds` 0 is treated as 1).
pub fn generate(seed: u64, max_cmds: usize) -> CommandSeq {
    let mut rng = Prng::new(seed);
    let cap = max_cmds.max(1) as u64;
    let n = 1 + rng.below(cap) as usize;
    let commands = (0..n).map(|_| draw(&mut rng)).collect();
    CommandSeq { seed, commands }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_sequence() {
        for seed in [0u64, 7, 42, u64::MAX] {
            let a = generate(seed, 24);
            let b = generate(seed, 24);
            assert_eq!(a, b, "seed {seed} must regenerate bit-identically");
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let distinct = (0..32).map(|s| generate(s, 24)).collect::<Vec<_>>();
        let all_equal = distinct.windows(2).all(|w| w[0].commands == w[1].commands);
        assert!(!all_equal, "32 seeds must not all collapse to one sequence");
    }

    #[test]
    fn every_generated_sequence_compiles_valid() {
        // The FaultPlan::validate-grade precondition check: whatever the
        // generator emits, the compiled config must pass the engine's own
        // validation (arrival traces monotone, fault windows disjoint,
        // overload knobs in range).
        for seed in 0..200u64 {
            let seq = generate(seed, 24);
            assert!(!seq.commands.is_empty());
            assert!(seq.commands.len() <= 24);
            let c = seq.compile();
            c.config
                .validate()
                .unwrap_or_else(|e| panic!("seed {seed} compiled invalid: {e}"));
            c.config
                .faults
                .validate(c.config.gpus.len(), c.config.classes.len(), c.config.duration_s)
                .unwrap_or_else(|e| panic!("seed {seed} fault plan invalid: {e}"));
        }
    }

    #[test]
    fn parameters_are_dyadic_for_exact_repro_strings() {
        for seed in 0..50u64 {
            for cmd in &generate(seed, 24).commands {
                let check = |x: f64| {
                    assert_eq!(x, q8(x), "{cmd:?} carries a non-dyadic parameter");
                };
                match *cmd {
                    Command::AdvanceTime { dt_s } => check(dt_s),
                    Command::ArriveBurst { over_s, .. } => check(over_s),
                    Command::Repartition { rate_scale, .. } => check(rate_scale),
                    Command::RetuneTenants { gold, bronze } => {
                        check(gold);
                        check(bronze);
                    }
                    Command::SetOverload { deadline_mult, .. } => check(deadline_mult),
                    Command::SetBrownout { threshold } => check(threshold),
                    Command::SetBreaker { threshold, .. } => check(threshold),
                    _ => {}
                }
            }
        }
    }
}
