//! Simulated-GPU substrate: resources, roofline pricing, energy and the
//! discrete-event core.
//!
//! This is the substitution for the paper's physical execution of
//! workloads on A100/A30 hardware (DESIGN.md §1): the roofline model
//! prices each training/inference step on the resource slice it runs on,
//! the energy model integrates board power over the simulated timeline,
//! and the DES drives open-loop serving experiments. `runtime::calibrate`
//! anchors the model against real HLO execution of the tiny L2 models.

pub mod calibrate;
pub mod desim;
pub mod energy;
pub mod perfmodel;
pub mod resource;

pub use desim::Des;
pub use energy::EnergyModel;
pub use perfmodel::{PerfError, PerfModel, StepEstimate};
pub use resource::{ExecResource, ShareMode};
