"""L1 Pallas kernel: fused layer normalization.

LayerNorm appears twice per transformer block; fusing the mean/variance
reduction with the affine transform keeps each row's statistics in VMEM
registers instead of round-tripping through HBM. Tiled over rows like
``linear.py``; runs under ``interpret=True`` on this CPU-only image;
differentiable via a custom VJP through the jnp reference.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_BLOCK_ROWS = 8
_EPS = 1e-5


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]                    # [block_rows, dim] in VMEM
    g = g_ref[...]                    # [dim]
    b = b_ref[...]                    # [dim]
    mu = x.mean(axis=-1, keepdims=True)            # VPU row reduction
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + _EPS) * g + b


def _pallas_layernorm(x, gamma, beta):
    rows, dim = x.shape
    pad = (-rows) % _BLOCK_ROWS
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        _layernorm_kernel,
        grid=(xp.shape[0] // _BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((_BLOCK_ROWS, dim), lambda i: (i, 0)),
            pl.BlockSpec((dim,), lambda i: (0,)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], dim), x.dtype),
        interpret=True,
    )(xp, gamma, beta)
    return out[:rows] if pad else out


@jax.custom_vjp
def fused_layernorm(x, gamma, beta):
    """LayerNorm over the last axis on the Pallas path.

    Shapes: ``x [rows, dim]``, ``gamma/beta [dim]``. Matches
    :func:`ref.layernorm_ref` (asserted in tests); gradients flow through
    the reference.
    """
    return _pallas_layernorm(x, gamma, beta)


def _fwd(x, gamma, beta):
    return _pallas_layernorm(x, gamma, beta), (x, gamma, beta)


def _bwd(residual, grad):
    x, gamma, beta = residual
    _, vjp = jax.vjp(ref.layernorm_ref, x, gamma, beta)
    return vjp(grad)


fused_layernorm.defvjp(_fwd, _bwd)
