//! Arena-refactor equivalence backstop.
//!
//! The arena/SoA hot path (handle-based request queues, enum-dispatched
//! arrivals/routers/policies, slab event calendar) must be a pure
//! representation change: for any command sequence the engine's outcome
//! is bit-identical run to run, identical with and without an inspector
//! attached, and the fuzz-report digest is bitwise-stable at any worker
//! count. This suite replays the pinned `model_regressions.rs` corpus
//! plus freshly generated sequences through a deep outcome fingerprint
//! (the whole conservation ledger, derived-metric bit patterns, per-class
//! and per-GPU vectors, and `events_processed` — everything except the
//! wall-derived `events_per_sec`), and pins the mega-sharding contract
//! (`shards == 1` is exactly the unsharded run; any shard count merges
//! bit-identically at any worker count).

use migperf::cluster::{
    FaultPlan, FleetConfig, FleetOutcome, FleetPolicyKind, NoopInspector, OverloadPolicy,
    RepartitionMode, RequestClass, RouterKind, TelemetryConfig,
};
use migperf::mig::gpu::GpuModel;
use migperf::models::zoo;
use migperf::orchestrator::ReconfigCost;
use migperf::sweep::{self, SweepEngine};
use migperf::testing::{case_seed, generate, run_case, run_fuzz, Command, CommandSeq};
use migperf::workload::arrival::ArrivalSpec;
use migperf::workload::spec::WorkloadSpec;

/// Deep determinism fingerprint: every counter in the conservation
/// ledger, the bit patterns of every derived float, and the per-class /
/// per-GPU breakdowns. `events_per_sec` is deliberately absent — it is
/// wall-derived and the only outcome field allowed to differ between
/// replays of the same config.
fn fingerprint(out: &FleetOutcome) -> Vec<u64> {
    let mut v = vec![
        out.arrived,
        out.routed,
        out.completed,
        out.slo_violations,
        out.failed_requests,
        out.retried_requests,
        out.lost_in_crash,
        out.shed_overload,
        out.shed_deadline,
        out.shed_capacity,
        out.shed_brownout,
        out.breaker_trips,
        out.reconfigurations,
        out.migrated_requests,
        out.stranded_requests,
        out.unavailable_routes,
        out.gpu_crashes,
        out.instance_crashes,
        out.train_steps,
        out.events_processed,
        out.goodput_rps.to_bits(),
        out.slo_violation_frac.to_bits(),
        out.fairness_jain.to_bits(),
        out.availability.to_bits(),
        out.reconfig_downtime_s.to_bits(),
        out.breaker_open_s.to_bits(),
        out.train_samples_per_s.to_bits(),
        out.pooled.avg_latency_ms.to_bits(),
        out.pooled.p50_latency_ms.to_bits(),
        out.pooled.p99_latency_ms.to_bits(),
        out.pooled.max_latency_ms.to_bits(),
    ];
    v.extend(out.arrived_per_class.iter().copied());
    v.extend(out.downtime_s_per_gpu.iter().map(|d| d.to_bits()));
    v.extend(out.per_class.iter().map(|s| s.avg_latency_ms.to_bits()));
    v.extend(out.per_gpu.iter().map(|s| s.completed));
    for t in &out.tenants {
        v.extend([t.arrived, t.completed, t.goodput_rps.to_bits()]);
    }
    v
}

/// The pinned corpus: the same sequences `tests/model_regressions.rs`
/// asserts model facts about, reused here as equivalence witnesses (they
/// cover breaker × repartition, crash × brownout, permanent outage ×
/// deadlines, and crash/recover/repartition churn).
fn corpus() -> Vec<(&'static str, CommandSeq)> {
    vec![
        (
            "breaker-half-open x repartition",
            CommandSeq {
                seed: 101,
                commands: vec![
                    Command::ResizeFleet { gpus: 2 },
                    Command::SetOverload { queue_cap: 2, deadline_mult: 1.0, drop_oldest: true },
                    Command::SetBreaker { threshold: 0.125, probes: 2 },
                    Command::SetRolling { rolling: true },
                    Command::ArriveBurst { class: 0, n: 200, over_s: 10.0 },
                    Command::ArriveBurst { class: 1, n: 200, over_s: 10.0 },
                    Command::AdvanceTime { dt_s: 6.0 },
                    Command::Repartition { gpu: 0, rate_scale: 0.25 },
                    Command::ArriveBurst { class: 0, n: 120, over_s: 8.0 },
                    Command::AdvanceTime { dt_s: 12.0 },
                    Command::Repartition { gpu: 0, rate_scale: 2.0 },
                    Command::AdvanceTime { dt_s: 10.0 },
                ],
            },
        ),
        (
            "crash during brownout escalation",
            CommandSeq {
                seed: 102,
                commands: vec![
                    Command::ResizeFleet { gpus: 2 },
                    Command::RetuneTenants { gold: 4.0, bronze: 0.5 },
                    Command::SetOverload { queue_cap: 2, deadline_mult: 1.0, drop_oldest: false },
                    Command::SetBrownout { threshold: 0.125 },
                    Command::ArriveBurst { class: 0, n: 180, over_s: 12.0 },
                    Command::ArriveBurst { class: 1, n: 180, over_s: 12.0 },
                    Command::AdvanceTime { dt_s: 7.0 },
                    Command::CrashGpu { gpu: 1 },
                    Command::ArriveBurst { class: 1, n: 100, over_s: 6.0 },
                    Command::AdvanceTime { dt_s: 9.0 },
                    Command::Recover { gpu: 1 },
                    Command::AdvanceTime { dt_s: 15.0 },
                ],
            },
        ),
        (
            "permanent crash under deadline shedding",
            CommandSeq {
                seed: 103,
                commands: vec![
                    Command::ResizeFleet { gpus: 2 },
                    Command::SetOverload { queue_cap: 4, deadline_mult: 2.0, drop_oldest: false },
                    Command::ArriveBurst { class: 0, n: 150, over_s: 10.0 },
                    Command::AdvanceTime { dt_s: 4.0 },
                    Command::CrashGpu { gpu: 0 },
                    Command::ArriveBurst { class: 0, n: 150, over_s: 10.0 },
                    Command::ArriveBurst { class: 1, n: 80, over_s: 10.0 },
                    Command::AdvanceTime { dt_s: 20.0 },
                ],
            },
        ),
        (
            "crash/recover/repartition churn",
            CommandSeq {
                seed: 104,
                commands: vec![
                    Command::ResizeFleet { gpus: 3 },
                    Command::SetRouter { router: 3 },
                    Command::ArriveBurst { class: 0, n: 160, over_s: 16.0 },
                    Command::ArriveBurst { class: 1, n: 160, over_s: 16.0 },
                    Command::AdvanceTime { dt_s: 3.0 },
                    Command::CrashGpu { gpu: 0 },
                    Command::CrashInstance { gpu: 1, class: 0 },
                    Command::AdvanceTime { dt_s: 4.0 },
                    Command::Recover { gpu: 0 },
                    Command::Repartition { gpu: 0, rate_scale: 1.5 },
                    Command::AdvanceTime { dt_s: 2.0 },
                    Command::Recover { gpu: 1 },
                    Command::CrashGpu { gpu: 0 },
                    Command::AdvanceTime { dt_s: 5.0 },
                    Command::Recover { gpu: 0 },
                    Command::AdvanceTime { dt_s: 12.0 },
                ],
            },
        ),
    ]
}

/// A plain diurnal fleet (no replay traces), used where the command
/// compiler's `ArrivalSpec::Replay` output would be rejected (mega
/// sharding cannot split a trace).
fn diurnal_fleet(n: usize, seed: u64) -> FleetConfig {
    let bert = zoo::lookup("bert-base").unwrap();
    let class = RequestClass {
        spec: WorkloadSpec::inference(bert, 8, 128),
        slo_ms: 40.0,
        arrival: ArrivalSpec::Diurnal {
            base_rate: 6.0 * n as f64,
            peak_rate: 40.0 * n as f64,
            period_s: 60.0,
        },
    };
    FleetConfig {
        gpus: vec![GpuModel::A100_80GB; n],
        train: None,
        classes: vec![class.clone(), class],
        tenants: Vec::new(),
        router: RouterKind::LeastLoaded,
        policy: FleetPolicyKind::Static,
        mode: RepartitionMode::Rolling,
        cost: ReconfigCost::default(),
        duration_s: 120.0,
        window_s: 10.0,
        rho_max: 0.75,
        faults: FaultPlan::none(),
        overload: OverloadPolicy::none(),
        telemetry: TelemetryConfig::off(),
        seed,
    }
}

#[test]
fn pinned_corpus_replays_bit_identically() {
    for (name, seq) in corpus() {
        // The sequence must still satisfy the live invariants and the
        // closed-form model after the refactor...
        let first = match run_case(&seq) {
            Ok(out) => out,
            Err(f) => panic!(
                "pinned case '{name}' violated the model:\n{}",
                f.violations.join("\n")
            ),
        };
        // ...and replay to the same bits, down to events_processed.
        let cfg = seq.compile().config;
        let again = cfg.run().expect("replay");
        assert_eq!(
            fingerprint(&first),
            fingerprint(&again),
            "'{name}': replaying the same sequence must reproduce every bit"
        );
        assert!(again.events_processed > again.arrived, "every arrival is at least one event");
    }
}

#[test]
fn inspector_attachment_is_free() {
    // run() is run_with_inspector(&mut NoopInspector); the probe hooks
    // must never perturb the simulation, for pinned and generated
    // sequences alike.
    for (name, seq) in corpus() {
        let cfg = seq.compile().config;
        let plain = cfg.run().expect("run");
        let mut noop = NoopInspector;
        let probed = cfg.run_with_inspector(&mut noop).expect("probed run");
        assert_eq!(
            fingerprint(&plain),
            fingerprint(&probed),
            "'{name}': attaching an inspector must not change the outcome"
        );
    }
    for i in 0..6u64 {
        let seq = generate(case_seed(23, i), 14);
        let cfg = seq.compile().config;
        let plain = cfg.run().expect("run");
        let mut noop = NoopInspector;
        let probed = cfg.run_with_inspector(&mut noop).expect("probed run");
        assert_eq!(fingerprint(&plain), fingerprint(&probed), "generated case {i}");
    }
}

#[test]
fn fuzz_digest_survives_reruns_and_worker_counts() {
    // Same parameters, fresh engine state: the digest is a pure function
    // of (cases, seed, max_cmds), not of scheduling or allocation order.
    let first = run_fuzz(16, 11, 12, &SweepEngine::serial());
    assert!(first.passed(), "fuzz violations:\n{:#?}", first.failures);
    let rerun = run_fuzz(16, 11, 12, &SweepEngine::serial());
    assert_eq!(first.digest, rerun.digest, "rerunning must reproduce the digest");
    for workers in [2usize, 4, 16] {
        let par = run_fuzz(16, 11, 12, &SweepEngine::new(workers));
        assert_eq!(
            par.digest, first.digest,
            "fuzz digest must be bitwise-identical at {workers} workers"
        );
    }
}

#[test]
fn mega_single_shard_is_the_unsharded_run() {
    let cfg = diurnal_fleet(3, 77);
    let direct = cfg.run().expect("direct");
    let sharded = sweep::run_mega(&SweepEngine::serial(), &cfg, 1).expect("1-shard mega");
    assert_eq!(
        fingerprint(&direct),
        fingerprint(&sharded),
        "shards == 1 must be exactly the unsharded simulation"
    );
}

#[test]
fn mega_merge_is_bit_identical_at_any_worker_count() {
    let cfg = diurnal_fleet(8, 78);
    let base = sweep::run_mega(&SweepEngine::serial(), &cfg, 4).expect("serial mega");
    assert_eq!(
        base.completed + base.failed_requests + base.lost_in_crash + base.shed_overload,
        base.arrived,
        "merged outcome must conserve requests"
    );
    for workers in [2usize, 4, 16] {
        let par = sweep::run_mega(&SweepEngine::new(workers), &cfg, 4).expect("parallel mega");
        assert_eq!(
            fingerprint(&base),
            fingerprint(&par),
            "mega merge must be bit-identical at {workers} workers"
        );
    }
}
