//! Stateful model-based testing of the fleet engine.
//!
//! The fleet engine composes crashes × repartitions × tenants × overload
//! shedding × telemetry; example-based tests cannot cover the
//! interleavings (a breaker going half-open during a rolling drain, a
//! crash landing mid-brownout-escalation), and the planned arena/SoA
//! hot-path refactor needs a correctness backstop that does. This module
//! is a proptest-*stateful*-style harness built std-only on the seeded
//! [`Prng`](crate::util::prng::Prng):
//!
//! * [`command`] — the [`Command`] grammar (arrive-burst, crash
//!   GPU/instance, recover, repartition, resize, retune tenants, toggle
//!   shed/brownout/breaker knobs, advance-time) and a *total* compiler
//!   from a [`CommandSeq`] to a valid [`FleetConfig`]: every input
//!   compiles (indices wrap, parameters clamp, impossible crashes are
//!   dropped), so validity is closed under command deletion and the
//!   shrinker can never escape the valid space;
//! * [`generate`] — the seeded sequence generator (same seed, same
//!   sequence, bit for bit);
//! * [`model`] — the simplified reference model: closed-form
//!   expectations over the compiled schedule (exact per-class arrival
//!   counts via [`ArrivalSpec::Replay`](crate::workload::arrival::ArrivalSpec),
//!   exact crash/downtime/availability bookkeeping, extended
//!   conservation fleet-wide and per tenant, mechanism-off zeros,
//!   brownout fairness-order monotonicity, telemetry/outcome
//!   reconciliation);
//! * [`driver`] — replays a sequence against the real engine under an
//!   [`InvariantInspector`] (never-route-to-ineligible-GPU, brownout
//!   ladder bounds, crash/recovery state checks, checked live at every
//!   routing decision and tick via the engine's
//!   [`EngineInspector`](crate::cluster::EngineInspector) hooks), then
//!   runs the model checks on the outcome; [`run_fuzz`] fans cases out
//!   through the [`SweepEngine`](crate::sweep::SweepEngine) under the
//!   bitwise-determinism contract (the report digest is identical at any
//!   worker count);
//! * [`shrink`] — a deterministic delete-chunk + halve-parameters
//!   minimizer that turns a failing sequence into a self-contained repro
//!   (seed + command list) pasteable into `rust/tests/model_regressions.rs`.
//!
//! The CLI entry point is `migperf fuzz --cases N --seed S`; CI runs a
//! 50-case smoke per PR and a 2000-case nightly sweep.

pub mod command;
pub mod driver;
pub mod generate;
pub mod model;
pub mod shrink;

pub use command::{Command, CommandSeq, Compiled};
pub use driver::{
    case_seed, run_case, run_fuzz, CaseFailure, FailedCase, FuzzReport, InvariantInspector,
};
pub use generate::generate;
pub use model::check_outcome;
pub use shrink::{repro_string, shrink};
