//! Configuration for the determinism auditor: which paths carry the
//! bitwise-determinism contract, which files are sanctioned wall-clock
//! sites, which hot-path modules are under the panic budget, and the
//! parser for the checked-in `lint-budget.toml` ratchet file.

use std::collections::BTreeMap;

/// Repo-specific lint configuration. Paths are matched as substrings of
/// the scanned file's forward-slash path, so the config works whether the
/// linter runs from `rust/` (CI) or the repo root.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Modules under the bitwise-determinism contract: map-iteration,
    /// unstable-sort, float-order and entropy rules fire only here.
    pub deterministic_paths: Vec<String>,
    /// Files where wall-clock reads are sanctioned wholesale (the CLI,
    /// the benches, the pjrt-gated executor). Sites inside deterministic
    /// modules are instead annotated inline with `lint:allow`.
    pub wallclock_allowed: Vec<String>,
    /// Engine hot-path modules under the `lint-budget.toml` ratchet
    /// (exact path suffixes, not substrings).
    pub budget_modules: Vec<String>,
    /// Directory-name fragments skipped when *walking* directories;
    /// explicitly listed files are always linted (so CI can run the
    /// linter directly on a known-bad fixture).
    pub walk_excludes: Vec<String>,
}

impl Default for LintConfig {
    fn default() -> Self {
        let s = |v: &[&str]| v.iter().map(|p| p.to_string()).collect();
        LintConfig {
            deterministic_paths: s(&[
                "src/cluster/",
                "src/sweep/",
                "src/simgpu/",
                "src/testing/",
                "src/workload/",
                "src/metrics/",
            ]),
            wallclock_allowed: s(&["src/main.rs", "benches/", "src/runtime/executor.rs"]),
            budget_modules: s(&[
                "src/cluster/engine.rs",
                "src/cluster/mega.rs",
                "src/cluster/overload.rs",
                "src/cluster/router.rs",
                "src/simgpu/desim.rs",
                "src/sweep/engine.rs",
                "src/workload/serving.rs",
            ]),
            walk_excludes: s(&["lint_fixtures", "target/"]),
        }
    }
}

impl LintConfig {
    /// True if `path` is under the bitwise-determinism contract.
    pub fn is_deterministic(&self, path: &str) -> bool {
        self.deterministic_paths.iter().any(|p| path.contains(p.as_str()))
    }

    /// True if wall-clock reads are sanctioned wholesale in `path`.
    pub fn is_wallclock_allowed(&self, path: &str) -> bool {
        self.wallclock_allowed.iter().any(|p| path.contains(p.as_str()))
    }

    /// The budget key for `path`, if it is a budgeted hot-path module.
    pub fn budget_key(&self, path: &str) -> Option<&str> {
        self.budget_modules.iter().map(String::as_str).find(|m| path.ends_with(m))
    }
}

/// Per-module panic-budget counters. The checked-in numbers are a
/// ratchet: a count above budget is an error, a count below budget is a
/// stale-budget warning (an error under `--strict`), so the file always
/// matches reality and can only move down.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BudgetEntry {
    /// `.unwrap()` calls outside `#[cfg(test)]` items.
    pub unwrap: u64,
    /// `.expect(…)` calls outside `#[cfg(test)]` items.
    pub expect: u64,
    /// `panic!(…)` invocations outside `#[cfg(test)]` items.
    pub panic: u64,
    /// Index expressions `x[i]` outside `#[cfg(test)]` items.
    pub index: u64,
}

impl BudgetEntry {
    /// Counter value by name.
    pub fn get(&self, counter: &str) -> Option<u64> {
        match counter {
            "unwrap" => Some(self.unwrap),
            "expect" => Some(self.expect),
            "panic" => Some(self.panic),
            "index" => Some(self.index),
            _ => None,
        }
    }

    /// Counters in canonical order, paired with their names.
    pub fn counters(&self) -> [(&'static str, u64); 4] {
        [
            ("unwrap", self.unwrap),
            ("expect", self.expect),
            ("panic", self.panic),
            ("index", self.index),
        ]
    }
}

/// The parsed `lint-budget.toml`: module path → counters.
#[derive(Debug, Clone, Default)]
pub struct BudgetTable {
    /// Entries keyed by module path as written in the file.
    pub entries: BTreeMap<String, BudgetEntry>,
}

impl BudgetTable {
    /// Entry for a scanned file, matched by path suffix so the table
    /// written relative to `rust/` also resolves from the repo root.
    pub fn entry_for(&self, path: &str) -> Option<(&str, &BudgetEntry)> {
        self.entries
            .iter()
            .find(|(k, _)| path.ends_with(k.as_str()))
            .map(|(k, e)| (k.as_str(), e))
    }
}

/// Parse the `lint-budget.toml` subset:
///
/// ```toml
/// [budget."src/cluster/engine.rs"]
/// unwrap = 0
/// expect = 4
/// panic = 1
/// index = 120
/// ```
///
/// Comments (`#`) and blank lines are ignored. Anything else is an error
/// — the ratchet file is machine-written, so leniency only hides typos.
pub fn parse_budget(text: &str) -> Result<BudgetTable, String> {
    let mut table = BudgetTable::default();
    let mut current: Option<String> = None;
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = match raw.find('#') {
            Some(pos) => &raw[..pos],
            None => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {lineno}: unterminated section header"))?;
            let path = inner
                .strip_prefix("budget.\"")
                .and_then(|p| p.strip_suffix('"'))
                .ok_or_else(|| {
                    format!("line {lineno}: expected [budget.\"<path>\"], got [{inner}]")
                })?;
            if path.is_empty() {
                return Err(format!("line {lineno}: empty module path"));
            }
            if table.entries.contains_key(path) {
                return Err(format!("line {lineno}: duplicate section for {path}"));
            }
            table.entries.insert(path.to_string(), BudgetEntry::default());
            current = Some(path.to_string());
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {lineno}: expected `key = value`, got `{line}`"))?;
        let key = key.trim();
        let value: u64 = value
            .trim()
            .parse()
            .map_err(|_| format!("line {lineno}: `{key}` needs a non-negative integer"))?;
        let section = current
            .as_ref()
            .ok_or_else(|| format!("line {lineno}: `{key}` outside any [budget.\"…\"] section"))?;
        let entry = table.entries.get_mut(section).expect("section was just inserted");
        match key {
            "unwrap" => entry.unwrap = value,
            "expect" => entry.expect = value,
            "panic" => entry.panic = value,
            "index" => entry.index = value,
            other => return Err(format!("line {lineno}: unknown counter `{other}`")),
        }
    }
    Ok(table)
}

/// Serialize a budget table in the canonical checked-in format.
pub fn render_budget(table: &BudgetTable) -> String {
    let mut out = String::new();
    out.push_str(
        "# Panic-budget ratchet for engine hot-path modules (see `migperf lint`).\n\
         # Counts cover code outside #[cfg(test)] items and may only go down:\n\
         # above-budget fails the lint gate, below-budget is a stale-budget\n\
         # warning (error under --strict) telling you to tighten this file.\n",
    );
    for (path, e) in &table.entries {
        out.push('\n');
        out.push_str(&format!("[budget.\"{path}\"]\n"));
        for (name, value) in e.counters() {
            out.push_str(&format!("{name} = {value}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_classifies_paths() {
        let cfg = LintConfig::default();
        assert!(cfg.is_deterministic("src/cluster/engine.rs"));
        assert!(cfg.is_deterministic("rust/src/metrics/collector.rs"));
        assert!(!cfg.is_deterministic("src/mig/controller.rs"));
        assert!(cfg.is_wallclock_allowed("benches/perf_hotpath.rs"));
        assert!(cfg.is_wallclock_allowed("src/main.rs"));
        assert!(cfg.is_wallclock_allowed("src/runtime/executor.rs"));
        assert!(!cfg.is_wallclock_allowed("src/cluster/engine.rs"));
        assert_eq!(cfg.budget_key("rust/src/cluster/engine.rs"), Some("src/cluster/engine.rs"));
        assert_eq!(cfg.budget_key("src/cluster/telemetry.rs"), None);
    }

    #[test]
    fn budget_roundtrip() {
        let mut table = BudgetTable::default();
        table.entries.insert(
            "src/cluster/engine.rs".to_string(),
            BudgetEntry { unwrap: 1, expect: 2, panic: 3, index: 4 },
        );
        let text = render_budget(&table);
        let back = parse_budget(&text).unwrap();
        assert_eq!(back.entries.len(), 1);
        let e = back.entries.get("src/cluster/engine.rs").unwrap();
        assert_eq!(*e, BudgetEntry { unwrap: 1, expect: 2, panic: 3, index: 4 });
    }

    #[test]
    fn budget_parses_comments_and_suffix_match() {
        let text = "# header\n[budget.\"src/sweep/engine.rs\"]\nunwrap = 7 # inline\n";
        let table = parse_budget(text).unwrap();
        let (key, e) = table.entry_for("rust/src/sweep/engine.rs").unwrap();
        assert_eq!(key, "src/sweep/engine.rs");
        assert_eq!(e.unwrap, 7);
        assert_eq!(e.expect, 0);
    }

    #[test]
    fn budget_rejects_malformed_input() {
        assert!(parse_budget("[budget.\"a\"]\nbogus = 1\n").is_err(), "unknown counter");
        assert!(parse_budget("unwrap = 1\n").is_err(), "counter outside a section");
        assert!(parse_budget("[nope]\n").is_err(), "non-budget section");
        assert!(parse_budget("[budget.\"a\"]\nunwrap = -1\n").is_err(), "negative count");
        assert!(parse_budget("[budget.\"a\"]\nunwrap\n").is_err(), "missing value");
        assert!(
            parse_budget("[budget.\"a\"]\n[budget.\"a\"]\n").is_err(),
            "duplicate section"
        );
    }
}
