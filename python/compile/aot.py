"""AOT lowering: JAX/Pallas entry points → HLO text artifacts.

Runs ONCE at build time (``make artifacts``). For every entry point it
writes ``artifacts/<name>.hlo.txt`` plus a ``manifest.json`` describing
input specs, output arity and analytic FLOPs; the training entry also gets
``bert_tiny.params.bin`` (flat little-endian f32, spec order) so the rust
runtime can seed the training loop.

HLO *text* is the interchange format, NOT ``lowered.compile()`` /
serialized protos: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the rust ``xla`` crate)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Inference entries close over baked-in weights (single tensor input, the
token/image batch) — that keeps the rust serving hot path to one literal.
The training entry takes (params..., tokens, targets) and returns
(loss, new_params...) so rust can run the optimizer loop.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Lower a jitted computation to HLO text with return_tuple=True."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tensor_spec(name, arr_or_shape, dtype):
    shape = list(arr_or_shape.shape) if hasattr(arr_or_shape, "shape") else list(arr_or_shape)
    return {"name": name, "dtype": dtype, "shape": shape}


def _bert_flops(cfg: model.BertConfig, batch: int, train: bool) -> float:
    """Dominant-term FLOPs of one tiny-BERT execution (for calibration)."""
    s, h, l, m = cfg.max_seq, cfg.hidden, cfg.layers, cfg.mlp_mult
    per_tok = 2 * (4 * h * h + 2 * m * h * h) * l + 2 * h * cfg.vocab
    attn = 4 * l * s * s * h
    fwd = batch * (s * per_tok + attn)
    return float(fwd * (3 if train else 1))


def _resnet_flops(cfg: model.ResNetConfig, batch: int) -> float:
    """Rough conv FLOPs of one tiny-ResNet forward."""
    hw = cfg.in_size * cfg.in_size
    total = 2 * 9 * 3 * cfg.channels[0] * hw
    size = hw
    in_c = cfg.channels[0]
    for s, c in enumerate(cfg.channels):
        if s > 0:
            size //= 4
        total += 2 * 9 * in_c * c * size + 2 * 9 * c * c * size
        in_c = c
    return float(batch * total)


def build_entries(out_dir: str):
    """Lower every entry point, returning manifest entry dicts."""
    entries = []
    cfg = model.TINY_BERT
    params = model.bert_init(cfg, seed=0)

    # --- BERT inference at several batch sizes (weights baked in) ---
    for batch in (1, 4, 8):
        name = f"bert_tiny_infer_b{batch}"
        fn = lambda tokens: (model.bert_infer_pooled(params, tokens, cfg),)
        spec = jax.ShapeDtypeStruct((batch, cfg.max_seq), jnp.int32)
        text = to_hlo_text(jax.jit(fn).lower(spec))
        hlo_file = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, hlo_file), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "hlo_file": hlo_file,
            "inputs": [_tensor_spec("tokens", (batch, cfg.max_seq), "i32")],
            "num_outputs": 1,
            "flops": _bert_flops(cfg, batch, train=False),
        })

    # --- BERT training step (params explicit; loss + new params out) ---
    batch = 8
    name = f"bert_tiny_train_b{batch}"

    def train_fn(*args):
        ps = list(args[: len(params)])
        tokens, targets = args[len(params)], args[len(params) + 1]
        loss, new_ps = model.bert_train_step(ps, tokens, targets, cfg)
        return (loss, *new_ps)

    arg_specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params]
    arg_specs.append(jax.ShapeDtypeStruct((batch, cfg.max_seq), jnp.int32))
    arg_specs.append(jax.ShapeDtypeStruct((batch, cfg.max_seq), jnp.int32))
    # §Perf (L2): donate the parameter buffers — the lowered HLO gets
    # input/output aliasing, so XLA updates weights in place instead of
    # allocating a fresh copy of every tensor each step.
    text = to_hlo_text(
        jax.jit(train_fn, donate_argnums=tuple(range(len(params)))).lower(*arg_specs)
    )
    hlo_file = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_file), "w") as f:
        f.write(text)
    params_file = "bert_tiny.params.bin"
    flat = np.concatenate([np.asarray(p, dtype=np.float32).ravel() for p in params])
    flat.tofile(os.path.join(out_dir, params_file))
    inputs = [
        _tensor_spec(n, shape, "f32") for (n, shape) in model.bert_param_specs(cfg)
    ]
    inputs.append(_tensor_spec("tokens", (batch, cfg.max_seq), "i32"))
    inputs.append(_tensor_spec("targets", (batch, cfg.max_seq), "i32"))
    entries.append({
        "name": name,
        "hlo_file": hlo_file,
        "inputs": inputs,
        "num_outputs": 1 + len(params),
        "flops": _bert_flops(cfg, batch, train=True),
        "params_file": params_file,
        "num_param_inputs": len(params),
    })

    # --- ResNet inference (weights baked in) ---
    rcfg = model.TINY_RESNET
    rparams = model.resnet_init(rcfg, seed=1)
    for batch in (1, 8):
        name = f"resnet_tiny_infer_b{batch}"
        fn = lambda images: (model.resnet_forward(rparams, images, rcfg),)
        spec = jax.ShapeDtypeStruct((batch, 3, rcfg.in_size, rcfg.in_size), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(spec))
        hlo_file = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, hlo_file), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "hlo_file": hlo_file,
            "inputs": [_tensor_spec("images", (batch, 3, rcfg.in_size, rcfg.in_size), "f32")],
            "num_outputs": 1,
            "flops": _resnet_flops(rcfg, batch),
        })

    return entries


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = parser.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    entries = build_entries(out_dir)
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    total = sum(
        os.path.getsize(os.path.join(out_dir, e["hlo_file"])) for e in entries
    )
    print(f"wrote {len(entries)} entries ({total / 1e6:.1f} MB of HLO) to {out_dir}")


if __name__ == "__main__":
    main()
