// Lint fixture (never compiled): correctly suppressed findings, both
// leading (line above) and trailing (same line) form. Expected: zero
// findings.

pub fn probe() -> f64 {
    // lint:allow(wall-clock, reason="fixture demonstrates a sanctioned wall-only probe")
    let t0 = std::time::Instant::now();
    let dt = t0.elapsed().as_secs_f64(); // lint:allow(wall-clock, reason="wall-only, never checksummed")
    dt
}
