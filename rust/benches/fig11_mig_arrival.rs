//! Fig 11 (appendix C): tail latency of 4 ResNet-50 inference processes
//! on 4 MIG 1g.6gb instances (A30) under different request arrival rates.
//!
//! The MIG counterpart of Fig 10: physical isolation keeps the tail flat
//! until each slice itself saturates.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, shape_check};
use migperf::mig::controller::MigController;
use migperf::mig::gpu::GpuModel;
use migperf::models::zoo;
use migperf::sharing::mps::MpsModel;
use migperf::simgpu::resource::ExecResource;
use migperf::sweep::{self, SweepEngine};
use migperf::util::table::{fmt_num, sparkline, Table};
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;

const RATES: &[f64] = &[10.0, 20.0, 40.0, 80.0, 200.0, 480.0];
const REQUESTS: u64 = 1500;

fn main() {
    banner("Figure 11", "4×1g.6gb MIG ResNet-50 servers on A30: p99 vs arrival rate");
    // Build the partition through the controller so the layout is verified
    // against NVIDIA's rules (4×1g.6gb is the only way to get 4 tenants).
    let mut ctl = MigController::new(GpuModel::A30_24GB);
    ctl.enable_mig().unwrap();
    let gis = ctl.partition_uniform("1g.6gb", 4).expect("A30 supports 4×1g.6gb");
    let resources: Vec<ExecResource> = gis
        .iter()
        .map(|gi| ExecResource::from_gi(GpuModel::A30_24GB, ctl.instance(*gi).unwrap().profile))
        .collect();

    let spec = WorkloadSpec::inference(zoo::lookup("resnet50").unwrap(), 1, 224);
    // One sweep-engine grid: the MIG rate axis plus the MPS cross-check
    // point at the near-saturation rate (last grid entry).
    let hi_rate = RATES[RATES.len() - 2];
    let mut sims: Vec<ServingSim> = RATES
        .iter()
        .map(|&rate| ServingSim {
            mode: SharingMode::Mig(resources.clone()),
            load: LoadMode::OpenPoisson { rate, requests_per_server: REQUESTS },
            spec: spec.clone(),
            seed: 88,
        })
        .collect();
    sims.push(ServingSim {
        mode: SharingMode::Mps {
            gpu: ExecResource::whole_gpu(GpuModel::A30_24GB),
            n_clients: 4,
            model: MpsModel::default(),
        },
        load: LoadMode::OpenPoisson { rate: hi_rate, requests_per_server: REQUESTS },
        spec: spec.clone(),
        seed: 88,
    });
    let outs = sweep::run_serving(&SweepEngine::from_env(), &sims).expect("fig11 sims");

    let mut t = Table::new(&["rate/server req/s", "avg_ms", "p99_ms", "max_ms"]);
    let mut p99s = Vec::new();
    for (&rate, out) in RATES.iter().zip(&outs) {
        let out = &out.pooled;
        p99s.push(out.p99_latency_ms);
        t.row(&[
            fmt_num(rate),
            fmt_num(out.avg_latency_ms),
            fmt_num(out.p99_latency_ms),
            fmt_num(out.max_latency_ms),
        ]);
    }
    println!("\n{}p99 trend: {}", t.render(), sparkline(&p99s));
    let chart = migperf::util::plot::render(
        &[migperf::util::plot::PlotSeries {
            label: "MIG 4×1g.6gb p99 ms vs rate/server".into(),
            points: RATES.iter().zip(&p99s).map(|(&r, &p)| (r, p)).collect(),
        }],
        56,
        10,
    );
    println!("\n{chart}");

    // Cross-check vs Fig 10 (MPS) at a high rate: near saturation the
    // MPS tail inflates far beyond its median (interference), while each
    // isolated MIG slice degrades only by its own queueing. Note that at
    // *low* rates MPS is absolutely faster — each request briefly gets
    // the whole GPU — which is the same effect the paper reports as "MPS
    // comparable to MIG for small workloads".
    let mps_out = &outs[RATES.len()].pooled;
    let mig_hi = &outs[RATES.len() - 2].pooled;
    let mig_spread = mig_hi.p99_latency_ms / mig_hi.avg_latency_ms;
    let mps_spread = mps_out.p99_latency_ms / mps_out.avg_latency_ms;
    shape_check(
        &format!(
            "near saturation MIG tail spread (p99/avg {mig_spread:.2}) below MPS spread \
             ({mps_spread:.2}) (Figs 10 vs 11)"
        ),
        mig_spread < mps_spread,
    );
    shape_check(
        "MIG p99 flat until per-slice saturation, then explodes (Fig 11)",
        p99s[1] / p99s[0] < 2.0 && p99s.last().unwrap() > &(p99s[0] * 5.0),
    );
}
