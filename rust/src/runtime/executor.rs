//! PJRT execution engine: load HLO artifacts, compile once, execute many.
//!
//! The heart of the rust-side request path: `Engine` wraps one PJRT CPU
//! client, compiles each artifact the first time it is requested, and
//! caches the loaded executable. Inputs/outputs cross the boundary as
//! literals built from plain `f32`/`i32` slices.
//!
//! HLO *text* is the interchange format — see `/opt/xla-example/README.md`
//! and `python/compile/aot.py`: jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects, while the text parser
//! reassigns ids.
//!
//! The PJRT client needs the `xla` bindings, which the offline toolchain
//! does not carry; the real engine is therefore gated behind the `pjrt`
//! cargo feature. The default build ships a stub `Engine` with the same
//! API that errors at construction, so everything guarded by
//! `runtime::artifacts_available()` degrades gracefully.

use std::path::Path;

use super::manifest::{read_f32_blob, DType, EntryPoint, Manifest};

/// Runtime execution error (replaces the old `anyhow` chains with a plain
/// message type; context is folded into the message).
#[derive(Debug, Clone, PartialEq)]
pub struct ExecError(pub String);

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ExecError {}

/// Executor result alias.
pub type Result<T> = std::result::Result<T, ExecError>;

fn err(msg: impl Into<String>) -> ExecError {
    ExecError(msg.into())
}

/// A host-side tensor crossing into/out of an executable.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    /// f32 data with shape.
    F32(Vec<f32>, Vec<i64>),
    /// i32 data with shape.
    I32(Vec<i32>, Vec<i64>),
}

impl HostTensor {
    /// Shape of the tensor.
    pub fn shape(&self) -> &[i64] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    /// Element count.
    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::I32(v, _) => v.len(),
        }
    }

    /// Borrow f32 data (None for i32 tensors).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Some(v),
            _ => None,
        }
    }
}

/// Outcome of one execution: outputs plus the measured wall time.
#[derive(Debug)]
pub struct ExecOutcome {
    /// Output tensors, in tuple order.
    pub outputs: Vec<HostTensor>,
    /// Wall-clock seconds the execution took (used for calibration).
    pub wall_s: f64,
}

#[cfg(feature = "pjrt")]
// Scoped escape hatch from the determinism lints: the PJRT cache is
// keyed by artifact path (point lookups only, never iterated) and wall
// timing here feeds calibration, not checksums.
#[allow(clippy::disallowed_types, clippy::disallowed_methods)]
mod pjrt_impl {
    use super::*;
    use std::collections::HashMap;
    use std::time::Instant;

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        let lit = match t {
            HostTensor::F32(v, shape) => xla::Literal::vec1(v)
                .reshape(shape)
                .map_err(|e| err(format!("reshaping f32 input: {e:?}")))?,
            HostTensor::I32(v, shape) => xla::Literal::vec1(v)
                .reshape(shape)
                .map_err(|e| err(format!("reshaping i32 input: {e:?}")))?,
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().map_err(|e| err(format!("output shape: {e:?}")))?;
        let dims: Vec<i64> = shape.dims().to_vec();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(
                lit.to_vec::<f32>().map_err(|e| err(format!("reading f32 output: {e:?}")))?,
                dims,
            )),
            xla::ElementType::S32 => Ok(HostTensor::I32(
                lit.to_vec::<i32>().map_err(|e| err(format!("reading i32 output: {e:?}")))?,
                dims,
            )),
            other => Err(err(format!("unsupported output element type {other:?}"))),
        }
    }

    /// PJRT execution engine with an executable cache.
    pub struct Engine {
        client: xla::PjRtClient,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        /// Create an engine on the PJRT CPU client.
        pub fn cpu() -> Result<Engine> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| err(format!("creating PJRT CPU client: {e:?}")))?;
            Ok(Engine { client, cache: HashMap::new() })
        }

        /// Platform name of the underlying client (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Number of executables currently cached.
        pub fn cached(&self) -> usize {
            self.cache.len()
        }

        /// Load and compile an HLO text file under a cache key.
        pub fn load_hlo_text(&mut self, key: &str, path: &Path) -> Result<()> {
            if self.cache.contains_key(key) {
                return Ok(());
            }
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| err(format!("parsing HLO text {path:?}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err(format!("compiling {key}: {e:?}")))?;
            self.cache.insert(key.to_string(), exe);
            Ok(())
        }

        /// Execute a cached executable with host tensors; returns outputs
        /// and wall time. The executable must have been lowered with
        /// `return_tuple=True` (aot.py always does).
        pub fn execute(&self, key: &str, inputs: &[HostTensor]) -> Result<ExecOutcome> {
            let exe =
                self.cache.get(key).ok_or_else(|| err(format!("executable '{key}' not loaded")))?;
            let literals: Vec<xla::Literal> =
                inputs.iter().map(to_literal).collect::<Result<_>>()?;
            let start = Instant::now();
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| err(format!("executing {key}: {e:?}")))?;
            let wall_s = start.elapsed().as_secs_f64();
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("syncing {key} output: {e:?}")))?;
            let parts = tuple.to_tuple().map_err(|e| err(format!("untupling {key}: {e:?}")))?;
            let outputs = parts.iter().map(from_literal).collect::<Result<Vec<_>>>()?;
            Ok(ExecOutcome { outputs, wall_s })
        }

        /// Load every entry of a manifest (compiling all artifacts up
        /// front).
        pub fn load_manifest(&mut self, manifest: &Manifest) -> Result<()> {
            for e in &manifest.entries {
                self.load_hlo_text(&e.name, &manifest.hlo_path(e))?;
            }
            Ok(())
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Engine;

/// Stub engine used when the crate is built without the `pjrt` feature:
/// same API, but construction fails, so callers gated on
/// [`crate::runtime::artifacts_available`] skip real execution.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always errors: the PJRT backend was not built.
    pub fn cpu() -> Result<Engine> {
        Err(err("PJRT backend not built (enable the `pjrt` cargo feature)"))
    }

    /// Platform name (stub).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Number of executables currently cached (stub: always 0).
    pub fn cached(&self) -> usize {
        0
    }

    /// Always errors on the stub engine.
    pub fn load_hlo_text(&mut self, _key: &str, _path: &Path) -> Result<()> {
        Err(err("PJRT backend not built (enable the `pjrt` cargo feature)"))
    }

    /// Always errors on the stub engine.
    pub fn execute(&self, _key: &str, _inputs: &[HostTensor]) -> Result<ExecOutcome> {
        Err(err("PJRT backend not built (enable the `pjrt` cargo feature)"))
    }

    /// Always errors on the stub engine.
    pub fn load_manifest(&mut self, _manifest: &Manifest) -> Result<()> {
        Err(err("PJRT backend not built (enable the `pjrt` cargo feature)"))
    }
}

/// Split a flat f32 params blob into per-tensor [`HostTensor`]s following
/// the entry's parameter input specs.
pub fn unflatten_params(entry: &EntryPoint, flat: &[f32]) -> Result<Vec<HostTensor>> {
    let mut out = Vec::new();
    let mut offset = 0usize;
    for spec in entry.inputs.iter().take(entry.num_param_inputs) {
        if spec.dtype != DType::F32 {
            return Err(err(format!("parameter input '{}' must be f32", spec.name)));
        }
        let n = spec.elements();
        if offset + n > flat.len() {
            return Err(err(format!(
                "params blob too short: need {} elements at offset {offset}, have {}",
                n,
                flat.len()
            )));
        }
        out.push(HostTensor::F32(flat[offset..offset + n].to_vec(), spec.shape.clone()));
        offset += n;
    }
    if offset != flat.len() {
        return Err(err(format!("params blob has {} trailing elements", flat.len() - offset)));
    }
    Ok(out)
}

/// Load an entry's initial parameters from its params blob.
pub fn load_params(manifest: &Manifest, entry: &EntryPoint) -> Result<Vec<HostTensor>> {
    let path = manifest
        .params_path(entry)
        .ok_or_else(|| err(format!("entry '{}' has no params file", entry.name)))?;
    let flat =
        read_f32_blob(&path).map_err(|e| err(format!("reading {path:?}: {e}")))?;
    unflatten_params(entry, &flat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn entry_with_params() -> EntryPoint {
        EntryPoint {
            name: "t".into(),
            hlo_file: "t.hlo.txt".into(),
            inputs: vec![
                TensorSpec { name: "w0".into(), shape: vec![2, 3], dtype: DType::F32 },
                TensorSpec { name: "b0".into(), shape: vec![3], dtype: DType::F32 },
                TensorSpec { name: "x".into(), shape: vec![1, 2], dtype: DType::I32 },
            ],
            num_outputs: 1,
            flops: 0.0,
            params_file: Some("t.params.bin".into()),
            num_param_inputs: 2,
        }
    }

    #[test]
    fn unflatten_splits_by_spec() {
        let e = entry_with_params();
        let flat: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let parts = unflatten_params(&e, &flat).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].shape(), &[2, 3]);
        assert_eq!(parts[0].as_f32().unwrap(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(parts[1].as_f32().unwrap(), &[6.0, 7.0, 8.0]);
    }

    #[test]
    fn unflatten_rejects_wrong_length() {
        let e = entry_with_params();
        assert!(unflatten_params(&e, &[0.0; 8]).is_err(), "too short");
        assert!(unflatten_params(&e, &[0.0; 10]).is_err(), "too long");
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0], vec![2]);
        assert_eq!(t.elements(), 2);
        assert_eq!(t.shape(), &[2]);
        assert!(t.as_f32().is_some());
        let i = HostTensor::I32(vec![1, 2, 3], vec![3]);
        assert!(i.as_f32().is_none());
        assert_eq!(i.elements(), 3);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_missing_backend() {
        let e = Engine::cpu().unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs and
    // are gated on the artifacts directory existing.
}
