//! Deep-learning model zoo: analytic cost descriptors.
//!
//! The paper benchmarks models from TorchHub and Hugging Face (Appendix A
//! Table 4): ResNet-18/34/50/101 for image classification and
//! DistilBERT/BERT/BERT-Large for text classification. This module
//! describes each model analytically — parameters, forward FLOPs,
//! activation footprint — so the simulator can price a training or
//! inference step on any GPU instance at paper scale, while the
//! *executable* tiny variants live in `python/compile/model.py` and run
//! through `runtime::`.

pub mod cost;
pub mod zoo;

pub use cost::{infer_cost, train_cost, Precision, StepCost};
pub use zoo::{lookup, ModelDesc, ModelFamily, ZOO};
