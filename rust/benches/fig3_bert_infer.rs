//! Fig 3: BERT inference on A100 GPU instances — latency, GRACT, memory
//! and energy vs batch size (the paper sweeps input size for inference;
//! §4.4 discusses the batch-size axis, which is what we sweep here, with
//! a seq-length sweep as a second panel matching the figure caption).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, maybe_write_csv, print_series, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::profiler::session::ProfileSession;
use migperf::profiler::task::{BenchTask, SweepAxis};
use migperf::workload::spec::WorkloadKind;

fn main() {
    banner("Figure 3", "BERT-base inference on A100 GIs");
    let gis = vec!["1g.10gb".to_string(), "2g.20gb".into(), "3g.40gb".into(), "7g.80gb".into()];

    // Batch-size sweep (panels a–d as discussed in §4.4).
    let task = BenchTask {
        name: "fig3-batch".into(),
        gpu: GpuModel::A100_80GB,
        gi_profiles: gis.clone(),
        model: "bert-base".into(),
        kind: WorkloadKind::Inference,
        batch: 8,
        seq: 128,
        sweep: SweepAxis::Batch(vec![1, 2, 4, 8, 16, 32]),
        iterations: 200,
        layout: Default::default(),
    };
    let report = ProfileSession::default().run(&task).expect("fig3 session");
    print_series(&report, "(a) avg latency ms", |s| s.avg_latency_ms, "batch", false);
    print_series(&report, "(b) GRACT", |s| s.mean_gract, "batch", false);
    print_series(&report, "(c) FB used MiB", |s| s.peak_fb_mib, "batch", false);
    print_series(&report, "(d) energy J", |s| s.energy_j, "batch", false);
    maybe_write_csv("fig3_batch", &report);

    // Sequence-length sweep (the figure's title axis).
    let task_seq = BenchTask {
        name: "fig3-seq".into(),
        gpu: GpuModel::A100_80GB,
        gi_profiles: gis,
        model: "bert-base".into(),
        kind: WorkloadKind::Inference,
        batch: 8,
        seq: 128,
        sweep: SweepAxis::SeqLen(vec![32, 64, 128, 256, 512]),
        iterations: 200,
        layout: Default::default(),
    };
    let report_seq = ProfileSession::default().run(&task_seq).expect("fig3 seq session");
    print_series(&report_seq, "avg latency ms", |s| s.avg_latency_ms, "seq", true);
    maybe_write_csv("fig3_seq", &report_seq);
    println!();

    let lat = |inst: &str, batch: u32| {
        report
            .rows()
            .iter()
            .find(|r| r.instance == inst && r.batch == batch)
            .map(|r| r.summary.avg_latency_ms)
            .unwrap()
    };
    shape_check(
        "latency strongly batch-sensitive on small GI (Fig 3a)",
        lat("1g.10gb", 32) / lat("1g.10gb", 1) > 4.0,
    );
    shape_check(
        "batch influence marginal on large GI (Fig 3a)",
        lat("7g.80gb", 32) / lat("7g.80gb", 1) < lat("1g.10gb", 32) / lat("1g.10gb", 1) / 2.0,
    );
    let gract = |inst: &str| {
        report
            .rows()
            .iter()
            .find(|r| r.instance == inst && r.batch == 8)
            .map(|r| r.summary.mean_gract)
            .unwrap()
    };
    shape_check(
        "utilization decreases as GI size increases (Fig 3b)",
        gract("1g.10gb") > gract("2g.20gb") && gract("2g.20gb") > gract("7g.80gb"),
    );
    let fb = |batch: u32| {
        report
            .rows()
            .iter()
            .find(|r| r.instance == "7g.80gb" && r.batch == batch)
            .map(|r| r.summary.peak_fb_mib)
            .unwrap()
    };
    shape_check(
        "FB growth marginal at small batch, larger at big batch (Fig 3c)",
        (fb(2) - fb(1)) < (fb(32) - fb(16)),
    );
    let seq_lat = |inst: &str, seq: u32| {
        report_seq
            .rows()
            .iter()
            .find(|r| r.instance == inst && r.seq == seq)
            .map(|r| r.summary.avg_latency_ms)
            .unwrap()
    };
    shape_check(
        "sequence length superlinear in latency on small GI",
        seq_lat("1g.10gb", 512) / seq_lat("1g.10gb", 128) > 3.9,
    );
}
