//! MIG controller: GPU-instance and compute-instance lifecycle.
//!
//! Mirrors the paper's MIG Controller component (§3.2): python APIs to
//! "1) enable MIG on a GPU, 2) operate the partition process, and 3) track
//! the GIs", plus compute-instance (CI) creation inside a GI so that
//! "computation resources for jobs running in the same GI can be isolated
//! while the memory resources can be shared".
//!
//! The controller wraps the [`PlacementEngine`] rule checker with a state
//! machine that matches `nvidia-smi mig` semantics: MIG mode must be
//! enabled before partitioning, GIs cannot be destroyed while they still
//! hold CIs, and MIG mode cannot be disabled while GIs exist.

use std::collections::BTreeMap;

use super::gpu::GpuModel;
use super::placement::{Placement, PlacementEngine, PlacementError};
use super::profile::{lookup, GiProfile};

/// Opaque GPU-instance identifier (stable for the controller's lifetime).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GiId(pub u32);

/// Opaque compute-instance identifier, scoped to its GI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CiId(pub u32);

/// A live compute instance inside a GI.
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeInstance {
    /// Identifier within the parent GI.
    pub id: CiId,
    /// Compute slices owned by this CI.
    pub slices: u32,
}

/// A live GPU instance.
#[derive(Debug, Clone)]
pub struct GpuInstance {
    /// Identifier on this GPU.
    pub id: GiId,
    /// Profile this GI was created from.
    pub profile: &'static GiProfile,
    /// Memory-slice offset where it lives.
    pub start: u32,
    /// MIG device UUID-style handle (what CUDA_VISIBLE_DEVICES takes).
    pub uuid: String,
    /// Compute instances inside this GI.
    pub compute_instances: Vec<ComputeInstance>,
}

impl GpuInstance {
    /// Compute slices not yet assigned to a CI.
    pub fn free_ci_slices(&self) -> u32 {
        let used: u32 = self.compute_instances.iter().map(|c| c.slices).sum();
        self.profile.compute_slices - used
    }
}

/// Controller errors.
#[derive(Debug)]
pub enum MigError {
    /// Operation requires MIG mode on.
    MigDisabled,
    /// MIG mode already in the requested state.
    AlreadyInState(&'static str),
    /// Cannot disable MIG while instances exist.
    InstancesExist(usize),
    /// Unknown profile name for this GPU.
    UnknownProfile(String),
    /// Placement rules rejected the request.
    Placement(PlacementError),
    /// No free slot for the profile.
    NoSlot(String),
    /// GI id not found.
    NoSuchGi(GiId),
    /// CI id not found in the GI.
    NoSuchCi(GiId, CiId),
    /// GI still holds CIs.
    CisExist(GiId, usize),
    /// CI slice request exceeds what the GI has free.
    CiSlicesExhausted {
        /// Requested slices.
        need: u32,
        /// Free slices in the GI.
        free: u32,
    },
}

impl std::fmt::Display for MigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MigError::MigDisabled => write!(f, "MIG mode is not enabled on this GPU"),
            MigError::AlreadyInState(state) => write!(f, "MIG mode is already {state}"),
            MigError::InstancesExist(n) => {
                write!(f, "cannot disable MIG: {n} GPU instance(s) still exist")
            }
            MigError::UnknownProfile(name) => {
                write!(f, "unknown GI profile '{name}' for this GPU model")
            }
            // Transparent: placement failures surface with their own text.
            MigError::Placement(e) => write!(f, "{e}"),
            MigError::NoSlot(name) => {
                write!(f, "no valid placement available for profile '{name}'")
            }
            MigError::NoSuchGi(gi) => write!(f, "no such GPU instance: {gi:?}"),
            MigError::NoSuchCi(gi, ci) => write!(f, "no such compute instance {ci:?} in {gi:?}"),
            MigError::CisExist(gi, n) => {
                write!(f, "GPU instance {gi:?} still has {n} compute instance(s)")
            }
            MigError::CiSlicesExhausted { need, free } => write!(
                f,
                "compute-instance request of {need} slice(s) exceeds {free} free in the GI"
            ),
        }
    }
}

impl std::error::Error for MigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MigError::Placement(e) => Some(e),
            _ => None,
        }
    }
}

impl From<PlacementError> for MigError {
    fn from(e: PlacementError) -> Self {
        MigError::Placement(e)
    }
}

/// MIG controller for one physical GPU.
#[derive(Debug)]
pub struct MigController {
    model: GpuModel,
    /// Index of this GPU on its server (part of the MIG UUID).
    gpu_index: u32,
    engine: PlacementEngine,
    mig_enabled: bool,
    instances: BTreeMap<GiId, GpuInstance>,
    next_gi: u32,
    next_ci: u32,
}

impl MigController {
    /// Controller for GPU 0 of the given model.
    pub fn new(model: GpuModel) -> Self {
        Self::for_gpu(model, 0)
    }

    /// Controller for a specific GPU index on a server.
    pub fn for_gpu(model: GpuModel, gpu_index: u32) -> Self {
        MigController {
            model,
            gpu_index,
            engine: PlacementEngine::new(model),
            mig_enabled: false,
            instances: BTreeMap::new(),
            next_gi: 0,
            next_ci: 0,
        }
    }

    /// GPU model under management.
    pub fn model(&self) -> GpuModel {
        self.model
    }

    /// Whether MIG mode is currently enabled.
    pub fn mig_enabled(&self) -> bool {
        self.mig_enabled
    }

    /// Enable MIG mode (idempotent failure, like `nvidia-smi -mig 1`).
    pub fn enable_mig(&mut self) -> Result<(), MigError> {
        if self.mig_enabled {
            return Err(MigError::AlreadyInState("enabled"));
        }
        self.mig_enabled = true;
        Ok(())
    }

    /// Disable MIG mode; fails while GIs exist.
    pub fn disable_mig(&mut self) -> Result<(), MigError> {
        if !self.mig_enabled {
            return Err(MigError::AlreadyInState("disabled"));
        }
        if !self.instances.is_empty() {
            return Err(MigError::InstancesExist(self.instances.len()));
        }
        self.mig_enabled = false;
        Ok(())
    }

    fn placements(&self) -> Vec<Placement> {
        self.instances
            .values()
            .map(|gi| Placement { profile: gi.profile, start: gi.start })
            .collect()
    }

    /// Create a GI of the named profile at the first valid slot.
    pub fn create_instance(&mut self, profile_name: &str) -> Result<GiId, MigError> {
        if !self.mig_enabled {
            return Err(MigError::MigDisabled);
        }
        let profile = lookup(self.model, profile_name)
            .ok_or_else(|| MigError::UnknownProfile(profile_name.to_string()))?;
        let start = self
            .engine
            .find_slot(&self.placements(), profile)
            .ok_or_else(|| MigError::NoSlot(profile_name.to_string()))?;
        self.create_at(profile, start)
    }

    /// Create a GI at an explicit memory-slice offset.
    pub fn create_instance_at(&mut self, profile_name: &str, start: u32) -> Result<GiId, MigError> {
        if !self.mig_enabled {
            return Err(MigError::MigDisabled);
        }
        let profile = lookup(self.model, profile_name)
            .ok_or_else(|| MigError::UnknownProfile(profile_name.to_string()))?;
        self.engine.check(&self.placements(), &Placement { profile, start })?;
        self.create_at(profile, start)
    }

    fn create_at(&mut self, profile: &'static GiProfile, start: u32) -> Result<GiId, MigError> {
        let id = GiId(self.next_gi);
        self.next_gi += 1;
        let uuid = format!("MIG-GPU-{}/{}/{}", self.gpu_index, id.0, profile.name);
        self.instances.insert(
            id,
            GpuInstance { id, profile, start, uuid, compute_instances: Vec::new() },
        );
        Ok(id)
    }

    /// Destroy a GI. Its CIs must have been destroyed first.
    pub fn destroy_instance(&mut self, id: GiId) -> Result<(), MigError> {
        let gi = self.instances.get(&id).ok_or(MigError::NoSuchGi(id))?;
        if !gi.compute_instances.is_empty() {
            return Err(MigError::CisExist(id, gi.compute_instances.len()));
        }
        self.instances.remove(&id);
        Ok(())
    }

    /// Create a CI of `slices` compute slices inside a GI.
    pub fn create_compute_instance(&mut self, gi: GiId, slices: u32) -> Result<CiId, MigError> {
        let inst = self.instances.get_mut(&gi).ok_or(MigError::NoSuchGi(gi))?;
        let free = inst.free_ci_slices();
        if slices == 0 || slices > free {
            return Err(MigError::CiSlicesExhausted { need: slices, free });
        }
        let id = CiId(self.next_ci);
        self.next_ci += 1;
        inst.compute_instances.push(ComputeInstance { id, slices });
        Ok(id)
    }

    /// Create the default CI spanning the GI's full compute capacity.
    pub fn create_default_ci(&mut self, gi: GiId) -> Result<CiId, MigError> {
        let slices = self.instance(gi)?.profile.compute_slices;
        self.create_compute_instance(gi, slices)
    }

    /// Destroy one CI.
    pub fn destroy_compute_instance(&mut self, gi: GiId, ci: CiId) -> Result<(), MigError> {
        let inst = self.instances.get_mut(&gi).ok_or(MigError::NoSuchGi(gi))?;
        let before = inst.compute_instances.len();
        inst.compute_instances.retain(|c| c.id != ci);
        if inst.compute_instances.len() == before {
            return Err(MigError::NoSuchCi(gi, ci));
        }
        Ok(())
    }

    /// Look up one instance.
    pub fn instance(&self, id: GiId) -> Result<&GpuInstance, MigError> {
        self.instances.get(&id).ok_or(MigError::NoSuchGi(id))
    }

    /// All live instances, ordered by id.
    pub fn list_instances(&self) -> Vec<&GpuInstance> {
        self.instances.values().collect()
    }

    /// Profiles that can still be placed right now.
    pub fn available_profiles(&self) -> Vec<&'static GiProfile> {
        if !self.mig_enabled {
            return Vec::new();
        }
        self.engine.available_profiles(&self.placements())
    }

    /// Destroy all CIs and GIs (convenience for benchmark teardown).
    pub fn reset(&mut self) {
        for gi in self.instances.values_mut() {
            gi.compute_instances.clear();
        }
        self.instances.clear();
    }

    /// Partition the GPU into `n` equal instances of the given profile,
    /// returning the created ids. Fails atomically: on error, nothing new
    /// remains.
    pub fn partition_uniform(&mut self, profile_name: &str, n: u32) -> Result<Vec<GiId>, MigError> {
        let mut made = Vec::new();
        for _ in 0..n {
            match self.create_instance(profile_name) {
                Ok(id) => made.push(id),
                Err(e) => {
                    for id in made {
                        let _ = self.destroy_instance(id);
                    }
                    return Err(e);
                }
            }
        }
        Ok(made)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled(model: GpuModel) -> MigController {
        let mut c = MigController::new(model);
        c.enable_mig().unwrap();
        c
    }

    #[test]
    fn requires_mig_mode() {
        let mut c = MigController::new(GpuModel::A100_80GB);
        assert!(matches!(c.create_instance("1g.10gb"), Err(MigError::MigDisabled)));
        c.enable_mig().unwrap();
        assert!(c.create_instance("1g.10gb").is_ok());
    }

    #[test]
    fn enable_twice_fails() {
        let mut c = enabled(GpuModel::A100_80GB);
        assert!(matches!(c.enable_mig(), Err(MigError::AlreadyInState("enabled"))));
    }

    #[test]
    fn disable_blocked_by_instances() {
        let mut c = enabled(GpuModel::A100_80GB);
        let gi = c.create_instance("2g.20gb").unwrap();
        assert!(matches!(c.disable_mig(), Err(MigError::InstancesExist(1))));
        c.destroy_instance(gi).unwrap();
        c.disable_mig().unwrap();
        assert!(!c.mig_enabled());
    }

    #[test]
    fn partition_into_seven() {
        let mut c = enabled(GpuModel::A100_80GB);
        let ids = c.partition_uniform("1g.10gb", 7).unwrap();
        assert_eq!(ids.len(), 7);
        assert_eq!(c.list_instances().len(), 7);
        // Eighth fails.
        assert!(matches!(c.create_instance("1g.10gb"), Err(MigError::NoSlot(_))));
    }

    #[test]
    fn partition_uniform_rolls_back() {
        let mut c = enabled(GpuModel::A30_24GB);
        // 3×2g.12gb cannot fit on A30 (max 2): all-or-nothing.
        assert!(c.partition_uniform("2g.12gb", 3).is_err());
        assert_eq!(c.list_instances().len(), 0);
    }

    #[test]
    fn unknown_profile() {
        let mut c = enabled(GpuModel::A30_24GB);
        assert!(matches!(c.create_instance("3g.40gb"), Err(MigError::UnknownProfile(_))));
    }

    #[test]
    fn explicit_offset_validation() {
        let mut c = enabled(GpuModel::A100_80GB);
        assert!(c.create_instance_at("3g.40gb", 4).is_ok());
        assert!(matches!(
            c.create_instance_at("3g.40gb", 2),
            Err(MigError::Placement(PlacementError::InvalidOffset { .. }))
        ));
    }

    #[test]
    fn uuids_are_unique_and_stable() {
        let mut c = enabled(GpuModel::A100_80GB);
        let a = c.create_instance("1g.10gb").unwrap();
        let b = c.create_instance("1g.10gb").unwrap();
        let ua = c.instance(a).unwrap().uuid.clone();
        let ub = c.instance(b).unwrap().uuid.clone();
        assert_ne!(ua, ub);
        assert!(ua.starts_with("MIG-GPU-0/"));
    }

    #[test]
    fn ci_lifecycle() {
        let mut c = enabled(GpuModel::A100_80GB);
        let gi = c.create_instance("3g.40gb").unwrap();
        let c1 = c.create_compute_instance(gi, 1).unwrap();
        let c2 = c.create_compute_instance(gi, 2).unwrap();
        assert_eq!(c.instance(gi).unwrap().free_ci_slices(), 0);
        assert!(matches!(
            c.create_compute_instance(gi, 1),
            Err(MigError::CiSlicesExhausted { need: 1, free: 0 })
        ));
        // GI destruction blocked while CIs exist (nvidia-smi semantics).
        assert!(matches!(c.destroy_instance(gi), Err(MigError::CisExist(_, 2))));
        c.destroy_compute_instance(gi, c1).unwrap();
        c.destroy_compute_instance(gi, c2).unwrap();
        c.destroy_instance(gi).unwrap();
    }

    #[test]
    fn default_ci_spans_profile() {
        let mut c = enabled(GpuModel::A30_24GB);
        let gi = c.create_instance("2g.12gb").unwrap();
        c.create_default_ci(gi).unwrap();
        assert_eq!(c.instance(gi).unwrap().free_ci_slices(), 0);
    }

    #[test]
    fn destroy_unknown_ci() {
        let mut c = enabled(GpuModel::A30_24GB);
        let gi = c.create_instance("1g.6gb").unwrap();
        assert!(matches!(
            c.destroy_compute_instance(gi, CiId(99)),
            Err(MigError::NoSuchCi(_, _))
        ));
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = enabled(GpuModel::A100_80GB);
        let gi = c.create_instance("2g.20gb").unwrap();
        c.create_default_ci(gi).unwrap();
        c.reset();
        assert!(c.list_instances().is_empty());
        c.disable_mig().unwrap();
    }

    #[test]
    fn available_profiles_shrink() {
        let mut c = enabled(GpuModel::A100_80GB);
        let n0 = c.available_profiles().len();
        c.create_instance("4g.40gb").unwrap();
        let after: Vec<&str> = c.available_profiles().iter().map(|p| p.name).collect();
        assert!(after.len() < n0);
        assert!(!after.contains(&"3g.40gb"), "exclusion rule must hide 3g.40gb");
        assert!(!after.contains(&"7g.80gb"));
        assert!(after.contains(&"1g.10gb"));
    }
}
