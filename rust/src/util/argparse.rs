//! Tiny command-line argument parser for the `migperf` CLI.
//!
//! No `clap` in the offline toolchain, so this module implements the small
//! subset MIGPerf needs: subcommands, `--flag`, `--key value` /
//! `--key=value` options with typed accessors, positional arguments, and
//! generated help text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative description of one option for help text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without leading dashes, e.g. `batch-size`.
    pub name: &'static str,
    /// Placeholder for the value in help output; empty for boolean flags.
    pub value: &'static str,
    /// One-line description.
    pub help: &'static str,
    /// Default rendered in help output (informational only).
    pub default: Option<&'static str>,
}

/// Parsed arguments: subcommand, options, flags and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-option token, if any (the subcommand).
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Errors from argument parsing or typed access.
#[derive(Debug)]
pub enum ArgError {
    /// An option that expects a value appeared last without one.
    MissingValue(String),
    /// Typed accessor failed to parse the value.
    BadValue {
        /// Option name.
        name: String,
        /// Offending raw value.
        value: String,
        /// What was expected.
        expected: &'static str,
    },
    /// A required option was absent.
    Missing(String),
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::MissingValue(name) => write!(f, "option --{name} expects a value"),
            ArgError::BadValue { name, value, expected } => {
                write!(f, "invalid value for --{name}: '{value}' ({expected})")
            }
            ArgError::Missing(name) => write!(f, "missing required option --{name}"),
        }
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse a raw token stream (usually `std::env::args().skip(1)`).
    ///
    /// Every `--name` token consumes the following token as its value
    /// unless it contains `=` or the name appears in `bool_flags`.
    pub fn parse<I: IntoIterator<Item = String>>(
        tokens: I,
        bool_flags: &[&str],
    ) -> Result<Args, ArgError> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    match it.next() {
                        Some(v) => {
                            out.opts.insert(body.to_string(), v);
                        }
                        None => return Err(ArgError::MissingValue(body.to_string())),
                    }
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Raw string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    /// String option with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Required string option.
    pub fn required(&self, name: &str) -> Result<String, ArgError> {
        self.get(name).map(str::to_string).ok_or_else(|| ArgError::Missing(name.to_string()))
    }

    /// Typed option access with default.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|_| ArgError::BadValue {
                name: name.to_string(),
                value: raw.to_string(),
                expected: std::any::type_name::<T>(),
            }),
        }
    }

    /// Comma-separated list of a parseable type, e.g. `--batch 1,2,4`.
    pub fn list_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, ArgError>
    where
        T: Clone,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().map_err(|_| ArgError::BadValue {
                        name: name.to_string(),
                        value: s.to_string(),
                        expected: std::any::type_name::<T>(),
                    })
                })
                .collect(),
        }
    }

    /// True if the boolean flag was present.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments after the subcommand.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render help text for a subcommand.
pub fn render_help(program: &str, command: &str, about: &str, opts: &[OptSpec]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{about}\n");
    let _ = writeln!(s, "USAGE:\n  {program} {command} [OPTIONS]\n");
    if !opts.is_empty() {
        let _ = writeln!(s, "OPTIONS:");
        for o in opts {
            let left = if o.value.is_empty() {
                format!("--{}", o.name)
            } else {
                format!("--{} <{}>", o.name, o.value)
            };
            let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(s, "  {left:<28} {}{default}", o.help);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(toks("bench --model bert-base --batch 8"), &[]).unwrap();
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("model"), Some("bert-base"));
        assert_eq!(a.parse_or::<u32>("batch", 1).unwrap(), 8);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(toks("run --gi=1g.10gb"), &[]).unwrap();
        assert_eq!(a.get("gi"), Some("1g.10gb"));
    }

    #[test]
    fn bool_flags_do_not_consume() {
        let a = Args::parse(toks("run --real positional"), &["real"]).unwrap();
        assert!(a.flag("real"));
        assert_eq!(a.positional(), &["positional".to_string()]);
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(toks("x --batch 1,2,4,8"), &[]).unwrap();
        assert_eq!(a.list_or::<u32>("batch", &[]).unwrap(), vec![1, 2, 4, 8]);
        let b = Args::parse(toks("x"), &[]).unwrap();
        assert_eq!(b.list_or::<u32>("batch", &[16]).unwrap(), vec![16]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(matches!(
            Args::parse(toks("x --model"), &[]),
            Err(ArgError::MissingValue(m)) if m == "model"
        ));
    }

    #[test]
    fn bad_typed_value() {
        let a = Args::parse(toks("x --batch nope"), &[]).unwrap();
        assert!(matches!(a.parse_or::<u32>("batch", 1), Err(ArgError::BadValue { .. })));
    }

    #[test]
    fn required_missing() {
        let a = Args::parse(toks("x"), &[]).unwrap();
        assert!(matches!(a.required("model"), Err(ArgError::Missing(_))));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(toks("x"), &[]).unwrap();
        assert_eq!(a.str_or("out", "results"), "results");
        assert_eq!(a.parse_or::<f64>("rate", 2.5).unwrap(), 2.5);
        assert!(!a.flag("real"));
    }

    #[test]
    fn help_renders_options() {
        let h = render_help(
            "migperf",
            "bench",
            "Run a benchmark",
            &[OptSpec {
                name: "model",
                value: "NAME",
                help: "model to run",
                default: Some("bert-base"),
            }],
        );
        assert!(h.contains("--model <NAME>"));
        assert!(h.contains("[default: bert-base]"));
    }
}
