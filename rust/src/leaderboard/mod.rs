//! Benchmark leaderboard.
//!
//! The paper maintains "a public leaderboard to continuously update the
//! recent benchmark studies on MIG" (§2.1). This module is that
//! leaderboard's engine: a persistent store of submitted run summaries
//! keyed by (model, workload, GPU, instance), with ranking queries and a
//! markdown renderer for publication.

use std::collections::BTreeMap;
use std::path::Path;

use crate::metrics::collector::RunSummary;
use crate::metrics::export::summary_to_json;
use crate::util::json::{self, Json};
use crate::util::table::{fmt_num, Table};

/// One leaderboard submission.
#[derive(Debug, Clone)]
pub struct Entry {
    /// Submitter identity (free-form).
    pub submitter: String,
    /// Model benchmarked.
    pub model: String,
    /// `training` or `inference`.
    pub workload: String,
    /// GPU + instance, e.g. `a100/1g.10gb`.
    pub device: String,
    /// Batch size used.
    pub batch: u32,
    /// The measured summary.
    pub summary: RunSummary,
}

/// The leaderboard store.
#[derive(Debug, Default)]
pub struct Leaderboard {
    entries: Vec<Entry>,
}

/// Ranking metric for queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rank {
    /// Higher throughput is better.
    Throughput,
    /// Lower p99 latency is better.
    TailLatency,
    /// Lower energy is better.
    Energy,
}

impl Leaderboard {
    /// Empty leaderboard.
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit an entry.
    pub fn submit(&mut self, entry: Entry) {
        self.entries.push(entry);
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no entries exist.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries for a (model, workload) pair, best-first under `rank`.
    pub fn ranking(&self, model: &str, workload: &str, rank: Rank) -> Vec<&Entry> {
        let mut rows: Vec<&Entry> = self
            .entries
            .iter()
            .filter(|e| e.model == model && e.workload == workload)
            .collect();
        rows.sort_by(|a, b| {
            let key = |e: &Entry| match rank {
                Rank::Throughput => -e.summary.throughput,
                Rank::TailLatency => e.summary.p99_latency_ms,
                Rank::Energy => e.summary.energy_j,
            };
            key(a).partial_cmp(&key(b)).unwrap()
        });
        rows
    }

    /// Distinct (model, workload) boards present.
    pub fn boards(&self) -> Vec<(String, String)> {
        let mut set = BTreeMap::new();
        for e in &self.entries {
            set.insert((e.model.clone(), e.workload.clone()), ());
        }
        set.into_keys().collect()
    }

    /// Render one board as a markdown table.
    pub fn render_markdown(&self, model: &str, workload: &str, rank: Rank) -> String {
        let mut t =
            Table::new(&["#", "device", "batch", "tput", "p99_ms", "energy_j", "submitter"]);
        for (i, e) in self.ranking(model, workload, rank).iter().enumerate() {
            t.row(&[
                (i + 1).to_string(),
                e.device.clone(),
                e.batch.to_string(),
                fmt_num(e.summary.throughput),
                fmt_num(e.summary.p99_latency_ms),
                fmt_num(e.summary.energy_j),
                e.submitter.clone(),
            ]);
        }
        format!("## {model} / {workload}\n\n{}", t.render())
    }

    /// Serialize the whole leaderboard to JSON.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("submitter", e.submitter.as_str().into()),
                    ("model", e.model.as_str().into()),
                    ("workload", e.workload.as_str().into()),
                    ("device", e.device.as_str().into()),
                    ("batch", (e.batch as i64).into()),
                    ("summary", summary_to_json(&e.summary)),
                ])
            })
            .collect();
        Json::obj(vec![("entries", Json::Arr(entries))])
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
    }

    /// Load from a JSON file previously written by [`save`](Self::save).
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let v = json::parse(&text).map_err(|e| e.to_string())?;
        let mut lb = Leaderboard::new();
        for e in v.get("entries").and_then(Json::as_arr).ok_or("missing entries")? {
            let s = e.get("summary").ok_or("missing summary")?;
            let f = |k: &str| s.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            lb.submit(Entry {
                submitter: e.get("submitter").and_then(Json::as_str).unwrap_or("?").into(),
                model: e.get("model").and_then(Json::as_str).ok_or("missing model")?.into(),
                workload: e
                    .get("workload")
                    .and_then(Json::as_str)
                    .ok_or("missing workload")?
                    .into(),
                device: e.get("device").and_then(Json::as_str).unwrap_or("?").into(),
                batch: e.get("batch").and_then(Json::as_i64).unwrap_or(0) as u32,
                summary: RunSummary {
                    label: s.get("label").and_then(Json::as_str).unwrap_or("").into(),
                    completed: f("completed") as u64,
                    avg_latency_ms: f("avg_latency_ms"),
                    std_latency_ms: f("std_latency_ms"),
                    p50_latency_ms: f("p50_latency_ms"),
                    p99_latency_ms: f("p99_latency_ms"),
                    max_latency_ms: f("max_latency_ms"),
                    throughput: f("throughput"),
                    mean_gract: f("mean_gract"),
                    peak_fb_mib: f("peak_fb_mib"),
                    energy_j: f("energy_j"),
                    duration_s: f("duration_s"),
                },
            });
        }
        Ok(lb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(device: &str, tput: f64, p99: f64) -> Entry {
        Entry {
            submitter: "migperf".into(),
            model: "bert-base".into(),
            workload: "inference".into(),
            device: device.into(),
            batch: 8,
            summary: RunSummary {
                label: device.into(),
                completed: 100,
                avg_latency_ms: p99 * 0.6,
                std_latency_ms: 0.1,
                p50_latency_ms: p99 * 0.5,
                p99_latency_ms: p99,
                max_latency_ms: p99 * 1.2,
                throughput: tput,
                mean_gract: 0.8,
                peak_fb_mib: 1000.0,
                energy_j: 100.0 / tput,
                duration_s: 1.0,
            },
        }
    }

    #[test]
    fn ranking_orders_by_metric() {
        let mut lb = Leaderboard::new();
        lb.submit(entry("a100/1g.10gb", 100.0, 10.0));
        lb.submit(entry("a100/7g.80gb", 700.0, 2.0));
        lb.submit(entry("a30/1g.6gb", 60.0, 14.0));
        let by_tput = lb.ranking("bert-base", "inference", Rank::Throughput);
        assert_eq!(by_tput[0].device, "a100/7g.80gb");
        assert_eq!(by_tput[2].device, "a30/1g.6gb");
        let by_tail = lb.ranking("bert-base", "inference", Rank::TailLatency);
        assert_eq!(by_tail[0].device, "a100/7g.80gb");
        let by_energy = lb.ranking("bert-base", "inference", Rank::Energy);
        assert_eq!(by_energy[0].device, "a100/7g.80gb");
    }

    #[test]
    fn boards_deduplicate() {
        let mut lb = Leaderboard::new();
        lb.submit(entry("x", 1.0, 1.0));
        lb.submit(entry("y", 2.0, 2.0));
        assert_eq!(lb.boards(), vec![("bert-base".to_string(), "inference".to_string())]);
    }

    #[test]
    fn markdown_contains_ranks() {
        let mut lb = Leaderboard::new();
        lb.submit(entry("a100/7g.80gb", 700.0, 2.0));
        lb.submit(entry("a100/1g.10gb", 100.0, 10.0));
        let md = lb.render_markdown("bert-base", "inference", Rank::Throughput);
        assert!(md.contains("## bert-base / inference"));
        let pos7 = md.find("7g.80gb").unwrap();
        let pos1 = md.find("1g.10gb").unwrap();
        assert!(pos7 < pos1, "7g must rank first");
    }

    #[test]
    fn save_load_roundtrip() {
        let mut lb = Leaderboard::new();
        lb.submit(entry("a100/3g.40gb", 300.0, 4.0));
        let dir = std::env::temp_dir().join("migperf-lb-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("board.json");
        lb.save(&path).unwrap();
        let back = Leaderboard::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        let e = &back.ranking("bert-base", "inference", Rank::Throughput)[0];
        assert_eq!(e.device, "a100/3g.40gb");
        assert!((e.summary.throughput - 300.0).abs() < 1e-9);
    }

    #[test]
    fn unknown_board_is_empty() {
        let lb = Leaderboard::new();
        assert!(lb.ranking("gpt", "inference", Rank::Throughput).is_empty());
        assert!(lb.is_empty());
    }
}
