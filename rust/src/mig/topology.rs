//! Server topology: the physical testbeds the paper benchmarks on.
//!
//! Appendix A Table 3 describes two servers — an 8×A100-80GB machine and a
//! 2×A30 machine. The coordinator (paper Fig 1) distributes benchmark
//! tasks to "dedicated servers"; this module models those servers so a
//! whole benchmark suite can run against a faithful inventory.

use super::controller::MigController;
use super::gpu::GpuModel;

/// Host-side description of a benchmark server (paper Table 3).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerSpec {
    /// Human name used in reports.
    pub name: &'static str,
    /// CPU model string.
    pub cpu_model: &'static str,
    /// Number of physical CPU sockets.
    pub cpu_sockets: u32,
    /// Physical core count.
    pub cpu_cores: u32,
    /// Virtual CPU count.
    pub vcpus: u32,
    /// Host memory, GiB.
    pub memory_gib: u32,
    /// GPU model installed.
    pub gpu_model: GpuModel,
    /// Number of GPUs installed.
    pub gpu_count: u32,
    /// NVIDIA driver version (informational, used by the compat rig).
    pub driver: &'static str,
    /// CUDA version (informational).
    pub cuda: &'static str,
}

/// The paper's A100 server (Table 3, left column).
pub static A100_SERVER: ServerSpec = ServerSpec {
    name: "a100-server",
    cpu_model: "Intel Xeon Platinum 8369B",
    cpu_sockets: 2,
    cpu_cores: 64,
    vcpus: 128,
    memory_gib: 1024,
    gpu_model: GpuModel::A100_80GB,
    gpu_count: 8,
    driver: "470.82.01",
    cuda: "11.4",
};

/// The paper's A30 server (Table 3, right column).
pub static A30_SERVER: ServerSpec = ServerSpec {
    name: "a30-server",
    cpu_model: "AMD EPYC 7302P",
    cpu_sockets: 1,
    cpu_cores: 16,
    vcpus: 32,
    memory_gib: 128,
    gpu_model: GpuModel::A30_24GB,
    gpu_count: 2,
    driver: "515.65.01",
    cuda: "11.6",
};

/// A running server instance: spec + one MIG controller per GPU.
#[derive(Debug)]
pub struct Server {
    /// Static description.
    pub spec: &'static ServerSpec,
    /// Controllers, one per physical GPU.
    pub gpus: Vec<MigController>,
}

impl Server {
    /// Boot a server from its spec with MIG disabled on every GPU.
    pub fn boot(spec: &'static ServerSpec) -> Self {
        let gpus = (0..spec.gpu_count)
            .map(|i| MigController::for_gpu(spec.gpu_model, i))
            .collect();
        Server { spec, gpus }
    }

    /// The paper's testbed: both servers.
    pub fn paper_testbed() -> Vec<Server> {
        vec![Server::boot(&A100_SERVER), Server::boot(&A30_SERVER)]
    }

    /// Controller for one GPU index.
    pub fn gpu(&mut self, index: usize) -> Option<&mut MigController> {
        self.gpus.get_mut(index)
    }

    /// Total GPU instances live across all GPUs.
    pub fn total_instances(&self) -> usize {
        self.gpus.iter().map(|g| g.list_instances().len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_matches_table3() {
        let servers = Server::paper_testbed();
        assert_eq!(servers.len(), 2);
        assert_eq!(servers[0].spec.gpu_count, 8);
        assert_eq!(servers[0].spec.gpu_model, GpuModel::A100_80GB);
        assert_eq!(servers[0].spec.vcpus, 128);
        assert_eq!(servers[1].spec.gpu_count, 2);
        assert_eq!(servers[1].spec.gpu_model, GpuModel::A30_24GB);
        assert_eq!(servers[1].spec.memory_gib, 128);
    }

    #[test]
    fn gpus_are_independent() {
        let mut s = Server::boot(&A30_SERVER);
        s.gpu(0).unwrap().enable_mig().unwrap();
        s.gpu(0).unwrap().create_instance("1g.6gb").unwrap();
        assert!(!s.gpu(1).unwrap().mig_enabled());
        assert_eq!(s.total_instances(), 1);
    }

    #[test]
    fn gpu_index_bounds() {
        let mut s = Server::boot(&A30_SERVER);
        assert!(s.gpu(1).is_some());
        assert!(s.gpu(2).is_none());
    }
}
