// Lint fixture (never compiled): every hazard the rules look for,
// hidden inside string literals, raw strings, byte strings, chars and
// comments. Expected: ZERO findings — a rule firing here means the
// lexer leaked a literal interior into the token stream.
//
// Instant::now() for k in m.keys() thread_rng() sort_unstable_by
/* SystemTime::now() partial_cmp rand::thread_rng() debug_assert!(v.pop()) */

pub fn hostile() -> &'static str {
    let a = "Instant::now() HashMap.iter() // rand::thread_rng()";
    let b = r#"SystemTime "quoted" partial_cmp .elapsed()"#;
    let c = b"debug_assert!(v.pop()) UNIX_EPOCH";
    let d = br#"for x in seen { OsRng }"#;
    let e = 'I';
    let f = "multi\nline \\\" escape RandomState";
    let _ = (a, b, c, d, e, f);
    "clean"
}
