//! Analytic step-cost computation.
//!
//! Converts a (model, batch, seq/image) workload description into the three
//! quantities the roofline performance model prices: FLOPs, HBM traffic
//! and frame-buffer residency. Formulas are the standard dominant-term
//! estimates; DESIGN.md §3.4 explains how they drive the paper's figure
//! shapes.

use super::zoo::{ModelDesc, ModelFamily};

/// Numeric precision of the workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// FP16/BF16 with tensor cores (the paper's default).
    Half,
    /// FP32 without tensor cores.
    Single,
}

impl Precision {
    /// Bytes per element.
    pub fn bytes(&self) -> u64 {
        match self {
            Precision::Half => 2,
            Precision::Single => 4,
        }
    }
}

/// Cost of one step (one forward batch, or one fwd+bwd+update batch).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepCost {
    /// Total floating-point operations.
    pub flops: f64,
    /// HBM bytes moved (reads + writes, after ideal L2 reuse).
    pub hbm_bytes: f64,
    /// Peak frame-buffer residency in bytes (weights + activations + state).
    pub fb_bytes: f64,
    /// Batch size, carried for the SM-saturation efficiency curve.
    pub batch: u32,
    /// Precision used.
    pub precision: Precision,
}

/// Forward FLOPs for one sample of `model` at sequence length `seq`
/// (transformers) or the 224×224 reference size (CNNs).
fn fwd_flops_per_sample(model: &ModelDesc, seq: u32) -> f64 {
    match model.family {
        ModelFamily::Cnn => model.fwd_gflops_ref * 1e9,
        ModelFamily::Transformer => {
            // Dense part: 2 FLOPs per parameter per token (matmul dominated;
            // embeddings excluded via the 0.95 non-embedding factor), plus
            // the quadratic attention term 2·2·L·s²·h (QKᵀ and AV matmuls).
            let s = seq as f64;
            let h = model.hidden as f64;
            let l = model.layers as f64;
            let dense = 2.0 * (model.params as f64 * 0.95) * s;
            let attn = 4.0 * l * s * s * h;
            dense + attn
        }
    }
}

/// Activation bytes per sample, scaled from the reference input size.
fn act_bytes_per_sample(model: &ModelDesc, seq: u32, precision: Precision) -> f64 {
    let scale = match model.family {
        ModelFamily::Cnn => 1.0,
        // Linear in seq for the dense activations plus a quadratic
        // attention-matrix term that starts mattering past ~256 tokens.
        ModelFamily::Transformer => {
            let s = seq as f64 / 128.0;
            s + 0.15 * s * s
        }
    };
    model.act_bytes_per_sample as f64 * scale * precision.bytes() as f64 / 2.0
}

/// Price one inference step: forward pass over a batch.
///
/// `seq` is the token count for transformers and ignored for CNNs.
pub fn infer_cost(model: &ModelDesc, batch: u32, seq: u32, precision: Precision) -> StepCost {
    assert!(batch > 0, "batch must be positive");
    let b = batch as f64;
    let flops = fwd_flops_per_sample(model, seq) * b;
    let weight_bytes = model.param_bytes(precision.bytes()) as f64;
    let act = act_bytes_per_sample(model, seq, precision);
    // Weights stream from HBM once per step (ideal L2 reuse across the
    // batch); activations are written and re-read once per layer boundary.
    let hbm = weight_bytes + 2.0 * act * b;
    // FB residency: weights + live activations (inference frees layer by
    // layer; ~25% of total activations are live at the peak).
    let fb = weight_bytes + 0.25 * act * b + 256.0 * (1 << 20) as f64; // +workspace/context
    StepCost { flops, hbm_bytes: hbm, fb_bytes: fb, batch, precision }
}

/// Price one training step: forward + backward + optimizer update.
pub fn train_cost(model: &ModelDesc, batch: u32, seq: u32, precision: Precision) -> StepCost {
    assert!(batch > 0, "batch must be positive");
    let b = batch as f64;
    // Backward ≈ 2× forward FLOPs; optimizer update is memory-bound and
    // negligible in FLOPs.
    let flops = 3.0 * fwd_flops_per_sample(model, seq) * b;
    let weight_bytes = model.param_bytes(precision.bytes()) as f64;
    let act = act_bytes_per_sample(model, seq, precision);
    // Weights read fwd+bwd, gradients written, optimizer state (Adam:
    // fp32 master + 2 moments) read/written once.
    let opt_state = model.param_bytes(4) as f64 * 3.0;
    let hbm = 3.0 * weight_bytes + 2.0 * opt_state + 3.0 * act * b;
    // FB: weights + grads + optimizer state + *all* activations (kept for
    // backward).
    let fb = 2.0 * weight_bytes + opt_state + act * b + 512.0 * (1 << 20) as f64;
    StepCost { flops, hbm_bytes: hbm, fb_bytes: fb, batch, precision }
}

/// Arithmetic intensity (FLOPs per HBM byte) — decides compute- vs
/// memory-bound on the roofline.
impl StepCost {
    /// FLOPs per byte of HBM traffic.
    pub fn intensity(&self) -> f64 {
        self.flops / self.hbm_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::lookup;

    #[test]
    fn bert_base_ref_flops_close_to_published() {
        let m = lookup("bert-base").unwrap();
        let per_sample = fwd_flops_per_sample(m, 128) / 1e9;
        // Published ≈ 22.5 GFLOPs at seq=128; dominant-term estimate
        // should land within ~35%.
        assert!(
            (per_sample - m.fwd_gflops_ref).abs() / m.fwd_gflops_ref < 0.35,
            "estimate {per_sample} vs published {}",
            m.fwd_gflops_ref
        );
    }

    #[test]
    fn intensity_grows_with_batch() {
        let m = lookup("bert-base").unwrap();
        let c1 = infer_cost(m, 1, 128, Precision::Half);
        let c32 = infer_cost(m, 32, 128, Precision::Half);
        assert!(c32.intensity() > c1.intensity(), "batching must amortize weight reads");
    }

    #[test]
    fn train_is_about_3x_infer_flops() {
        let m = lookup("resnet50").unwrap();
        let i = infer_cost(m, 8, 224, Precision::Half);
        let t = train_cost(m, 8, 224, Precision::Half);
        assert!((t.flops / i.flops - 3.0).abs() < 1e-9);
        assert!(t.fb_bytes > i.fb_bytes);
        assert!(t.hbm_bytes > i.hbm_bytes);
    }

    #[test]
    fn flops_linear_in_batch() {
        let m = lookup("resnet18").unwrap();
        let c4 = infer_cost(m, 4, 224, Precision::Half);
        let c8 = infer_cost(m, 8, 224, Precision::Half);
        assert!((c8.flops / c4.flops - 2.0).abs() < 1e-9);
    }

    #[test]
    fn seq_length_superlinear_for_transformers() {
        let m = lookup("bert-large").unwrap();
        let c128 = infer_cost(m, 1, 128, Precision::Half);
        let c512 = infer_cost(m, 1, 512, Precision::Half);
        // seq ×4 → more than ×4 FLOPs (attention quadratic term).
        assert!(c512.flops / c128.flops > 4.0);
    }

    #[test]
    fn seq_irrelevant_for_cnns() {
        let m = lookup("resnet50").unwrap();
        let a = infer_cost(m, 8, 1, Precision::Half);
        let b = infer_cost(m, 8, 999, Precision::Half);
        assert_eq!(a.flops, b.flops);
    }

    #[test]
    fn precision_changes_bytes_not_flops() {
        let m = lookup("bert-base").unwrap();
        let h = infer_cost(m, 8, 128, Precision::Half);
        let s = infer_cost(m, 8, 128, Precision::Single);
        assert_eq!(h.flops, s.flops);
        assert!(s.hbm_bytes > h.hbm_bytes);
        assert!(s.fb_bytes > h.fb_bytes);
    }

    #[test]
    fn fb_fits_expected_envelope() {
        // BERT-base fp16 inference at batch 8 must fit a 1g.10gb slice
        // (paper Fig 2c: "even for the smallest GIs, it can handle BERT").
        let m = lookup("bert-base").unwrap();
        let c = infer_cost(m, 8, 128, Precision::Half);
        assert!(c.fb_bytes < 9.75 * (1u64 << 30) as f64, "fb={}", c.fb_bytes);
        // BERT-large training at batch 128 must NOT fit in 10 GiB.
        let big = train_cost(lookup("bert-large").unwrap(), 128, 128, Precision::Half);
        assert!(big.fb_bytes > 9.75 * (1u64 << 30) as f64);
    }

    #[test]
    #[should_panic(expected = "batch must be positive")]
    fn zero_batch_panics() {
        let m = lookup("resnet18").unwrap();
        let _ = infer_cost(m, 0, 224, Precision::Half);
    }
}
