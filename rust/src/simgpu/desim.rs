//! Discrete-event simulator core.
//!
//! A classic event-calendar simulator: a virtual clock plus a min-heap of
//! timestamped events. The serving experiments (paper Figs 4–7, 10–11)
//! run open-loop request streams against multiple simulated GPU instances
//! or MPS clients; the DES makes an hour of simulated traffic cost
//! milliseconds of wall time and keeps every run deterministic.
//!
//! # Storage layout
//!
//! Events live in a slab arena addressed by `u32` slots, with the hot
//! ordering fields — timestamp and FIFO sequence — in structure-of-arrays
//! columns beside the payload column. The calendar itself is a binary
//! min-heap of *slots*, so a sift touches only the two `Vec`s of scalars
//! plus one `u32` move per level instead of shuffling whole
//! `(f64, u64, payload)` triples through a `BinaryHeap`. Popped slots
//! recycle through a free list, so a steady-state simulation performs no
//! allocation at all in the event loop regardless of how many events it
//! processes. Pop order is exactly the old `BinaryHeap` contract:
//! earliest timestamp first, FIFO (schedule order) among equal
//! timestamps — `(at, seq)` is a total order, so the heap's internal
//! shape never leaks into results and the bitwise-determinism contract
//! is preserved.

/// Clamp a requested event time onto the valid `[now, ∞)` range.
///
/// Returns the sanitized time and whether a clamp was needed: a NaN or
/// past timestamp maps to `now`. Release builds route every schedule
/// through this instead of corrupting the heap order (a NaN timestamp
/// would make the comparator lie and strand events); debug builds still
/// panic at the call site so tests catch the bug at its source.
#[inline]
pub(crate) fn sanitize_event_time(at: f64, now: f64) -> (f64, bool) {
    // `!(at >= now)` is true for NaN as well as for past timestamps.
    if at >= now {
        (at, false)
    } else {
        (now, true)
    }
}

/// Discrete-event simulation driver.
///
/// Slab-arena event calendar: `at`/`order` are SoA columns holding the
/// ordering key of every live slot, `payload` the event bodies, `heap`
/// a binary min-heap of slot indices keyed by `(at, order)`.
#[derive(Debug)]
pub struct Des<E> {
    now: f64,
    seq: u64,
    processed: u64,
    clamped: u64,
    at: Vec<f64>,
    order: Vec<u64>,
    payload: Vec<Option<E>>,
    free: Vec<u32>,
    heap: Vec<u32>,
}

impl<E> Des<E> {
    /// Fresh simulator with the clock at zero.
    pub fn new() -> Self {
        Des {
            now: 0.0,
            seq: 0,
            processed: 0,
            clamped: 0,
            at: Vec::new(),
            order: Vec::new(),
            payload: Vec::new(),
            free: Vec::new(),
            heap: Vec::new(),
        }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of schedules whose timestamp had to be clamped onto the
    /// valid range (NaN or in the past). Always zero in debug builds,
    /// where such schedules panic instead.
    pub fn clamped(&self) -> u64 {
        self.clamped
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Slot capacity of the event arena (high-water mark of concurrently
    /// pending events; recycled slots do not grow it).
    pub fn arena_capacity(&self) -> usize {
        self.at.len()
    }

    /// `true` when slot `a` orders strictly before slot `b`: earlier
    /// timestamp first, FIFO sequence among equals. Timestamps are
    /// sanitized non-NaN at insertion, so `<`/`==` are a total order.
    #[inline]
    fn before(&self, a: u32, b: u32) -> bool {
        let (a, b) = (a as usize, b as usize);
        self.at[a] < self.at[b] || (self.at[a] == self.at[b] && self.order[a] < self.order[b])
    }

    fn sift_up(&mut self, mut pos: usize) {
        while pos > 0 {
            let parent = (pos - 1) / 2;
            if self.before(self.heap[pos], self.heap[parent]) {
                self.heap.swap(pos, parent);
                pos = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut pos: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * pos + 1;
            if l >= n {
                break;
            }
            let r = l + 1;
            let mut best = l;
            if r < n && self.before(self.heap[r], self.heap[l]) {
                best = r;
            }
            if self.before(self.heap[best], self.heap[pos]) {
                self.heap.swap(pos, best);
                pos = best;
            } else {
                break;
            }
        }
    }

    /// Schedule `payload` at absolute virtual time `at` (must not be in
    /// the past).
    ///
    /// Debug builds panic on a NaN or past timestamp; release builds
    /// clamp it to `now` (counted in [`Des::clamped`], reported once on
    /// stderr) rather than corrupt the calendar order.
    pub fn schedule_at(&mut self, at: f64, payload: E) {
        debug_assert!(at >= self.now, "cannot schedule in the past: {at} < {}", self.now);
        let (at, was_clamped) = sanitize_event_time(at, self.now);
        if was_clamped {
            if self.clamped == 0 {
                eprintln!(
                    "migperf desim: clamped NaN/past event time to now={} (further clamps \
                     counted silently)",
                    self.now
                );
            }
            self.clamped += 1;
        }
        let slot = match self.free.pop() {
            Some(s) => {
                let i = s as usize;
                self.at[i] = at;
                self.order[i] = self.seq;
                self.payload[i] = Some(payload);
                s
            }
            None => {
                let s = u32::try_from(self.at.len()).expect("event arena overflow");
                self.at.push(at);
                self.order.push(self.seq);
                self.payload.push(Some(payload));
                s
            }
        };
        self.seq += 1;
        self.heap.push(slot);
        self.sift_up(self.heap.len() - 1);
    }

    /// Schedule `payload` after a delay from now.
    ///
    /// Debug builds panic on a NaN or negative delay; release builds
    /// clamp it to zero via the same guard as [`Des::schedule_at`].
    pub fn schedule_in(&mut self, delay: f64, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload);
    }

    /// Timestamp of the next event without popping it.
    fn peek_at(&self) -> Option<f64> {
        self.heap.first().map(|&s| self.at[s as usize])
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next(&mut self) -> Option<(f64, E)> {
        let slot = *self.heap.first()?;
        let last = self.heap.pop().expect("heap non-empty");
        if !self.heap.is_empty() {
            self.heap[0] = last;
            self.sift_down(0);
        }
        let i = slot as usize;
        let at = self.at[i];
        let payload = self.payload[i].take().expect("live slot has a payload");
        self.free.push(slot);
        self.now = at;
        self.processed += 1;
        Some((at, payload))
    }

    /// Run until the queue is empty or `horizon` (virtual seconds) is
    /// passed. The handler may schedule further events through the `&mut
    /// Des` it receives.
    pub fn run_until(&mut self, horizon: f64, mut handler: impl FnMut(&mut Des<E>, f64, E)) {
        while let Some(at) = self.peek_at() {
            if at > horizon {
                break;
            }
            let (at, payload) = self.next().unwrap();
            handler(self, at, payload);
        }
        // Advance the clock to the horizon only when it is finite. With
        // `horizon = f64::INFINITY` the old expression set `now` to
        // infinity, which poisoned every later `schedule_in` (now + delay
        // = inf); an exhausted-queue run leaves the clock at the last
        // processed event instead.
        if horizon.is_finite() {
            self.now = self.now.max(horizon);
        }
    }
}

impl<E> Default for Des<E> {
    fn default() -> Self {
        Des::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut des: Des<&str> = Des::new();
        des.schedule_at(3.0, "c");
        des.schedule_at(1.0, "a");
        des.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(des.now(), 3.0);
    }

    #[test]
    fn fifo_among_equal_timestamps() {
        let mut des: Des<u32> = Des::new();
        for i in 0..10 {
            des.schedule_at(5.0, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| des.next().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handler_can_reschedule() {
        // A self-perpetuating tick: event at t schedules another at t+1.
        let mut des: Des<()> = Des::new();
        des.schedule_at(0.0, ());
        let mut ticks = 0;
        des.run_until(5.5, |des, _t, ()| {
            ticks += 1;
            des.schedule_in(1.0, ());
        });
        assert_eq!(ticks, 6); // t = 0,1,2,3,4,5
        assert!(des.pending() == 1); // the t=6 tick remains
    }

    #[test]
    fn run_until_respects_horizon() {
        let mut des: Des<u32> = Des::new();
        des.schedule_at(1.0, 1);
        des.schedule_at(100.0, 2);
        let mut seen = Vec::new();
        des.run_until(10.0, |_, _, e| seen.push(e));
        assert_eq!(seen, vec![1]);
        assert_eq!(des.pending(), 1);
    }

    #[test]
    #[should_panic(expected = "cannot schedule in the past")]
    fn scheduling_in_past_panics() {
        let mut des: Des<()> = Des::new();
        des.schedule_at(5.0, ());
        des.next();
        des.schedule_at(1.0, ());
    }

    #[test]
    fn infinite_horizon_leaves_clock_usable() {
        let mut des: Des<u8> = Des::new();
        des.schedule_at(2.0, 1);
        des.run_until(f64::INFINITY, |_, _, _| {});
        assert_eq!(des.now(), 2.0, "clock stays at the last processed event");
        // Regression: this used to panic-or-poison because `now` was +inf.
        des.schedule_in(1.0, 2);
        assert_eq!(des.next(), Some((3.0, 2)));
    }

    #[test]
    fn finite_horizon_still_advances_clock() {
        let mut des: Des<u8> = Des::new();
        des.schedule_at(1.0, 1);
        des.run_until(10.0, |_, _, _| {});
        assert_eq!(des.now(), 10.0);
    }

    #[test]
    fn processed_counter() {
        let mut des: Des<u8> = Des::new();
        des.schedule_in(0.0, 0);
        des.schedule_in(1.0, 1);
        des.run_until(f64::INFINITY, |_, _, _| {});
        assert_eq!(des.processed(), 2);
        assert_eq!(des.pending(), 0);
    }

    #[test]
    fn sanitize_clamps_nan_and_past_times() {
        // The release-build guard: NaN and past timestamps clamp to now,
        // valid times (including now itself and +inf) pass untouched.
        assert_eq!(sanitize_event_time(5.0, 3.0), (5.0, false));
        assert_eq!(sanitize_event_time(3.0, 3.0), (3.0, false));
        assert_eq!(sanitize_event_time(f64::INFINITY, 3.0), (f64::INFINITY, false));
        assert_eq!(sanitize_event_time(1.0, 3.0), (3.0, true));
        assert_eq!(sanitize_event_time(-2.0, 0.0), (0.0, true));
        assert_eq!(sanitize_event_time(f64::NAN, 3.0), (3.0, true));
        assert_eq!(sanitize_event_time(f64::NEG_INFINITY, 3.0), (3.0, true));
    }

    #[test]
    fn arena_slots_recycle_through_the_free_list() {
        // A ping-pong of schedule/pop keeps at most two events pending,
        // so the arena must plateau at two slots no matter how many
        // events flow through it.
        let mut des: Des<u32> = Des::new();
        des.schedule_at(0.0, 0);
        des.schedule_at(0.5, 1);
        let mut n = 0u32;
        des.run_until(1000.0, |des, _, _| {
            n += 1;
            if n < 500 {
                des.schedule_in(1.0, n);
            }
        });
        assert_eq!(n, 501, "both seeds plus 499 rescheduled events");
        assert_eq!(des.arena_capacity(), 2, "free list recycles slots");
        assert_eq!(des.clamped(), 0);
    }

    #[test]
    fn interleaved_schedule_and_pop_keeps_total_order() {
        // Mix pops and pushes so recycled slots carry fresh keys; the
        // output must still be globally (time, FIFO) ordered.
        let mut des: Des<usize> = Des::new();
        for i in 0..8 {
            des.schedule_at(i as f64 * 2.0, i);
        }
        let mut seen: Vec<(f64, usize)> = Vec::new();
        let mut extra = 100;
        des.run_until(f64::INFINITY, |des, t, e| {
            seen.push((t, e));
            if extra < 104 {
                des.schedule_in(1.0, extra);
                extra += 1;
            }
            if e == 0 {
                extra = 100;
                des.schedule_in(1.0, extra);
                extra += 1;
            }
        });
        let times: Vec<f64> = seen.iter().map(|&(t, _)| t).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "{times:?}");
        assert_eq!(seen.len(), 8 + 5);
    }
}
