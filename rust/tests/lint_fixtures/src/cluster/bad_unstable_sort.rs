// Lint fixture (never compiled): a non-total float comparator in a
// deterministic module. Expected on line 6: float-order AND
// unstable-sort. The total_cmp sort on line 8 must NOT fire.

pub fn sort_latencies(v: &mut Vec<f64>, w: &mut Vec<f64>) {
    v.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());

    w.sort_unstable_by(f64::total_cmp);
}
