//! ASCII table and sparkline rendering for benchmark reports.
//!
//! MIGPerf's "visualizer" component (paper §3.2) renders results directly
//! in the terminal: aligned tables for the paper's Tables 1–2 and compact
//! unicode sparklines for figure series, so `cargo bench` output is
//! human-readable without plotting tools.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row; padded/truncated to the header width.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        let mut r: Vec<String> = cells.to_vec();
        r.resize(self.header.len(), String::new());
        self.rows.push(r);
        self
    }

    /// Convenience for string-slice rows.
    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with column alignment and a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| display_width(h)).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(display_width(cell));
            }
        }
        let mut out = String::new();
        render_row(&mut out, &self.header, &widths);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        render_row(&mut out, &sep, &widths);
        for row in &self.rows {
            render_row(&mut out, row, &widths);
        }
        out
    }
}

fn display_width(s: &str) -> usize {
    s.chars().count()
}

fn render_row(out: &mut String, cells: &[String], widths: &[usize]) {
    for (i, w) in widths.iter().enumerate() {
        let cell = cells.get(i).map(String::as_str).unwrap_or("");
        out.push_str(cell);
        for _ in display_width(cell)..*w {
            out.push(' ');
        }
        if i + 1 < widths.len() {
            out.push_str("  ");
        }
    }
    out.push('\n');
}

/// Render a series of values as a unicode sparkline (▁▂▃▄▅▆▇█).
///
/// Values are min-max normalized; a constant series renders mid-height.
pub fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    values
        .iter()
        .map(|&v| {
            if hi - lo < 1e-12 {
                BARS[3]
            } else {
                let idx = ((v - lo) / (hi - lo) * 7.0).round() as usize;
                BARS[idx.min(7)]
            }
        })
        .collect()
}

/// Format a float with engineering-friendly precision: 3 significant-ish
/// digits, no scientific notation for the magnitudes benchmarks produce.
pub fn fmt_num(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["longer-name", "22"]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines should start their second column at the same offset.
        let off = lines[0].find("value").unwrap();
        assert_eq!(lines[2].chars().count().min(off + 1), off + 1);
        assert!(lines[3].starts_with("longer-name"));
    }

    #[test]
    fn short_rows_are_padded() {
        let mut t = Table::new(&["a", "b", "c"]);
        t.row_strs(&["only-one"]);
        let out = t.render();
        assert!(out.contains("only-one"));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }

    #[test]
    fn sparkline_constant_and_empty() {
        assert_eq!(sparkline(&[]), "");
        let s = sparkline(&[5.0, 5.0, 5.0]);
        assert!(s.chars().all(|c| c == '▄'));
    }

    #[test]
    fn fmt_num_magnitudes() {
        assert_eq!(fmt_num(0.0), "0");
        assert_eq!(fmt_num(12345.6), "12346");
        assert_eq!(fmt_num(42.25), "42.2");
        assert_eq!(fmt_num(3.14159), "3.14");
        assert_eq!(fmt_num(0.012345), "0.0123");
    }
}
