//! Fig 9 (appendix): ResNet-50 inference on A100 GPU instances vs batch.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{banner, maybe_write_csv, print_series, shape_check};
use migperf::mig::gpu::GpuModel;
use migperf::profiler::session::ProfileSession;
use migperf::profiler::task::{BenchTask, SweepAxis};
use migperf::workload::spec::WorkloadKind;

fn main() {
    banner("Figure 9", "ResNet-50 inference on A100 GIs vs batch size (appendix B)");
    let task = BenchTask {
        name: "fig9".into(),
        gpu: GpuModel::A100_80GB,
        gi_profiles: vec![
            "1g.10gb".into(),
            "2g.20gb".into(),
            "3g.40gb".into(),
            "7g.80gb".into(),
        ],
        model: "resnet50".into(),
        kind: WorkloadKind::Inference,
        batch: 8,
        seq: 224,
        sweep: SweepAxis::Batch(vec![1, 2, 4, 8, 16, 32]),
        iterations: 200,
        layout: Default::default(),
    };
    let report = ProfileSession::default().run(&task).expect("fig9 session");
    print_series(&report, "(a) avg latency ms", |s| s.avg_latency_ms, "batch", false);
    print_series(&report, "(b) GRACT", |s| s.mean_gract, "batch", false);
    print_series(&report, "(c) FB used MiB", |s| s.peak_fb_mib, "batch", false);
    print_series(&report, "(d) energy J", |s| s.energy_j, "batch", false);
    maybe_write_csv("fig9", &report);
    println!();

    let lat = |inst: &str, batch: u32| {
        report
            .rows()
            .iter()
            .find(|r| r.instance == inst && r.batch == batch)
            .map(|r| r.summary.avg_latency_ms)
            .unwrap()
    };
    shape_check(
        "small-GI latency batch-sensitive, large-GI marginal (Fig 9a)",
        lat("1g.10gb", 32) / lat("1g.10gb", 1) > 2.0
            && lat("7g.80gb", 32) / lat("7g.80gb", 1) < lat("1g.10gb", 32) / lat("1g.10gb", 1),
    );
    shape_check(
        "latency non-increasing with GI size (Fig 9a)",
        lat("7g.80gb", 8) <= lat("3g.40gb", 8)
            && lat("3g.40gb", 8) <= lat("2g.20gb", 8)
            && lat("2g.20gb", 8) <= lat("1g.10gb", 8),
    );
}
