//! Hybrid training + inference orchestration on one MIG GPU.
//!
//! ```bash
//! cargo run --release --example hybrid_orchestration
//! ```
//!
//! The paper's motivating scenario (§1) and headline future-work item
//! (§5): "set up three 4/7, 2/7, and 1/7 GIs, and perform both training
//! (on 4/7 GI) and inference (on 2/7 and 1/7 GIs) workloads
//! simultaneously". This example builds exactly that layout, runs BERT
//! training on the 4g instance while two inference servers handle Poisson
//! traffic on the 2g and 1g instances, and reports per-instance metrics
//! plus whole-board energy — demonstrating that physical isolation keeps
//! the inference tail flat while training hammers its own slice.

use migperf::mig::controller::MigController;
use migperf::mig::gpu::GpuModel;
use migperf::models::zoo;
use migperf::simgpu::energy::EnergyModel;
use migperf::simgpu::perfmodel::PerfModel;
use migperf::simgpu::resource::ExecResource;
use migperf::util::table::{fmt_num, Table};
use migperf::workload::serving::{LoadMode, ServingSim, SharingMode};
use migperf::workload::spec::WorkloadSpec;
use migperf::workload::training::{run_training, TrainingConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // The paper's mixed partition: 4g (train) + 2g + 1g (serve).
    let gpu = GpuModel::A100_80GB;
    let mut ctl = MigController::new(gpu);
    ctl.enable_mig()?;
    let train_gi = ctl.create_instance("4g.40gb")?;
    let infer2_gi = ctl.create_instance("2g.20gb")?;
    let infer1_gi = ctl.create_instance("1g.10gb")?;
    println!("layout:");
    for gi in ctl.list_instances() {
        println!(
            "  {} at mem-slice {} ({})",
            gi.profile.name,
            gi.start,
            gi.profile.slice_notation(gpu)
        );
    }

    let pm = PerfModel::default();
    let em = EnergyModel::default();
    let train_res = ExecResource::from_gi(gpu, ctl.instance(train_gi)?.profile);
    let infer2_res = ExecResource::from_gi(gpu, ctl.instance(infer2_gi)?.profile);
    let infer1_res = ExecResource::from_gi(gpu, ctl.instance(infer1_gi)?.profile);

    // Training on the 4/7 instance: BERT-base, batch 32.
    let bert = zoo::lookup("bert-base").unwrap();
    let train_spec = WorkloadSpec::training(bert, 32, 128);
    let train_summary = run_training(
        &train_res,
        &train_spec,
        &TrainingConfig { steps: 500, sample_interval_s: 0.5 },
        &pm,
        &em,
    )?;

    // Inference on the 2/7 and 1/7 instances: open-loop Poisson traffic,
    // simulated concurrently with the training run (MIG isolation means
    // no cross-talk — that is the point being demonstrated).
    let resnet = zoo::lookup("resnet50").unwrap();
    let serve = |res: &ExecResource, rate: f64, seed: u64| {
        ServingSim {
            mode: SharingMode::Mig(vec![res.clone()]),
            load: LoadMode::OpenPoisson { rate, requests_per_server: 2000 },
            spec: WorkloadSpec::inference(resnet, 4, 224),
            seed,
        }
        .run()
    };
    let s2 = serve(&infer2_res, 60.0, 1)?;
    let s1 = serve(&infer1_res, 25.0, 2)?;

    let mut t = Table::new(&[
        "instance", "workload", "completed", "avg_ms", "p99_ms", "tput", "gract", "energy_j",
    ]);
    t.row(&[
        "4g.40gb".into(),
        "bert-base train b32".into(),
        train_summary.completed.to_string(),
        fmt_num(train_summary.avg_latency_ms),
        fmt_num(train_summary.p99_latency_ms),
        fmt_num(train_summary.throughput),
        fmt_num(train_summary.mean_gract),
        fmt_num(train_summary.energy_j),
    ]);
    for (name, load, out) in
        [("2g.20gb", "resnet50 serve @60rps", &s2), ("1g.10gb", "resnet50 serve @25rps", &s1)]
    {
        let s = &out.pooled;
        t.row(&[
            name.into(),
            load.into(),
            s.completed.to_string(),
            fmt_num(s.avg_latency_ms),
            fmt_num(s.p99_latency_ms),
            fmt_num(s.throughput),
            fmt_num(s.mean_gract),
            fmt_num(s.energy_j),
        ]);
    }
    println!("\nhybrid train + serve on one A100:\n{}", t.render());

    // Isolation check: serving tail on the 1g slice matches a solo run.
    let solo = serve(&infer1_res, 25.0, 2)?;
    let delta =
        (solo.pooled.p99_latency_ms - s1.pooled.p99_latency_ms).abs() / s1.pooled.p99_latency_ms;
    println!(
        "isolation: 1g serving p99 with training co-resident differs {:.2}% from solo (MIG physical isolation)",
        delta * 100.0
    );
    assert!(delta < 1e-9, "MIG isolation must make co-location invisible");
    Ok(())
}
