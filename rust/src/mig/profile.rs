//! GPU-instance (GI) profiles.
//!
//! A GI profile names a fixed bundle of compute slices + memory slices,
//! e.g. `1g.10gb` = 1 compute slice and 10 GiB (one A100-80GB memory
//! slice). The set of profiles per GPU is hard-coded by NVIDIA (paper §1:
//! "NVIDIA limits the partition by setting up hard-coded rules"); this
//! module encodes the published tables for A100-80GB and A30.

use super::gpu::GpuModel;

/// A MIG GPU-instance profile.
#[derive(Debug, Clone, PartialEq)]
pub struct GiProfile {
    /// Canonical NVIDIA name, e.g. `2g.20gb`.
    pub name: &'static str,
    /// Compute slices (the `Ng` part).
    pub compute_slices: u32,
    /// Memory slices occupied.
    pub memory_slices: u32,
    /// Frame buffer available to workloads, GiB.
    pub memory_gib: f64,
    /// Maximum number of instances of this profile alone on one GPU.
    pub max_count: u32,
    /// Valid placement start offsets, in memory-slice units.
    ///
    /// NVIDIA publishes placements per profile; a GI occupies
    /// `[start, start + memory_slices)` in the memory-slice map.
    pub placements: &'static [u32],
}

/// A100-80GB GI profiles (NVIDIA MIG user guide, GA100 80GB table).
#[rustfmt::skip]
pub static A100_PROFILES: &[GiProfile] = &[
    GiProfile { name: "1g.10gb", compute_slices: 1, memory_slices: 1, memory_gib: 9.75, max_count: 7, placements: &[0, 1, 2, 3, 4, 5, 6] },
    GiProfile { name: "1g.20gb", compute_slices: 1, memory_slices: 2, memory_gib: 19.5, max_count: 4, placements: &[0, 2, 4, 6] },
    GiProfile { name: "2g.20gb", compute_slices: 2, memory_slices: 2, memory_gib: 19.5, max_count: 3, placements: &[0, 2, 4] },
    GiProfile { name: "3g.40gb", compute_slices: 3, memory_slices: 4, memory_gib: 39.25, max_count: 2, placements: &[0, 4] },
    GiProfile { name: "4g.40gb", compute_slices: 4, memory_slices: 4, memory_gib: 39.25, max_count: 1, placements: &[0] },
    GiProfile { name: "7g.80gb", compute_slices: 7, memory_slices: 8, memory_gib: 78.0, max_count: 1, placements: &[0] },
];

/// A30 GI profiles (NVIDIA MIG user guide, GA100 24GB/A30 table).
#[rustfmt::skip]
pub static A30_PROFILES: &[GiProfile] = &[
    GiProfile { name: "1g.6gb", compute_slices: 1, memory_slices: 1, memory_gib: 5.81, max_count: 4, placements: &[0, 1, 2, 3] },
    GiProfile { name: "2g.12gb", compute_slices: 2, memory_slices: 2, memory_gib: 11.75, max_count: 2, placements: &[0, 2] },
    GiProfile { name: "4g.24gb", compute_slices: 4, memory_slices: 4, memory_gib: 23.5, max_count: 1, placements: &[0] },
];

/// Pairs of profiles that NVIDIA's rules forbid from coexisting even when
/// a naive slice count would fit. The paper calls out the famous example:
/// "users can not have both 4/7 and 3/7 GIs simultaneously for an A100".
pub static A100_EXCLUSIONS: &[(&str, &str)] = &[("4g.40gb", "3g.40gb")];

/// Profile table for a GPU model.
pub fn profiles_for(model: GpuModel) -> &'static [GiProfile] {
    match model {
        GpuModel::A100_80GB => A100_PROFILES,
        GpuModel::A30_24GB => A30_PROFILES,
    }
}

/// Exclusion pairs for a GPU model.
pub fn exclusions_for(model: GpuModel) -> &'static [(&'static str, &'static str)] {
    match model {
        GpuModel::A100_80GB => A100_EXCLUSIONS,
        GpuModel::A30_24GB => &[],
    }
}

/// Look up a profile by name on a model (case-insensitive).
pub fn lookup(model: GpuModel, name: &str) -> Option<&'static GiProfile> {
    let lname = name.to_ascii_lowercase();
    profiles_for(model).iter().find(|p| p.name == lname)
}

impl GiProfile {
    /// Fraction of the whole GPU's compute this profile owns.
    pub fn compute_fraction(&self, model: GpuModel) -> f64 {
        self.compute_slices as f64 / model.spec().compute_slices as f64
    }

    /// Fraction of the whole GPU's memory bandwidth (and L2) this owns.
    pub fn memory_fraction(&self, model: GpuModel) -> f64 {
        self.memory_slices as f64 / model.spec().memory_slices as f64
    }

    /// SM count in this profile on the given model.
    pub fn sm_count(&self, model: GpuModel) -> u32 {
        self.compute_slices * model.spec().sms_per_slice()
    }

    /// Human-readable "k/N" form used throughout the paper (e.g. "4/7").
    pub fn slice_notation(&self, model: GpuModel) -> String {
        format!("{}/{}", self.compute_slices, model.spec().compute_slices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_profile_count_and_names() {
        let names: Vec<&str> = A100_PROFILES.iter().map(|p| p.name).collect();
        assert!(names.contains(&"1g.10gb"));
        assert!(names.contains(&"7g.80gb"));
        assert_eq!(A100_PROFILES.len(), 6);
    }

    #[test]
    fn placements_fit_on_device() {
        for model in GpuModel::all() {
            let mem_slices = model.spec().memory_slices;
            for p in profiles_for(*model) {
                for &start in p.placements {
                    assert!(
                        start + p.memory_slices <= mem_slices,
                        "{} placement {start} overflows {model}",
                        p.name
                    );
                }
                assert!(p.compute_slices <= model.spec().compute_slices);
            }
        }
    }

    #[test]
    fn max_count_consistent_with_slices() {
        for model in GpuModel::all() {
            let spec = model.spec();
            for p in profiles_for(*model) {
                // max_count can never exceed what compute or memory slices allow.
                assert!(p.max_count * p.compute_slices <= spec.compute_slices + 0, "{}", p.name);
                assert!(p.max_count * p.memory_slices <= spec.memory_slices, "{}", p.name);
                // ...but 1g.20gb-style profiles are deliberately sparser; at
                // minimum one instance must fit.
                assert!(p.max_count >= 1);
            }
        }
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(lookup(GpuModel::A100_80GB, "1G.10GB").is_some());
        assert!(lookup(GpuModel::A100_80GB, "1g.6gb").is_none(), "A30 profile on A100");
        assert!(lookup(GpuModel::A30_24GB, "1g.6gb").is_some());
    }

    #[test]
    fn fractions() {
        let p = lookup(GpuModel::A100_80GB, "2g.20gb").unwrap();
        assert!((p.compute_fraction(GpuModel::A100_80GB) - 2.0 / 7.0).abs() < 1e-12);
        assert!((p.memory_fraction(GpuModel::A100_80GB) - 0.25).abs() < 1e-12);
        assert_eq!(p.sm_count(GpuModel::A100_80GB), 28);
        assert_eq!(p.slice_notation(GpuModel::A100_80GB), "2/7");
    }

    #[test]
    fn full_gpu_profiles_own_everything() {
        let p7 = lookup(GpuModel::A100_80GB, "7g.80gb").unwrap();
        assert_eq!(p7.compute_fraction(GpuModel::A100_80GB), 1.0);
        assert_eq!(p7.memory_fraction(GpuModel::A100_80GB), 1.0);
        let p4 = lookup(GpuModel::A30_24GB, "4g.24gb").unwrap();
        assert_eq!(p4.compute_fraction(GpuModel::A30_24GB), 1.0);
    }

    #[test]
    fn exclusion_table_names_exist() {
        for (a, b) in exclusions_for(GpuModel::A100_80GB) {
            assert!(lookup(GpuModel::A100_80GB, a).is_some());
            assert!(lookup(GpuModel::A100_80GB, b).is_some());
        }
    }
}
