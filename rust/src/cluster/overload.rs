//! SLO-aware overload protection and graceful degradation.
//!
//! MIGPerf's serving characterization is about meeting tail-latency SLOs
//! on partitioned GPUs, but an unbounded ingress admits every request
//! under sustained overload (diurnal peaks above capacity, crash-shrunk
//! fleets) and p99 grows without bound. Real MIG serving stacks degrade
//! gracefully instead: Tan et al. (2021) treat SLO feasibility as a hard
//! admission constraint and MISO (Li et al., 2022) motivates protecting
//! high-weight tenants when multi-tenant capacity is contended. This
//! module supplies the fleet engine's protection layer:
//!
//! * **per-request deadlines** derived from each class's SLO
//!   (`deadline = arrival + deadline_mult × slo`); expired requests are
//!   shed at dispatch, never served;
//! * **bounded per-replica queues** with pluggable shedding disciplines
//!   ([`ShedDiscipline`]): reject-newest at admission or drop-oldest on
//!   enqueue;
//! * **tenant-weighted brownout**: when the fleet-wide shed fraction in
//!   an observation window crosses a threshold, the lowest-weight
//!   tenants are shed at the ingress first (ties to the lowest tenant
//!   index), so high-weight tenants keep their SLO; the highest-weight
//!   tenant is never browned out;
//! * **per-GPU ingress circuit breakers**: a GPU whose window shed
//!   fraction exceeds a cap is removed from routing (open), then
//!   re-admitted through a bounded half-open probe window; any probe
//!   shed re-opens the breaker. Breakers compose with the crash/recover
//!   health states — a crashed GPU is excluded by health regardless of
//!   its breaker, and an open breaker keeps a freshly recovered GPU out
//!   of the ingress until its probes succeed.
//!
//! Everything here is plain deterministic arithmetic over windowed
//! counters — no clocks, no randomness — so shedding decisions preserve
//! the engine's bitwise-determinism contract at any sweep worker count.
//! [`OverloadPolicy::none`] disables every mechanism and leaves the
//! engine byte-identical to the unprotected path.

use super::tenancy::{tenant_of_classes, Tenant};

/// Half-open probe budget used when the CLI or a config does not choose
/// one explicitly.
pub const DEFAULT_BREAKER_PROBES: u64 = 8;

/// What to do when a bounded replica queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedDiscipline {
    /// The incoming request is rejected at admission; the queue keeps
    /// its older work.
    RejectNewest,
    /// The oldest *waiting* request (the in-service head is exempt) is
    /// dropped to make room for the newcomer. A cap-1 queue whose head
    /// is in service has nothing waiting, so the newcomer is rejected
    /// instead.
    DropOldest,
}

impl ShedDiscipline {
    /// Report name of the discipline.
    pub fn name(&self) -> &'static str {
        match self {
            ShedDiscipline::RejectNewest => "reject-newest",
            ShedDiscipline::DropOldest => "drop-oldest",
        }
    }

    /// Parse a discipline name.
    pub fn parse(s: &str) -> Option<ShedDiscipline> {
        match s.to_ascii_lowercase().as_str() {
            "reject" | "reject-newest" => Some(ShedDiscipline::RejectNewest),
            "drop" | "drop-oldest" => Some(ShedDiscipline::DropOldest),
            _ => None,
        }
    }
}

/// Why the overload guard shed a request. Every shed increments exactly
/// one per-class counter, so the conservation invariant extends to
/// `completed + failed + lost_in_crash + shed_overload = arrived`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedCause {
    /// The deadline expired while the request waited for dispatch.
    Deadline,
    /// A bounded replica queue was full.
    Capacity,
    /// The request's tenant was browned out at the fleet ingress.
    Brownout,
}

/// Overload-protection policy (plain data: clone freely into sweep
/// grids). [`OverloadPolicy::none`] disables everything.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadPolicy {
    /// Per-replica queue bound, counting the in-service head; 0 means
    /// unbounded (no capacity shedding).
    pub queue_cap: usize,
    /// Discipline applied when a bounded queue is full.
    pub shed: ShedDiscipline,
    /// Deadline multiplier: a request of a class with SLO `s` expires
    /// `deadline_mult × s` after arrival. 0 disables deadlines.
    pub deadline_mult: f64,
    /// Fleet-wide shed fraction per observation window (pressure sheds /
    /// arrivals) above which the brownout escalates by one tenant.
    /// `f64::INFINITY` disables brownout.
    pub brownout_threshold: f64,
    /// Per-GPU shed fraction per observation window (sheds at the GPU /
    /// requests routed to it) above which its ingress breaker trips.
    /// `f64::INFINITY` disables breakers.
    pub breaker_threshold: f64,
    /// Requests admitted through a half-open breaker before it decides
    /// to close (no probe shed) or re-open (any probe shed).
    pub breaker_probes: u64,
}

impl OverloadPolicy {
    /// No overload protection: the engine behaves byte-identically to
    /// the unprotected path.
    pub fn none() -> OverloadPolicy {
        OverloadPolicy {
            queue_cap: 0,
            shed: ShedDiscipline::RejectNewest,
            deadline_mult: 0.0,
            brownout_threshold: f64::INFINITY,
            breaker_threshold: f64::INFINITY,
            breaker_probes: DEFAULT_BREAKER_PROBES,
        }
    }

    /// True when every mechanism is disabled.
    pub fn is_disabled(&self) -> bool {
        self.queue_cap == 0
            && self.deadline_mult == 0.0
            && self.brownout_threshold.is_infinite()
            && self.breaker_threshold.is_infinite()
    }

    /// Reject policies that would produce NaN deadlines or degenerate
    /// thresholds.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.deadline_mult.is_finite() && self.deadline_mult >= 0.0) {
            return Err(format!(
                "deadline_mult = {} must be non-negative and finite (0 disables deadlines)",
                self.deadline_mult
            ));
        }
        let frac = |name: &str, v: f64| -> Result<(), String> {
            // Finite thresholds are shed *fractions*; infinity disables.
            if v.is_nan() || v <= 0.0 || (v.is_finite() && v > 1.0) {
                return Err(format!(
                    "{name} = {v} must be a shed fraction in (0, 1] or infinite to disable"
                ));
            }
            Ok(())
        };
        frac("brownout_threshold", self.brownout_threshold)?;
        frac("breaker_threshold", self.breaker_threshold)?;
        if self.breaker_threshold.is_finite() && self.breaker_probes == 0 {
            return Err(
                "breaker_probes must be positive when the breaker is enabled: a breaker \
                 with no probes could never close again"
                    .into(),
            );
        }
        Ok(())
    }
}

/// Ingress circuit-breaker lifecycle for one GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal ingress.
    Closed,
    /// Excluded from routing until the next observation window.
    Open,
    /// Admitting up to `breaker_probes` requests; any shed re-opens.
    HalfOpen,
}

#[derive(Debug, Clone)]
struct GpuBreaker {
    state: BreakerState,
    /// When the breaker last opened (for open-time accounting).
    opened_t: f64,
    /// Remaining half-open probe budget.
    probes_left: u64,
    /// A request was shed at this GPU while half-open.
    probe_shed: bool,
    /// Requests the router placed on this GPU in the current window.
    window_routed: u64,
    /// Capacity/deadline sheds at this GPU in the current window.
    window_shed: u64,
}

impl GpuBreaker {
    fn new() -> GpuBreaker {
        GpuBreaker {
            state: BreakerState::Closed,
            opened_t: 0.0,
            probes_left: 0,
            probe_shed: false,
            window_routed: 0,
            window_shed: 0,
        }
    }
}

/// Runtime overload state for one fleet run: deadline table, per-GPU
/// breakers, the brownout ladder and the cumulative per-class shed
/// counters (kept per class so they re-aggregate per tenant).
#[derive(Debug)]
pub struct OverloadGuard {
    policy: OverloadPolicy,
    /// Per-class deadline offsets, seconds (`INFINITY` when disabled).
    deadline_s: Vec<f64>,
    breakers: Vec<GpuBreaker>,
    /// Class → tenant index (for the ingress brownout check).
    tenant_of: Vec<usize>,
    /// Tenant indices ordered lowest weight first, ties to the lowest
    /// index — the deterministic brownout ladder.
    brownout_order: Vec<usize>,
    /// How many tenants off the ladder are currently browned out
    /// (never all of them: the highest-weight tenant keeps serving).
    brownout_level: usize,
    /// Browned-out flag per tenant, recomputed from the ladder.
    browned_out: Vec<bool>,
    /// Fleet-wide arrivals in the current window.
    window_arrived: u64,
    /// Fleet-wide capacity/deadline sheds in the current window (the
    /// pressure signal; brownout sheds are the response, not pressure).
    window_pressure: u64,
    shed_deadline: Vec<u64>,
    shed_capacity: Vec<u64>,
    shed_brownout: Vec<u64>,
    breaker_trips: u64,
    breaker_open_s: f64,
}

impl OverloadGuard {
    /// Build the guard for a validated config. `tenants` is the
    /// effective tenant set (the engine's per-class synthesis when the
    /// config declares none).
    pub fn new(
        policy: OverloadPolicy,
        slo_ms: &[f64],
        tenants: &[Tenant],
        n_gpus: usize,
    ) -> OverloadGuard {
        let deadline_s: Vec<f64> = slo_ms
            .iter()
            .map(|&s| {
                if policy.deadline_mult > 0.0 {
                    policy.deadline_mult * s / 1e3
                } else {
                    f64::INFINITY
                }
            })
            .collect();
        let mut brownout_order: Vec<usize> = (0..tenants.len()).collect();
        brownout_order.sort_by(|&a, &b| {
            tenants[a].weight.total_cmp(&tenants[b].weight).then(a.cmp(&b))
        });
        OverloadGuard {
            policy,
            deadline_s,
            breakers: (0..n_gpus).map(|_| GpuBreaker::new()).collect(),
            tenant_of: tenant_of_classes(tenants, slo_ms.len()),
            brownout_order,
            brownout_level: 0,
            browned_out: vec![false; tenants.len()],
            window_arrived: 0,
            window_pressure: 0,
            shed_deadline: vec![0; slo_ms.len()],
            shed_capacity: vec![0; slo_ms.len()],
            shed_brownout: vec![0; slo_ms.len()],
            breaker_trips: 0,
            breaker_open_s: 0.0,
        }
    }

    /// The per-replica queue bound (0 = unbounded).
    pub fn queue_cap(&self) -> usize {
        self.policy.queue_cap
    }

    /// The full-queue discipline.
    pub fn discipline(&self) -> ShedDiscipline {
        self.policy.shed
    }

    /// True when deadline expiry is in play.
    pub fn deadlines_enabled(&self) -> bool {
        self.policy.deadline_mult > 0.0
    }

    /// True when per-GPU breakers are in play (breaker transitions are
    /// the one capacity-return event without a recovery event, so the
    /// engine re-offers stranded requests on ticks only in this case).
    pub fn breaker_enabled(&self) -> bool {
        self.policy.breaker_threshold.is_finite()
    }

    /// Deadline for a request of `class` arriving at `arrived`
    /// (`INFINITY` when deadlines are disabled).
    pub fn deadline(&self, class: usize, arrived: f64) -> f64 {
        arrived + self.deadline_s[class]
    }

    /// Count one fleet-ingress arrival into the brownout window.
    pub fn note_arrival(&mut self) {
        self.window_arrived += 1;
    }

    /// Brownout check at the fleet ingress: may this class's tenant be
    /// admitted right now?
    pub fn admits_class(&self, class: usize) -> bool {
        match self.tenant_of.get(class) {
            Some(&ti) if ti < self.browned_out.len() => !self.browned_out[ti],
            _ => true,
        }
    }

    /// Breaker check: may the router place requests on GPU `g`?
    pub fn gpu_admits(&self, g: usize) -> bool {
        match self.breakers[g].state {
            BreakerState::Closed => true,
            BreakerState::Open => false,
            BreakerState::HalfOpen => self.breakers[g].probes_left > 0,
        }
    }

    /// Breaker state of GPU `g` (for tests and reporting).
    pub fn breaker_state(&self, g: usize) -> BreakerState {
        self.breakers[g].state
    }

    /// Current brownout ladder level: how many tenants (lowest weight
    /// first) are browned out right now (for telemetry and reporting).
    pub fn brownout_level(&self) -> usize {
        self.brownout_level
    }

    /// Record that the router placed a request on GPU `g` (consumes a
    /// half-open probe).
    pub fn note_route(&mut self, g: usize) {
        let b = &mut self.breakers[g];
        b.window_routed += 1;
        if b.state == BreakerState::HalfOpen {
            b.probes_left = b.probes_left.saturating_sub(1);
        }
    }

    /// Record one shed. `gpu` is the GPU the shed happened at
    /// (capacity/deadline), or `None` for ingress brownout sheds.
    pub fn note_shed(&mut self, gpu: Option<usize>, class: usize, cause: ShedCause) {
        match cause {
            ShedCause::Deadline => self.shed_deadline[class] += 1,
            ShedCause::Capacity => self.shed_capacity[class] += 1,
            ShedCause::Brownout => self.shed_brownout[class] += 1,
        }
        if cause != ShedCause::Brownout {
            self.window_pressure += 1;
        }
        if let Some(g) = gpu {
            let b = &mut self.breakers[g];
            b.window_shed += 1;
            if b.state == BreakerState::HalfOpen {
                b.probe_shed = true;
            }
        }
    }

    /// Observation-window boundary at simulated time `t`: advance the
    /// breaker state machines on the window that just ended, move the
    /// brownout ladder, and reset the window counters.
    pub fn on_tick(&mut self, t: f64) {
        if self.policy.breaker_threshold.is_finite() {
            for b in self.breakers.iter_mut() {
                match b.state {
                    BreakerState::Closed => {
                        // Deadline sheds of earlier admissions can push
                        // the fraction past 1; `>` keeps the check sane.
                        if b.window_routed > 0
                            && b.window_shed as f64
                                > self.policy.breaker_threshold * b.window_routed as f64
                        {
                            b.state = BreakerState::Open;
                            b.opened_t = t;
                            self.breaker_trips += 1;
                        }
                    }
                    BreakerState::Open => {
                        self.breaker_open_s += t - b.opened_t;
                        b.state = BreakerState::HalfOpen;
                        b.probes_left = self.policy.breaker_probes;
                        b.probe_shed = false;
                    }
                    BreakerState::HalfOpen => {
                        if b.probe_shed {
                            b.state = BreakerState::Open;
                            b.opened_t = t;
                            self.breaker_trips += 1;
                        } else {
                            b.state = BreakerState::Closed;
                        }
                    }
                }
                b.window_routed = 0;
                b.window_shed = 0;
            }
        }
        if self.policy.brownout_threshold.is_finite() && !self.browned_out.is_empty() {
            let max_level = self.browned_out.len() - 1; // never all tenants
            let pressure = self.window_pressure as f64;
            let arrived = self.window_arrived as f64;
            if arrived > 0.0 && pressure > self.policy.brownout_threshold * arrived {
                self.brownout_level = (self.brownout_level + 1).min(max_level);
            } else if pressure * 2.0 < self.policy.brownout_threshold * arrived
                || self.window_arrived == 0
            {
                // Hysteresis: de-escalate at half the trigger fraction.
                self.brownout_level = self.brownout_level.saturating_sub(1);
            }
            for f in self.browned_out.iter_mut() {
                *f = false;
            }
            for &ti in &self.brownout_order[..self.brownout_level] {
                self.browned_out[ti] = true;
            }
        }
        self.window_arrived = 0;
        self.window_pressure = 0;
    }

    /// Close out open-time accounting at the end of the run: breakers
    /// still open pay up to the nominal horizon, mirroring the crash
    /// downtime convention.
    pub fn finish(&mut self, horizon_s: f64) {
        for b in &self.breakers {
            if b.state == BreakerState::Open {
                self.breaker_open_s += (horizon_s - b.opened_t).max(0.0);
            }
        }
    }

    /// Per-class deadline sheds.
    pub fn shed_deadline_per_class(&self) -> &[u64] {
        &self.shed_deadline
    }

    /// Per-class capacity sheds.
    pub fn shed_capacity_per_class(&self) -> &[u64] {
        &self.shed_capacity
    }

    /// Per-class brownout sheds.
    pub fn shed_brownout_per_class(&self) -> &[u64] {
        &self.shed_brownout
    }

    /// Breaker trips (transitions into `Open`).
    pub fn breaker_trips(&self) -> u64 {
        self.breaker_trips
    }

    /// Total seconds breakers spent open (summed over GPUs, clamped to
    /// the horizon by [`OverloadGuard::finish`]).
    pub fn breaker_open_s(&self) -> f64 {
        self.breaker_open_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenants() -> Vec<Tenant> {
        vec![
            Tenant::new("gold", 3.0, vec![0]),
            Tenant::new("bronze", 1.0, vec![1]),
            Tenant::new("silver", 2.0, vec![2]),
        ]
    }

    fn guard(policy: OverloadPolicy) -> OverloadGuard {
        OverloadGuard::new(policy, &[40.0, 40.0, 40.0], &tenants(), 2)
    }

    #[test]
    fn discipline_names_parse_and_render() {
        assert_eq!(ShedDiscipline::parse("reject"), Some(ShedDiscipline::RejectNewest));
        assert_eq!(ShedDiscipline::parse("reject-newest"), Some(ShedDiscipline::RejectNewest));
        assert_eq!(ShedDiscipline::parse("drop"), Some(ShedDiscipline::DropOldest));
        assert_eq!(ShedDiscipline::parse("DROP-OLDEST"), Some(ShedDiscipline::DropOldest));
        assert_eq!(ShedDiscipline::parse("lifo"), None);
        assert_eq!(ShedDiscipline::RejectNewest.name(), "reject-newest");
        assert_eq!(ShedDiscipline::DropOldest.name(), "drop-oldest");
    }

    #[test]
    fn none_policy_is_disabled_and_valid() {
        let p = OverloadPolicy::none();
        assert!(p.is_disabled());
        p.validate().unwrap();
        let g = guard(p);
        assert!(g.deadline(0, 5.0).is_infinite(), "no deadline when disabled");
        assert!(!g.deadlines_enabled());
        assert!(!g.breaker_enabled());
        assert!(g.admits_class(0) && g.admits_class(1) && g.admits_class(2));
        assert!(g.gpu_admits(0) && g.gpu_admits(1));
    }

    #[test]
    fn validate_rejects_degenerate_policies() {
        let ok = OverloadPolicy { queue_cap: 4, deadline_mult: 2.0, ..OverloadPolicy::none() };
        ok.validate().unwrap();

        let mut p = OverloadPolicy::none();
        p.deadline_mult = -1.0;
        assert!(p.validate().is_err(), "negative multiplier");
        p.deadline_mult = f64::NAN;
        assert!(p.validate().is_err(), "NaN multiplier");
        p.deadline_mult = f64::INFINITY;
        assert!(p.validate().is_err(), "infinite multiplier");

        let mut p = OverloadPolicy::none();
        p.brownout_threshold = 0.0;
        assert!(p.validate().is_err(), "zero brownout threshold");
        p.brownout_threshold = 1.5;
        assert!(p.validate().is_err(), "fraction above 1");
        p.brownout_threshold = f64::NAN;
        assert!(p.validate().is_err(), "NaN threshold");

        let mut p = OverloadPolicy::none();
        p.breaker_threshold = 0.5;
        p.breaker_probes = 0;
        assert!(p.validate().is_err(), "enabled breaker needs probes");
        p.breaker_probes = 1;
        p.validate().unwrap();
    }

    #[test]
    fn deadlines_scale_with_the_class_slo() {
        let p = OverloadPolicy { deadline_mult: 2.0, ..OverloadPolicy::none() };
        let g = OverloadGuard::new(p, &[40.0, 100.0], &Tenant::per_class(2), 1);
        assert!((g.deadline(0, 10.0) - 10.08).abs() < 1e-12, "10 + 2×40ms");
        assert!((g.deadline(1, 10.0) - 10.2).abs() < 1e-12, "10 + 2×100ms");
        assert!(g.deadlines_enabled());
    }

    #[test]
    fn brownout_sheds_lowest_weight_tenants_first_with_hysteresis() {
        let p = OverloadPolicy { brownout_threshold: 0.5, ..OverloadPolicy::none() };
        let mut g = guard(p);
        // Window 1: 10 arrivals, 6 pressure sheds → fraction 0.6 > 0.5.
        for _ in 0..10 {
            g.note_arrival();
        }
        for _ in 0..6 {
            g.note_shed(Some(0), 0, ShedCause::Capacity);
        }
        g.on_tick(10.0);
        // bronze (weight 1) is first on the ladder and owns class 1.
        assert!(g.admits_class(0), "gold stays admitted");
        assert!(!g.admits_class(1), "bronze is browned out first");
        assert!(g.admits_class(2), "silver stays admitted");
        // Window 2: still over threshold → silver (weight 2) joins; gold
        // (highest weight) is never browned out.
        for _ in 0..10 {
            g.note_arrival();
        }
        for _ in 0..8 {
            g.note_shed(Some(0), 0, ShedCause::Deadline);
        }
        g.on_tick(20.0);
        assert!(g.admits_class(0), "gold is never browned out");
        assert!(!g.admits_class(1));
        assert!(!g.admits_class(2), "silver browned out at level 2");
        // Window 3: pressure between half and full threshold → hold.
        for _ in 0..10 {
            g.note_arrival();
        }
        for _ in 0..4 {
            g.note_shed(Some(0), 0, ShedCause::Capacity);
        }
        g.on_tick(30.0);
        assert!(!g.admits_class(1) && !g.admits_class(2), "0.4 holds the level");
        // Windows 4-5: pressure clear of half the threshold → step down.
        for _ in 0..10 {
            g.note_arrival();
        }
        g.on_tick(40.0);
        assert!(g.admits_class(2), "silver re-admitted first");
        assert!(!g.admits_class(1));
        for _ in 0..10 {
            g.note_arrival();
        }
        g.on_tick(50.0);
        assert!(g.admits_class(1), "bronze re-admitted last");
        assert_eq!(g.shed_brownout_per_class(), &[0, 0, 0], "the guard only gates");
    }

    #[test]
    fn brownout_never_sheds_a_single_tenant_fleet() {
        let p = OverloadPolicy { brownout_threshold: 0.1, ..OverloadPolicy::none() };
        let mut g = OverloadGuard::new(p, &[40.0], &Tenant::per_class(1), 1);
        for _ in 0..4 {
            g.note_arrival();
            g.note_shed(Some(0), 0, ShedCause::Capacity);
        }
        g.on_tick(10.0);
        assert!(g.admits_class(0), "the only tenant always keeps serving");
    }

    #[test]
    fn breaker_trips_half_opens_and_recloses() {
        let p = OverloadPolicy {
            breaker_threshold: 0.5,
            breaker_probes: 2,
            ..OverloadPolicy::none()
        };
        let mut g = guard(p);
        assert!(g.breaker_enabled());
        // GPU 0 sheds 3 of 4 routed → fraction 0.75 > 0.5: trips.
        for _ in 0..4 {
            g.note_route(0);
        }
        for _ in 0..3 {
            g.note_shed(Some(0), 0, ShedCause::Capacity);
        }
        g.note_route(1); // GPU 1 is healthy
        g.on_tick(10.0);
        assert_eq!(g.breaker_state(0), BreakerState::Open);
        assert!(!g.gpu_admits(0), "open breaker excludes the GPU");
        assert!(g.gpu_admits(1));
        assert_eq!(g.breaker_trips(), 1);
        // Next tick: half-open with a 2-probe budget.
        g.on_tick(20.0);
        assert_eq!(g.breaker_state(0), BreakerState::HalfOpen);
        assert!((g.breaker_open_s() - 10.0).abs() < 1e-12, "open 10 → 20");
        assert!(g.gpu_admits(0));
        g.note_route(0);
        assert!(g.gpu_admits(0), "one probe left");
        g.note_route(0);
        assert!(!g.gpu_admits(0), "probe budget exhausted until the tick");
        // Probes served cleanly → close.
        g.on_tick(30.0);
        assert_eq!(g.breaker_state(0), BreakerState::Closed);
        assert!(g.gpu_admits(0));
        assert_eq!(g.breaker_trips(), 1, "a clean half-open is not a trip");
    }

    #[test]
    fn breaker_reopens_on_a_probe_shed_and_finish_clamps_open_time() {
        let p = OverloadPolicy {
            breaker_threshold: 0.5,
            breaker_probes: 4,
            ..OverloadPolicy::none()
        };
        let mut g = guard(p);
        g.note_route(0);
        g.note_shed(Some(0), 0, ShedCause::Deadline);
        g.on_tick(10.0);
        assert_eq!(g.breaker_state(0), BreakerState::Open);
        g.on_tick(20.0);
        assert_eq!(g.breaker_state(0), BreakerState::HalfOpen);
        g.note_route(0);
        g.note_shed(Some(0), 0, ShedCause::Capacity);
        g.on_tick(30.0);
        assert_eq!(g.breaker_state(0), BreakerState::Open, "probe shed re-opens");
        assert_eq!(g.breaker_trips(), 2);
        // Run ends at t = 35 with the breaker still open: 30 → 35 counts.
        g.finish(35.0);
        assert!((g.breaker_open_s() - 15.0).abs() < 1e-12, "10→20 plus 30→35");
    }

    #[test]
    fn shed_counters_attribute_by_cause_and_class() {
        let p = OverloadPolicy { queue_cap: 1, ..OverloadPolicy::none() };
        let mut g = guard(p);
        g.note_shed(Some(0), 0, ShedCause::Capacity);
        g.note_shed(Some(1), 1, ShedCause::Deadline);
        g.note_shed(None, 2, ShedCause::Brownout);
        g.note_shed(None, 2, ShedCause::Brownout);
        assert_eq!(g.shed_capacity_per_class(), &[1, 0, 0]);
        assert_eq!(g.shed_deadline_per_class(), &[0, 1, 0]);
        assert_eq!(g.shed_brownout_per_class(), &[0, 0, 2]);
    }
}
