//! Model-based regression corpus for the fleet engine.
//!
//! Each named case pins one command sequence — the format the fuzz
//! shrinker emits (`migperf fuzz` prints failures in exactly this shape,
//! ready to paste here). A case passes when [`run_case`] returns `Ok`,
//! i.e. the real engine agreed with the live routing/brownout invariants
//! *and* the closed-form reference model on every check: extended
//! conservation (fleet and per tenant), exact arrival/crash/downtime
//! bookkeeping, mechanism-off zeros, bitwise-recomputable derived
//! metrics, telemetry reconciliation and brownout fairness order.
//!
//! The corpus deliberately covers the interleavings example tests miss:
//! a breaker cycling while a scripted repartition drains the same GPU, a
//! crash landing mid-brownout-escalation, a permanent outage under
//! deadline shedding, and back-to-back crash/recover/repartition churn.
//! Plus the harness's own contract: `run_fuzz` digests are
//! bitwise-identical at 1/2/4/16 workers.

use migperf::cluster::FleetOutcome;
use migperf::sweep::SweepEngine;
use migperf::testing::{run_case, run_fuzz, Command, CommandSeq};

/// Run a pinned sequence and require the engine to satisfy every
/// invariant; panics with the violations and a pasteable repro if not.
fn assert_clean(name: &str, seq: &CommandSeq) -> FleetOutcome {
    match run_case(seq) {
        Ok(out) => out,
        Err(f) => panic!(
            "pinned case '{name}' violated the model:\n{}\nrepro:\n{}",
            f.violations.join("\n"),
            migperf::testing::repro_string(&f.seq)
        ),
    }
}

#[test]
fn pinned_breaker_half_open_during_repartition() {
    // An ingress breaker under a tight queue bound and deadlines, pushed
    // by sustained two-class load, with a scripted repartition of the
    // same GPU landing while the breaker may be half-open — the
    // interleaving where a half-open probe grant could race the drain's
    // eligibility gate. The model must still see perfect conservation
    // and never-route-to-ineligible-GPU must hold at every decision.
    let seq = CommandSeq {
        seed: 101,
        commands: vec![
            Command::ResizeFleet { gpus: 2 },
            Command::SetOverload { queue_cap: 2, deadline_mult: 1.0, drop_oldest: true },
            Command::SetBreaker { threshold: 0.125, probes: 2 },
            Command::SetRolling { rolling: true },
            Command::ArriveBurst { class: 0, n: 200, over_s: 10.0 },
            Command::ArriveBurst { class: 1, n: 200, over_s: 10.0 },
            Command::AdvanceTime { dt_s: 6.0 },
            Command::Repartition { gpu: 0, rate_scale: 0.25 },
            Command::ArriveBurst { class: 0, n: 120, over_s: 8.0 },
            Command::AdvanceTime { dt_s: 12.0 },
            Command::Repartition { gpu: 0, rate_scale: 2.0 },
            Command::AdvanceTime { dt_s: 10.0 },
        ],
    };
    let compiled = seq.compile();
    let out = assert_clean("breaker-half-open × repartition", &seq);
    for (c, trace) in compiled.times.iter().enumerate() {
        assert_eq!(
            out.arrived_per_class[c] as usize,
            trace.len(),
            "class {c}: replay schedule fixes the exact arrival count"
        );
    }
    assert!(out.reconfigurations <= 2, "at most the two scripted repartitions execute");
    assert_eq!(out.unavailable_routes, 0, "rolling drains must divert, not enqueue");
    assert_eq!(out.gpu_crashes + out.instance_crashes, 0);
}

#[test]
fn pinned_crash_during_brownout_escalation() {
    // Skewed tenant weights and a low brownout threshold so shedding
    // pressure walks the ladder, then a whole-GPU crash in the middle of
    // the escalation and a recovery while load is still flowing. The
    // protected (highest-weight) tenant must end with zero brownout
    // shed, the ladder must move at most one level per tick, and the
    // crash bookkeeping must stay exact.
    let seq = CommandSeq {
        seed: 102,
        commands: vec![
            Command::ResizeFleet { gpus: 2 },
            Command::RetuneTenants { gold: 4.0, bronze: 0.5 },
            Command::SetOverload { queue_cap: 2, deadline_mult: 1.0, drop_oldest: false },
            Command::SetBrownout { threshold: 0.125 },
            Command::ArriveBurst { class: 0, n: 180, over_s: 12.0 },
            Command::ArriveBurst { class: 1, n: 180, over_s: 12.0 },
            Command::AdvanceTime { dt_s: 7.0 },
            Command::CrashGpu { gpu: 1 },
            Command::ArriveBurst { class: 1, n: 100, over_s: 6.0 },
            Command::AdvanceTime { dt_s: 9.0 },
            Command::Recover { gpu: 1 },
            Command::AdvanceTime { dt_s: 15.0 },
        ],
    };
    let out = assert_clean("crash during brownout escalation", &seq);
    assert_eq!(out.gpu_crashes, 1);
    assert_eq!(out.fault_log.len(), 1);
    assert!((out.downtime_s_per_gpu[1] - 9.0).abs() < 1e-9, "crash at 7, recover at 16");
    let gold = out.tenants.iter().find(|t| t.name == "gold").expect("gold tenant");
    assert_eq!(
        gold.shed_brownout, 0,
        "the highest-weight tenant is last in brownout order and never sheds"
    );
}

#[test]
fn pinned_permanent_crash_under_deadline_shedding() {
    // One GPU of two dies and never comes back while deadlines are
    // enforced: the survivor absorbs what it can, expired requests shed,
    // and anything stranded when the horizon closes must be accounted as
    // failed — conservation has to balance through all four terms.
    let seq = CommandSeq {
        seed: 103,
        commands: vec![
            Command::ResizeFleet { gpus: 2 },
            Command::SetOverload { queue_cap: 4, deadline_mult: 2.0, drop_oldest: false },
            Command::ArriveBurst { class: 0, n: 150, over_s: 10.0 },
            Command::AdvanceTime { dt_s: 4.0 },
            Command::CrashGpu { gpu: 0 },
            Command::ArriveBurst { class: 0, n: 150, over_s: 10.0 },
            Command::ArriveBurst { class: 1, n: 80, over_s: 10.0 },
            Command::AdvanceTime { dt_s: 20.0 },
        ],
    };
    let compiled = seq.compile();
    let out = assert_clean("permanent crash under deadline shedding", &seq);
    assert_eq!(out.gpu_crashes, 1);
    assert!(out.fault_log[0].down_s.is_infinite(), "no recover command: permanent outage");
    // Exact downtime: crash at t=4 pays out to the horizon.
    let expect = compiled.config.duration_s - 4.0;
    assert_eq!(out.downtime_s_per_gpu[0].to_bits(), expect.to_bits());
    assert!(out.availability < 1.0);
}

#[test]
fn pinned_crash_recover_repartition_churn() {
    // Back-to-back churn on one GPU: crash, recover, immediately
    // repartition, crash again — with an instance-level crash on the
    // sibling. Epoch staling, drain bookkeeping and the fault ledger all
    // have to stay consistent through the pile-up.
    let seq = CommandSeq {
        seed: 104,
        commands: vec![
            Command::ResizeFleet { gpus: 3 },
            Command::SetRouter { router: 3 },
            Command::ArriveBurst { class: 0, n: 160, over_s: 16.0 },
            Command::ArriveBurst { class: 1, n: 160, over_s: 16.0 },
            Command::AdvanceTime { dt_s: 3.0 },
            Command::CrashGpu { gpu: 0 },
            Command::CrashInstance { gpu: 1, class: 0 },
            Command::AdvanceTime { dt_s: 4.0 },
            Command::Recover { gpu: 0 },
            Command::Repartition { gpu: 0, rate_scale: 1.5 },
            Command::AdvanceTime { dt_s: 2.0 },
            Command::Recover { gpu: 1 },
            Command::CrashGpu { gpu: 0 },
            Command::AdvanceTime { dt_s: 5.0 },
            Command::Recover { gpu: 0 },
            Command::AdvanceTime { dt_s: 12.0 },
        ],
    };
    let out = assert_clean("crash/recover/repartition churn", &seq);
    assert_eq!(out.gpu_crashes, 2);
    assert_eq!(out.instance_crashes, 1);
    assert_eq!(out.fault_log.len(), 3);
    // GPU 0: down [3, 7) and [9, 14) → 9 s of downtime.
    assert!((out.downtime_s_per_gpu[0] - 9.0).abs() < 1e-9);
    // Instance crashes never count as GPU downtime.
    assert_eq!(out.downtime_s_per_gpu[1], 0.0);
}

#[test]
fn fuzz_report_is_bitwise_deterministic_across_worker_counts() {
    let serial = run_fuzz(24, 7, 16, &SweepEngine::new(1));
    assert!(
        serial.passed(),
        "fuzz smoke (24 cases, seed 7) found violations:\n{:#?}",
        serial.failures
    );
    for workers in [2usize, 4, 16] {
        let par = run_fuzz(24, 7, 16, &SweepEngine::new(workers));
        assert_eq!(
            par.digest, serial.digest,
            "fuzz digest must be bitwise-identical at {workers} workers"
        );
        assert!(par.passed());
    }
}
