//! System-level integration tests: coordinator → profiler → simulator →
//! metrics → export, exercised together as a user would.

use migperf::coordinator::{Client, Coordinator};
use migperf::frameworks::{run_serving_matrix, run_training_matrix};
use migperf::metrics::export;
use migperf::mig::gpu::GpuModel;
use migperf::mig::topology::Server;
use migperf::profiler::session::ProfileSession;
use migperf::profiler::task::{BenchTask, SweepAxis};
use migperf::util::json;
use migperf::workload::spec::WorkloadKind;

fn small_task(name: &str) -> BenchTask {
    BenchTask {
        name: name.into(),
        gpu: GpuModel::A30_24GB,
        gi_profiles: vec!["1g.6gb".into(), "2g.12gb".into(), "4g.24gb".into()],
        model: "resnet50".into(),
        kind: WorkloadKind::Inference,
        batch: 4,
        seq: 224,
        sweep: SweepAxis::Batch(vec![1, 4, 16]),
        iterations: 50,
        layout: Default::default(),
    }
}

#[test]
fn full_pipeline_task_to_csv() {
    // Task → session → report → CSV → parse back and sanity-check values.
    let report = ProfileSession::default().run(&small_task("pipeline")).unwrap();
    assert_eq!(report.rows().len(), 9);
    let rows: Vec<_> = report.rows().iter().map(|r| r.summary.clone()).collect();
    let csv = export::summaries_to_csv(&rows);
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 10);
    // Every data row has 12 comma-separated fields.
    for line in &lines[1..] {
        assert_eq!(line.split(',').count(), 12, "bad row: {line}");
    }
}

#[test]
fn full_pipeline_task_to_json_and_back() {
    let report = ProfileSession::default().run(&small_task("jsonpipe")).unwrap();
    let doc = report.to_json().to_pretty();
    let v = json::parse(&doc).unwrap();
    assert_eq!(v.get("task").unwrap().as_str(), Some("jsonpipe"));
    let rows = v.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 9);
    for r in rows {
        let s = r.get("summary").unwrap();
        assert!(s.get("throughput").unwrap().as_f64().unwrap() > 0.0);
    }
}

#[test]
fn coordinator_runs_paper_suite() {
    // A miniature of the paper's whole evaluation as one suite: training
    // characterization, inference characterization, on both servers.
    let mut coord = Coordinator::paper_testbed();
    let mut client = Client::new(&mut coord);
    let suite = r#"[
        {"name": "train-a100", "gpu": "a100",
         "gi_profiles": ["1g.10gb", "7g.80gb"],
         "model": "bert-base", "kind": "training",
         "batch_sweep": [8, 32], "seq": 128, "iterations": 20},
        {"name": "infer-a100", "gpu": "a100",
         "gi_profiles": ["1g.10gb", "7g.80gb"],
         "model": "bert-base", "kind": "inference",
         "batch_sweep": [1, 8], "seq": 128, "iterations": 20},
        {"name": "infer-a30", "gpu": "a30",
         "gi_profiles": ["1g.6gb"],
         "model": "resnet50", "kind": "inference",
         "batch_sweep": [1, 8], "seq": 224, "iterations": 20}
    ]"#;
    let ids = client.submit_suite_json(suite).unwrap();
    assert_eq!(ids.len(), 3);
    let out = client.collect_suite_json(&ids).unwrap();
    let parsed = json::parse(&out).unwrap();
    let reports = parsed.as_arr().unwrap();
    assert_eq!(reports.len(), 3);
    // Cross-report consistency: 7g must beat 1g on training throughput.
    let train = reports[0].get("rows").unwrap().as_arr().unwrap();
    let tput = |inst: &str, batch: i64| {
        train
            .iter()
            .find(|r| {
                r.get("instance").unwrap().as_str() == Some(inst)
                    && r.get("batch").unwrap().as_i64() == Some(batch)
            })
            .and_then(|r| r.get("summary").unwrap().get("throughput").unwrap().as_f64())
            .unwrap()
    };
    assert!(tput("7g.80gb", 32) > tput("1g.10gb", 32) * 2.0);
}

#[test]
fn compat_matrices_match_paper_tables() {
    let t1 = run_training_matrix();
    let t2 = run_serving_matrix();
    // Table 1 rows in paper order.
    let names: Vec<&str> = t1.iter().map(|r| r.framework).collect();
    assert_eq!(names, vec!["PyTorch", "TensorFlow", "MxNet", "PaddlePaddle"]);
    assert!(t1.iter().all(|r| r.works_on_mig0 && !r.works_on_mig1));
    let names2: Vec<&str> = t2.iter().map(|r| r.framework).collect();
    assert_eq!(
        names2,
        vec!["TensorFlow Serving", "Triton Inference Server", "Ray Serve"]
    );
    assert!(t2.iter().all(|r| r.works_on_mig0 && !r.works_on_mig1));
}

#[test]
fn paper_testbed_topology_boots() {
    let mut servers = Server::paper_testbed();
    // Partition every GPU of the A100 server into 7 small instances.
    let a100 = &mut servers[0];
    for i in 0..a100.spec.gpu_count as usize {
        let ctl = a100.gpu(i).unwrap();
        ctl.enable_mig().unwrap();
        ctl.partition_uniform("1g.10gb", 7).unwrap();
    }
    assert_eq!(a100.total_instances(), 56); // 8 GPUs × 7 GIs
}

#[test]
fn prometheus_export_from_training_series() {
    use migperf::simgpu::energy::EnergyModel;
    use migperf::simgpu::perfmodel::PerfModel;
    use migperf::simgpu::resource::ExecResource;
    use migperf::workload::spec::WorkloadSpec;
    use migperf::workload::training::{run_training, TrainingConfig};

    let gpu = GpuModel::A100_80GB;
    let p = migperf::mig::profile::lookup(gpu, "2g.20gb").unwrap();
    let res = ExecResource::from_gi(gpu, p);
    let spec = WorkloadSpec::training(migperf::models::zoo::lookup("bert-base").unwrap(), 32, 128);
    let _summary = run_training(
        &res,
        &spec,
        &TrainingConfig { steps: 50, sample_interval_s: 0.25 },
        &PerfModel::default(),
        &EnergyModel::default(),
    )
    .unwrap();
    // The collector's series live inside the summary path; rebuild a
    // sampler-driven set through the same API to exercise export.
    let mut sampler = migperf::metrics::dcgm::DcgmSampler::new("2g.20gb", 0.5);
    sampler.report(
        1.0,
        migperf::metrics::dcgm::InstantState { gract: 0.8, fb_bytes: 2e9, power_w: 150.0 },
    );
    let set = sampler.finish(2.0);
    let prom = export::series_to_prometheus(&set);
    assert!(prom.contains("# TYPE migperf_gract gauge"));
    assert!(prom.contains("instance=\"2g.20gb\""));
    let csv = export::series_to_csv(&set);
    assert!(csv.lines().count() > 3);
}

#[test]
fn oom_rows_survive_the_whole_pipeline() {
    // An OOM sweep point must surface as a skipped row all the way out to
    // the JSON report, not crash the coordinator.
    let mut coord = Coordinator::paper_testbed();
    let mut client = Client::new(&mut coord);
    let id = client
        .submit_json(
            r#"{"name": "oom", "gpu": "a100", "gi_profiles": ["1g.10gb"],
                "model": "bert-large", "kind": "training",
                "batch_sweep": [8, 256], "seq": 128, "iterations": 10}"#,
        )
        .unwrap();
    let report = client.collect(id).unwrap();
    assert_eq!(report.rows().len(), 2);
    assert!(report.rows()[0].skipped.is_none(), "batch 8 fits");
    assert!(report.rows()[1].skipped.is_some(), "batch 256 OOMs");
    let doc = report.to_json().to_string();
    assert!(doc.contains("out of memory"));
}

#[test]
fn cli_binary_smoke() {
    // Run the actual binary for the compat and profiles commands.
    let bin = env!("CARGO_BIN_EXE_migperf");
    let out = std::process::Command::new(bin).args(["compat"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table 1"));
    assert!(text.contains("PyTorch"));
    assert!(text.contains("Device not found"));

    let out = std::process::Command::new(bin)
        .args(["profiles", "--gpu", "a30"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("1g.6gb"));

    let out = std::process::Command::new(bin)
        .args(["partition", "--gpu", "a100", "--gi", "4g.40gb,3g.40gb"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "excluded combination must fail the CLI");

    let out = std::process::Command::new(bin)
        .args([
            "bench", "--gpu", "a30", "--model", "resnet18", "--gi", "1g.6gb", "--batch", "1,4",
            "--iters", "10", "--csv",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).starts_with("label,"));
}
