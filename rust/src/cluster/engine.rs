//! The fleet simulation engine.
//!
//! Runs N MIG-partitioned GPUs inside one discrete-event simulation:
//! fleet-wide request classes arrive on aggregate streams, a routing
//! policy ([`Router`]) dispatches each request to one GPU's replica, and
//! a fleet policy ([`super::policy::FleetPolicyImpl`]) decides per
//! observation window *which GPU* to repartition. Two reconfiguration
//! disciplines are modelled:
//!
//! * **rolling** — the chosen GPU stops taking traffic, its queued
//!   requests migrate to sibling GPUs, and only in-flight work drains
//!   before the instance churn; the fleet keeps serving while one member
//!   reconfigures (zero-downtime from the requests' point of view);
//! * **in-place** — the single-GPU discipline applied blindly at fleet
//!   scale: the router keeps dispatching to the reconfiguring GPU and
//!   every queued request waits out drain → churn → resume.
//!
//! The difference is the bench headline: at a diurnal peak, rolling
//! repartition strictly lowers the SLO-violation fraction because the
//! downtime is amortized across siblings instead of being paid by queued
//! requests. Everything is seeded and iteration-order deterministic, so
//! fleet runs are bit-identical at any sweep worker count.
//!
//! On top of reconfiguration the engine injects *failures* from the
//! [`FaultPlan`](super::faults::FaultPlan) in the config: whole-GPU and
//! per-replica crashes dump their queued and in-flight requests, which
//! are retried through the router within a per-request budget (keeping
//! their original arrival timestamps, so latency spans the outage),
//! shed by the retry-storm guard, or lost outright. The router's health
//! check ([`GpuHealth`]) excludes crashed GPUs in both repartition
//! disciplines, crashes abort any repartition in progress on the victim,
//! and policy proposals pause while any GPU is down (reconfigurations
//! only roll through a fully-serving fleet).
//!
//! The ingress is additionally protected by the overload layer
//! ([`OverloadPolicy`](super::overload::OverloadPolicy)): per-request
//! deadlines derived from each class's SLO, bounded per-replica queues
//! with reject-newest/drop-oldest shedding, tenant-weighted brownout
//! under fleet-wide pressure, and per-GPU ingress circuit breakers that
//! compose with the crash health states. Request conservation extends
//! across the crash and shed paths: `completed + failed_requests +
//! lost_in_crash + shed_overload = arrived`, pinned by
//! `tests/fleet_properties.rs`. Because the crash schedule and the
//! overload policy are part of the config, faulted and shedding sweeps
//! stay bit-identical at any worker count.
//!
//! The hot path is arena-backed: live requests park their fields in the
//! run's [`ReqArena`] (structure-of-arrays columns indexed by `u32`
//! handles, slots recycled through a free list) and every replica and
//! stranded queue holds handles, so routing, migration, crash retries
//! and shedding move 4-byte indices instead of 32-byte structs and a
//! steady-state run performs no per-request heap allocation. Arrival
//! streams, the router and the fleet policy are enum-dispatched
//! ([`ArrivalProcess`], [`Router`]) — no boxed-trait indirection in the
//! per-event loop.

use std::collections::VecDeque;

use crate::metrics::collector::{MetricsCollector, RunSummary};
use crate::mig::enumerate::Layout;
use crate::mig::gpu::GpuModel;
use crate::mig::placement::PlacementEngine;
use crate::orchestrator::{churn, ReconfigCost, ServiceObs};
use crate::scheduler::{
    plan_fleet_for_demand, plan_fleet_for_demand_weighted, DemandWorkload, RatePlan, Scheduler,
};
use crate::simgpu::desim::Des;
use crate::simgpu::perfmodel::{PerfError, StepEstimate};
use crate::simgpu::resource::ExecResource;
use crate::util::prng::Prng;
use crate::util::stats::percentile_sorted;
use crate::workload::arrival::{ArrivalError, ArrivalProcess, ArrivalSpec};
use crate::workload::spec::WorkloadSpec;

use super::faults::{FaultPlan, FaultRecord};
use super::overload::{BreakerState, OverloadGuard, OverloadPolicy, ShedCause, ShedDiscipline};
use super::policy::{FleetCtx, FleetObs, FleetPolicyKind, GpuObs};
use super::router::{GpuHealth, Router, RouterKind};
use super::telemetry::{FleetRecorder, FleetTelemetry, TelemetryConfig};
use super::tenancy::{jain_index, tenant_of_classes, validate_tenants, Tenant, TenantOutcome};

/// One fleet-wide request class: a workload, its SLO, and the aggregate
/// arrival stream the router spreads across the fleet.
#[derive(Debug, Clone)]
pub struct RequestClass {
    /// The per-request workload.
    pub spec: WorkloadSpec,
    /// Latency SLO, milliseconds.
    pub slo_ms: f64,
    /// Fleet-wide arrival process driving the class.
    pub arrival: ArrivalSpec,
}

/// How a GPU repartition is executed at fleet level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepartitionMode {
    /// Drain one GPU while its traffic migrates to siblings.
    Rolling,
    /// Keep routing to the GPU; queued requests wait out the churn.
    InPlace,
}

impl RepartitionMode {
    /// Report name of the mode.
    pub fn name(&self) -> &'static str {
        match self {
            RepartitionMode::Rolling => "rolling",
            RepartitionMode::InPlace => "in-place",
        }
    }

    /// Parse a mode name.
    pub fn parse(s: &str) -> Option<RepartitionMode> {
        match s.to_ascii_lowercase().as_str() {
            "rolling" | "roll" => Some(RepartitionMode::Rolling),
            "inplace" | "in-place" => Some(RepartitionMode::InPlace),
            _ => None,
        }
    }
}

/// A complete fleet simulation (plain data: clone freely into sweep
/// grids).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// The fleet, possibly heterogeneous, in fleet order.
    pub gpus: Vec<GpuModel>,
    /// Best-effort training job replicated onto every GPU, if any.
    pub train: Option<WorkloadSpec>,
    /// The request classes served fleet-wide.
    pub classes: Vec<RequestClass>,
    /// Tenants grouping the request classes under SLO weights. Empty
    /// means the implicit default — one tenant per class at weight 1 —
    /// which keeps demand splitting and planning exactly as before and
    /// only adds per-tenant accounting to the outcome. A non-empty set
    /// must partition the classes exactly (validated) and additionally
    /// switches the demand planners to the tenant-weighted split.
    pub tenants: Vec<Tenant>,
    /// Request routing policy.
    pub router: RouterKind,
    /// Fleet repartitioning policy.
    pub policy: FleetPolicyKind,
    /// Reconfiguration discipline.
    pub mode: RepartitionMode,
    /// Reconfiguration cost model.
    pub cost: ReconfigCost,
    /// Simulated run length, seconds.
    pub duration_s: f64,
    /// Observation-window length (policy tick period), seconds.
    pub window_s: f64,
    /// Utilization bound the planner sizes replicas for (ρ_max).
    pub rho_max: f64,
    /// Failure-injection schedule and ingress retry policy
    /// ([`FaultPlan::none`] for a fault-free run).
    pub faults: FaultPlan,
    /// SLO-aware overload protection: deadlines, bounded queues,
    /// brownout and ingress breakers ([`OverloadPolicy::none`] disables
    /// everything and keeps the engine byte-identical to the
    /// unprotected path).
    pub overload: OverloadPolicy,
    /// Observability: windowed time-series, DCGM counter timelines and
    /// sampled lifecycle spans ([`TelemetryConfig::off`] disables
    /// everything; the recorder is strictly observational either way,
    /// so the simulation results are identical on and off).
    pub telemetry: TelemetryConfig,
    /// PRNG seed (class arrival streams derive per-class seeds from it).
    pub seed: u64,
}

/// Why a fleet run failed.
#[derive(Debug)]
pub enum FleetError {
    /// Configuration rejected before the simulation started.
    Invalid(String),
    /// No valid per-GPU layouts can host the workloads.
    Infeasible(String),
    /// An arrival process could not be constructed.
    Arrival(ArrivalError),
    /// A workload failed to fit its assigned instance.
    Perf(PerfError),
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Invalid(m) => write!(f, "invalid fleet config: {m}"),
            FleetError::Infeasible(m) => write!(f, "infeasible: {m}"),
            FleetError::Arrival(e) => write!(f, "arrival process: {e}"),
            FleetError::Perf(e) => write!(f, "performance model: {e}"),
        }
    }
}

impl std::error::Error for FleetError {}

impl From<ArrivalError> for FleetError {
    fn from(e: ArrivalError) -> Self {
        FleetError::Arrival(e)
    }
}

impl From<PerfError> for FleetError {
    fn from(e: PerfError) -> Self {
        FleetError::Perf(e)
    }
}

/// One fleet repartitioning event in the decision log.
#[derive(Debug, Clone)]
pub struct FleetDecision {
    /// Time the policy decided to repartition (simulated seconds).
    pub t: f64,
    /// Fleet index of the repartitioned GPU.
    pub gpu: usize,
    /// Layout before the switch (`+`-joined profile names).
    pub from: String,
    /// Layout after the switch.
    pub to: String,
    /// Window observation that motivated the move.
    pub reason: String,
    /// Instances destroyed plus created by the switch.
    pub churn: u32,
    /// Seconds from decision to resume (drain + instance churn).
    pub downtime_s: f64,
    /// Queued requests migrated to sibling GPUs at drain start (rolling).
    pub migrated: u64,
}

/// Aggregate result of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// Policy that produced the run.
    pub policy: &'static str,
    /// Router that spread the traffic.
    pub router: &'static str,
    /// Reconfiguration discipline.
    pub mode: RepartitionMode,
    /// Number of GPUs in the fleet.
    pub fleet_size: usize,
    /// Simulated run length, seconds.
    pub duration_s: f64,
    /// Fleet-pooled serving summary (exact pooled percentiles).
    pub pooled: RunSummary,
    /// Per-class summaries pooled across GPUs.
    pub per_class: Vec<RunSummary>,
    /// Per-GPU summaries pooled across classes.
    pub per_gpu: Vec<RunSummary>,
    /// Requests that arrived within the horizon.
    pub arrived: u64,
    /// Per-class arrivals, in class order.
    pub arrived_per_class: Vec<u64>,
    /// Requests the router placed directly on arrival (each counted
    /// once; the rest waited at the fleet ingress until a GPU resumed,
    /// and queued requests displaced by a rolling drain keep their
    /// original count).
    pub routed: u64,
    /// Requests completed (including backlog served after the horizon).
    pub completed: u64,
    /// Completions that blew their SLO.
    pub slo_violations: u64,
    /// SLO-respecting completions per second over the run (requests/s).
    pub goodput_rps: f64,
    /// Fraction of completions that blew their SLO.
    pub slo_violation_frac: f64,
    /// Per-tenant accounting, in tenant order (when the config declares
    /// no tenants, one implicit tenant per class at weight 1).
    pub tenants: Vec<TenantOutcome>,
    /// Jain's fairness index over weight-normalized tenant goodput
    /// (`goodput_t / weight_t`): 1 is perfectly weighted-fair, `1/n` is
    /// maximally unfair.
    pub fairness_jain: f64,
    /// Training steps completed across the fleet.
    pub train_steps: u64,
    /// Training throughput across the fleet, samples/s.
    pub train_samples_per_s: f64,
    /// Number of repartitions executed.
    pub reconfigurations: u64,
    /// Total per-GPU downtime paid to repartitions, seconds.
    pub reconfig_downtime_s: f64,
    /// Queued requests migrated to siblings at drain starts (rolling).
    pub migrated_requests: u64,
    /// Requests that waited at the fleet ingress because no GPU could
    /// accept them (only possible in rolling mode with every GPU down).
    pub stranded_requests: u64,
    /// Requests enqueued on a GPU that was draining or reconfiguring
    /// (only possible in in-place mode; zero under rolling).
    pub unavailable_routes: u64,
    /// Requests that terminally failed: shed by the retry-storm guard or
    /// still stranded at the fleet ingress when the run ended (possible
    /// only under permanent failures).
    pub failed_requests: u64,
    /// Crash-dumped requests re-admitted at the ingress (each re-admission
    /// counts once; a request crashed twice counts twice).
    pub retried_requests: u64,
    /// Requests dumped by a crash with their retry budget exhausted.
    pub lost_in_crash: u64,
    /// Requests shed by the overload layer, total
    /// (`shed_deadline + shed_capacity + shed_brownout`); the fourth
    /// term of the conservation invariant.
    pub shed_overload: u64,
    /// Requests shed at dispatch because their deadline had expired
    /// (expired requests are never served).
    pub shed_deadline: u64,
    /// Requests shed by the bounded-queue discipline (reject-newest or
    /// drop-oldest).
    pub shed_capacity: u64,
    /// Requests shed at the fleet ingress while their tenant was
    /// browned out.
    pub shed_brownout: u64,
    /// Ingress circuit-breaker trips (transitions into open).
    pub breaker_trips: u64,
    /// Total seconds ingress breakers spent open, summed over GPUs and
    /// clamped to the horizon.
    pub breaker_open_s: f64,
    /// Whole-GPU crashes executed.
    pub gpu_crashes: u64,
    /// Instance-level (single-replica) crashes executed.
    pub instance_crashes: u64,
    /// Per-GPU seconds spent crashed within the nominal horizon
    /// `[0, duration_s]` (whole-GPU crashes only; instance crashes do not
    /// count as GPU downtime), in fleet order.
    pub downtime_s_per_gpu: Vec<f64>,
    /// Fleet availability over the horizon:
    /// `1 − Σ downtime / (fleet size × duration)`.
    pub availability: f64,
    /// Discrete events the simulator processed over the run (arrivals,
    /// completions, ticks, faults — everything popped off the calendar).
    /// Deterministic per config and seed.
    pub events_processed: u64,
    /// Simulator throughput: `events_processed` divided by the host
    /// wall-clock seconds the run took. Wall-derived, so it varies
    /// between machines and runs — excluded from every determinism
    /// fingerprint, checksum and regression comparison.
    pub events_per_sec: f64,
    /// Executed fault timeline, in crash order.
    pub fault_log: Vec<FaultRecord>,
    /// Every layout each GPU adopted, in order (initial layout first).
    pub layouts: Vec<Vec<Layout>>,
    /// Per-repartition decision log.
    pub decisions: Vec<FleetDecision>,
    /// Observability payload (windowed series + sampled spans); `None`
    /// when the run's [`TelemetryConfig`] was off.
    pub telemetry: Option<FleetTelemetry>,
}

/// Completion and reconfiguration events carry the epoch they were
/// scheduled under; a crash bumps the victim's epoch, so in-flight events
/// for work the crash destroyed arrive stale and are ignored.
#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive { class: usize },
    ServeDone { gpu: usize, class: usize, epoch: u64 },
    TrainDone { gpu: usize, epoch: u64 },
    Tick,
    ReconfigDone { gpu: usize, epoch: u64 },
    Crash { fault: usize },
    Recover { fault: usize },
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    Running,
    Draining,
    Reconfiguring,
    Down,
}

/// One queued request: its monotone arrival id (telemetry span key and
/// trace-sampling anchor; stable across retries and migrations), its
/// original arrival time (never re-stamped, so queueing latency spans
/// outages), how many crash retries it has already consumed, and its
/// SLO-derived deadline (`INFINITY` when deadlines are disabled; stamped
/// once at arrival, so it survives migration, stranding and crash
/// retries).
#[derive(Debug, Clone, Copy)]
struct Req {
    id: u64,
    arrived: f64,
    tries: u32,
    deadline: f64,
}

/// Slab-allocated request arena: the hot fields of every live request
/// live in structure-of-arrays columns indexed by a `u32` handle, and
/// the replica / stranded queues hold handles instead of `Req` values.
/// Slots are recycled through a free list when a request leaves the
/// system (completed, shed, lost or failed), so the columns grow to the
/// peak number of in-flight requests — not the total arrival count —
/// and the steady-state hot path performs no per-request allocation.
///
/// Tenant and epoch are deliberately not columns: a request's tenant is
/// a pure function of its class (`tenant_of[class]`), and epochs belong
/// to replicas/GPUs, not requests.
#[derive(Debug, Default)]
struct ReqArena {
    id: Vec<u64>,
    arrived: Vec<f64>,
    deadline: Vec<f64>,
    tries: Vec<u32>,
    free: Vec<u32>,
}

impl ReqArena {
    /// Park a request in the arena, reusing a released slot when one is
    /// available.
    fn alloc(&mut self, req: Req) -> u32 {
        match self.free.pop() {
            Some(h) => {
                let i = h as usize;
                self.id[i] = req.id;
                self.arrived[i] = req.arrived;
                self.deadline[i] = req.deadline;
                self.tries[i] = req.tries;
                h
            }
            None => {
                let h = u32::try_from(self.id.len()).expect("more than u32::MAX live requests");
                self.id.push(req.id);
                self.arrived.push(req.arrived);
                self.deadline.push(req.deadline);
                self.tries.push(req.tries);
                h
            }
        }
    }

    fn id(&self, h: u32) -> u64 {
        self.id[h as usize]
    }

    fn arrived(&self, h: u32) -> f64 {
        self.arrived[h as usize]
    }

    fn deadline(&self, h: u32) -> f64 {
        self.deadline[h as usize]
    }

    fn tries(&self, h: u32) -> u32 {
        self.tries[h as usize]
    }

    /// Copy one request's fields back out of the columns.
    #[cfg(test)]
    fn req(&self, h: u32) -> Req {
        let i = h as usize;
        Req {
            id: self.id[i],
            arrived: self.arrived[i],
            tries: self.tries[i],
            deadline: self.deadline[i],
        }
    }

    /// Consume one crash retry in place: the handle, id, arrival stamp
    /// and deadline all survive (a crash does not buy extra SLO time).
    fn bump_tries(&mut self, h: u32) {
        self.tries[h as usize] += 1;
    }

    /// Return a slot to the free list once the request leaves the
    /// system. The caller must not use the handle again.
    fn release(&mut self, h: u32) {
        self.free.push(h);
    }
}

#[derive(Debug)]
struct Replica {
    queue: VecDeque<u32>, // ReqArena handles; front = in service when busy
    busy: bool,
    busy_since: f64,
    /// Crashed by an instance-level fault; excluded from routing until
    /// the fault recovers.
    down: bool,
    /// Bumped when a crash aborts the in-flight request, staling its
    /// pending `ServeDone`.
    epoch: u64,
    window_arrivals: u64,
    window_completed: u64,
    window_violations: u64,
    window_busy_s: f64,
    window_lat: Vec<f64>,
}

impl Replica {
    fn new() -> Replica {
        Replica {
            queue: VecDeque::new(),
            busy: false,
            busy_since: 0.0,
            down: false,
            epoch: 0,
            window_arrivals: 0,
            window_completed: 0,
            window_violations: 0,
            window_busy_s: 0.0,
            window_lat: Vec::new(),
        }
    }
}

#[derive(Debug)]
struct PendingReconfig {
    plan: RatePlan,
    decided_t: f64,
    reason: String,
    migrated: u64,
}

#[derive(Debug)]
struct GpuState {
    phase: Phase,
    replicas: Vec<Replica>, // class order
    train_busy: bool,
    /// Bumped when a crash aborts the in-flight training step.
    train_epoch: u64,
    /// Bumped when a crash aborts an in-flight reconfiguration.
    reconfig_epoch: u64,
    window_train_steps: u64,
    svc_est: Vec<StepEstimate>,
    svc_power: Vec<f64>,
    train_est: Option<StepEstimate>,
    /// Power draw of the training instance under the current layout, W
    /// (0 when no training job; feeds the train DCGM POWER series).
    train_power: f64,
    pending: Option<PendingReconfig>,
}

impl GpuState {
    /// Project the internal lifecycle onto the router's health view.
    fn health(&self) -> GpuHealth {
        match self.phase {
            Phase::Running => GpuHealth::Serving,
            Phase::Draining => GpuHealth::Draining,
            Phase::Reconfiguring => GpuHealth::Reconfiguring,
            Phase::Down => GpuHealth::Down,
        }
    }
}

/// Read-only probe into the engine's live state, handed to an
/// [`EngineInspector`] at each hook point. Everything here is a
/// borrowed view — the probe cannot mutate the simulation, so an
/// inspector can never change an outcome (the bitwise-determinism
/// contract extends to inspected runs).
pub struct EngineProbe<'a> {
    gpus: &'a [GpuState],
    guard: &'a OverloadGuard,
    mode: RepartitionMode,
}

impl EngineProbe<'_> {
    /// Fleet size.
    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// Reconfiguration discipline of the run.
    pub fn mode(&self) -> RepartitionMode {
        self.mode
    }

    /// The router's health view of one GPU.
    pub fn gpu_health(&self, g: usize) -> GpuHealth {
        self.gpus[g].health()
    }

    /// True while the replica is crashed by an instance-level fault.
    pub fn replica_down(&self, g: usize, class: usize) -> bool {
        self.gpus[g].replicas[class].down
    }

    /// True while the replica serves an in-flight request.
    pub fn replica_busy(&self, g: usize, class: usize) -> bool {
        self.gpus[g].replicas[class].busy
    }

    /// Current queue length of one replica (front = in service when
    /// busy).
    pub fn queue_depth(&self, g: usize, class: usize) -> usize {
        self.gpus[g].replicas[class].queue.len()
    }

    /// The ingress breaker's admission verdict for one GPU.
    pub fn gpu_admits(&self, g: usize) -> bool {
        self.guard.gpu_admits(g)
    }

    /// One GPU's ingress breaker state.
    pub fn breaker_state(&self, g: usize) -> BreakerState {
        self.guard.breaker_state(g)
    }

    /// Current brownout ladder level (number of browned-out tenants).
    pub fn brownout_level(&self) -> usize {
        self.guard.brownout_level()
    }

    /// The exact routing-eligibility predicate `route_request` uses:
    /// health-gated (crashed GPUs and replicas excluded; under rolling,
    /// draining/reconfiguring GPUs too) AND-ed with the ingress breaker.
    pub fn may_route(&self, g: usize, class: usize) -> bool {
        let inplace = self.mode == RepartitionMode::InPlace;
        self.gpus[g].health().may_route(inplace, self.gpus[g].replicas[class].down)
            && self.guard.gpu_admits(g)
    }
}

/// Read-only observer of a fleet run, for invariant checkers and the
/// model-based testing harness. Every hook defaults to a no-op; the
/// engine calls them with a borrowed [`EngineProbe`], so inspectors can
/// assert on live state but never steer the simulation.
pub trait EngineInspector {
    /// A request of `class` was routed to `gpu` — called right after the
    /// router chose the destination and *before* any breaker/queue
    /// bookkeeping, so the probe shows the state the decision was made
    /// against. Covers every dispatch path: arrivals, drain migration,
    /// crash retries and stranded re-dispatch.
    fn on_route(&mut self, _t: f64, _gpu: usize, _class: usize, _probe: &EngineProbe) {}
    /// A window tick fired (after the overload guard advanced its
    /// breaker/brownout state machines for the closing window).
    fn on_tick(&mut self, _t: f64, _probe: &EngineProbe) {}
    /// A crash executed on `gpu` (`class: None` = whole GPU), after its
    /// queues were dumped and retries re-dispatched.
    fn on_crash(&mut self, _t: f64, _gpu: usize, _class: Option<usize>, _probe: &EngineProbe) {}
    /// A recovery executed on `gpu` (`class: None` = whole GPU), after
    /// stranded re-dispatch and the defensive restart.
    fn on_recover(&mut self, _t: f64, _gpu: usize, _class: Option<usize>, _probe: &EngineProbe) {}
}

/// The default inspector: observes nothing.
pub struct NoopInspector;

impl EngineInspector for NoopInspector {}

/// Move the queue head into service. `est`/`power_w` are the replica's
/// current step estimate and power draw (copied out by the caller to
/// avoid aliasing the GPU state); the telemetry recorder observes the
/// serve-start and drives the instance's DCGM counters busy.
#[allow(clippy::too_many_arguments)] // DES plumbing, not an API
fn start_replica(
    des: &mut Des<Ev>,
    r: &mut Replica,
    arena: &ReqArena,
    tel: &mut FleetRecorder,
    gpu: usize,
    class: usize,
    now: f64,
    est: StepEstimate,
    power_w: f64,
) {
    debug_assert!(!r.busy, "replica g{gpu}c{class} already busy");
    debug_assert!(!r.down, "replica g{gpu}c{class} is crashed");
    r.busy = true;
    r.busy_since = now;
    des.schedule_in(est.seconds, Ev::ServeDone { gpu, class, epoch: r.epoch });
    let head = r.queue.front().map_or(0, |&h| arena.id(h));
    tel.on_serve_start(now, head, gpu, class, est, power_w);
}

/// Drain barrier for one GPU: once every replica and the training job are
/// idle (and a repartition is pending), the instance churn begins and
/// `ReconfigDone` is scheduled.
fn maybe_begin_reconfig(
    des: &mut Des<Ev>,
    gs: &mut GpuState,
    gpu: usize,
    current: &Layout,
    cost: &ReconfigCost,
) {
    let Some(pend) = &gs.pending else { return };
    if gs.phase == Phase::Draining && !gs.train_busy && gs.replicas.iter().all(|r| !r.busy) {
        gs.phase = Phase::Reconfiguring;
        des.schedule_in(
            cost.latency_s(current, &pend.plan.layout),
            Ev::ReconfigDone { gpu, epoch: gs.reconfig_epoch },
        );
    }
}

/// Ask the router for a destination GPU under the configured discipline.
/// Availability runs through the [`GpuHealth`] check, so crashed GPUs and
/// crashed replicas are excluded in both disciplines, AND-ed with the
/// overload guard's per-GPU ingress breakers. `available`/`depth` are
/// caller-owned scratch buffers (refilled here), so the DES hot path
/// performs no per-event heap allocation.
fn route_request(
    router: &mut Router,
    gpus_state: &[GpuState],
    mode: RepartitionMode,
    class: usize,
    guard: &OverloadGuard,
    available: &mut Vec<bool>,
    depth: &mut Vec<usize>,
) -> Option<usize> {
    available.clear();
    depth.clear();
    let inplace = mode == RepartitionMode::InPlace;
    for (g, gs) in gpus_state.iter().enumerate() {
        available
            .push(gs.health().may_route(inplace, gs.replicas[class].down) && guard.gpu_admits(g));
        depth.push(gs.replicas[class].queue.len());
    }
    router.route(class, available, depth)
}

/// Dump one replica's queued and in-flight requests at a crash, staling
/// any pending `ServeDone` and crediting the partial busy time to the
/// window counters. The recorder marks the in-flight head stale and
/// zeroes the instance's DCGM counters.
fn flush_replica(
    r: &mut Replica,
    arena: &ReqArena,
    tel: &mut FleetRecorder,
    gpu: usize,
    class: usize,
    now: f64,
    dumped: &mut Vec<(usize, u32)>,
) {
    if r.busy {
        r.window_busy_s += now - r.busy_since;
        r.busy = false;
        r.epoch += 1;
        if let Some(&head) = r.queue.front() {
            tel.on_stale(now, arena.id(head), class, gpu);
        }
    }
    tel.on_replica_down(now, gpu, class);
    for req in r.queue.drain(..) {
        dumped.push((class, req));
    }
}

/// How one dispatch attempt ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dispatch {
    /// Enqueued on the given GPU (it may still be deadline-shed later,
    /// at the moment it would enter service).
    Placed(usize),
    /// No replica may take the class; the caller strands the request.
    Stranded,
    /// Shed by the bounded-queue discipline; already counted by the
    /// guard, the request leaves the system here.
    Shed,
}

/// Deadline expiry at dispatch: pop expired requests off the front of an
/// *idle* replica's queue — they are shed, never served. The in-service
/// head is exempt by construction (callers only filter idle replicas,
/// right before starting service).
fn shed_expired(
    guard: &mut OverloadGuard,
    arena: &mut ReqArena,
    r: &mut Replica,
    tel: &mut FleetRecorder,
    gpu: usize,
    class: usize,
    now: f64,
) {
    if !guard.deadlines_enabled() {
        return;
    }
    debug_assert!(!r.busy, "deadline filter on a busy replica g{gpu}c{class}");
    while let Some(&front) = r.queue.front() {
        if arena.deadline(front) < now {
            let expired = r.queue.pop_front().expect("front exists");
            guard.note_shed(Some(gpu), class, ShedCause::Deadline);
            tel.on_shed(now, arena.id(expired), class, Some(gpu), ShedCause::Deadline);
            arena.release(expired);
        } else {
            break;
        }
    }
}

/// Route one request and enqueue it on the chosen GPU, starting the
/// replica when it is idle and serving. This is the single dispatch rule
/// shared by arrivals, drain migration, crash retries and stranded
/// re-dispatch, and the overload guard's capacity bound and deadline
/// expiry apply on every one of those paths.
///
/// `req` is an arena handle. On [`Dispatch::Shed`] the request left the
/// system and its slot is released here; on [`Dispatch::Stranded`] the
/// caller keeps the handle (and parks it in a stranded queue).
#[allow(clippy::too_many_arguments)] // DES plumbing, not an API
fn dispatch_req(
    des: &mut Des<Ev>,
    router: &mut Router,
    gpus_state: &mut [GpuState],
    mode: RepartitionMode,
    guard: &mut OverloadGuard,
    tel: &mut FleetRecorder,
    insp: &mut dyn EngineInspector,
    arena: &mut ReqArena,
    class: usize,
    req: u32,
    now: f64,
    available: &mut Vec<bool>,
    depth: &mut Vec<usize>,
) -> Dispatch {
    let Some(g) = route_request(router, gpus_state, mode, class, guard, available, depth) else {
        return Dispatch::Stranded;
    };
    // Observe before `note_route` mutates the guard (a half-open breaker
    // consumes a probe there): the inspector sees exactly the state the
    // routing decision was made against.
    insp.on_route(now, g, class, &EngineProbe { gpus: &*gpus_state, guard: &*guard, mode });
    guard.note_route(g);
    tel.on_route(now, arena.id(req), class, g);
    let gs = &mut gpus_state[g];
    let cap = guard.queue_cap();
    if cap > 0 && gs.replicas[class].queue.len() >= cap {
        guard.note_shed(Some(g), class, ShedCause::Capacity);
        match guard.discipline() {
            ShedDiscipline::RejectNewest => {
                tel.on_shed(now, arena.id(req), class, Some(g), ShedCause::Capacity);
                arena.release(req);
                return Dispatch::Shed;
            }
            ShedDiscipline::DropOldest => {
                // front = in service when busy: drop the oldest *waiting*
                // request. A cap-1 queue whose head is in service has
                // nothing waiting, so the newcomer is rejected instead.
                let drop_at = usize::from(gs.replicas[class].busy);
                if drop_at < gs.replicas[class].queue.len() {
                    let victim =
                        gs.replicas[class].queue.remove(drop_at).expect("index checked");
                    tel.on_shed(now, arena.id(victim), class, Some(g), ShedCause::Capacity);
                    arena.release(victim);
                } else {
                    tel.on_shed(now, arena.id(req), class, Some(g), ShedCause::Capacity);
                    arena.release(req);
                    return Dispatch::Shed;
                }
            }
        }
    }
    gs.replicas[class].queue.push_back(req);
    tel.on_enqueue(now, arena.id(req), class, g);
    if gs.phase == Phase::Running && !gs.replicas[class].busy {
        // The queue may hold work that waited out a drain or an outage;
        // expired entries are shed before anything enters service. The
        // newcomer cannot be older than its own deadline at arrival, but
        // re-dispatched (migrated/retried/stranded) requests can.
        shed_expired(guard, arena, &mut gs.replicas[class], tel, g, class, now);
        if !gs.replicas[class].queue.is_empty() {
            let est = gs.svc_est[class];
            let power_w = gs.svc_power[class];
            start_replica(des, &mut gs.replicas[class], arena, tel, g, class, now, est, power_w);
        }
    }
    Dispatch::Placed(g)
}

/// Merge the per-class stranded queues into one globally oldest-first
/// dispatch order, ties broken by the lowest class index. The queues are
/// drained; callers re-enqueue whatever they cannot dispatch.
///
/// Ordering matters: re-dispatch used to run class by class in class
/// index order, so after a recovery class 0's *whole* backlog jumped
/// ahead of older class-1 requests — a low-index class could starve a
/// higher-index one out of every capacity-return event. (A class queue
/// is also not internally sorted: crash retries append old-timestamp
/// requests behind younger stranded arrivals, so the sort is needed
/// within classes too.)
fn stranded_dispatch_order(stranded: &mut [VecDeque<u32>], arena: &ReqArena) -> Vec<(usize, u32)> {
    let total: usize = stranded.iter().map(|q| q.len()).sum();
    let mut merged: Vec<(usize, u32)> = Vec::with_capacity(total);
    for (c, q) in stranded.iter_mut().enumerate() {
        merged.extend(q.drain(..).map(|req| (c, req)));
    }
    merged.sort_by(|a, b| arena.arrived(a.1).total_cmp(&arena.arrived(b.1)).then(a.0.cmp(&b.0)));
    merged
}

/// Re-dispatch requests stranded at the fleet ingress, globally oldest
/// first across classes (ties to the lowest class index). A class whose
/// dispatch fails is blocked for the rest of the pass — availability
/// cannot change mid-drain, and requests behind the failure must not
/// overtake it — while other classes keep draining. Called whenever
/// capacity returns (a reconfiguration completes or a crash recovers).
#[allow(clippy::too_many_arguments)] // DES plumbing, not an API
fn drain_stranded(
    des: &mut Des<Ev>,
    router: &mut Router,
    gpus_state: &mut [GpuState],
    mode: RepartitionMode,
    guard: &mut OverloadGuard,
    tel: &mut FleetRecorder,
    insp: &mut dyn EngineInspector,
    arena: &mut ReqArena,
    stranded: &mut [VecDeque<u32>],
    t: f64,
    available: &mut Vec<bool>,
    depth: &mut Vec<usize>,
) {
    let merged = stranded_dispatch_order(stranded, arena);
    if merged.is_empty() {
        return;
    }
    let mut blocked = vec![false; stranded.len()];
    for (c, req) in merged {
        if blocked[c] {
            stranded[c].push_back(req);
            continue;
        }
        match dispatch_req(
            des, router, gpus_state, mode, guard, tel, insp, arena, c, req, t, available, depth,
        ) {
            // A capacity shed is terminal (already counted), not a block:
            // requests behind it may still find room.
            Dispatch::Placed(_) | Dispatch::Shed => {}
            Dispatch::Stranded => {
                blocked[c] = true;
                stranded[c].push_back(req);
            }
        }
    }
}

/// Flush the windowed telemetry series at `t`. Runs right after
/// `OverloadGuard::on_tick` and *before* the engine resets the window
/// counters (and once more after the event loop, so the residual
/// backlog window is captured) — every counter increment is observed in
/// exactly one flush, which is why each windowed series sums exactly to
/// its `FleetOutcome` total. Shed series diff the guard's cumulative
/// per-class counters, so tick-time sheds (migration-induced capacity
/// drops happen after this snapshot) telescope into the next flush
/// without losing a count.
fn telemetry_window_flush(
    tel: &mut FleetRecorder,
    t: f64,
    gpus_state: &[GpuState],
    guard: &OverloadGuard,
) {
    if !tel.timelines_enabled() {
        return;
    }
    tel.window_begin(t);
    for (g, gs) in gpus_state.iter().enumerate() {
        for (c, r) in gs.replicas.iter().enumerate() {
            tel.window_replica(
                g,
                c,
                r.queue.len(),
                r.window_busy_s,
                r.window_arrivals,
                r.window_completed,
                r.window_violations,
            );
        }
        tel.window_train(g, gs.window_train_steps);
        tel.window_breaker(g, guard.breaker_state(g));
    }
    tel.window_end(
        guard.brownout_level(),
        guard.shed_deadline_per_class(),
        guard.shed_capacity_per_class(),
        guard.shed_brownout_per_class(),
    );
}

impl FleetConfig {
    /// Reject configurations that would produce NaN clocks or degenerate
    /// simulations.
    pub fn validate(&self) -> Result<(), FleetError> {
        if self.gpus.is_empty() {
            return Err(FleetError::Invalid("at least one GPU is required".into()));
        }
        if self.classes.is_empty() {
            return Err(FleetError::Invalid("at least one request class is required".into()));
        }
        if !(self.duration_s.is_finite() && self.duration_s > 0.0) {
            return Err(FleetError::Invalid(format!(
                "duration_s = {} must be positive and finite",
                self.duration_s
            )));
        }
        if !(self.window_s.is_finite() && self.window_s > 0.0) {
            return Err(FleetError::Invalid(format!(
                "window_s = {} must be positive and finite",
                self.window_s
            )));
        }
        if self.window_s >= self.duration_s {
            return Err(FleetError::Invalid(format!(
                "window_s = {} must be smaller than duration_s = {}: no policy tick \
                 would ever fire, so every policy would silently behave as static",
                self.window_s, self.duration_s
            )));
        }
        if !(self.rho_max.is_finite() && self.rho_max > 0.0 && self.rho_max < 1.0) {
            return Err(FleetError::Invalid(format!(
                "rho_max = {} must be in (0, 1)",
                self.rho_max
            )));
        }
        for (i, c) in self.classes.iter().enumerate() {
            if !(c.slo_ms.is_finite() && c.slo_ms > 0.0) {
                return Err(FleetError::Invalid(format!(
                    "class {i}: slo_ms = {} must be positive and finite",
                    c.slo_ms
                )));
            }
            c.arrival.validate()?;
        }
        if !self.tenants.is_empty() {
            validate_tenants(&self.tenants, self.classes.len()).map_err(FleetError::Invalid)?;
        }
        self.faults
            .validate(self.gpus.len(), self.classes.len(), self.duration_s)
            .map_err(FleetError::Invalid)?;
        self.overload.validate().map_err(FleetError::Invalid)?;
        self.telemetry.validate().map_err(FleetError::Invalid)?;
        self.cost.validate().map_err(FleetError::Invalid)
    }

    /// The demand-workload template handed to the planners: training (if
    /// any) first, then classes with their fleet-wide mean rates.
    fn demand_workloads(&self) -> (Vec<DemandWorkload>, Vec<usize>) {
        let mut ws = Vec::with_capacity(self.classes.len() + 1);
        if let Some(t) = &self.train {
            ws.push(DemandWorkload::training(t.clone()));
        }
        let base = ws.len();
        let class_workloads: Vec<usize> = (0..self.classes.len()).map(|i| base + i).collect();
        for c in &self.classes {
            ws.push(DemandWorkload::service(c.spec.clone(), c.slo_ms, c.arrival.mean_rate()));
        }
        (ws, class_workloads)
    }

    /// Resolve one GPU's plan into per-class step estimates + power
    /// draws, the training estimate, and the training power draw (0 when
    /// no training job — telemetry feeds it into the train instance's
    /// DCGM POWER series).
    #[allow(clippy::type_complexity)]
    fn materialize_gpu(
        &self,
        sched: &Scheduler,
        plan: &RatePlan,
        class_base: usize,
    ) -> Result<(Vec<StepEstimate>, Vec<f64>, Option<StepEstimate>, f64), FleetError> {
        let gpu = sched.gpu;
        let mut svc_est = Vec::with_capacity(self.classes.len());
        let mut svc_power = Vec::with_capacity(self.classes.len());
        for (ci, c) in self.classes.iter().enumerate() {
            let inst = plan.instance_of(class_base + ci).ok_or_else(|| {
                FleetError::Infeasible(format!("class {ci} missing from the plan"))
            })?;
            let res = ExecResource::from_gi(gpu, plan.layout.placements[inst].profile);
            let est = sched.perf.step(&res, &c.spec.step_cost())?;
            svc_power.push(sched.energy.power_w(&res, est.gract));
            svc_est.push(est);
        }
        let (train_est, train_power) = match &self.train {
            Some(spec) => {
                let inst = plan.instance_of(0).ok_or_else(|| {
                    FleetError::Infeasible("training missing from the plan".into())
                })?;
                let res = ExecResource::from_gi(gpu, plan.layout.placements[inst].profile);
                let est = sched.perf.step(&res, &spec.step_cost())?;
                let power = sched.energy.power_w(&res, est.gract);
                (Some(est), power)
            }
            None => (None, 0.0),
        };
        Ok((svc_est, svc_power, train_est, train_power))
    }

    /// Run the fleet simulation to completion.
    pub fn run(&self) -> Result<FleetOutcome, FleetError> {
        self.run_with_inspector(&mut NoopInspector)
    }

    /// Run the fleet simulation to completion with a read-only
    /// [`EngineInspector`] observing routing decisions, window ticks,
    /// crashes and recoveries. The inspector cannot steer the run:
    /// `run()` is exactly this with [`NoopInspector`], byte-for-byte.
    pub fn run_with_inspector(
        &self,
        insp: &mut dyn EngineInspector,
    ) -> Result<FleetOutcome, FleetError> {
        // Wall clock over the whole run (planning + event loop +
        // pooling); feeds only the wall-derived `events_per_sec`, never
        // the simulation.
        #[allow(clippy::disallowed_methods)] // sanctioned wall-only site
        // lint:allow(wall-clock, reason="sanctioned wall-only site: feeds events_per_sec, which is excluded from every checksum")
        let wall_start = std::time::Instant::now();
        self.validate()?;
        let n_gpus = self.gpus.len();
        let n_classes = self.classes.len();
        let schedulers: Vec<Scheduler> = self.gpus.iter().map(|&g| Scheduler::new(g)).collect();
        let placement_engines: Vec<PlacementEngine> =
            self.gpus.iter().map(|&g| PlacementEngine::new(g)).collect();
        let (workloads, class_workloads) = self.demand_workloads();
        let class_base = workloads.len() - n_classes;

        // Effective tenancy: explicit tenants switch the demand planners
        // to the tenant-weighted split; the synthesized per-class default
        // only adds accounting and leaves planning byte-for-byte as
        // before.
        let tenants_eff: Vec<Tenant> = if self.tenants.is_empty() {
            Tenant::per_class(n_classes)
        } else {
            self.tenants.clone()
        };
        let tenant_of: Vec<usize> = tenant_of_classes(&tenants_eff, n_classes);
        let weighted_planning = !self.tenants.is_empty();

        // Initial per-GPU layouts: the fleet demand packer at whole-trace
        // mean rates — every policy starts from the same baseline.
        let fleet_plan = if weighted_planning {
            plan_fleet_for_demand_weighted(
                &schedulers,
                &workloads,
                &class_workloads,
                &tenants_eff,
                self.rho_max,
            )
        } else {
            plan_fleet_for_demand(&schedulers, &workloads, self.rho_max)
        }
        .ok_or_else(|| {
            FleetError::Infeasible(
                "no per-GPU layouts host every class at whole-trace mean rates".into(),
            )
        })?;
        let weights = fleet_plan.weights;
        let mut plans = fleet_plan.plans;
        let mut gpus_state: Vec<GpuState> = Vec::with_capacity(n_gpus);
        for (g, plan) in plans.iter().enumerate() {
            placement_engines[g]
                .check_layout(&plan.layout.placements)
                .map_err(|e| FleetError::Infeasible(e.to_string()))?;
            let (svc_est, svc_power, train_est, train_power) =
                self.materialize_gpu(&schedulers[g], plan, class_base)?;
            gpus_state.push(GpuState {
                phase: Phase::Running,
                replicas: (0..n_classes).map(|_| Replica::new()).collect(),
                train_busy: false,
                train_epoch: 0,
                reconfig_epoch: 0,
                window_train_steps: 0,
                svc_est,
                svc_power,
                train_est,
                train_power,
                pending: None,
            });
        }

        let mut seeder = Prng::new(self.seed);
        let mut arrivals: Vec<ArrivalProcess> = Vec::with_capacity(n_classes);
        for c in &self.classes {
            arrivals.push(c.arrival.build(seeder.next_u64())?);
        }
        // The router sees the *declared* tenant set: with none declared,
        // WeightedFair collapses to a single all-classes tenant (plain
        // least-loaded) rather than inheriting the per-class accounting
        // synthesis, which would demote symmetric traffic to deep queues.
        let mut router = self.router.build(n_classes, &self.tenants);
        let mut policy = self.policy.build();
        // Overload guard: deadlines, bounded queues, brownout ladder and
        // per-GPU ingress breakers. Disabled policies leave every check
        // vacuous, so the run is byte-identical to the unprotected path.
        let slo_ms: Vec<f64> = self.classes.iter().map(|c| c.slo_ms).collect();
        // Hot per-class scalars, hoisted out of the per-event path so a
        // completion never reaches back into the config structs.
        let class_batch: Vec<u64> = self.classes.iter().map(|c| c.spec.batch as u64).collect();
        let mut guard = OverloadGuard::new(self.overload, &slo_ms, &tenants_eff, n_gpus);
        // Telemetry recorder: strictly observational (never feeds back
        // into routing, shedding or scheduling), so the simulation is
        // bit-identical with telemetry on or off; when off every hook
        // early-returns and no payload is allocated.
        let mut tel = FleetRecorder::new(
            &self.telemetry,
            n_gpus,
            n_classes,
            &tenants_eff,
            &tenant_of,
            self.train.is_some(),
        );
        // Monotone arrival ids: the span key and trace-sampling anchor.
        // Assigned unconditionally (they never influence the DES), so
        // traced and untraced runs see identical event sequences.
        let mut next_req_id: u64 = 0;
        // Latest event time: the final telemetry flush and DCGM horizon
        // must cover the backlog tail served past `duration_s`.
        let mut end_t: f64 = 0.0;

        let mut collectors: Vec<Vec<MetricsCollector>> = (0..n_gpus)
            .map(|g| {
                self.classes
                    .iter()
                    .enumerate()
                    .map(|(c, cl)| MetricsCollector::new(format!("{}#g{g}c{c}", cl.spec.label())))
                    .collect()
            })
            .collect();

        let mut arrived_per_class: Vec<u64> = vec![0; n_classes];
        let mut slo_met: Vec<u64> = vec![0; n_classes];
        let mut violations: Vec<u64> = vec![0; n_classes];
        let mut stranded: Vec<VecDeque<u32>> = vec![VecDeque::new(); n_classes];
        let mut last_change: Vec<f64> = vec![0.0; n_gpus];
        let mut layouts: Vec<Vec<Layout>> =
            plans.iter().map(|p| vec![p.layout.clone()]).collect();
        let mut decisions: Vec<FleetDecision> = Vec::new();
        let mut routed: u64 = 0;
        let mut migrated_requests: u64 = 0;
        let mut stranded_requests: u64 = 0;
        let mut unavailable_routes: u64 = 0;
        let mut train_steps: u64 = 0;
        let mut reconfig_downtime = 0.0;
        // Terminal-failure accounting is kept per class so it can be
        // re-aggregated per tenant; the outcome totals are the sums.
        let mut failed_per_class: Vec<u64> = vec![0; n_classes];
        let mut retried_per_class: Vec<u64> = vec![0; n_classes];
        let mut lost_per_class: Vec<u64> = vec![0; n_classes];
        let mut gpu_crashes: u64 = 0;
        let mut instance_crashes: u64 = 0;
        let mut downtime_per_gpu: Vec<f64> = vec![0.0; n_gpus];
        let mut down_since: Vec<f64> = vec![0.0; n_gpus];
        let mut fault_log: Vec<FaultRecord> = Vec::new();

        // Router scratch buffers, reused across every routing decision.
        let mut avail_scratch: Vec<bool> = Vec::with_capacity(n_gpus);
        let mut depth_scratch: Vec<usize> = Vec::with_capacity(n_gpus);
        // The request arena: every live request's fields, SoA columns.
        let mut arena = ReqArena::default();

        let mut des: Des<Ev> = Des::new();
        // Seed the calendar: one stream per class, training on every GPU,
        // the first policy tick, the crash schedule.
        for (c, a) in arrivals.iter_mut().enumerate() {
            let t0 = a.next_gap();
            if t0.is_finite() && t0 <= self.duration_s {
                des.schedule_at(t0, Ev::Arrive { class: c });
            }
        }
        for (g, gs) in gpus_state.iter_mut().enumerate() {
            if let Some(est) = &gs.train_est {
                gs.train_busy = true;
                des.schedule_at(est.seconds, Ev::TrainDone { gpu: g, epoch: 0 });
                tel.on_train_busy(0.0, g, *est, gs.train_power);
            }
        }
        if self.window_s < self.duration_s {
            des.schedule_at(self.window_s, Ev::Tick);
        }
        for (i, inj) in self.faults.injections.iter().enumerate() {
            des.schedule_at(inj.t, Ev::Crash { fault: i });
        }

        while let Some((t, ev)) = des.next() {
            end_t = end_t.max(t);
            match ev {
                Ev::Arrive { class } => {
                    arrived_per_class[class] += 1;
                    guard.note_arrival();
                    let id = next_req_id;
                    next_req_id += 1;
                    tel.on_arrive(t, id, class);
                    let gap = arrivals[class].next_gap();
                    if gap.is_finite() && t + gap <= self.duration_s {
                        des.schedule_at(t + gap, Ev::Arrive { class });
                    }
                    // Brownout gates admission before routing: a browned-out
                    // tenant's request is shed at the fleet edge and never
                    // touches a replica queue or the router state.
                    if !guard.admits_class(class) {
                        guard.note_shed(None, class, ShedCause::Brownout);
                        tel.on_shed(t, id, class, None, ShedCause::Brownout);
                        continue;
                    }
                    let req = arena.alloc(Req {
                        id,
                        arrived: t,
                        tries: 0,
                        deadline: guard.deadline(class, t),
                    });
                    match dispatch_req(
                        &mut des,
                        &mut router,
                        &mut gpus_state,
                        self.mode,
                        &mut guard,
                        &mut tel,
                        insp,
                        &mut arena,
                        class,
                        req,
                        t,
                        &mut avail_scratch,
                        &mut depth_scratch,
                    ) {
                        Dispatch::Placed(g) => {
                            routed += 1;
                            if gpus_state[g].phase != Phase::Running {
                                unavailable_routes += 1;
                            }
                            gpus_state[g].replicas[class].window_arrivals += 1;
                        }
                        Dispatch::Shed => {}
                        Dispatch::Stranded => {
                            stranded[class].push_back(req);
                            stranded_requests += 1;
                            tel.on_stranded(t, id, class);
                        }
                    }
                }
                Ev::ServeDone { gpu, class, epoch } => {
                    if gpus_state[gpu].replicas[class].epoch != epoch {
                        continue; // stale: the in-flight request was lost to a crash
                    }
                    {
                        let gs = &mut gpus_state[gpu];
                        let req = gs.replicas[class]
                            .queue
                            .pop_front()
                            .expect("completion without request");
                        let arrived_at = arena.arrived(req);
                        gs.replicas[class].busy = false;
                        let busy_s = t - gs.replicas[class].busy_since;
                        gs.replicas[class].window_busy_s += busy_s;
                        let latency_ms = (t - arrived_at) * 1e3;
                        collectors[gpu][class].record_completion(t, latency_ms, class_batch[class]);
                        collectors[gpu][class].record_energy(gs.svc_power[class] * busy_s);
                        collectors[gpu][class].record_gract(gs.svc_est[class].gract);
                        collectors[gpu][class].record_fb(gs.svc_est[class].fb_bytes);
                        gs.replicas[class].window_completed += 1;
                        gs.replicas[class].window_lat.push(latency_ms);
                        let violated = latency_ms > slo_ms[class];
                        if violated {
                            violations[class] += 1;
                            gs.replicas[class].window_violations += 1;
                        } else {
                            slo_met[class] += 1;
                        }
                        let est = gs.svc_est[class];
                        tel.on_done(t, arena.id(req), gpu, class, latency_ms, violated, est);
                        arena.release(req);
                    }
                    match gpus_state[gpu].phase {
                        Phase::Running => {
                            let gs = &mut gpus_state[gpu];
                            shed_expired(
                                &mut guard,
                                &mut arena,
                                &mut gs.replicas[class],
                                &mut tel,
                                gpu,
                                class,
                                t,
                            );
                            if !gs.replicas[class].queue.is_empty() {
                                let est = gs.svc_est[class];
                                let power_w = gs.svc_power[class];
                                let r = &mut gs.replicas[class];
                                start_replica(
                                    &mut des, r, &arena, &mut tel, gpu, class, t, est, power_w,
                                );
                            }
                        }
                        Phase::Draining => maybe_begin_reconfig(
                            &mut des,
                            &mut gpus_state[gpu],
                            gpu,
                            &plans[gpu].layout,
                            &self.cost,
                        ),
                        Phase::Reconfiguring | Phase::Down => {}
                    }
                }
                Ev::TrainDone { gpu, epoch } => {
                    if gpus_state[gpu].train_epoch != epoch {
                        continue; // stale: the in-flight step was lost to a crash
                    }
                    gpus_state[gpu].train_busy = false;
                    train_steps += 1;
                    gpus_state[gpu].window_train_steps += 1;
                    if let Some(est) = gpus_state[gpu].train_est {
                        tel.on_train_idle(t, gpu, est);
                    }
                    match gpus_state[gpu].phase {
                        Phase::Running => {
                            if t < self.duration_s {
                                let gs = &mut gpus_state[gpu];
                                if let Some(est) = &gs.train_est {
                                    gs.train_busy = true;
                                    let epoch = gs.train_epoch;
                                    des.schedule_in(est.seconds, Ev::TrainDone { gpu, epoch });
                                    tel.on_train_busy(t, gpu, *est, gs.train_power);
                                }
                            }
                        }
                        Phase::Draining => maybe_begin_reconfig(
                            &mut des,
                            &mut gpus_state[gpu],
                            gpu,
                            &plans[gpu].layout,
                            &self.cost,
                        ),
                        Phase::Reconfiguring | Phase::Down => {}
                    }
                }
                Ev::Tick => {
                    // Window boundary: breaker state machines and the
                    // brownout ladder advance on the shed/route counts of
                    // the window that just closed.
                    guard.on_tick(t);
                    // Telemetry flushes the closing window before the engine
                    // resets its counters below, so every increment lands in
                    // exactly one flushed window and Σ(window) = final total.
                    telemetry_window_flush(&mut tel, t, &gpus_state, &guard);
                    insp.on_tick(
                        t,
                        &EngineProbe { gpus: &gpus_state, guard: &guard, mode: self.mode },
                    );
                    let mut gpu_obs = Vec::with_capacity(n_gpus);
                    for gs in gpus_state.iter_mut() {
                        let mut services = Vec::with_capacity(n_classes);
                        for r in gs.replicas.iter_mut() {
                            r.window_lat.sort_unstable_by(f64::total_cmp);
                            services.push(ServiceObs {
                                arrivals: r.window_arrivals,
                                rate_rps: r.window_arrivals as f64 / self.window_s,
                                completed: r.window_completed,
                                violations: r.window_violations,
                                p99_ms: percentile_sorted(&r.window_lat, 99.0),
                                busy_frac: (r.window_busy_s / self.window_s).min(1.0),
                                queue_depth: r.queue.len(),
                            });
                        }
                        gpu_obs.push(GpuObs {
                            services,
                            train_steps: gs.window_train_steps,
                            running: gs.phase == Phase::Running,
                        });
                    }
                    let obs = FleetObs { t, window_s: self.window_s, gpus: gpu_obs };
                    // Proposals only while the whole fleet is serving, so
                    // reconfigurations roll through one GPU at a time.
                    let all_running = gpus_state.iter().all(|gs| gs.phase == Phase::Running);
                    if all_running {
                        let action = {
                            let ctx = FleetCtx {
                                schedulers: &schedulers,
                                workloads: &workloads,
                                class_workloads: &class_workloads,
                                tenants: &tenants_eff,
                                tenant_of: &tenant_of,
                                weighted_planning,
                                current: &plans,
                                weights: &weights,
                                now: t,
                                last_change_t: &last_change,
                                rho_max: self.rho_max,
                            };
                            policy.decide(&obs, &ctx)
                        };
                        if let Some(action) = action {
                            let g = action.gpu;
                            if g < n_gpus && action.plan.layout != plans[g].layout {
                                placement_engines[g]
                                    .check_layout(&action.plan.layout.placements)
                                    .map_err(|e| FleetError::Infeasible(e.to_string()))?;
                                gpus_state[g].phase = Phase::Draining;
                                gpus_state[g].pending = Some(PendingReconfig {
                                    plan: action.plan,
                                    decided_t: t,
                                    reason: action.reason,
                                    migrated: 0,
                                });
                                if self.mode == RepartitionMode::Rolling {
                                    // Migrate queued-but-not-started
                                    // requests to sibling GPUs; the
                                    // in-service head (if any) finishes
                                    // under the old layout.
                                    let mut migrated_here: u64 = 0;
                                    for c in 0..n_classes {
                                        let keep = usize::from(gpus_state[g].replicas[c].busy);
                                        let keep =
                                            keep.min(gpus_state[g].replicas[c].queue.len());
                                        let moved =
                                            gpus_state[g].replicas[c].queue.split_off(keep);
                                        for req in moved {
                                            migrated_here += 1;
                                            tel.on_migrate(t, arena.id(req), c, g);
                                            match dispatch_req(
                                                &mut des,
                                                &mut router,
                                                &mut gpus_state,
                                                RepartitionMode::Rolling,
                                                &mut guard,
                                                &mut tel,
                                                insp,
                                                &mut arena,
                                                c,
                                                req,
                                                t,
                                                &mut avail_scratch,
                                                &mut depth_scratch,
                                            ) {
                                                Dispatch::Placed(_) | Dispatch::Shed => {}
                                                Dispatch::Stranded => {
                                                    stranded[c].push_back(req);
                                                    stranded_requests += 1;
                                                    tel.on_stranded(t, arena.id(req), c);
                                                }
                                            }
                                        }
                                    }
                                    migrated_requests += migrated_here;
                                    if let Some(p) = gpus_state[g].pending.as_mut() {
                                        p.migrated = migrated_here;
                                    }
                                }
                                maybe_begin_reconfig(
                                    &mut des,
                                    &mut gpus_state[g],
                                    g,
                                    &plans[g].layout,
                                    &self.cost,
                                );
                            }
                        }
                    }
                    for gs in gpus_state.iter_mut() {
                        for r in gs.replicas.iter_mut() {
                            r.window_arrivals = 0;
                            r.window_completed = 0;
                            r.window_violations = 0;
                            r.window_busy_s = 0.0;
                            r.window_lat.clear();
                        }
                        gs.window_train_steps = 0;
                    }
                    if t + self.window_s < self.duration_s {
                        des.schedule_at(t + self.window_s, Ev::Tick);
                    }
                    // A breaker re-closing is the only capacity-return
                    // transition with no Recover/ReconfigDone event, so
                    // stranded work must be re-offered here. Gated on the
                    // breaker being enabled: router.route can mutate cursor
                    // and credit state even on failed routes, and the
                    // disabled path must stay byte-identical to PR 5.
                    if guard.breaker_enabled() {
                        drain_stranded(
                            &mut des,
                            &mut router,
                            &mut gpus_state,
                            self.mode,
                            &mut guard,
                            &mut tel,
                            insp,
                            &mut arena,
                            &mut stranded,
                            t,
                            &mut avail_scratch,
                            &mut depth_scratch,
                        );
                    }
                }
                Ev::ReconfigDone { gpu, epoch } => {
                    if gpus_state[gpu].reconfig_epoch != epoch {
                        continue; // stale: a crash aborted this reconfiguration
                    }
                    let pend = gpus_state[gpu]
                        .pending
                        .take()
                        .expect("reconfiguration without a pending target");
                    let from = plans[gpu].profile_names().join("+");
                    let to = pend.plan.profile_names().join("+");
                    let churn_n = churn(&plans[gpu].layout, &pend.plan.layout);
                    plans[gpu] = pend.plan;
                    let bound = self.materialize_gpu(&schedulers[gpu], &plans[gpu], class_base)?;
                    {
                        let gs = &mut gpus_state[gpu];
                        gs.svc_est = bound.0;
                        gs.svc_power = bound.1;
                        gs.train_est = bound.2;
                        gs.train_power = bound.3;
                        gs.phase = Phase::Running;
                    }
                    let downtime = t - pend.decided_t;
                    reconfig_downtime += downtime;
                    decisions.push(FleetDecision {
                        t: pend.decided_t,
                        gpu,
                        from,
                        to,
                        reason: pend.reason,
                        churn: churn_n,
                        downtime_s: downtime,
                        migrated: pend.migrated,
                    });
                    layouts[gpu].push(plans[gpu].layout.clone());
                    last_change[gpu] = t;
                    // Re-dispatch requests stranded while no replica could
                    // take them (fleets of one under rolling repartition,
                    // or crashes that downed every destination).
                    drain_stranded(
                        &mut des,
                        &mut router,
                        &mut gpus_state,
                        self.mode,
                        &mut guard,
                        &mut tel,
                        insp,
                        &mut arena,
                        &mut stranded,
                        t,
                        &mut avail_scratch,
                        &mut depth_scratch,
                    );
                    // Put the resumed GPU back to work (crashed replicas
                    // stay idle until their fault recovers). Requests whose
                    // deadline lapsed during the outage are shed, not served.
                    {
                        let gs = &mut gpus_state[gpu];
                        for c in 0..n_classes {
                            if !gs.replicas[c].down && !gs.replicas[c].busy {
                                shed_expired(
                                    &mut guard,
                                    &mut arena,
                                    &mut gs.replicas[c],
                                    &mut tel,
                                    gpu,
                                    c,
                                    t,
                                );
                                if !gs.replicas[c].queue.is_empty() {
                                    let est = gs.svc_est[c];
                                    let power_w = gs.svc_power[c];
                                    start_replica(
                                        &mut des,
                                        &mut gs.replicas[c],
                                        &arena,
                                        &mut tel,
                                        gpu,
                                        c,
                                        t,
                                        est,
                                        power_w,
                                    );
                                }
                            }
                        }
                        if t < self.duration_s {
                            if let Some(est) = &gs.train_est {
                                gs.train_busy = true;
                                let epoch = gs.train_epoch;
                                des.schedule_in(
                                    self.cost.train_restore_s + est.seconds,
                                    Ev::TrainDone { gpu, epoch },
                                );
                                tel.on_train_busy(t, gpu, *est, gs.train_power);
                            }
                        }
                    }
                }
                Ev::Crash { fault } => {
                    let inj = self.faults.injections[fault];
                    let g = inj.gpu;
                    // Dump every affected queue first, then decide retry /
                    // shed / lose — retries must never land back on a
                    // replica this crash is taking down.
                    let mut dumped: Vec<(usize, u32)> = Vec::new();
                    match inj.class {
                        None => {
                            gpu_crashes += 1;
                            down_since[g] = t;
                            let gs = &mut gpus_state[g];
                            if gs.phase == Phase::Reconfiguring {
                                // Abort the in-flight churn; the pending
                                // plan is discarded and the GPU recovers
                                // on its old layout.
                                gs.reconfig_epoch += 1;
                            }
                            gs.pending = None;
                            gs.phase = Phase::Down;
                            if gs.train_busy {
                                gs.train_busy = false;
                                gs.train_epoch += 1;
                            }
                            if gs.train_est.is_some() {
                                tel.on_train_down(t, g);
                            }
                            for c in 0..n_classes {
                                flush_replica(
                                    &mut gs.replicas[c],
                                    &arena,
                                    &mut tel,
                                    g,
                                    c,
                                    t,
                                    &mut dumped,
                                );
                            }
                        }
                        Some(c) => {
                            instance_crashes += 1;
                            let gs = &mut gpus_state[g];
                            gs.replicas[c].down = true;
                            flush_replica(
                                &mut gs.replicas[c],
                                &arena,
                                &mut tel,
                                g,
                                c,
                                t,
                                &mut dumped,
                            );
                            if gs.phase == Phase::Draining {
                                // Losing the in-flight request may
                                // complete the drain barrier.
                                maybe_begin_reconfig(&mut des, gs, g, &plans[g].layout, &self.cost);
                            }
                        }
                    }
                    let mut lost_here: u64 = 0;
                    let mut retried_here: u64 = 0;
                    let mut shed_here: u64 = 0;
                    for (c, req) in dumped {
                        if arena.tries(req) >= self.faults.retry_budget {
                            lost_here += 1;
                            lost_per_class[c] += 1;
                            tel.on_lost(t, arena.id(req), c, g);
                            arena.release(req);
                        } else if retried_here >= self.faults.storm_guard {
                            shed_here += 1;
                            failed_per_class[c] += 1;
                            tel.on_failed_storm(t, arena.id(req), c, g);
                            arena.release(req);
                        } else {
                            retried_here += 1;
                            retried_per_class[c] += 1;
                            tel.on_retry(t, arena.id(req), c, g);
                            // The retry keeps the handle, id, arrival stamp
                            // and deadline: a crash does not buy extra SLO
                            // time.
                            arena.bump_tries(req);
                            match dispatch_req(
                                &mut des,
                                &mut router,
                                &mut gpus_state,
                                self.mode,
                                &mut guard,
                                &mut tel,
                                insp,
                                &mut arena,
                                c,
                                req,
                                t,
                                &mut avail_scratch,
                                &mut depth_scratch,
                            ) {
                                Dispatch::Placed(_) | Dispatch::Shed => {}
                                Dispatch::Stranded => {
                                    stranded[c].push_back(req);
                                    stranded_requests += 1;
                                    tel.on_stranded(t, arena.id(req), c);
                                }
                            }
                        }
                    }
                    fault_log.push(FaultRecord {
                        t,
                        gpu: g,
                        class: inj.class,
                        down_s: inj.down_s,
                        lost: lost_here,
                        retried: retried_here,
                        shed: shed_here,
                    });
                    if inj.down_s.is_finite() {
                        des.schedule_in(inj.down_s, Ev::Recover { fault });
                    }
                    insp.on_crash(
                        t,
                        g,
                        inj.class,
                        &EngineProbe { gpus: &gpus_state, guard: &guard, mode: self.mode },
                    );
                }
                Ev::Recover { fault } => {
                    let inj = self.faults.injections[fault];
                    let g = inj.gpu;
                    match inj.class {
                        None => {
                            // Downtime is measured against the nominal
                            // horizon, so availability stays in [0, 1]
                            // even when recovery lands in the backlog
                            // tail past `duration_s`.
                            downtime_per_gpu[g] +=
                                (t.min(self.duration_s) - down_since[g]).max(0.0);
                            let gs = &mut gpus_state[g];
                            gs.phase = Phase::Running;
                            if t < self.duration_s {
                                if let Some(est) = &gs.train_est {
                                    gs.train_busy = true;
                                    let epoch = gs.train_epoch;
                                    des.schedule_in(
                                        self.cost.train_restore_s + est.seconds,
                                        Ev::TrainDone { gpu: g, epoch },
                                    );
                                    tel.on_train_busy(t, g, *est, gs.train_power);
                                }
                            }
                        }
                        Some(c) => {
                            gpus_state[g].replicas[c].down = false;
                        }
                    }
                    drain_stranded(
                        &mut des,
                        &mut router,
                        &mut gpus_state,
                        self.mode,
                        &mut guard,
                        &mut tel,
                        insp,
                        &mut arena,
                        &mut stranded,
                        t,
                        &mut avail_scratch,
                        &mut depth_scratch,
                    );
                    // Defensive restart: queues on the recovered GPU are
                    // normally empty (the crash flushed them and routing
                    // excluded it while down), but a crash that lands
                    // mid-drain can leave migrated-in work behind; it is
                    // dispatched exactly once here. Expired requests are
                    // shed, never served.
                    let gs = &mut gpus_state[g];
                    if gs.phase == Phase::Running {
                        for c in 0..n_classes {
                            if !gs.replicas[c].down && !gs.replicas[c].busy {
                                shed_expired(
                                    &mut guard,
                                    &mut arena,
                                    &mut gs.replicas[c],
                                    &mut tel,
                                    g,
                                    c,
                                    t,
                                );
                                if !gs.replicas[c].queue.is_empty() {
                                    let est = gs.svc_est[c];
                                    let power_w = gs.svc_power[c];
                                    start_replica(
                                        &mut des,
                                        &mut gs.replicas[c],
                                        &arena,
                                        &mut tel,
                                        g,
                                        c,
                                        t,
                                        est,
                                        power_w,
                                    );
                                }
                            }
                        }
                    }
                    insp.on_recover(
                        t,
                        g,
                        inj.class,
                        &EngineProbe { gpus: &gpus_state, guard: &guard, mode: self.mode },
                    );
                }
            }
        }

        // Breakers still open when the horizon closes pay open-time up to
        // the nominal horizon, mirroring the downtime convention below.
        guard.finish(self.duration_s);

        // Final telemetry flush: the residual backlog window (events past
        // the last Tick, including the drain tail beyond `duration_s`) is
        // captured so Σ(window series) equals the outcome totals exactly.
        let end_t = end_t.max(self.duration_s);
        telemetry_window_flush(&mut tel, end_t, &gpus_state, &guard);
        if tel.tracing_enabled() {
            for (c, q) in stranded.iter().enumerate() {
                for &req in q {
                    tel.on_failed_end(end_t, arena.id(req), c);
                }
            }
        }
        let telemetry = tel.into_output(end_t);

        // A permanently-failed fleet can leave requests stranded with
        // nothing left to recover: they fail, they are not silently
        // dropped (conservation: completed + failed + lost + shed = arrived).
        for (c, q) in stranded.iter_mut().enumerate() {
            failed_per_class[c] += q.len() as u64;
            q.clear();
        }
        // GPUs still down at the end pay downtime up to the nominal
        // horizon.
        for (g, gs) in gpus_state.iter().enumerate() {
            if gs.phase == Phase::Down {
                downtime_per_gpu[g] += (self.duration_s - down_since[g]).max(0.0);
            }
        }
        let availability =
            1.0 - downtime_per_gpu.iter().sum::<f64>() / (n_gpus as f64 * self.duration_s);

        // Pool metrics: per class across GPUs, per GPU across classes, and
        // fleet-wide. Conventions match the serving pooler: throughput is
        // the sum of per-part rates, the window is the longest part window.
        let part_summaries: Vec<Vec<RunSummary>> =
            collectors.iter().map(|row| row.iter().map(|c| c.summarize()).collect()).collect();
        let finish = |mut s: RunSummary, parts: &[&RunSummary]| -> RunSummary {
            s.throughput = parts.iter().map(|p| p.throughput).sum();
            s.duration_s = parts.iter().map(|p| p.duration_s).fold(0.0, f64::max);
            s
        };
        let per_class: Vec<RunSummary> = (0..n_classes)
            .map(|c| {
                let merged = MetricsCollector::pooled(
                    format!("class{c}:{}", self.classes[c].spec.label()),
                    (0..n_gpus).map(|g| &collectors[g][c]),
                );
                let parts: Vec<&RunSummary> = (0..n_gpus).map(|g| &part_summaries[g][c]).collect();
                finish(merged.summarize(), &parts)
            })
            .collect();
        let per_gpu: Vec<RunSummary> = (0..n_gpus)
            .map(|g| {
                let merged = MetricsCollector::pooled(format!("gpu{g}"), collectors[g].iter());
                let parts: Vec<&RunSummary> = part_summaries[g].iter().collect();
                finish(merged.summarize(), &parts)
            })
            .collect();
        let pooled = {
            let merged = MetricsCollector::pooled("fleet", collectors.iter().flatten());
            let parts: Vec<&RunSummary> = part_summaries.iter().flatten().collect();
            finish(merged.summarize(), &parts)
        };

        let arrived: u64 = arrived_per_class.iter().sum();
        let met_total: u64 = slo_met.iter().sum();
        let viol_total: u64 = violations.iter().sum();
        let completed = met_total + viol_total;
        let failed_requests: u64 = failed_per_class.iter().sum();
        let retried_requests: u64 = retried_per_class.iter().sum();
        let lost_in_crash: u64 = lost_per_class.iter().sum();
        let shed_deadline: u64 = guard.shed_deadline_per_class().iter().sum();
        let shed_capacity: u64 = guard.shed_capacity_per_class().iter().sum();
        let shed_brownout: u64 = guard.shed_brownout_per_class().iter().sum();
        let shed_overload = shed_deadline + shed_capacity + shed_brownout;

        // Per-tenant accounting: re-aggregate the per-class counters over
        // the tenant partition, then summarize fairness as Jain's index
        // over weight-normalized goodput.
        let mut tenant_rows: Vec<TenantOutcome> = tenants_eff
            .iter()
            .map(|tn| TenantOutcome {
                name: tn.name.clone(),
                weight: tn.weight,
                classes: tn.classes.clone(),
                arrived: 0,
                completed: 0,
                slo_violations: 0,
                failed: 0,
                lost_in_crash: 0,
                retried: 0,
                shed_deadline: 0,
                shed_capacity: 0,
                shed_brownout: 0,
                goodput_rps: 0.0,
                slo_violation_frac: 0.0,
                norm_goodput_rps: 0.0,
            })
            .collect();
        for c in 0..n_classes {
            let ti = tenant_of[c];
            if ti == usize::MAX {
                continue; // unreachable for a validated tenant set
            }
            let row = &mut tenant_rows[ti];
            row.arrived += arrived_per_class[c];
            row.completed += slo_met[c] + violations[c];
            row.slo_violations += violations[c];
            row.failed += failed_per_class[c];
            row.lost_in_crash += lost_per_class[c];
            row.retried += retried_per_class[c];
            row.shed_deadline += guard.shed_deadline_per_class()[c];
            row.shed_capacity += guard.shed_capacity_per_class()[c];
            row.shed_brownout += guard.shed_brownout_per_class()[c];
        }
        for row in &mut tenant_rows {
            row.goodput_rps = (row.completed - row.slo_violations) as f64 / self.duration_s;
            row.slo_violation_frac = if row.completed > 0 {
                row.slo_violations as f64 / row.completed as f64
            } else {
                0.0
            };
            row.norm_goodput_rps = row.goodput_rps / row.weight;
        }
        let norm: Vec<f64> = tenant_rows.iter().map(|r| r.norm_goodput_rps).collect();
        let fairness_jain = jain_index(&norm);

        let train_batch = self.train.as_ref().map(|t| t.batch as f64).unwrap_or(0.0);
        // Simulator throughput: deterministic event count over the
        // wall-clock the run took. Wall-derived, so `events_per_sec`
        // never participates in determinism fingerprints or checksums.
        let events_processed = des.processed();
        // lint:allow(wall-clock, reason="sanctioned wall-only site: feeds events_per_sec, which is excluded from every checksum")
        let wall_s = wall_start.elapsed().as_secs_f64();
        let events_per_sec =
            if wall_s > 0.0 { events_processed as f64 / wall_s } else { 0.0 };
        Ok(FleetOutcome {
            policy: self.policy.name(),
            router: self.router.name(),
            mode: self.mode,
            fleet_size: n_gpus,
            duration_s: self.duration_s,
            pooled,
            per_class,
            per_gpu,
            arrived,
            arrived_per_class,
            routed,
            completed,
            slo_violations: viol_total,
            goodput_rps: met_total as f64 / self.duration_s,
            slo_violation_frac: if completed > 0 {
                viol_total as f64 / completed as f64
            } else {
                0.0
            },
            tenants: tenant_rows,
            fairness_jain,
            train_steps,
            train_samples_per_s: train_steps as f64 * train_batch / self.duration_s,
            reconfigurations: decisions.len() as u64,
            reconfig_downtime_s: reconfig_downtime,
            migrated_requests,
            stranded_requests,
            unavailable_routes,
            failed_requests,
            retried_requests,
            lost_in_crash,
            shed_overload,
            shed_deadline,
            shed_capacity,
            shed_brownout,
            breaker_trips: guard.breaker_trips(),
            breaker_open_s: guard.breaker_open_s(),
            gpu_crashes,
            instance_crashes,
            downtime_s_per_gpu: downtime_per_gpu,
            availability,
            events_processed,
            events_per_sec,
            fault_log,
            layouts,
            decisions,
            telemetry,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::lookup;
    use crate::orchestrator::ReactiveParams;

    /// The §Fleet demo scenario, compressed for tests: per-GPU load equal
    /// to the orchestrator demo (two bert-base services ramping 6 → 60
    /// req/s each, bert-base training co-located), scaled to `n` GPUs via
    /// fleet-wide arrival rates.
    fn demo(
        n: usize,
        policy: FleetPolicyKind,
        router: RouterKind,
        mode: RepartitionMode,
        duration_s: f64,
        period_s: f64,
    ) -> FleetConfig {
        let bert = lookup("bert-base").unwrap();
        let class = RequestClass {
            spec: WorkloadSpec::inference(bert, 8, 128),
            slo_ms: 40.0,
            arrival: ArrivalSpec::Diurnal {
                base_rate: 6.0 * n as f64,
                peak_rate: 60.0 * n as f64,
                period_s,
            },
        };
        FleetConfig {
            gpus: vec![GpuModel::A100_80GB; n],
            train: Some(WorkloadSpec::training(bert, 32, 128)),
            classes: vec![class.clone(), class],
            tenants: Vec::new(),
            router,
            policy,
            mode,
            cost: ReconfigCost::default(),
            duration_s,
            window_s: 10.0,
            rho_max: 0.75,
            faults: FaultPlan::none(),
            overload: OverloadPolicy::none(),
            telemetry: TelemetryConfig::off(),
            seed: 2024,
        }
    }

    fn reactive() -> FleetPolicyKind {
        FleetPolicyKind::Reactive(ReactiveParams::default())
    }

    #[test]
    fn static_run_completes_and_conserves_requests() {
        let out = demo(
            2,
            FleetPolicyKind::Static,
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        )
        .run()
        .unwrap();
        assert!(out.arrived > 1000, "arrived {}", out.arrived);
        assert_eq!(out.completed, out.arrived, "every admitted request completes");
        assert_eq!(out.routed, out.arrived, "static fleets never strand requests");
        assert_eq!(out.reconfigurations, 0);
        assert!(out.decisions.is_empty());
        assert_eq!(out.unavailable_routes, 0);
        assert_eq!(out.migrated_requests, 0);
        assert_eq!(out.stranded_requests, 0);
        assert!(out.goodput_rps > 0.0);
        assert!(out.train_steps > 0);
        assert_eq!(out.fleet_size, 2);
        assert_eq!(out.per_gpu.len(), 2);
        assert_eq!(out.per_class.len(), 2);
        for (c, s) in out.per_class.iter().enumerate() {
            assert_eq!(
                s.completed, out.arrived_per_class[c],
                "class {c} completions must equal its arrivals"
            );
        }
        for l in &out.layouts {
            assert_eq!(l.len(), 1, "static never adopts a second layout");
        }
    }

    #[test]
    fn reactive_rolling_repartitions_without_unavailable_routes() {
        let out = demo(
            2,
            reactive(),
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        )
        .run()
        .unwrap();
        assert!(out.reconfigurations >= 1, "diurnal peak must force a repartition");
        assert_eq!(out.unavailable_routes, 0, "rolling never routes to a draining GPU");
        assert_eq!(out.completed, out.arrived);
        assert_eq!(out.decisions.len() as u64, out.reconfigurations);
        let downtime: f64 = out.decisions.iter().map(|d| d.downtime_s).sum();
        assert!((downtime - out.reconfig_downtime_s).abs() < 1e-9);
        for d in &out.decisions {
            assert!(d.gpu < 2, "{d:?}");
            assert!(d.churn > 0, "a layout switch must churn instances: {d:?}");
            assert!(d.downtime_s > 0.0, "{d:?}");
            assert!(d.from != d.to, "{d:?}");
        }
        let adopted: usize = out.layouts.iter().map(|l| l.len() - 1).sum();
        assert_eq!(adopted as u64, out.reconfigurations);
    }

    #[test]
    fn inplace_keeps_routing_to_the_churning_gpu() {
        let out = demo(
            2,
            reactive(),
            RouterKind::RoundRobin,
            RepartitionMode::InPlace,
            240.0,
            120.0,
        )
        .run()
        .unwrap();
        assert!(out.reconfigurations >= 1);
        assert_eq!(out.migrated_requests, 0, "in-place never migrates queues");
        assert_eq!(out.stranded_requests, 0, "in-place always finds a destination");
        assert!(
            out.unavailable_routes > 0,
            "round-robin must hit the reconfiguring GPU during its downtime"
        );
        assert_eq!(out.completed, out.arrived);
    }

    #[test]
    fn rolling_no_worse_than_inplace_at_the_peak() {
        let run = |mode| {
            demo(2, reactive(), RouterKind::LeastLoaded, mode, 240.0, 120.0).run().unwrap()
        };
        let rolling = run(RepartitionMode::Rolling);
        let inplace = run(RepartitionMode::InPlace);
        assert!(rolling.reconfigurations >= 1);
        assert!(inplace.reconfigurations >= 1);
        assert!(
            rolling.slo_violation_frac <= inplace.slo_violation_frac,
            "rolling {:.4} must not violate more than in-place {:.4}",
            rolling.slo_violation_frac,
            inplace.slo_violation_frac
        );
    }

    #[test]
    fn fleet_of_one_strands_and_recovers_under_rolling() {
        let out = demo(
            1,
            reactive(),
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        )
        .run()
        .unwrap();
        assert!(out.reconfigurations >= 1, "the single GPU must still repartition");
        assert!(
            out.stranded_requests > 0,
            "with no sibling, rolling must strand requests during churn"
        );
        assert_eq!(out.unavailable_routes, 0);
        assert!(out.routed <= out.arrived, "each request is router-counted at most once");
        assert_eq!(out.completed, out.arrived, "stranded requests are served after resume");
    }

    #[test]
    fn runs_are_bitwise_deterministic_per_seed() {
        let mk = || {
            let router = RouterKind::Affinity { spill: 4 };
            demo(2, reactive(), router, RepartitionMode::Rolling, 240.0, 120.0).run().unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits());
        assert_eq!(a.pooled.p99_latency_ms.to_bits(), b.pooled.p99_latency_ms.to_bits());
        assert_eq!(a.reconfig_downtime_s.to_bits(), b.reconfig_downtime_s.to_bits());
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.decisions.len(), b.decisions.len());
        assert_eq!(a.train_steps, b.train_steps);
    }

    #[test]
    fn heterogeneous_fleet_serves_within_capacity_weights() {
        let resnet = lookup("resnet50").unwrap();
        let class = RequestClass {
            spec: WorkloadSpec::inference(resnet, 4, 224),
            slo_ms: 200.0,
            arrival: ArrivalSpec::Poisson { rate: 20.0 },
        };
        let cfg = FleetConfig {
            gpus: vec![GpuModel::A100_80GB, GpuModel::A30_24GB],
            train: None,
            classes: vec![class.clone(), class],
            tenants: Vec::new(),
            router: RouterKind::LeastLoaded,
            policy: FleetPolicyKind::Static,
            mode: RepartitionMode::Rolling,
            cost: ReconfigCost::default(),
            duration_s: 120.0,
            window_s: 10.0,
            rho_max: 0.75,
            faults: FaultPlan::none(),
            overload: OverloadPolicy::none(),
            telemetry: TelemetryConfig::off(),
            seed: 7,
        };
        let out = cfg.run().unwrap();
        assert_eq!(out.fleet_size, 2);
        assert_eq!(out.completed, out.arrived);
        assert_eq!(out.train_steps, 0);
        assert!(out.per_gpu.iter().all(|s| s.completed > 0), "both GPUs serve traffic");
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let base = || {
            let (policy, router) = (FleetPolicyKind::Static, RouterKind::LeastLoaded);
            demo(2, policy, router, RepartitionMode::Rolling, 240.0, 120.0)
        };
        let mut cfg = base();
        cfg.gpus.clear();
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.classes.clear();
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.duration_s = f64::NAN;
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.window_s = 240.0; // >= duration: no policy tick would ever fire
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.rho_max = 1.5;
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.classes[0].slo_ms = -1.0;
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.classes[0].arrival = ArrivalSpec::Poisson { rate: f64::NAN };
        assert!(matches!(cfg.run(), Err(FleetError::Arrival(_))));

        let mut cfg = base();
        cfg.cost.instance_churn_s = f64::INFINITY;
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.classes[0].slo_ms = 0.01; // below launch overhead
        assert!(matches!(cfg.run(), Err(FleetError::Infeasible(_))));

        let mut cfg = base();
        cfg.faults.injections.push(crate::cluster::faults::FaultInjection {
            t: 500.0, // beyond duration_s = 240
            gpu: 0,
            class: None,
            down_s: 5.0,
        });
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.faults.injections.push(crate::cluster::faults::FaultInjection {
            t: 50.0,
            gpu: 9, // out of range
            class: None,
            down_s: 5.0,
        });
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));
    }

    #[test]
    fn fault_free_runs_report_full_availability() {
        let out = demo(
            2,
            FleetPolicyKind::Static,
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        )
        .run()
        .unwrap();
        assert_eq!(out.failed_requests, 0);
        assert_eq!(out.retried_requests, 0);
        assert_eq!(out.lost_in_crash, 0);
        assert_eq!(out.gpu_crashes, 0);
        assert_eq!(out.instance_crashes, 0);
        assert!(out.fault_log.is_empty());
        assert_eq!(out.downtime_s_per_gpu, vec![0.0, 0.0]);
        assert_eq!(out.availability, 1.0);
    }

    #[test]
    fn gpu_crash_sheds_to_the_sibling_and_conserves_requests() {
        let mut cfg = demo(
            2,
            FleetPolicyKind::Static,
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        );
        cfg.faults = FaultPlan {
            injections: vec![crate::cluster::faults::FaultInjection {
                t: 100.0,
                gpu: 0,
                class: None,
                down_s: 30.0,
            }],
            ..FaultPlan::none()
        };
        let out = cfg.run().unwrap();
        assert_eq!(out.gpu_crashes, 1);
        assert_eq!(out.fault_log.len(), 1);
        assert_eq!(out.fault_log[0].gpu, 0);
        assert_eq!(out.fault_log[0].t, 100.0);
        assert_eq!(
            out.completed + out.failed_requests + out.lost_in_crash,
            out.arrived,
            "conservation must hold across the crash"
        );
        assert!((out.downtime_s_per_gpu[0] - 30.0).abs() < 1e-9);
        assert_eq!(out.downtime_s_per_gpu[1], 0.0);
        let expected = 1.0 - 30.0 / (2.0 * 240.0);
        assert!((out.availability - expected).abs() < 1e-12, "{}", out.availability);
        // With a sibling up and the default retry budget, dumped requests
        // are retried rather than lost.
        assert_eq!(out.lost_in_crash, 0);
        assert_eq!(out.failed_requests, 0);
        assert_eq!(out.completed, out.arrived);
    }

    #[test]
    fn permanent_crash_on_a_fleet_of_one_fails_the_tail() {
        let bert = lookup("bert-base").unwrap();
        let class = RequestClass {
            spec: WorkloadSpec::inference(bert, 8, 128),
            slo_ms: 40.0,
            arrival: ArrivalSpec::Poisson { rate: 20.0 },
        };
        let mut cfg = FleetConfig {
            gpus: vec![GpuModel::A100_80GB],
            train: Some(WorkloadSpec::training(bert, 32, 128)),
            classes: vec![class.clone(), class],
            tenants: Vec::new(),
            router: RouterKind::LeastLoaded,
            policy: FleetPolicyKind::Static,
            mode: RepartitionMode::Rolling,
            cost: ReconfigCost::default(),
            duration_s: 240.0,
            window_s: 10.0,
            rho_max: 0.75,
            faults: FaultPlan::none(),
            overload: OverloadPolicy::none(),
            telemetry: TelemetryConfig::off(),
            seed: 11,
        };
        cfg.faults = FaultPlan {
            injections: vec![crate::cluster::faults::FaultInjection {
                t: 60.0,
                gpu: 0,
                class: None,
                down_s: f64::INFINITY,
            }],
            retry_budget: 0,
            ..FaultPlan::none()
        };
        let out = cfg.run().unwrap();
        assert_eq!(out.gpu_crashes, 1);
        assert_eq!(
            out.completed + out.failed_requests + out.lost_in_crash,
            out.arrived,
            "conservation must hold under a permanent failure"
        );
        assert!(
            out.failed_requests > 0,
            "arrivals after the permanent crash must fail, not vanish"
        );
        assert_eq!(out.retried_requests, 0, "retry budget 0 never re-admits");
        assert!((out.downtime_s_per_gpu[0] - 180.0).abs() < 1e-9, "60 → 240 is down");
        assert!((out.availability - 0.25).abs() < 1e-12);
    }

    #[test]
    fn faulted_runs_are_bitwise_deterministic_per_seed() {
        let mk = || {
            let mut cfg = demo(
                2,
                reactive(),
                RouterKind::LeastLoaded,
                RepartitionMode::Rolling,
                240.0,
                120.0,
            );
            cfg.faults = FaultPlan::from_mtbf(2, 240.0, 80.0, 15.0, 99);
            cfg.run().unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits());
        assert_eq!(a.pooled.p99_latency_ms.to_bits(), b.pooled.p99_latency_ms.to_bits());
        assert_eq!(a.availability.to_bits(), b.availability.to_bits());
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.retried_requests, b.retried_requests);
        assert_eq!(a.lost_in_crash, b.lost_in_crash);
        assert_eq!(a.failed_requests, b.failed_requests);
        assert_eq!(a.fault_log.len(), b.fault_log.len());
        assert_eq!(
            a.completed + a.failed_requests + a.lost_in_crash,
            a.arrived,
            "conservation must hold under the stochastic schedule"
        );
    }

    #[test]
    fn stranded_redispatch_is_globally_oldest_first() {
        // Class 0 holds younger requests than class 1's oldest: the old
        // per-class drain dispatched all of class 0 first, so after a
        // recovery class 0's whole backlog jumped ahead of older class-1
        // requests. The merged order is globally oldest-first with ties
        // to the lowest class index, and it sorts *within* classes too
        // (crash retries append old-timestamp requests behind younger
        // stranded arrivals).
        let mut arena = ReqArena::default();
        let rq = |arena: &mut ReqArena, arrived: f64, tries: u32| {
            arena.alloc(Req { id: 0, arrived, tries, deadline: f64::INFINITY })
        };
        let mut stranded: Vec<VecDeque<u32>> = vec![VecDeque::new(), VecDeque::new()];
        let h = rq(&mut arena, 10.0, 0);
        stranded[0].push_back(h);
        let h = rq(&mut arena, 20.0, 0);
        stranded[0].push_back(h);
        let h = rq(&mut arena, 5.0, 1);
        stranded[1].push_back(h);
        let h = rq(&mut arena, 20.0, 0);
        stranded[1].push_back(h);
        let h = rq(&mut arena, 12.0, 1);
        stranded[1].push_back(h);
        let order = stranded_dispatch_order(&mut stranded, &arena);
        let key: Vec<(usize, f64)> = order.iter().map(|&(c, h)| (c, arena.arrived(h))).collect();
        assert_eq!(
            key,
            vec![(1, 5.0), (0, 10.0), (1, 12.0), (0, 20.0), (1, 20.0)],
            "globally oldest first, ties to the lowest class index"
        );
        assert!(stranded.iter().all(|q| q.is_empty()), "the queues are drained");
    }

    #[test]
    fn req_arena_recycles_slots_through_the_free_list() {
        let mut arena = ReqArena::default();
        let a = arena.alloc(Req { id: 1, arrived: 0.5, tries: 0, deadline: 1.0 });
        let b = arena.alloc(Req { id: 2, arrived: 0.75, tries: 0, deadline: 2.0 });
        assert_eq!((a, b), (0, 1));
        arena.release(a);
        let c = arena.alloc(Req { id: 3, arrived: 1.0, tries: 1, deadline: 3.0 });
        assert_eq!(c, a, "released slots are reused before the columns grow");
        assert_eq!(arena.id.len(), 2, "the columns never grow past the live peak");
        let r = arena.req(c);
        assert_eq!((r.id, r.tries), (3, 1));
        assert_eq!(r.arrived.to_bits(), 1.0f64.to_bits());
        assert_eq!(r.deadline.to_bits(), 3.0f64.to_bits());
        arena.bump_tries(b);
        assert_eq!(arena.tries(b), 1);
        assert_eq!(arena.id(b), 2);
    }

    #[test]
    fn runs_report_events_processed_and_throughput() {
        let out = demo(
            2,
            FleetPolicyKind::Static,
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        )
        .run()
        .unwrap();
        // Every arrival pops at least an Arrive and a ServeDone, plus
        // ticks and training completions.
        assert!(
            out.events_processed > 2 * out.arrived,
            "events {} vs arrived {}",
            out.events_processed,
            out.arrived
        );
        assert!(out.events_per_sec > 0.0, "wall-derived throughput must be positive");
    }

    #[test]
    fn default_tenancy_reports_one_tenant_per_class() {
        let out = demo(
            2,
            FleetPolicyKind::Static,
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        )
        .run()
        .unwrap();
        assert_eq!(out.tenants.len(), 2, "one implicit tenant per class");
        let mut arrived = 0;
        for (c, row) in out.tenants.iter().enumerate() {
            assert_eq!(row.name, format!("t{c}"));
            assert_eq!(row.weight, 1.0);
            assert_eq!(row.classes, vec![c]);
            assert_eq!(row.arrived, out.arrived_per_class[c]);
            assert_eq!(
                row.completed + row.failed + row.lost_in_crash,
                row.arrived,
                "per-tenant conservation must hold fault-free"
            );
            assert_eq!(
                row.norm_goodput_rps.to_bits(),
                row.goodput_rps.to_bits(),
                "weight 1 normalizes to itself"
            );
            arrived += row.arrived;
        }
        assert_eq!(arrived, out.arrived, "tenants partition the traffic exactly");
        assert!(
            out.fairness_jain > 0.0 && out.fairness_jain <= 1.0,
            "jain {} out of range",
            out.fairness_jain
        );
    }

    #[test]
    fn explicit_tenants_account_and_plan_by_weight() {
        let mut cfg = demo(
            2,
            FleetPolicyKind::Static,
            RouterKind::WeightedFair,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        );
        cfg.tenants = vec![
            Tenant::new("gold", 3.0, vec![0]),
            Tenant::new("bronze", 1.0, vec![1]),
        ];
        let out = cfg.run().unwrap();
        assert_eq!(out.router, "weighted-fair");
        assert_eq!(out.tenants.len(), 2);
        assert_eq!(out.tenants[0].name, "gold");
        assert_eq!(out.tenants[0].weight, 3.0);
        assert_eq!(out.tenants[1].classes, vec![1]);
        for row in &out.tenants {
            assert_eq!(row.completed + row.failed + row.lost_in_crash, row.arrived);
            assert!(row.arrived > 100, "{}: arrived {}", row.name, row.arrived);
            let norm = row.goodput_rps / row.weight;
            assert_eq!(row.norm_goodput_rps.to_bits(), norm.to_bits());
        }
        assert_eq!(
            out.tenants.iter().map(|r| r.arrived).sum::<u64>(),
            out.arrived,
            "tenants partition the traffic exactly"
        );
        assert!(out.fairness_jain > 0.0 && out.fairness_jain <= 1.0);
        assert_eq!(out.completed, out.arrived, "fault-free runs serve everything");
    }

    #[test]
    fn invalid_tenant_sets_are_rejected() {
        let base = || {
            demo(
                2,
                FleetPolicyKind::Static,
                RouterKind::LeastLoaded,
                RepartitionMode::Rolling,
                240.0,
                120.0,
            )
        };
        let mut cfg = base();
        cfg.tenants = vec![Tenant::new("a", 1.0, vec![0])]; // class 1 unowned
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))));

        let mut cfg = base();
        cfg.tenants = vec![Tenant::new("a", 0.0, vec![0]), Tenant::new("b", 1.0, vec![1])];
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))), "zero weight");

        let mut cfg = base();
        cfg.tenants = vec![Tenant::new("a", 1.0, vec![0, 1]), Tenant::new("b", 1.0, vec![1])];
        assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))), "class owned twice");
    }

    #[test]
    fn mode_names_parse_and_render() {
        assert_eq!(RepartitionMode::parse("rolling"), Some(RepartitionMode::Rolling));
        assert_eq!(RepartitionMode::parse("in-place"), Some(RepartitionMode::InPlace));
        assert_eq!(RepartitionMode::parse("inplace"), Some(RepartitionMode::InPlace));
        assert_eq!(RepartitionMode::parse("nope"), None);
        assert_eq!(RepartitionMode::Rolling.name(), "rolling");
        assert_eq!(RepartitionMode::InPlace.name(), "in-place");
    }

    /// `completed + failed + lost_in_crash + shed_overload = arrived`, at
    /// the fleet level and per tenant, with the shed total splitting
    /// exactly into its three causes.
    fn assert_conserved(out: &FleetOutcome) {
        assert_eq!(
            out.shed_overload,
            out.shed_deadline + out.shed_capacity + out.shed_brownout,
            "shed total must split exactly by cause"
        );
        assert_eq!(
            out.completed + out.failed_requests + out.lost_in_crash + out.shed_overload,
            out.arrived,
            "extended conservation"
        );
        for row in &out.tenants {
            assert_eq!(
                row.completed
                    + row.failed
                    + row.lost_in_crash
                    + row.shed_deadline
                    + row.shed_capacity
                    + row.shed_brownout,
                row.arrived,
                "extended conservation for tenant {}",
                row.name
            );
        }
    }

    /// One A100 carrying the two-class demo load: peak demand far
    /// exceeds capacity, so every shed mechanism has pressure to act on.
    fn overloaded(policy: OverloadPolicy) -> FleetConfig {
        let mut cfg = demo(
            1,
            FleetPolicyKind::Static,
            RouterKind::LeastLoaded,
            RepartitionMode::Rolling,
            240.0,
            120.0,
        );
        cfg.overload = policy;
        cfg
    }

    #[test]
    fn capacity_shedding_bounds_queues_and_conserves() {
        for shed in [ShedDiscipline::RejectNewest, ShedDiscipline::DropOldest] {
            let out = overloaded(OverloadPolicy { queue_cap: 1, shed, ..OverloadPolicy::none() })
                .run()
                .unwrap();
            assert!(out.shed_capacity > 0, "{}: cap 1 under 2x load must shed", shed.name());
            assert_eq!(out.shed_deadline, 0, "{}: deadlines disabled", shed.name());
            assert_eq!(out.shed_brownout, 0, "{}: brownout disabled", shed.name());
            assert_conserved(&out);
        }
    }

    #[test]
    fn deadline_shedding_sheds_expired_and_conserves() {
        let out =
            overloaded(OverloadPolicy { deadline_mult: 1.0, ..OverloadPolicy::none() })
                .run()
                .unwrap();
        assert!(out.shed_deadline > 0, "40 ms deadlines at 2x load must expire requests");
        assert_eq!(out.shed_capacity, 0, "queues unbounded");
        assert_conserved(&out);
        // Every served request cleared its deadline, so none of the
        // completions can be slower than the deadline multiple of the SLO.
        assert!(out.goodput_rps > 0.0, "the fleet still serves in-deadline work");
    }

    #[test]
    fn brownout_sheds_the_lowest_weight_tenant_first() {
        let mut cfg = overloaded(OverloadPolicy {
            queue_cap: 1,
            brownout_threshold: 0.05,
            ..OverloadPolicy::none()
        });
        cfg.tenants = vec![
            Tenant::new("gold", 3.0, vec![0]),
            Tenant::new("bronze", 1.0, vec![1]),
        ];
        let out = cfg.run().unwrap();
        assert!(out.shed_brownout > 0, "sustained capacity pressure must trip the brownout");
        assert_eq!(
            out.tenants[0].shed_brownout, 0,
            "gold outweighs bronze and is never browned out in a two-tenant fleet"
        );
        assert!(out.tenants[1].shed_brownout > 0, "bronze is browned out first");
        assert_conserved(&out);
    }

    #[test]
    fn breaker_trips_under_sustained_shedding() {
        let out = overloaded(OverloadPolicy {
            queue_cap: 1,
            breaker_threshold: 0.5,
            ..OverloadPolicy::none()
        })
        .run()
        .unwrap();
        assert!(out.breaker_trips > 0, "cap-1 overload must trip the per-GPU breaker");
        assert!(out.breaker_open_s > 0.0, "a tripped breaker accumulates open time");
        assert_conserved(&out);
    }

    #[test]
    fn invalid_overload_policies_are_rejected() {
        let bad = |p: OverloadPolicy| {
            let cfg = overloaded(p);
            assert!(matches!(cfg.run(), Err(FleetError::Invalid(_))), "{p:?}");
        };
        bad(OverloadPolicy { deadline_mult: -1.0, ..OverloadPolicy::none() });
        bad(OverloadPolicy { deadline_mult: f64::NAN, ..OverloadPolicy::none() });
        bad(OverloadPolicy { brownout_threshold: 0.0, ..OverloadPolicy::none() });
        bad(OverloadPolicy { brownout_threshold: 1.5, ..OverloadPolicy::none() });
        bad(OverloadPolicy { breaker_threshold: -0.2, ..OverloadPolicy::none() });
        bad(OverloadPolicy {
            breaker_threshold: 0.5,
            breaker_probes: 0,
            ..OverloadPolicy::none()
        });
    }

    #[test]
    fn shedding_is_deterministic_and_composes_with_faults() {
        let cfg = || {
            let mut cfg = overloaded(OverloadPolicy {
                queue_cap: 2,
                shed: ShedDiscipline::DropOldest,
                deadline_mult: 2.0,
                breaker_threshold: 0.5,
                ..OverloadPolicy::none()
            });
            cfg.faults.injections.push(crate::cluster::faults::FaultInjection {
                t: 60.0,
                gpu: 0,
                class: Some(0),
                down_s: 30.0,
            });
            cfg
        };
        let a = cfg().run().unwrap();
        let b = cfg().run().unwrap();
        assert_eq!(a.shed_deadline, b.shed_deadline);
        assert_eq!(a.shed_capacity, b.shed_capacity);
        assert_eq!(a.shed_brownout, b.shed_brownout);
        assert_eq!(a.breaker_trips, b.breaker_trips);
        assert_eq!(a.breaker_open_s.to_bits(), b.breaker_open_s.to_bits());
        assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits());
        assert!(a.shed_overload > 0, "the composed policy sheds under crash pressure");
        assert_conserved(&a);
    }
}
